"""Quickstart: SCARLET in ~60 lines.

Runs communication-efficient federated distillation (soft-label caching
+ Enhanced ERA) on a synthetic non-IID task with 8 clients, then prints
accuracy + exact communication costs vs the DS-FL baseline.

  PYTHONPATH=src python examples/quickstart.py

REPRO_EXAMPLES_QUICK=1 shrinks the runs to CI-smoke size (same code
path, toy rounds — tests/test_examples.py runs every example this way).
"""
import os

import jax.numpy as jnp

from repro.core import cache, era
from repro.fl.engine import FLConfig, run_method

QUICK = bool(os.environ.get("REPRO_EXAMPLES_QUICK"))


def main():
    cfg = FLConfig(
        n_clients=8, n_classes=10, dim=16, rounds=6 if QUICK else 40,
        public_size=800, public_per_round=100, private_size=1000,
        alpha=0.05,            # strong non-IID (Dirichlet)
        cluster_scale=2.0, noise=2.5,
        eval_every=3 if QUICK else 10, seed=0,
    )

    # --- the two core primitives, standalone -------------------------------
    z = jnp.asarray([[0.15, 0.10, 0.75], [0.4, 0.35, 0.25]])
    print("Enhanced ERA (beta=2):", era.enhanced_era(z, 2.0))
    c = cache.init_cache(public_size=800, num_classes=10)
    miss = cache.miss_mask(c, jnp.arange(100), t=1, D=25)
    print(f"cold cache: {int(miss.sum())}/100 soft-labels must be requested")

    # --- full FL runs -------------------------------------------------------
    print("\nSCARLET (cache D=25, Enhanced ERA beta=1.5):")
    h = run_method("scarlet", cfg, cache_duration=25, beta=1.5)
    s = h.ledger.summary()
    print(f"  server acc={h.final_server_acc:.3f}  client acc={h.final_client_acc:.3f}")
    print(f"  uplink {s['uplink_mean']/1e3:.1f} KB/round  "
          f"downlink {s['downlink_mean']/1e3:.1f} KB/round  "
          f"total {s['cumulative_total']/1e6:.2f} MB")

    print("\nDS-FL baseline (ERA T=0.1, no cache):")
    h2 = run_method("dsfl", cfg, T=0.1)
    s2 = h2.ledger.summary()
    print(f"  server acc={h2.final_server_acc:.3f}  client acc={h2.final_client_acc:.3f}")
    print(f"  uplink {s2['uplink_mean']/1e3:.1f} KB/round  "
          f"total {s2['cumulative_total']/1e6:.2f} MB")

    saved = 1 - s["cumulative_total"] / s2["cumulative_total"]
    print(f"\nSCARLET saves {saved:.0%} total communication at comparable accuracy.")


if __name__ == "__main__":
    main()
