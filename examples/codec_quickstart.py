"""Codec quickstart: soft-label wire formats in ~50 lines.

Shows the codec subsystem standalone (encode/decode round trip +
analytic payload bytes), then plugs codecs into a SCARLET run on the
scanned engine and prints the uplink-vs-accuracy trade-off.

  PYTHONPATH=src python examples/codec_quickstart.py

REPRO_EXAMPLES_QUICK=1 shrinks the FL runs to CI-smoke size (same code
path, toy rounds — tests/test_examples.py runs every example this way).
"""
import os

import jax
import jax.numpy as jnp

from repro.compress import get_codec
from repro.fl import FLConfig, run_method

QUICK = bool(os.environ.get("REPRO_EXAMPLES_QUICK"))


def main():
    # --- codecs standalone --------------------------------------------------
    z = jax.random.dirichlet(jax.random.PRNGKey(0), jnp.ones(10), (4,))
    base = jax.random.dirichlet(jax.random.PRNGKey(1), jnp.ones(10), (4,))
    print("payload bytes for 100 soft-labels, 10 classes:")
    for spec in ("identity", "quant8", "quant1", "topk2", "cache_delta+quant8"):
        c = get_codec(spec)
        z_hat = c.roundtrip(z, base=base, present=jnp.ones(4, bool))
        err = float(jnp.abs(z - z_hat).max())
        print(f"  {spec:20s} {c.payload_bytes(100, 10):7.1f} B"
              f"   max roundtrip err {err:.4f}")

    # --- codecs in a full FL run -------------------------------------------
    cfg = FLConfig(
        n_clients=8, n_classes=10, dim=16, rounds=6 if QUICK else 40,
        public_size=800, public_per_round=100, private_size=1000,
        alpha=0.05, cluster_scale=2.0, noise=2.5,
        eval_every=3 if QUICK else 10, seed=0,
    )
    print("\nSCARLET (cache D=25) with different uplink codecs:")
    base_up = None
    for spec in ("identity", "quant8", "cache_delta+quant8"):
        h = run_method("scarlet", cfg, cache_duration=25, beta=1.5,
                       engine="scan", codec=spec)
        up = h.ledger.cumulative_uplink
        base_up = base_up or up
        print(f"  {spec:20s} uplink {up / 1e3:8.1f} KB"
              f"  ({base_up / up:4.1f}x)   server acc {h.final_server_acc:.3f}")


if __name__ == "__main__":
    main()
