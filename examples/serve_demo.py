"""Serve a small assigned-architecture model with batched requests:
prefill + token-by-token decode through the KV/SSM cache serve_step —
the same code path the multi-pod dry-run lowers at 32k/500k.

  PYTHONPATH=src python examples/serve_demo.py [--arch mamba2-1.3b]

REPRO_EXAMPLES_QUICK=1 switches the argparse defaults to CI-smoke
sizes (same decode path — tests/test_examples.py runs it this way).
"""
import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCHS, ASSIGNED
from repro.launch.specs import make_batch
from repro.models import registry


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b", choices=sorted(ASSIGNED))
    quick = bool(os.environ.get("REPRO_EXAMPLES_QUICK"))
    ap.add_argument("--batch", type=int, default=2 if quick else 4)
    ap.add_argument("--prompt-len", type=int, default=4 if quick else 16)
    ap.add_argument("--gen-len", type=int, default=6 if quick else 24)
    args = ap.parse_args()

    cfg = ARCHS[args.arch].reduced()  # CPU-sized variant of the family
    print(f"serving {cfg.name} ({cfg.family}): "
          f"{cfg.n_layers}L d={cfg.d_model} vocab={cfg.vocab_size}")
    params, _ = registry.init(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, args.batch, args.prompt_len)
    max_len = args.prompt_len + args.gen_len

    cache = registry.init_decode_cache(cfg, args.batch, max_len)
    decode = jax.jit(lambda p, c, t, i: registry.decode_step(cfg, p, c, t, i))

    # prefill by teacher-forcing the prompt through serve_step (exercises
    # the exact decode path the dry-run lowers; a fused prefill would batch
    # this — see launch/dryrun.py prefill mode)
    toks = batch["tokens"]
    t0 = time.time()
    for pos in range(args.prompt_len):
        logits, cache = decode(params, cache, toks[:, pos:pos + 1], jnp.int32(pos))
    out = [int(x) for x in np.asarray(jnp.argmax(logits, -1))]
    generated = [out]
    for pos in range(args.prompt_len, max_len - 1):
        tok = jnp.asarray(out, jnp.int32)[:, None]
        logits, cache = decode(params, cache, tok, jnp.int32(pos))
        out = [int(x) for x in np.asarray(jnp.argmax(logits, -1))]
        generated.append(out)
    dt = time.time() - t0
    gen = np.array(generated).T
    print(f"generated {gen.shape[1]} tokens x {args.batch} seqs "
          f"in {dt:.2f}s ({gen.shape[1]*args.batch/dt:.1f} tok/s on CPU)")
    for i, row in enumerate(gen[:2]):
        print(f"  seq{i}: {row[:12].tolist()}...")


if __name__ == "__main__":
    main()
