"""Paper Fig. 11 as an example: SCARLET's soft-label cache as a drop-in
module for OTHER distillation-based FL methods (CFD / COMET /
Selective-FD), D=25.

  PYTHONPATH=src python examples/caching_for_baselines.py

REPRO_EXAMPLES_QUICK=1 shrinks the runs to CI-smoke size (same code
path, toy rounds — tests/test_examples.py runs every example this way).
"""
import os

from repro.fl.engine import FLConfig, run_method

QUICK = bool(os.environ.get("REPRO_EXAMPLES_QUICK"))


def main():
    cfg = FLConfig(
        n_clients=12, n_classes=10, dim=16, rounds=6 if QUICK else 80,
        public_size=1200, public_per_round=120, private_size=1500,
        alpha=0.05, cluster_scale=2.0, noise=2.5,
        eval_every=3 if QUICK else 20,
    )
    for method, kw in (("cfd", {}), ("comet", {"n_clusters": 2}),
                       ("selective_fd", {"tau_client": 0.0625})):
        base = run_method(method, cfg, **kw)
        cached = run_method(method, cfg, use_cache=True, cache_duration=25, **kw)
        b, c = base.ledger.summary(), cached.ledger.summary()
        print(f"{method:14s} acc {base.final_server_acc:.3f} -> "
              f"{cached.final_server_acc:.3f}   comm "
              f"{b['cumulative_total']/1e6:6.2f} MB -> "
              f"{c['cumulative_total']/1e6:6.2f} MB "
              f"({1-c['cumulative_total']/b['cumulative_total']:.0%} saved)")


if __name__ == "__main__":
    main()
