"""End-to-end driver: full SCARLET training run across the non-IID
spectrum, with all baselines, several hundred rounds, multi-seed — the
synthetic-scale analog of the paper's main comparison (Fig. 8).

  PYTHONPATH=src python examples/fl_noniid_train.py [--rounds 300] [--seeds 3]

REPRO_EXAMPLES_QUICK=1 switches the argparse defaults to CI-smoke
sizes (same code path — tests/test_examples.py runs it this way).
"""
import argparse
import os

import numpy as np

from repro.fl.engine import FLConfig, run_method

QUICK = bool(os.environ.get("REPRO_EXAMPLES_QUICK"))

METHODS = [
    ("scarlet", dict(cache_duration=25, beta=1.5)),
    ("dsfl", dict(T=0.1)),
    ("cfd", dict()),
    ("comet", dict(n_clusters=2)),
    ("selective_fd", dict(tau_client=0.0625)),
    ("fedavg", dict()),
    ("individual", dict()),
]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=4 if QUICK else 300)
    ap.add_argument("--seeds", type=int, default=1 if QUICK else 3)
    ap.add_argument("--alpha", type=float, default=0.05)
    args = ap.parse_args()

    print(f"alpha={args.alpha}  rounds={args.rounds}  seeds={args.seeds}")
    print(f"{'method':14s} {'server_acc':>16s} {'client_acc':>16s} "
          f"{'uplinkKB/rnd':>13s} {'cumMB':>8s}")
    for name, kw in METHODS:
        accs, caccs, ups, cums = [], [], [], []
        for seed in range(args.seeds):
            cfg = FLConfig(
                n_clients=12, n_classes=10, dim=16, rounds=args.rounds,
                public_size=1200, public_per_round=120, private_size=1500,
                alpha=args.alpha, cluster_scale=2.0, noise=2.5,
                eval_every=max(args.rounds // 10, 1), seed=seed,
            )
            h = run_method(name, cfg, **kw)
            s = h.ledger.summary()
            accs.append(h.final_server_acc)
            caccs.append(h.final_client_acc)
            ups.append(s["uplink_mean"] / 1e3)
            cums.append(s["cumulative_total"] / 1e6)
        def _col(vals):
            # None = never measured (the individual baseline has no
            # server model), distinct from an actual 0.0 accuracy
            if any(v is None for v in vals):
                return f"{'n/a':>14s}"
            return f"{np.mean(vals):8.3f}±{np.std(vals):.3f}"

        print(f"{name:14s} {_col(accs)} {_col(caccs)} "
              f"{np.mean(ups):13.1f} {np.mean(cums):8.2f}")


if __name__ == "__main__":
    main()
