"""Telemetry quickstart: both observability planes in ~60 lines.

Device plane: ``telemetry=True`` threads a ``RoundTelemetry`` pytree
through the round body — participation, cache hit/miss/expiry, catch-up
and wire bytes, teacher-entropy/beta/codec-error gauges — accumulated
on device (inside the single-compilation ``lax.scan`` on the scanned
engine: no host callbacks) and returned as ``History.telemetry``.

Host plane: ``SpanTracer`` wraps the run in wall-clock spans and
exports a Chrome trace (load in chrome://tracing or Perfetto), a spans
JSONL, and a ``run_record.json`` that ``python -m repro.obs render``
turns into a report.

  PYTHONPATH=src python examples/telemetry_quickstart.py

REPRO_EXAMPLES_QUICK=1 shrinks the runs to CI-smoke size (same code
path, toy rounds — tests/test_examples.py runs every example this way).
"""
import os

import numpy as np

from repro.fl import FLConfig, run_method
from repro.obs import SpanTracer, device as obs_device
from repro.obs.export import write_chrome_trace, write_run_record, \
    write_spans_jsonl
from repro.obs.report import render

QUICK = bool(os.environ.get("REPRO_EXAMPLES_QUICK"))
OUT = os.path.join("experiments", "obs_demo")


def main():
    cfg = FLConfig(
        n_clients=8, n_classes=10, dim=16, rounds=6 if QUICK else 40,
        public_size=800, public_per_round=100, private_size=1000,
        alpha=0.05, cluster_scale=2.0, noise=2.5,
        eval_every=3 if QUICK else 10, seed=0,
    )
    kw = dict(cache_duration=5, use_cache=True, beta=1.5,
              codec="cache_delta+quant8", telemetry=True)

    tracer = SpanTracer("telemetry_quickstart", meta={"quick": QUICK})
    with tracer.span("run", engine="scan"):
        hist = run_method("scarlet", cfg, engine="scan", **kw)
    with tracer.span("run", engine="host"):
        hist_host = run_method("scarlet", cfg, engine="host",
                               rng_backend="jax", **kw)

    # the parity contract: host and scan emit the SAME counter stacks
    for f in obs_device.EXACT_FIELDS:
        a, b = hist.telemetry.stacks()[f], hist_host.telemetry.stacks()[f]
        assert np.array_equal(a, b), f"host/scan telemetry diverged on {f}"
    print("host/scan telemetry parity: OK "
          f"({len(obs_device.EXACT_FIELDS)} exact counter stacks equal)")

    import jax
    if jax.device_count() > 1:  # shard engine needs a real client mesh
        with tracer.span("run", engine="shard"):
            hist_shard = run_method("scarlet", cfg, engine="shard", **kw)
        for f in obs_device.EXACT_FIELDS:
            assert np.array_equal(hist.telemetry.stacks()[f],
                                  hist_shard.telemetry.stacks()[f])
        print(f"shard telemetry parity: OK ({jax.device_count()} devices)")

    os.makedirs(OUT, exist_ok=True)
    write_chrome_trace(os.path.join(OUT, "trace.json"), tracer)
    write_spans_jsonl(os.path.join(OUT, "spans.jsonl"), tracer)
    write_run_record(os.path.join(OUT, "run_record.json"),
                     name="telemetry_quickstart", config=cfg, history=hist,
                     tracer=tracer)
    print(f"wrote {OUT}/trace.json, spans.jsonl, run_record.json\n")

    import json
    record = json.load(open(os.path.join(OUT, "run_record.json")))
    print(render(record, fmt="text"))


if __name__ == "__main__":
    main()
