"""Kernel micro-benchmarks: Pallas vs jnp reference, on paper-scale
shapes (|P^t|=1000 x N) and LM-vocab distillation shapes.

The Pallas mode is backend-detected (``kernels.runtime``): the numbers
below are native-kernel timings only when running on TPU; on CPU the
kernels execute in interpreter mode, so treat the CPU deltas as
correctness/plumbing checks, not kernel wins.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks._common import emit, timeit
from repro.kernels import ops, ref
from repro.kernels.runtime import default_interpret

KEY = jax.random.PRNGKey(0)

_MODE = "pallas interpret" if default_interpret() else "pallas native tpu"


def run():
    rows = []
    # Enhanced ERA on the paper's per-round shape
    for B, N in ((1000, 10), (1000, 100)):
        z = jax.random.dirichlet(KEY, jnp.ones(N), (B,))
        f_ref = jax.jit(lambda z: ref.enhanced_era(z, 1.5))
        rows.append({
            "name": f"era_ref_B{B}_N{N}",
            "us_per_call": timeit(lambda: f_ref(z).block_until_ready()),
            "derived": "jnp oracle",
        })
        rows.append({
            "name": f"era_pallas_B{B}_N{N}",
            "us_per_call": timeit(lambda: ops.enhanced_era(z, 1.5).block_until_ready()),
            "derived": _MODE,
        })
    # fused client-mean + sharpening (the SCARLET server aggregation path)
    for K, B, N in ((10, 1000, 10), (50, 1000, 100)):
        zc = jax.random.dirichlet(KEY, jnp.ones(N), (K, B))
        f_ref = jax.jit(lambda z: ref.enhanced_era(jnp.mean(z, axis=0), 1.5))
        rows.append({
            "name": f"era_fused_ref_K{K}_B{B}_N{N}",
            "us_per_call": timeit(lambda: f_ref(zc).block_until_ready()),
            "derived": "jnp oracle (mean + sharpen, 2 passes)",
        })
        rows.append({
            "name": f"era_fused_pallas_K{K}_B{B}_N{N}",
            "us_per_call": timeit(
                lambda: ops.enhanced_era_fused(zc, 1.5).block_until_ready()),
            "derived": f"{_MODE} (one VMEM pass)",
        })
    # distillation loss at LM vocab
    B, V = 64, 32_000
    logits = jax.random.normal(KEY, (B, V))
    teacher = jax.nn.softmax(jax.random.normal(KEY, (B, V)))
    f_ref = jax.jit(lambda l, t: ref.distill_loss(l, t).mean())
    rows.append({
        "name": f"distill_ref_B{B}_V{V}",
        "us_per_call": timeit(lambda: f_ref(logits, teacher).block_until_ready()),
        "derived": "jnp oracle",
    })
    rows.append({
        "name": f"distill_pallas_B{B}_V{V}",
        "us_per_call": timeit(
            lambda: ops.distill_loss(logits, teacher).block_until_ready(), n=3),
        "derived": _MODE,
    })
    return rows


def main():
    emit(run())


if __name__ == "__main__":
    main()
