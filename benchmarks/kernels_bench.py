"""Kernel micro-benchmarks: Pallas vs jnp reference, on paper-scale
shapes (|P^t|=1000 x N) and LM-vocab distillation shapes.

The Pallas mode is backend-detected (``kernels.runtime``): the numbers
below are native-kernel timings only when running on TPU; on CPU the
kernels execute in interpreter mode, so treat the CPU deltas as
correctness/plumbing checks, not kernel wins.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks._common import emit, timeit, write_bench
from repro.kernels import ops, ref
from repro.kernels.runtime import default_interpret

KEY = jax.random.PRNGKey(0)

_MODE = "pallas interpret" if default_interpret() else "pallas native tpu"


def _fused_round_rows(shapes) -> list:
    """Fused round hot path vs the per-op chain it replaces (qdq
    round trip over all K*m rows + simplex + weighted mean + ERA), on
    the engines' uplink shapes."""
    rows = []
    for K, m, N in shapes:
        zc = jax.random.dirichlet(KEY, jnp.ones(N), (K, m))
        w = jnp.ones(K)

        @jax.jit
        def perop(z):
            zq = ops.quantize_dequantize(z, 8)
            zq = jnp.maximum(zq, 0.0)
            zq = zq / jnp.maximum(zq.sum(-1, keepdims=True), 1e-9)
            return ops.enhanced_era(jnp.mean(zq, axis=0), 1.5)

        rows.append({
            "name": f"round_perop_K{K}_m{m}_N{N}",
            "us_per_call": timeit(lambda: perop(zc).block_until_ready()),
            "derived": f"{_MODE} (qdq + simplex + mean + era chain)",
        })
        rows.append({
            "name": f"round_fused_K{K}_m{m}_N{N}",
            "us_per_call": timeit(lambda: ops.fused_round(
                zc, w, 1.5, mode="quant", bits=8).block_until_ready()),
            "derived": f"{_MODE} (one VMEM pass)",
        })
    return rows


def run(quick: bool = False):
    rows = []
    # Enhanced ERA on the paper's per-round shape
    for B, N in ((1000, 10),) if quick else ((1000, 10), (1000, 100)):
        z = jax.random.dirichlet(KEY, jnp.ones(N), (B,))
        f_ref = jax.jit(lambda z: ref.enhanced_era(z, 1.5))
        rows.append({
            "name": f"era_ref_B{B}_N{N}",
            "us_per_call": timeit(lambda: f_ref(z).block_until_ready()),
            "derived": "jnp oracle",
        })
        rows.append({
            "name": f"era_pallas_B{B}_N{N}",
            "us_per_call": timeit(lambda: ops.enhanced_era(z, 1.5).block_until_ready()),
            "derived": _MODE,
        })
    # fused client-mean + sharpening (the SCARLET server aggregation path)
    for K, B, N in ((10, 1000, 10),) if quick else ((10, 1000, 10),
                                                    (50, 1000, 100)):
        zc = jax.random.dirichlet(KEY, jnp.ones(N), (K, B))
        f_ref = jax.jit(lambda z: ref.enhanced_era(jnp.mean(z, axis=0), 1.5))
        rows.append({
            "name": f"era_fused_ref_K{K}_B{B}_N{N}",
            "us_per_call": timeit(lambda: f_ref(zc).block_until_ready()),
            "derived": "jnp oracle (mean + sharpen, 2 passes)",
        })
        rows.append({
            "name": f"era_fused_pallas_K{K}_B{B}_N{N}",
            "us_per_call": timeit(
                lambda: ops.enhanced_era_fused(zc, 1.5).block_until_ready()),
            "derived": f"{_MODE} (one VMEM pass)",
        })
    # the fused round hot path on engine uplink shapes (m = |P^t|)
    rows += _fused_round_rows(((200, 24, 10),) if quick
                              else ((200, 24, 10), (1000, 24, 10)))
    if not quick:
        # distillation loss at LM vocab
        B, V = 64, 32_000
        logits = jax.random.normal(KEY, (B, V))
        teacher = jax.nn.softmax(jax.random.normal(KEY, (B, V)))
        f_ref = jax.jit(lambda l, t: ref.distill_loss(l, t).mean())
        rows.append({
            "name": f"distill_ref_B{B}_V{V}",
            "us_per_call": timeit(lambda: f_ref(logits, teacher).block_until_ready()),
            "derived": "jnp oracle",
        })
        rows.append({
            "name": f"distill_pallas_B{B}_V{V}",
            "us_per_call": timeit(
                lambda: ops.distill_loss(logits, teacher).block_until_ready(), n=3),
            "derived": _MODE,
        })
    return rows


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default="", help="write BENCH json here")
    args = ap.parse_args()
    rows = run(quick=args.quick)
    emit(rows)
    if args.out:
        write_bench(args.out, "kernels", rows, quick=args.quick)


if __name__ == "__main__":
    main()
