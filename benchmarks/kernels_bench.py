"""Kernel micro-benchmarks: Pallas (interpret mode on CPU — relative
numbers only; native on TPU) vs jnp reference, on paper-scale shapes
(|P^t|=1000 x N) and LM-vocab distillation shapes."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks._common import emit, timeit
from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


def run():
    rows = []
    # Enhanced ERA on the paper's per-round shape
    for B, N in ((1000, 10), (1000, 100)):
        z = jax.random.dirichlet(KEY, jnp.ones(N), (B,))
        f_ref = jax.jit(lambda z: ref.enhanced_era(z, 1.5))
        rows.append({
            "name": f"era_ref_B{B}_N{N}",
            "us_per_call": timeit(lambda: f_ref(z).block_until_ready()),
            "derived": "jnp oracle",
        })
        rows.append({
            "name": f"era_pallas_B{B}_N{N}",
            "us_per_call": timeit(lambda: ops.enhanced_era(z, 1.5).block_until_ready()),
            "derived": "pallas interpret (native on TPU)",
        })
    # distillation loss at LM vocab
    B, V = 64, 32_000
    logits = jax.random.normal(KEY, (B, V))
    teacher = jax.nn.softmax(jax.random.normal(KEY, (B, V)))
    f_ref = jax.jit(lambda l, t: ref.distill_loss(l, t).mean())
    rows.append({
        "name": f"distill_ref_B{B}_V{V}",
        "us_per_call": timeit(lambda: f_ref(logits, teacher).block_until_ready()),
        "derived": "jnp oracle",
    })
    rows.append({
        "name": f"distill_pallas_B{B}_V{V}",
        "us_per_call": timeit(
            lambda: ops.distill_loss(logits, teacher).block_until_ready(), n=3),
        "derived": "pallas interpret (native on TPU)",
    })
    return rows


def main():
    emit(run())


if __name__ == "__main__":
    main()
