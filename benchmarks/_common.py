"""Shared benchmark substrate: default FL config + timing helpers."""
from __future__ import annotations

import time
from typing import Callable, Dict, List

from repro.fl.engine import FLConfig

# CPU-scale analog of the paper's setup: 100 clients / CIFAR -> 12
# clients / gaussian-mixture with disjoint public distribution.  Chosen
# so methods separate within ~1 minute per run.
def default_cfg(**kw) -> FLConfig:
    base = dict(
        n_clients=12,
        n_classes=10,
        dim=16,
        cluster_scale=2.0,
        noise=2.5,
        rounds=60,
        local_steps=4,
        distill_steps=4,
        lr=0.15,
        lr_dist=0.3,
        public_size=1200,
        public_per_round=120,
        private_size=1500,
        alpha=0.05,
        hidden=48,
        mlp_depth=2,
        seed=0,
        eval_every=10,
    )
    base.update(kw)
    return FLConfig(**base)


def timeit(fn: Callable, n: int = 5, warmup: int = 2) -> float:
    """Median wall-time per call in microseconds."""
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(n):
        t0 = time.perf_counter()
        fn()
        ts.append((time.perf_counter() - t0) * 1e6)
    ts.sort()
    return ts[len(ts) // 2]


def emit(rows: List[Dict]) -> None:
    for r in rows:
        print(f"{r['name']},{r.get('us_per_call', 0.0):.1f},{r.get('derived', '')}")
