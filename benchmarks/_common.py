"""Shared benchmark substrate: default FL config + timing helpers +
the ``BENCH_*.json`` writer the perf-regression gate consumes."""
from __future__ import annotations

import json
import platform
import sys
import time
from typing import Callable, Dict, List, Optional

from repro.fl.engine import FLConfig

BENCH_SCHEMA = 1

# CPU-scale analog of the paper's setup: 100 clients / CIFAR -> 12
# clients / gaussian-mixture with disjoint public distribution.  Chosen
# so methods separate within ~1 minute per run.
def default_cfg(**kw) -> FLConfig:
    base = dict(
        n_clients=12,
        n_classes=10,
        dim=16,
        cluster_scale=2.0,
        noise=2.5,
        rounds=60,
        local_steps=4,
        distill_steps=4,
        lr=0.15,
        lr_dist=0.3,
        public_size=1200,
        public_per_round=120,
        private_size=1500,
        alpha=0.05,
        hidden=48,
        mlp_depth=2,
        seed=0,
        eval_every=10,
    )
    base.update(kw)
    return FLConfig(**base)


def timeit(fn: Callable, n: int = 5, warmup: int = 2) -> float:
    """Median wall-time per call in microseconds."""
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(n):
        t0 = time.perf_counter()
        fn()
        ts.append((time.perf_counter() - t0) * 1e6)
    ts.sort()
    return ts[len(ts) // 2]


def emit(rows: List[Dict]) -> None:
    for r in rows:
        print(f"{r['name']},{r.get('us_per_call', 0.0):.1f},{r.get('derived', '')}")


def bench_env() -> Dict:
    """Environment/device metadata stamped into every BENCH file so a
    baseline mismatch (CPU vs TPU, different host) is visible in the
    diff.  Deliberately no timestamps: committed baselines must not
    churn when regenerated on the same setup."""
    import jax

    return {
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "device_kind": jax.devices()[0].device_kind,
        "jax": jax.__version__,
        "python": platform.python_version(),
        "machine": platform.machine(),
    }


def write_bench(path: str, name: str, rows: List[Dict],
                quick: Optional[bool] = None,
                telemetry: Optional[Dict] = None) -> None:
    """Write one benchmark's rows as a ``BENCH_<name>.json`` document —
    schema: {bench, schema, quick, env, rows}; rows keep every
    structured field the benchmark attached (``rounds_per_sec``,
    ``*_bytes``, ...) beyond the printed CSV triple.  ``telemetry`` is
    an optional device-plane summary (``TelemetryLog.summary()``) from
    an instrumented run — the perf gate ignores the key; humans and the
    ``repro.obs`` report reader don't."""
    doc = {
        "bench": name,
        "schema": BENCH_SCHEMA,
        "quick": bool(quick) if quick is not None else None,
        "env": bench_env(),
        "rows": rows,
    }
    if telemetry is not None:
        doc["telemetry"] = telemetry
    with open(path, "w") as f:
        json.dump(doc, f, sort_keys=True, indent=2)
        f.write("\n")
    print(f"# wrote {path} ({len(rows)} rows)", file=sys.stderr)
