"""Engine benchmark: host loop vs scanned (lax.scan) vs client-sharded.

Two sweeps:

- **scan vs host** (small K): the host loop dispatches dozens of small
  device programs per round and syncs the host every round; the scanned
  engine compiles the whole run into one XLA program.  The gap is
  dispatch/sync-bound, so the per-round compute load is deliberately
  tiny (1 local step, tiny MLP).
- **shard vs scan** (large K — 200/1000/4000 clients, the cohort sizes
  compressed-distillation papers sweep): the scanned engine keeps the
  whole client axis on one device; the sharded engine partitions it
  over the mesh "data" axis (``shard_map``), trading psum latency for
  per-device client load.  On a multi-chip platform this is the only
  way past single-device memory; on CPU it also exercises the exact
  production code path (the mesh uses every local device via
  ``best_data_axis``).
- **fused vs per-op round path** (K=200/1000): the scanned engine with
  ``fused_round=True`` runs codec round trip + masked aggregation +
  ERA sharpening as one ``round_kernel`` pass instead of the per-op
  chain (whose quantize kernel grids over all K*m soft-label rows).
  Codec is ``cache_delta+quant8`` — the paper's full-compression
  configuration and the deepest fused op chain.

Both device engines draw from the identical jax key stream, so all
engines run the same rounds.  ``--quick`` shrinks rounds/cohorts to CI
smoke sizes (and adapts the mesh to however many devices the runner
exposes, so it works at 1 device too); the fused sweep keeps its full
K=200/1000 points — they ARE the measurement (the perf gate tracks
their speedup) and stay CI-sized at a reduced round budget.
"""
from __future__ import annotations

import time

from benchmarks._common import emit, write_bench
from repro.fl import (
    FederatedDistillation,
    FLConfig,
    ScannedFederatedDistillation,
    ShardedFederatedDistillation,
)
from repro.fl.shard_engine import best_data_axis
from repro.fl.strategies import STRATEGIES

ROUNDS = 30
CLIENT_COUNTS = (10, 50, 200)
SHARD_ROUNDS = 10
SHARD_CLIENT_COUNTS = (200, 1000, 4000)
FUSED_ROUNDS = 8
FUSED_CLIENT_COUNTS = (200, 1000)
FUSED_CODEC = "cache_delta+quant8"
QUICK_ROUNDS = 8
QUICK_CLIENT_COUNTS = (10,)
QUICK_SHARD_CLIENT_COUNTS = (16,)
QUICK_FUSED_ROUNDS = 4


def _cfg(n_clients: int, rounds: int) -> FLConfig:
    return FLConfig(
        n_clients=n_clients, n_classes=10, dim=8, rounds=rounds,
        local_steps=1, distill_steps=1, public_size=256, public_per_round=24,
        private_size=200, alpha=0.05, hidden=12, eval_every=10**6, seed=0)


def _time_run(engine, rounds: int) -> float:
    engine.run(rounds)  # warmup: compile everything once
    t0 = time.perf_counter()
    engine.run(rounds)
    return time.perf_counter() - t0


def _scan_vs_host(counts, rounds) -> list:
    rows = []
    for K in counts:
        cfg = _cfg(K, rounds)
        host = FederatedDistillation(
            cfg, STRATEGIES["scarlet"](beta=1.5), cache_duration=4,
            rng_backend="jax")
        t_host = _time_run(host, rounds)
        scan = ScannedFederatedDistillation(
            cfg, STRATEGIES["scarlet"](beta=1.5), cache_duration=4)
        t_scan = _time_run(scan, rounds)
        rows.append({
            "name": f"engine_host_K{K}",
            "us_per_call": t_host / rounds * 1e6,
            "rounds_per_sec": rounds / t_host,
            "derived": f"{rounds / t_host:.1f} rounds/s",
        })
        rows.append({
            "name": f"engine_scan_K{K}",
            "us_per_call": t_scan / rounds * 1e6,
            "rounds_per_sec": rounds / t_scan,
            "speedup": t_host / t_scan,
            "derived": (f"{rounds / t_scan:.1f} rounds/s, "
                        f"{t_host / t_scan:.1f}x vs host loop"),
        })
    return rows


def _shard_vs_scan(counts, rounds) -> list:
    rows = []
    for K in counts:
        cfg = _cfg(K, rounds)
        scan = ScannedFederatedDistillation(
            cfg, STRATEGIES["scarlet"](beta=1.5), cache_duration=4)
        t_scan = _time_run(scan, rounds)
        data = best_data_axis(K)
        shard = ShardedFederatedDistillation(
            cfg, STRATEGIES["scarlet"](beta=1.5), cache_duration=4,
            mesh=f"{data}")
        t_shard = _time_run(shard, rounds)
        rows.append({
            # "base" suffix: the scan baseline of the *sharded* sweep —
            # K=200 also appears in the host-vs-scan sweep at a
            # different round budget, so names must stay unique
            "name": f"engine_scan_base_K{K}",
            "us_per_call": t_scan / rounds * 1e6,
            "rounds_per_sec": rounds / t_scan,
            "derived": f"{rounds / t_scan:.1f} rounds/s",
        })
        rows.append({
            "name": f"engine_shard_K{K}_d{data}",
            "us_per_call": t_shard / rounds * 1e6,
            "rounds_per_sec": rounds / t_shard,
            "speedup": t_scan / t_shard,
            "derived": (f"{rounds / t_shard:.1f} rounds/s, "
                        f"{t_scan / t_shard:.1f}x vs scan, "
                        f"{data} shards"),
        })
    return rows


def _fused_vs_perop(counts, rounds) -> list:
    import dataclasses

    rows = []
    for K in counts:
        cfg = dataclasses.replace(_cfg(K, rounds), uplink_codec=FUSED_CODEC)
        perop = ScannedFederatedDistillation(
            cfg, STRATEGIES["scarlet"](beta=1.5), cache_duration=4)
        t_perop = _time_run(perop, rounds)
        fused = ScannedFederatedDistillation(
            dataclasses.replace(cfg, fused_round=True),
            STRATEGIES["scarlet"](beta=1.5), cache_duration=4)
        t_fused = _time_run(fused, rounds)
        rows.append({
            "name": f"engine_scan_perop_K{K}",
            "us_per_call": t_perop / rounds * 1e6,
            "rounds_per_sec": rounds / t_perop,
            "codec": FUSED_CODEC,
            "derived": f"{rounds / t_perop:.1f} rounds/s, per-op chain",
        })
        rows.append({
            "name": f"engine_scan_fused_K{K}",
            "us_per_call": t_fused / rounds * 1e6,
            "rounds_per_sec": rounds / t_fused,
            "speedup": t_perop / t_fused,
            "codec": FUSED_CODEC,
            "derived": (f"{rounds / t_fused:.1f} rounds/s, "
                        f"{t_perop / t_fused:.2f}x vs per-op chain"),
        })
    return rows


def _telemetry_summary(rounds: int = QUICK_FUSED_ROUNDS) -> dict:
    """Device-plane summary of a small instrumented scan run, embedded
    in the BENCH doc so the benchmark record carries cache/comm counters
    alongside the timings (the perf gate ignores the key)."""
    import dataclasses

    cfg = dataclasses.replace(
        _cfg(QUICK_CLIENT_COUNTS[0], rounds),
        uplink_codec=FUSED_CODEC, telemetry=True)
    eng = ScannedFederatedDistillation(
        cfg, STRATEGIES["scarlet"](beta=1.5), cache_duration=4)
    return eng.run(rounds).telemetry.summary()


def run(quick: bool = False):
    if quick:
        rows = _scan_vs_host(QUICK_CLIENT_COUNTS, QUICK_ROUNDS)
        rows += _shard_vs_scan(QUICK_SHARD_CLIENT_COUNTS, QUICK_ROUNDS)
        rows += _fused_vs_perop(FUSED_CLIENT_COUNTS, QUICK_FUSED_ROUNDS)
        return rows
    rows = _scan_vs_host(CLIENT_COUNTS, ROUNDS)
    rows += _shard_vs_scan(SHARD_CLIENT_COUNTS, SHARD_ROUNDS)
    rows += _fused_vs_perop(FUSED_CLIENT_COUNTS, FUSED_ROUNDS)
    return rows


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default="", help="write BENCH json here")
    args = ap.parse_args()
    rows = run(quick=args.quick)
    emit(rows)
    if args.out:
        write_bench(args.out, "engine", rows, quick=args.quick,
                    telemetry=_telemetry_summary())


if __name__ == "__main__":
    main()
