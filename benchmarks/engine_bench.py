"""Engine benchmark: scanned (lax.scan) vs host-loop rounds/sec.

The host loop dispatches dozens of small device programs per round and
syncs the host every round (participation counts, miss counts, subset
sampling); the scanned engine compiles the whole run into one XLA
program.  The gap is therefore dispatch/sync-bound: this benchmark uses
a deliberately small per-round compute load (1 local step, tiny MLP) so
the per-round overhead — the thing the scanned engine removes — is what
gets measured.  Both engines draw from the identical jax key stream
(``rng_backend="jax"``), so they run the same rounds.

Scenario sweeps and multi-seed runs inherit the scanned numbers: a
sweep is N independent ``run()`` calls, each one program launch.
"""
from __future__ import annotations

import time

from benchmarks._common import emit
from repro.fl import FederatedDistillation, FLConfig, ScannedFederatedDistillation
from repro.fl.strategies import STRATEGIES

ROUNDS = 30
CLIENT_COUNTS = (10, 50, 200)
QUICK_ROUNDS = 8
QUICK_CLIENT_COUNTS = (10,)


def _cfg(n_clients: int, rounds: int) -> FLConfig:
    return FLConfig(
        n_clients=n_clients, n_classes=10, dim=8, rounds=rounds,
        local_steps=1, distill_steps=1, public_size=256, public_per_round=24,
        private_size=200, alpha=0.05, hidden=12, eval_every=10**6, seed=0)


def _time_run(engine, rounds: int) -> float:
    engine.run(rounds)  # warmup: compile everything once
    t0 = time.perf_counter()
    engine.run(rounds)
    return time.perf_counter() - t0


def run(quick: bool = False):
    rounds = QUICK_ROUNDS if quick else ROUNDS
    counts = QUICK_CLIENT_COUNTS if quick else CLIENT_COUNTS
    rows = []
    for K in counts:
        cfg = _cfg(K, rounds)
        host = FederatedDistillation(
            cfg, STRATEGIES["scarlet"](beta=1.5), cache_duration=4,
            rng_backend="jax")
        t_host = _time_run(host, rounds)
        scan = ScannedFederatedDistillation(
            cfg, STRATEGIES["scarlet"](beta=1.5), cache_duration=4)
        t_scan = _time_run(scan, rounds)
        rows.append({
            "name": f"engine_host_K{K}",
            "us_per_call": t_host / rounds * 1e6,
            "derived": f"{rounds / t_host:.1f} rounds/s",
        })
        rows.append({
            "name": f"engine_scan_K{K}",
            "us_per_call": t_scan / rounds * 1e6,
            "derived": (f"{rounds / t_scan:.1f} rounds/s, "
                        f"{t_host / t_scan:.1f}x vs host loop"),
        })
    return rows


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    emit(run(quick=args.quick))


if __name__ == "__main__":
    main()
