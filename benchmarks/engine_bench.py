"""Engine benchmark: scanned (lax.scan) vs host-loop rounds/sec.

The host loop dispatches dozens of small device programs per round and
syncs the host every round (participation counts, miss counts, subset
sampling); the scanned engine compiles the whole run into one XLA
program.  The gap is therefore dispatch/sync-bound: this benchmark uses
a deliberately small per-round compute load (1 local step, tiny MLP) so
the per-round overhead — the thing the scanned engine removes — is what
gets measured.  Both engines draw from the identical jax key stream
(``rng_backend="jax"``), so they run the same rounds.

Scenario sweeps and multi-seed runs inherit the scanned numbers: a
sweep is N independent ``run()`` calls, each one program launch.
"""
from __future__ import annotations

import time

from benchmarks._common import emit
from repro.fl import FederatedDistillation, FLConfig, ScannedFederatedDistillation
from repro.fl.strategies import STRATEGIES

ROUNDS = 30
CLIENT_COUNTS = (10, 50, 200)


def _cfg(n_clients: int) -> FLConfig:
    return FLConfig(
        n_clients=n_clients, n_classes=10, dim=8, rounds=ROUNDS,
        local_steps=1, distill_steps=1, public_size=256, public_per_round=24,
        private_size=200, alpha=0.05, hidden=12, eval_every=10**6, seed=0)


def _time_run(engine) -> float:
    engine.run(ROUNDS)  # warmup: compile everything once
    t0 = time.perf_counter()
    engine.run(ROUNDS)
    return time.perf_counter() - t0


def run():
    rows = []
    for K in CLIENT_COUNTS:
        cfg = _cfg(K)
        host = FederatedDistillation(
            cfg, STRATEGIES["scarlet"](beta=1.5), cache_duration=4,
            rng_backend="jax")
        t_host = _time_run(host)
        scan = ScannedFederatedDistillation(
            cfg, STRATEGIES["scarlet"](beta=1.5), cache_duration=4)
        t_scan = _time_run(scan)
        rows.append({
            "name": f"engine_host_K{K}",
            "us_per_call": t_host / ROUNDS * 1e6,
            "derived": f"{ROUNDS / t_host:.1f} rounds/s",
        })
        rows.append({
            "name": f"engine_scan_K{K}",
            "us_per_call": t_scan / ROUNDS * 1e6,
            "derived": (f"{ROUNDS / t_scan:.1f} rounds/s, "
                        f"{t_host / t_scan:.1f}x vs host loop"),
        })
    return rows


def main():
    emit(run())


if __name__ == "__main__":
    main()
