"""Engine benchmark: host loop vs scanned (lax.scan) vs client-sharded.

Two sweeps:

- **scan vs host** (small K): the host loop dispatches dozens of small
  device programs per round and syncs the host every round; the scanned
  engine compiles the whole run into one XLA program.  The gap is
  dispatch/sync-bound, so the per-round compute load is deliberately
  tiny (1 local step, tiny MLP).
- **shard vs scan** (large K — 200/1000/4000 clients, the cohort sizes
  compressed-distillation papers sweep): the scanned engine keeps the
  whole client axis on one device; the sharded engine partitions it
  over the mesh "data" axis (``shard_map``), trading psum latency for
  per-device client load.  On a multi-chip platform this is the only
  way past single-device memory; on CPU it also exercises the exact
  production code path (the mesh uses every local device via
  ``best_data_axis``).

Both device engines draw from the identical jax key stream, so all
engines run the same rounds.  ``--quick`` shrinks rounds/cohorts to CI
smoke sizes (and adapts the mesh to however many devices the runner
exposes, so it works at 1 device too).
"""
from __future__ import annotations

import time

from benchmarks._common import emit
from repro.fl import (
    FederatedDistillation,
    FLConfig,
    ScannedFederatedDistillation,
    ShardedFederatedDistillation,
)
from repro.fl.shard_engine import best_data_axis
from repro.fl.strategies import STRATEGIES

ROUNDS = 30
CLIENT_COUNTS = (10, 50, 200)
SHARD_ROUNDS = 10
SHARD_CLIENT_COUNTS = (200, 1000, 4000)
QUICK_ROUNDS = 8
QUICK_CLIENT_COUNTS = (10,)
QUICK_SHARD_CLIENT_COUNTS = (16,)


def _cfg(n_clients: int, rounds: int) -> FLConfig:
    return FLConfig(
        n_clients=n_clients, n_classes=10, dim=8, rounds=rounds,
        local_steps=1, distill_steps=1, public_size=256, public_per_round=24,
        private_size=200, alpha=0.05, hidden=12, eval_every=10**6, seed=0)


def _time_run(engine, rounds: int) -> float:
    engine.run(rounds)  # warmup: compile everything once
    t0 = time.perf_counter()
    engine.run(rounds)
    return time.perf_counter() - t0


def _scan_vs_host(counts, rounds) -> list:
    rows = []
    for K in counts:
        cfg = _cfg(K, rounds)
        host = FederatedDistillation(
            cfg, STRATEGIES["scarlet"](beta=1.5), cache_duration=4,
            rng_backend="jax")
        t_host = _time_run(host, rounds)
        scan = ScannedFederatedDistillation(
            cfg, STRATEGIES["scarlet"](beta=1.5), cache_duration=4)
        t_scan = _time_run(scan, rounds)
        rows.append({
            "name": f"engine_host_K{K}",
            "us_per_call": t_host / rounds * 1e6,
            "derived": f"{rounds / t_host:.1f} rounds/s",
        })
        rows.append({
            "name": f"engine_scan_K{K}",
            "us_per_call": t_scan / rounds * 1e6,
            "derived": (f"{rounds / t_scan:.1f} rounds/s, "
                        f"{t_host / t_scan:.1f}x vs host loop"),
        })
    return rows


def _shard_vs_scan(counts, rounds) -> list:
    rows = []
    for K in counts:
        cfg = _cfg(K, rounds)
        scan = ScannedFederatedDistillation(
            cfg, STRATEGIES["scarlet"](beta=1.5), cache_duration=4)
        t_scan = _time_run(scan, rounds)
        data = best_data_axis(K)
        shard = ShardedFederatedDistillation(
            cfg, STRATEGIES["scarlet"](beta=1.5), cache_duration=4,
            mesh=f"{data}")
        t_shard = _time_run(shard, rounds)
        rows.append({
            # "base" suffix: the scan baseline of the *sharded* sweep —
            # K=200 also appears in the host-vs-scan sweep at a
            # different round budget, so names must stay unique
            "name": f"engine_scan_base_K{K}",
            "us_per_call": t_scan / rounds * 1e6,
            "derived": f"{rounds / t_scan:.1f} rounds/s",
        })
        rows.append({
            "name": f"engine_shard_K{K}_d{data}",
            "us_per_call": t_shard / rounds * 1e6,
            "derived": (f"{rounds / t_shard:.1f} rounds/s, "
                        f"{t_scan / t_shard:.1f}x vs scan, "
                        f"{data} shards"),
        })
    return rows


def run(quick: bool = False):
    if quick:
        rows = _scan_vs_host(QUICK_CLIENT_COUNTS, QUICK_ROUNDS)
        rows += _shard_vs_scan(QUICK_SHARD_CLIENT_COUNTS, QUICK_ROUNDS)
        return rows
    rows = _scan_vs_host(CLIENT_COUNTS, ROUNDS)
    rows += _shard_vs_scan(SHARD_CLIENT_COUNTS, SHARD_ROUNDS)
    return rows


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    emit(run(quick=args.quick))


if __name__ == "__main__":
    main()
