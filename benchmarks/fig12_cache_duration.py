"""Paper Fig. 12: cache-duration D ablation (accuracy vs communication
trade-off; D=0 no cache, conservative D saves comm at ~no cost, huge D
degrades with stale labels).  Derived: final acc + cumulative MB per D."""
from __future__ import annotations

from benchmarks._common import default_cfg, emit
from repro.fl.engine import run_method


def run(rounds: int = 80):
    cfg = default_cfg(alpha=0.05, rounds=rounds)
    rows = []
    for D in (0, 5, 10, 25, 50, 200):
        h = run_method("scarlet", cfg, cache_duration=D,
                       use_cache=D > 0, beta=1.5)
        mb = h.ledger.summary()["cumulative_total"] / 1e6
        rows.append({
            "name": f"fig12_D{D}",
            "us_per_call": 0.0,
            "derived": f"server_acc={h.final_server_acc:.3f};"
                       f"client_acc={h.final_client_acc:.3f};cum_MB={mb:.2f}",
        })
    return rows


def main():
    emit(run())


if __name__ == "__main__":
    main()
