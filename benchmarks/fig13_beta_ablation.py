"""Paper Fig. 13/14/15: Enhanced-ERA sharpness beta ablation across
non-IID strengths (server optimum drifts to beta=1 as alpha grows;
beta~1.5 is a robust default).  Derived: final server/client acc grid."""
from __future__ import annotations

from benchmarks._common import default_cfg, emit
from repro.fl.engine import run_method


def run(rounds: int = 60):
    rows = []
    for alpha in (0.05, 0.3, 1.0):
        for beta in (0.5, 1.0, 1.5, 2.0, 3.0):
            cfg = default_cfg(alpha=alpha, rounds=rounds)
            h = run_method("scarlet", cfg, cache_duration=25, beta=beta)
            rows.append({
                "name": f"fig13_alpha{alpha}_beta{beta}",
                "us_per_call": 0.0,
                "derived": f"server_acc={h.final_server_acc:.3f};"
                           f"client_acc={h.final_client_acc:.3f}",
            })
    return rows


def main():
    emit(run())


if __name__ == "__main__":
    main()
