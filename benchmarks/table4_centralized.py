"""Paper Table IV: centralized (non-FL) reference — all private data
pooled, single model, lower LR (0.01x scale per the paper's note).
Derived: centralized test accuracy (the FL upper reference)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks._common import default_cfg, emit
from repro.data.synthetic import make_public_private
from repro.fl.engine import accuracy, local_train
from repro.models.resnet import init_mlp


def run(steps: int = 400):
    cfg = default_cfg()
    data = make_public_private(cfg.private_size, cfg.public_size,
                               cfg.n_classes, cfg.dim, seed=cfg.seed)
    params = init_mlp(jax.random.PRNGKey(0), cfg.dim, cfg.n_classes,
                      cfg.hidden, cfg.mlp_depth)
    x = jnp.asarray(data["x_private"])
    y = jnp.asarray(data["y_private"])
    mask = jnp.ones(len(y))
    params = local_train(params, x, y, mask, 0.05, steps)
    acc = float(accuracy(params, jnp.asarray(data["x_test"]),
                         jnp.asarray(data["y_test"]),
                         jnp.ones(len(data["y_test"]))))
    return [{
        "name": "table4_centralized",
        "us_per_call": 0.0,
        "derived": f"test_acc={acc:.3f} (upper reference, IID pooled data)",
    }]


def main():
    emit(run())


if __name__ == "__main__":
    main()
