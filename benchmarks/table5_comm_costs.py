"""Paper Table V: per-round uplink/downlink communication costs, with
the paper's exact setting (K=100 clients, |P^t|=1000, N=10 classes,
float32 soft-labels) computed analytically from each method's wire
format, plus the SCARLET cache hit rate from the Alg.-3 simulator
(D=50, |P|=10000).  Derived: MB/round up/down + reduction vs DS-FL."""
from __future__ import annotations

import numpy as np

from benchmarks._common import emit
from repro.core import comm
from repro.core.cache_sim import simulate_hit_rate


def run():
    K, m, N, P = 100, 1000, 10, 10_000
    rows = []
    # steady-state requested fraction for SCARLET (D=50)
    hit = simulate_hit_rate(P, m, 50, 1500)
    req_frac = float(1.0 - hit[500:].mean())

    def per_round(method: str):
        if method == "scarlet":
            return comm.distillation_round_cost(
                n_clients=K, n_selected=m, n_requested=int(m * req_frac),
                n_classes=N, with_cache_signals=True)
        if method in ("dsfl", "comet"):
            return comm.distillation_round_cost(
                n_clients=K, n_selected=m, n_requested=m, n_classes=N)
        if method == "cfd":
            return comm.distillation_round_cost(
                n_clients=K, n_selected=m, n_requested=m, n_classes=N,
                uplink_bits=1.0)
        if method == "selective_fd":
            # ~81% of labels pass the confidence selector (paper: 3.88/4.80)
            # — the gate masks only the uplink; the server still
            # broadcasts aggregated labels for every selected sample.
            return comm.distillation_round_cost(
                n_clients=K, n_selected=m, n_up_samples=m * 0.81,
                n_down_samples=m, n_classes=N)
        raise ValueError(method)

    base = per_round("dsfl")
    for method in ("scarlet", "dsfl", "comet", "cfd", "selective_fd"):
        c = per_round(method)
        up_mb = c.uplink / K / 1e6
        down_mb = c.downlink / K / 1e6
        red = 1 - c.uplink / base.uplink
        rows.append({
            "name": f"table5_{method}",
            "us_per_call": 0.0,
            "derived": f"up_MB_rnd={up_mb:.2f};down_MB_rnd={down_mb:.2f};"
                       f"uplink_reduction_vs_dsfl={red:.0%}",
        })
    rows.append({
        "name": "table5_scarlet_req_frac",
        "us_per_call": 0.0,
        "derived": f"requested_fraction={req_frac:.3f} (D=50, |P^t|/|P|=0.1)",
    })
    return rows


def main():
    emit(run())


if __name__ == "__main__":
    main()
