"""Codec x strategy Pareto frontier: cumulative uplink bytes vs accuracy.

Sweeps the soft-label wire codecs (``repro.compress``) across
distillation strategies on the scanned engine and emits, per point, the
cumulative uplink under the ledger's analytic accounting, the final
server accuracy (the proxy axis of the Pareto), and the uplink
reduction factor vs the dense fp32 identity codec of the same strategy.

Under SCARLET the cache already shrinks *how many* labels move;
codecs shrink *how large each label is* — the two compose, and
``cache_delta+quant8`` (8-bit residuals against the synchronized cache,
one class dropped via the sum-zero constraint) cuts uplink by
``32/8 * N/(N-1)`` = 4.4x at N=10 on top of the cache's hit-rate
savings.

  PYTHONPATH=src python -m benchmarks.codec_pareto [--quick]
"""
from __future__ import annotations

import dataclasses

from benchmarks._common import default_cfg, emit

CODEC_SPECS = (
    "identity",
    "quant8",
    "quant4",
    "topk2",
    "cache_delta",
    "cache_delta+quant8",
    "cache_delta+quant4",
)

STRATEGY_KW = {
    "scarlet": dict(cache_duration=10, beta=1.5),
    "dsfl": dict(T=0.1),
}


def run(rounds: int = 60, quick: bool = False):
    from repro.fl import run_method

    if quick:
        rounds = 12
    cfg = default_cfg(rounds=rounds)
    if quick:
        cfg = dataclasses.replace(cfg, n_clients=6, public_size=400,
                                  public_per_round=60, private_size=500,
                                  local_steps=2, distill_steps=2, hidden=24)

    rows = []
    for strat, kw in STRATEGY_KW.items():
        base_up = None
        for spec in CODEC_SPECS:
            h = run_method(strat, cfg, engine="scan", rounds=rounds,
                           codec=spec, **kw)
            up = h.ledger.cumulative_uplink
            if spec == "identity":
                base_up = up
            ratio = base_up / up if up > 0 else float("inf")
            rows.append({
                "name": f"codec_pareto_{strat}_{spec.replace('+', '_')}",
                "us_per_call": 0.0,
                "cum_uplink_bytes": up,
                "server_acc": h.final_server_acc,
                "uplink_x_vs_identity": ratio,
                "derived": (f"cum_up_MB={up / 1e6:.3f};"
                            f"server_acc={h.final_server_acc:.3f};"
                            f"uplink_x_vs_identity={ratio:.2f}"),
            })
    return rows


def main():
    import argparse

    from benchmarks._common import write_bench

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default="", help="write BENCH json here")
    args = ap.parse_args()
    rows = run(quick=args.quick)
    emit(rows)
    if args.out:
        write_bench(args.out, "codec", rows, quick=args.quick)


if __name__ == "__main__":
    main()
