"""CI perf-regression gate over committed ``BENCH_*.json`` baselines.

Compares a freshly generated set of BENCH documents (``--current-dir``)
against the committed baselines (``--baseline-dir``, default
``benchmarks/baselines``) and fails when a tracked metric regresses
beyond the tolerance band:

- ``us_per_call`` (lower is better): fails when
  ``current > baseline * (1 + ratio_tol) + abs_tol_us`` — the
  multiplicative band absorbs CI-runner speed variance, the additive
  floor keeps microsecond-scale rows from tripping on scheduler noise;
- ``rounds_per_sec`` / ``speedup`` (higher is better): fails when
  ``current < baseline * (1 - ratio_tol)`` — this is the term that
  catches the fused round path silently losing its advantage;
- a baseline row or file missing from the current run fails (coverage
  must never silently shrink); new rows/files are allowed;
- an environment mismatch (different backend or device kind) fails:
  cross-hardware timing comparisons are meaningless.

The comparison core (:func:`gate_docs`) is a pure function over the two
documents — unit-tested with simulated regressions in
``tests/test_perf_gate.py``.

  PYTHONPATH=src python -m benchmarks.perf_gate --current-dir /tmp/bench
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys
from typing import Dict, List

DEFAULT_RATIO_TOL = 0.75
DEFAULT_ABS_TOL_US = 500.0

# metric -> direction ("lower"/"higher" is better)
GATED_METRICS = {
    "us_per_call": "lower",
    "rounds_per_sec": "higher",
    "speedup": "higher",
}


def gate_docs(baseline: Dict, current: Dict, *,
              ratio_tol: float = DEFAULT_RATIO_TOL,
              abs_tol_us: float = DEFAULT_ABS_TOL_US) -> List[str]:
    """Failure messages from comparing one BENCH document pair."""
    fails: List[str] = []
    bench = baseline.get("bench", "?")
    b_env, c_env = baseline.get("env", {}), current.get("env", {})
    for k in ("backend", "device_kind"):
        if b_env.get(k) != c_env.get(k):
            fails.append(
                f"{bench}: env mismatch on {k!r}: baseline "
                f"{b_env.get(k)!r} vs current {c_env.get(k)!r} "
                "(regenerate the baseline on this hardware)")
    cur_rows = {r["name"]: r for r in current.get("rows", [])}
    for row in baseline.get("rows", []):
        name = row["name"]
        cur = cur_rows.get(name)
        if cur is None:
            fails.append(f"{bench}/{name}: row missing from current run")
            continue
        for metric, direction in GATED_METRICS.items():
            if metric not in row or not row[metric]:
                continue
            base_v = float(row[metric])
            cur_v = float(cur.get(metric, 0.0))
            if direction == "lower":
                limit = base_v * (1.0 + ratio_tol) + abs_tol_us
                if cur_v > limit:
                    fails.append(
                        f"{bench}/{name}: {metric} regressed "
                        f"{base_v:.1f} -> {cur_v:.1f} (limit {limit:.1f})")
            else:
                limit = base_v * (1.0 - ratio_tol)
                if cur_v < limit:
                    fails.append(
                        f"{bench}/{name}: {metric} regressed "
                        f"{base_v:.3f} -> {cur_v:.3f} (floor {limit:.3f})")
    return fails


def _load(path: str) -> Dict:
    with open(path) as f:
        return json.load(f)


def gate_dirs(baseline_dir: str, current_dir: str, *,
              ratio_tol: float = DEFAULT_RATIO_TOL,
              abs_tol_us: float = DEFAULT_ABS_TOL_US) -> List[str]:
    fails: List[str] = []
    paths = sorted(glob.glob(os.path.join(baseline_dir, "BENCH_*.json")))
    if not paths:
        return [f"no BENCH_*.json baselines found in {baseline_dir}"]
    for bpath in paths:
        fname = os.path.basename(bpath)
        cpath = os.path.join(current_dir, fname)
        if not os.path.exists(cpath):
            fails.append(f"{fname}: missing from current dir {current_dir}")
            continue
        fails += gate_docs(_load(bpath), _load(cpath),
                           ratio_tol=ratio_tol, abs_tol_us=abs_tol_us)
    return fails


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline-dir", default="benchmarks/baselines")
    ap.add_argument("--current-dir", required=True)
    ap.add_argument("--ratio-tol", type=float, default=DEFAULT_RATIO_TOL)
    ap.add_argument("--abs-tol-us", type=float, default=DEFAULT_ABS_TOL_US)
    args = ap.parse_args()
    fails = gate_dirs(args.baseline_dir, args.current_dir,
                      ratio_tol=args.ratio_tol, abs_tol_us=args.abs_tol_us)
    for msg in fails:
        print(f"PERF GATE FAIL: {msg}")
    if fails:
        sys.exit(1)
    print("perf gate: OK")


if __name__ == "__main__":
    main()
