"""Async engine throughput under traffic: windows x staleness decay.

One sweep over ``(window_ticks, staleness_decay)`` cells of the
buffered-aggregation engine (:mod:`repro.fl.async_engine`) under a
genuinely asynchronous traffic model — Poisson arrivals, 0-3 window
uniform report latency — so the timed program carries the full
dispatch/arrival bookkeeping: in-flight state, the split catch-up
ledger, and (at non-unit decay) the staleness-weight multiply.  The
claims under test:

- the async round body stays a single compiled ``lax.scan`` program
  (rounds/sec in the same regime as the scan engine, not a per-round
  host loop), and
- unit staleness decay costs nothing — the engine statically skips the
  weight hook, so the ``decay=1.0`` and ``decay=0.5`` cells isolate
  the hook's arithmetic.

Timings use the ``engine_bench`` recipe: dispatch-bound tiny model
(1 local step, depth-1 MLP), one full warmup leg to compile the
T-shaped scan, then an identically-shaped timed leg (same program,
cache hit).  ``cum_mb`` is the timed leg's ledger total — the byte
record the conformance suite pins.

``--quick`` keeps two CI-sized cells whose ``rounds_per_sec`` feeds
the perf-regression gate (``BENCH_async.json``).
"""
from __future__ import annotations

import time

from repro.fl import FLConfig
from repro.fl.async_engine import AsyncFederatedDistillation
from repro.fl.strategies import STRATEGIES
from repro.fl.traffic import ArrivalProcess, LatencyModel, TrafficModel

ROUNDS = 40
N_CLIENTS = 32
GRID = (  # (window_ticks, staleness_decay)
    (1, 1.0),
    (1, 0.5),
    (4, 1.0),
    (4, 0.5),
)
QUICK_GRID = ((1, 1.0), (4, 0.5))
QUICK_ROUNDS = 12


def _cfg(rounds: int) -> FLConfig:
    return FLConfig(
        n_clients=N_CLIENTS, n_classes=10, dim=8, rounds=2 * rounds + 1,
        local_steps=1, distill_steps=1, public_size=256, public_per_round=64,
        private_size=2 * N_CLIENTS, partition="uniform", hidden=8,
        mlp_depth=1, eval_every=10**6, seed=0)


def _traffic(window_ticks: int) -> TrafficModel:
    return TrafficModel(
        arrivals=ArrivalProcess("poisson", rate=1.5),
        latency=LatencyModel("uniform", lo=0, hi=3),
        window_ticks=window_ticks, seed=0)


def _bench_point(window_ticks: int, decay: float, rounds: int) -> dict:
    eng = AsyncFederatedDistillation(
        _cfg(rounds), STRATEGIES["scarlet"](beta=1.5, staleness_decay=decay),
        cache_duration=3, traffic=_traffic(window_ticks))
    eng.run(rounds)  # warmup: compiles the T-shaped scan program
    t0 = time.perf_counter()
    hist = eng.run(rounds)  # same shape -> compile-cache hit, pure run
    dt = time.perf_counter() - t0
    cum_mb = hist.ledger.cumulative_total / 1e6
    arrived = sum(1 for r in hist.ledger.rounds if r.uplink > 0)
    return {
        "name": f"async/w={window_ticks},decay={decay}",
        "us_per_call": dt / rounds * 1e6,
        "rounds_per_sec": rounds / dt,
        "window_ticks": window_ticks,
        "staleness_decay": decay,
        "cum_mb": cum_mb,
        "arrival_rounds": arrived,
        "derived": (f"K={N_CLIENTS} arr_rounds={arrived}/{rounds} "
                    f"cum={cum_mb:.2f}MB"),
    }


def run(quick: bool = False) -> list:
    grid = QUICK_GRID if quick else GRID
    rounds = QUICK_ROUNDS if quick else ROUNDS
    return [_bench_point(w, d, rounds) for w, d in grid]


if __name__ == "__main__":
    import argparse

    from benchmarks._common import emit, write_bench

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default="")
    args = ap.parse_args()
    rows = run(quick=args.quick)
    print("name,us_per_call,derived")
    emit(rows)
    if args.out:
        write_bench(args.out, "async", rows, quick=args.quick)
