"""Beyond-paper extensions (the paper's §V future directions):

1. Probabilistic per-sample cache expiry (hazard age/D) — removes the
   synchronized mass-refresh waves that destabilize training at large D
   (paper Fig. 12's D>=400 cliff).  Derived: accuracy + comm at a large
   scaled D, hard vs probabilistic, plus refresh-wave amplitude from the
   standalone simulator.
2. Adaptive Enhanced-ERA beta from server-visible aggregated soft-label
   entropy (beta_t = 1 + (beta_max-1) * H_norm).  Derived: accuracy vs
   the static default across non-IID strengths (reported even where it
   LOSES — the negative result supports the paper's claim that a static
   beta=1.5 is a robust default and adaptive tuning remains open).
"""
from __future__ import annotations

import numpy as np

from benchmarks._common import default_cfg, emit
from repro.core.cache_sim import simulate_hit_rate, simulate_hit_rate_probabilistic
from repro.fl.engine import run_method


def run(rounds: int = 80):
    rows = []

    # --- refresh-wave amplitude (simulator, paper-scale) -------------------
    for D in (200, 400):
        hard = simulate_hit_rate(10_000, 1_000, D, 1_500)[300:]
        prob = simulate_hit_rate_probabilistic(10_000, 1_000, D, 1_500)[300:]
        rows.append({
            "name": f"ext_prob_expiry_sim_D{D}",
            "us_per_call": 0.0,
            "derived": f"hard_hit={hard.mean():.3f}±{hard.std():.3f};"
                       f"prob_hit={prob.mean():.3f}±{prob.std():.3f};"
                       f"wave_amplitude_reduction={1 - prob.std()/max(hard.std(),1e-9):.0%}",
        })

    # --- FL accuracy at an aggressively large (scaled) D -------------------
    cfg = default_cfg(alpha=0.05, rounds=rounds)
    D_big = rounds // 2  # deliberately past the Fig.-12 cliff
    h_hard = run_method("scarlet", cfg, cache_duration=D_big, beta=1.5)
    h_prob = run_method("scarlet", cfg, cache_duration=D_big, beta=1.5,
                        probabilistic_expiry=True)
    rows.append({
        "name": f"ext_prob_expiry_fl_D{D_big}",
        "us_per_call": 0.0,
        "derived": f"hard_acc={h_hard.final_server_acc:.3f}"
                   f"(MB={h_hard.ledger.cumulative_total/1e6:.2f});"
                   f"prob_acc={h_prob.final_server_acc:.3f}"
                   f"(MB={h_prob.ledger.cumulative_total/1e6:.2f})",
    })

    # --- adaptive beta ------------------------------------------------------
    for alpha in (0.05, 0.3):
        cfg = default_cfg(alpha=alpha, rounds=rounds)
        h_fix = run_method("scarlet", cfg, cache_duration=10, beta=1.5)
        h_ada = run_method("scarlet", cfg, cache_duration=10, beta="adaptive",
                           beta_max=2.5)
        rows.append({
            "name": f"ext_adaptive_beta_alpha{alpha}",
            "us_per_call": 0.0,
            "derived": f"static1.5={h_fix.final_server_acc:.3f};"
                       f"adaptive={h_ada.final_server_acc:.3f};"
                       f"delta_pp={100*(h_ada.final_server_acc - h_fix.final_server_acc):+.1f}",
        })
    return rows


def main():
    emit(run())


if __name__ == "__main__":
    main()
