"""Paper Fig. 16: partial client participation sweep with the cache on
and off (catch-up packages).  Derived: final acc + cumulative MB at each
participation ratio p."""
from __future__ import annotations

from benchmarks._common import default_cfg, emit
from repro.fl.engine import run_method


def run(rounds: int = 60):
    rows = []
    for p in (0.25, 0.5, 1.0):
        for cache in (True, False):
            cfg = default_cfg(alpha=0.3, rounds=rounds, participation=p)
            D = max(rounds // 8, 4)  # staleness horizon scaled to budget
            h = run_method("scarlet", cfg, beta=1.0,
                           cache_duration=D if cache else 0, use_cache=cache)
            mb = h.ledger.summary()["cumulative_total"] / 1e6
            rows.append({
                "name": f"fig16_p{p}_{'cache' if cache else 'nocache'}",
                "us_per_call": 0.0,
                "derived": f"server_acc={h.final_server_acc:.3f};"
                           f"client_acc={h.final_client_acc:.3f};cum_MB={mb:.2f}",
            })
    return rows


def main():
    emit(run())


if __name__ == "__main__":
    main()
