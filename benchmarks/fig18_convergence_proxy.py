"""Paper Fig. 18 (Appendix D): practical convergence criteria — the
server's public-validation distillation loss and clients' private-
validation CE are deployable proxies (no test labels) that converge
concurrently with the unavailable ground-truth test accuracies.
Derived: Pearson correlation between each proxy and its accuracy."""
from __future__ import annotations

import numpy as np

from benchmarks._common import default_cfg, emit
from repro.fl.engine import run_method


def run(rounds: int = 80):
    cfg = default_cfg(alpha=0.05, rounds=rounds, eval_every=5)
    h = run_method("scarlet", cfg, cache_duration=10, beta=1.5)
    sa, svl = np.array(h.server_acc), np.array(h.server_val_loss)
    ca, cvl = np.array(h.client_acc), np.array(h.client_val_loss)
    r_s = float(np.corrcoef(sa, -svl)[0, 1])
    r_c = float(np.corrcoef(ca, -cvl)[0, 1])
    return [{
        "name": "fig18_convergence_proxies",
        "us_per_call": 0.0,
        "derived": f"corr_server_proxy={r_s:.3f};corr_client_proxy={r_c:.3f};"
                   f"final_server_val_loss={svl[-1]:.4f};"
                   f"final_client_val_loss={cvl[-1]:.4f}",
    }]


def main():
    emit(run())


if __name__ == "__main__":
    main()
