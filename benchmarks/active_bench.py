"""Active-set engine scaling: million-client rounds, O(m) device state.

One sweep over the population size K with a **fixed active set** m
(``fixed_fraction(m/K)``): per-round wall time, rounds/sec, live
device bytes, and host store bytes per K.  The claim under test is the
active engine's whole reason to exist — at K = 10^6 the model state on
device is the gathered ``(m, ...)`` stack plus the O(|P|) cache, so
device bytes are flat in K up to the few-bytes-per-client bookkeeping
vector (``last_sync``/participation: ~5 B/client), while the full
per-client parameter store lives on the host (``store_bytes`` is the
column that grows linearly).  The largest point runs the ``memmap``
backing — the configuration that outlives RAM.

Timings use the same recipe as ``engine_bench``: dispatch-bound tiny
model (1 local step, depth-1 MLP), one warmup round to compile the
gather-capacity jits, then a timed run.  ``device_bytes`` sums
``jax.live_arrays()`` after a gc pass — stable standalone and under
``--only`` lists, approximate if other benchmarks leaked arrays
earlier in the same process.

``--quick`` keeps two CI-sized points (K = 10^3, 10^4) whose
``rounds_per_sec`` feeds the perf-regression gate.
"""
from __future__ import annotations

import gc
import tempfile
import time

from repro.fl import FLConfig, Scenario, fixed_fraction
from repro.fl.active_engine import ActiveSetFederatedDistillation
from repro.fl.strategies import STRATEGIES

ACTIVE_M = 64
ROUNDS = 3
CLIENT_COUNTS = (10_000, 100_000, 1_000_000)
MEMMAP_FROM = 1_000_000  # the points that must not assume K fits in RAM
QUICK_CLIENT_COUNTS = (1_000, 10_000)


def _cfg(K: int) -> FLConfig:
    return FLConfig(
        n_clients=K, n_classes=10, dim=8, rounds=ROUNDS + 1,
        local_steps=1, distill_steps=1, public_size=256, public_per_round=64,
        private_size=2 * K, partition="uniform", hidden=8, mlp_depth=1,
        eval_every=10**6, seed=0)


def _bench_point(K: int, store_dir) -> dict:
    import jax

    backing = "memmap" if (K >= MEMMAP_FROM and store_dir) else "ram"
    eng = ActiveSetFederatedDistillation(
        _cfg(K), STRATEGIES["scarlet"](beta=1.5), cache_duration=3,
        scenario=Scenario(participation=fixed_fraction(ACTIVE_M / K)),
        store_backing=backing, store_dir=store_dir)
    eng.run(1)  # warmup: compile the gather-capacity jits
    t0 = time.perf_counter()
    eng.run(ROUNDS)
    dt = time.perf_counter() - t0
    gc.collect()
    device_bytes = sum(a.nbytes for a in jax.live_arrays())
    store_bytes = eng.store.nbytes
    row = {
        "name": f"active/K={K}",
        "us_per_call": dt / ROUNDS * 1e6,
        "rounds_per_sec": ROUNDS / dt,
        "device_bytes": int(device_bytes),
        "store_bytes": int(store_bytes),
        "active_m": ACTIVE_M,
        "backing": backing,
        "derived": (f"m={ACTIVE_M} dev={device_bytes / 1e6:.1f}MB "
                    f"store={store_bytes / 1e6:.1f}MB {backing}"),
    }
    del eng
    gc.collect()
    return row


def run(quick: bool = False) -> list:
    counts = QUICK_CLIENT_COUNTS if quick else CLIENT_COUNTS
    rows = []
    with tempfile.TemporaryDirectory(prefix="active_bench_store_") as d:
        for K in counts:
            rows.append(_bench_point(K, store_dir=d))
    return rows


if __name__ == "__main__":
    import argparse

    from benchmarks._common import emit, write_bench

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default="")
    args = ap.parse_args()
    rows = run(quick=args.quick)
    print("name,us_per_call,derived")
    emit(rows)
    if args.out:
        write_bench(args.out, "active", rows, quick=args.quick)
