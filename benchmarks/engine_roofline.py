"""Roofline terms for the FL round engines, from AOT-compiled HLO.

Aims the launch-layer analysis stack (:mod:`repro.launch.hlo_analysis`
+ :mod:`repro.launch.roofline`) at the scan/shard round programs: each
engine variant is AOT-lowered for a one-round batch
(``engine.aot_lower``), compiled, and its optimized HLO + XLA cost
analysis are reduced to the three roofline terms

  compute_s    = dot FLOPs / peak_flops
  memory_s     = HBM bytes accessed / hbm_bw
  collective_s = collective bytes / link_bw

plus the measured bottleneck (the max term).  Variants: scan per-op vs
scan fused (``FLConfig.fused_round``) and the client-sharded engine —
so the fused kernel's HBM-traffic reduction and the shard engine's
psum traffic are both visible in one table.

The hardware peaks come from a named :data:`repro.launch.roofline`
preset (``--hw``, default ``tpu_v5e``).  On the CPU dev container the
absolute seconds are notional, but the per-variant *ratios* (which
term dominates, how much traffic the fused path removes) are real
properties of the compiled program.

  PYTHONPATH=src python -m benchmarks.engine_roofline [--quick] [--hw tpu_v5e]
"""
from __future__ import annotations

import dataclasses

from benchmarks._common import emit, write_bench
from repro.fl import (
    FLConfig,
    ScannedFederatedDistillation,
    ShardedFederatedDistillation,
)
from repro.fl.shard_engine import best_data_axis
from repro.fl.strategies import STRATEGIES
from repro.launch import hlo_analysis, roofline

CODEC = "cache_delta+quant8"
CLIENT_COUNTS = (200, 1000)
QUICK_CLIENT_COUNTS = (200,)


def _cfg(n_clients: int) -> FLConfig:
    return FLConfig(
        n_clients=n_clients, n_classes=10, dim=8, rounds=1,
        local_steps=1, distill_steps=1, public_size=256, public_per_round=24,
        private_size=200, alpha=0.05, hidden=12, eval_every=10**6, seed=0,
        uplink_codec=CODEC)


def _cost_dict(compiled) -> dict:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):  # older jax returns [dict]
        ca = ca[0] if ca else {}
    return dict(ca or {})


def _analyze(engine, *, scheme: str, K: int, chips: int, mesh: str, hw) -> dict:
    compiled = engine.aot_lower(rounds=1).compile()
    hlo = compiled.as_text()
    cost = _cost_dict(compiled)
    summary = hlo_analysis.analyze(hlo)
    rl = roofline.compute_roofline_from_summary(
        arch="fl_round", shape=f"K{K}", mesh_name=mesh, scheme=scheme,
        chips=chips, summary=summary,
        bytes_accessed=float(cost.get("bytes accessed", 0.0)),
        xla_flops=float(cost.get("flops", 0.0)),
        model_flops=0.0, bytes_per_device=0.0, hw=hw)
    return {
        "name": f"roofline_{scheme}_K{K}",
        "us_per_call": 0.0,
        "compute_s": rl.compute_s,
        "memory_s": rl.memory_s,
        "collective_s": rl.collective_s,
        "bottleneck": rl.bottleneck,
        "dot_gflops_per_device": rl.hlo_gflops_per_device,
        "hbm_gbytes_per_device": rl.hlo_gbytes_per_device,
        "collective_gbytes_per_device": rl.collective_gbytes_per_device,
        "collective_counts": {k: v for k, v in rl.collective_counts.items() if v},
        "hw": rl.hw,
        "chips": chips,
        "derived": (f"bottleneck={rl.bottleneck};"
                    f"hbm_GB={rl.hlo_gbytes_per_device:.4f};"
                    f"coll_GB={rl.collective_gbytes_per_device:.6f}"),
    }


def run(quick: bool = False, hw: str = "tpu_v5e"):
    rows = []
    strat = lambda: STRATEGIES["scarlet"](beta=1.5)  # noqa: E731
    for K in QUICK_CLIENT_COUNTS if quick else CLIENT_COUNTS:
        cfg = _cfg(K)
        for scheme, fused in (("scan_perop", False), ("scan_fused", True)):
            eng = ScannedFederatedDistillation(
                dataclasses.replace(cfg, fused_round=fused), strat(),
                cache_duration=4)
            rows.append(_analyze(eng, scheme=scheme, K=K, chips=1,
                                 mesh="single", hw=hw))
        data = best_data_axis(K)
        if data > 1:  # sharded variant only when a mesh exists
            eng = ShardedFederatedDistillation(
                dataclasses.replace(cfg, fused_round=True), strat(),
                cache_duration=4, mesh=f"{data}")
            rows.append(_analyze(eng, scheme="shard_fused", K=K, chips=data,
                                 mesh=f"{data}", hw=hw))
    return rows


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--hw", default="tpu_v5e",
                    choices=sorted(roofline.HW_PRESETS))
    ap.add_argument("--out", default="", help="write BENCH json here")
    args = ap.parse_args()
    rows = run(quick=args.quick, hw=args.hw)
    emit(rows)
    if args.out:
        write_bench(args.out, "engine_roofline", rows, quick=args.quick)


if __name__ == "__main__":
    main()
