"""Paper Fig. 4: entropy control of ERA (temperature T) vs Enhanced ERA
(sharpness beta) on high- and low-entropy soft-labels.

Derived metric: entropy at the operating points + the identity check
(beta=1 recovers input entropy exactly; no T does for both inputs).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks._common import emit, timeit
from repro.core import era

HIGH = jnp.asarray([0.22, 0.20, 0.18, 0.15, 0.10, 0.06, 0.04, 0.03, 0.01, 0.01])
LOW = jnp.asarray([0.82, 0.06, 0.04, 0.03, 0.02, 0.01, 0.01, 0.005, 0.003, 0.002])


def run():
    rows = []
    h_high0 = float(era.entropy(HIGH))
    h_low0 = float(era.entropy(LOW))
    for T in (0.05, 0.1, 0.2, 0.5, 1.0):
        hh = float(era.entropy(era.era(HIGH, T)))
        hl = float(era.entropy(era.era(LOW, T)))
        rows.append({
            "name": f"fig4_era_T{T}",
            "us_per_call": timeit(lambda: era.era(HIGH, T).block_until_ready()),
            "derived": f"H_high={hh:.3f};H_low={hl:.3f};"
                       f"identity_err={abs(hh-h_high0)+abs(hl-h_low0):.3f}",
        })
    for beta in (0.5, 1.0, 1.5, 2.0, 3.0):
        hh = float(era.entropy(era.enhanced_era(HIGH, beta)))
        hl = float(era.entropy(era.enhanced_era(LOW, beta)))
        rows.append({
            "name": f"fig4_enhanced_era_beta{beta}",
            "us_per_call": timeit(
                lambda: era.enhanced_era(HIGH, beta).block_until_ready()),
            "derived": f"H_high={hh:.3f};H_low={hl:.3f};"
                       f"identity_err={abs(hh-h_high0)+abs(hl-h_low0):.3f}",
        })
    return rows


def main():
    emit(run())


if __name__ == "__main__":
    main()
