"""Benchmark driver: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows per benchmark.

  PYTHONPATH=src python -m benchmarks.run            # full suite
  PYTHONPATH=src python -m benchmarks.run --quick    # shorter FL runs
  PYTHONPATH=src python -m benchmarks.run --only fig3,table5
"""
from __future__ import annotations

import argparse
import inspect
import sys
import time
import traceback

from benchmarks import (
    active_bench,
    async_bench,
    codec_pareto,
    engine_bench,
    engine_roofline,
    ext_beyond_paper,
    hetero_bench,
    fig3_cache_sim,
    fig4_era_curves,
    fig5_era_vs_enhanced,
    fig8_comparison,
    fig11_caching_plugin,
    fig12_cache_duration,
    fig13_beta_ablation,
    fig16_partial_participation,
    fig18_convergence_proxy,
    kernels_bench,
    table4_centralized,
    table5_comm_costs,
)
from benchmarks._common import emit

SUITE = {
    "fig3": (fig3_cache_sim, {}),
    "fig4": (fig4_era_curves, {}),
    "table4": (table4_centralized, {}),
    "table5": (table5_comm_costs, {}),
    "fig5": (fig5_era_vs_enhanced, {"rounds": 60}),
    "fig8": (fig8_comparison, {"rounds": 60}),
    "fig11": (fig11_caching_plugin, {"rounds": 60}),
    "fig12": (fig12_cache_duration, {"rounds": 80}),
    "fig13": (fig13_beta_ablation, {"rounds": 50}),
    "fig16": (fig16_partial_participation, {"rounds": 50}),
    "fig18": (fig18_convergence_proxy, {"rounds": 80}),
    "kernels": (kernels_bench, {}),
    "engine": (engine_bench, {}),
    "active": (active_bench, {}),
    "async": (async_bench, {}),
    "engine_roofline": (engine_roofline, {}),
    "codec_pareto": (codec_pareto, {}),
    "hetero": (hetero_bench, {}),
    "ext": (ext_beyond_paper, {"rounds": 80}),
}

# benchmarks whose rows feed the perf-regression gate: --out-dir writes
# their BENCH_<file>.json next to each other (codec_pareto keeps the
# short "codec" document name)
BENCH_FILES = {
    "engine": "engine",
    "kernels": "kernels",
    "codec_pareto": "codec",
    "engine_roofline": "engine_roofline",
    "active": "active",
    "async": "async",
}

QUICK_ROUNDS = 25


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default="")
    ap.add_argument("--out-dir", default="",
                    help="write BENCH_<name>.json per gated benchmark here")
    args = ap.parse_args()

    names = [n.strip() for n in args.only.split(",") if n.strip()] or list(SUITE)
    print("name,us_per_call,derived")
    failures = 0
    for name in names:
        mod, kw = SUITE[name]
        if args.quick and "rounds" in kw:
            kw = {**kw, "rounds": QUICK_ROUNDS}
        # modules with a dedicated smoke mode take quick= directly
        if args.quick and "quick" in inspect.signature(mod.run).parameters:
            kw = {**kw, "quick": True}
        t0 = time.time()
        try:
            rows = mod.run(**kw)
            emit(rows)
            if args.out_dir and name in BENCH_FILES:
                import os

                from benchmarks._common import write_bench

                os.makedirs(args.out_dir, exist_ok=True)
                doc = BENCH_FILES[name]
                write_bench(os.path.join(args.out_dir, f"BENCH_{doc}.json"),
                            doc, rows, quick=args.quick)
            print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"# {name} FAILED:\n{traceback.format_exc()}", file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
