"""Paper Fig. 11: the soft-label caching mechanism as a drop-in for
other SOTA methods (CFD / COMET / Selective-FD), D=25, strong non-IID.
Derived: accuracy delta + communication reduction with cache on/off."""
from __future__ import annotations

from benchmarks._common import default_cfg, emit
from repro.fl.engine import run_method


def run(rounds: int = 60):
    cfg = default_cfg(alpha=0.05, rounds=rounds)
    # paper uses a conservative D=25 over 3000 rounds; scale the staleness
    # horizon to our round budget
    D = max(rounds // 8, 4)
    rows = []
    for method, kw in (("cfd", {}), ("comet", {"n_clusters": 2}),
                       ("selective_fd", {"tau_client": 0.0625})):
        h0 = run_method(method, cfg, **kw)
        h1 = run_method(method, cfg, use_cache=True, cache_duration=D, **kw)
        c0 = h0.ledger.summary()["cumulative_total"]
        c1 = h1.ledger.summary()["cumulative_total"]
        rows.append({
            "name": f"fig11_{method}_cache",
            "us_per_call": 0.0,
            "derived": f"acc_nocache={h0.final_server_acc:.3f};"
                       f"acc_cache={h1.final_server_acc:.3f};"
                       f"comm_reduction={1-c1/c0:.0%}",
        })
    return rows


def main():
    emit(run())


if __name__ == "__main__":
    main()
