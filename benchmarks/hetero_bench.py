"""Heterogeneous client-model cohorts: the paper's motivating workload.

Distillation-based FL exchanges soft-labels, so clients can run
*different architectures* — the central argument for the method family
over parameter sharing (FedMD; Sattler et al.; Itahara et al.).  This
sweep measures what that costs and buys on the synthetic task:

- **cohort mixes** (homogeneous vs 2- and 3-cohort splits around the
  same parameter budget) under SCARLET with the synchronized cache, on
  the scanned engine: final server/per-cohort client accuracy, exact
  communication, and wall-clock.  The ledger columns demonstrate the
  cohort invariant end to end: communication is *identical* across
  mixes, because the wire carries soft-labels whose shape does not
  depend on the client architecture.
- **scan vs shard** on the 3-cohort mix at larger K: the sharded engine
  partitions every cohort block over the mesh "data" axis
  (``best_data_axis`` keeps the sweep portable across device counts),
  so heterogeneous cohorts scale past one chip exactly like homogeneous
  ones.

``--quick`` (via run.py) shrinks rounds/K to CI-smoke sizes.
"""
from __future__ import annotations

import math
import time

from benchmarks._common import emit
from repro.fl import (
    CohortSpec,
    ScannedFederatedDistillation,
    ShardedFederatedDistillation,
    FLConfig,
)
from repro.fl.shard_engine import best_data_axis
from repro.fl.strategies import STRATEGIES

ROUNDS = 40
SHARD_ROUNDS = 10
SHARD_CLIENTS = 48
QUICK_ROUNDS = 6
QUICK_SHARD_CLIENTS = 8


def _cfg(n_clients: int, rounds: int, cohorts=None, **kw) -> FLConfig:
    base = dict(
        n_clients=n_clients, n_classes=10, dim=16, rounds=rounds,
        local_steps=3, distill_steps=3, public_size=600,
        public_per_round=80, private_size=900, alpha=0.05,
        cluster_scale=2.0, noise=2.5, hidden=48, mlp_depth=2,
        eval_every=rounds, seed=0, cohorts=cohorts)
    base.update(kw)
    return FLConfig(**base)


def _mixes(n_clients: int) -> dict:
    """Cohort mixes around the homogeneous (48, 2) parameter budget;
    sizes chosen to divide any test-mesh shard count."""
    a, b = n_clients // 2, n_clients - n_clients // 2
    t = n_clients // 4
    return {
        "homog": None,
        "2cohort": (CohortSpec(a, 64, 2), CohortSpec(b, 32, 1)),
        "3cohort": (CohortSpec(n_clients - 2 * t, 64, 3),
                    CohortSpec(t, 48, 2), CohortSpec(t, 24, 1)),
    }


def _run_timed(engine, rounds: int):
    engine.run(rounds)  # warmup leg: compile once
    t0 = time.perf_counter()
    hist = engine.run(rounds)
    return hist, time.perf_counter() - t0


def run(quick: bool = False):
    rounds = QUICK_ROUNDS if quick else ROUNDS
    n_clients = 8 if quick else 12
    rows = []

    # --- cohort mixes on the scanned engine ---------------------------
    for mix_name, cohorts in _mixes(n_clients).items():
        eng = ScannedFederatedDistillation(
            _cfg(n_clients, rounds, cohorts=cohorts),
            STRATEGIES["scarlet"](beta=1.5), cache_duration=25)
        hist, dt = _run_timed(eng, rounds)
        cacc = "/".join(f"{a:.3f}" for a in hist.cohort_client_acc[-1])
        rows.append(dict(
            name=f"hetero_scan_{mix_name}",
            us_per_call=dt / rounds * 1e6,
            derived=(f"srv_acc={hist.final_server_acc:.3f} "
                     f"cohort_acc={cacc} "
                     f"comm_mb={hist.ledger.cumulative_total / 1e6:.3f} "
                     f"models={eng.models.describe()}")))

    # --- 3-cohort mix: scan vs client-sharded -------------------------
    k_shard = QUICK_SHARD_CLIENTS if quick else SHARD_CLIENTS
    s_rounds = QUICK_ROUNDS if quick else SHARD_ROUNDS
    cohorts = _mixes(k_shard)["3cohort"]
    # the data axis must divide EVERY cohort block, not just K — size it
    # from the gcd of the cohort sizes (device-count-portable)
    d = best_data_axis(math.gcd(*(c.n_clients for c in cohorts)))
    cfg = _cfg(k_shard, s_rounds, cohorts=cohorts, mesh_spec=f"{d}")
    for label, cls in (("scan", ScannedFederatedDistillation),
                       ("shard", ShardedFederatedDistillation)):
        eng = cls(cfg, STRATEGIES["scarlet"](beta=1.5), cache_duration=25)
        hist, dt = _run_timed(eng, s_rounds)
        rows.append(dict(
            name=f"hetero_{label}_K{k_shard}",
            us_per_call=dt / s_rounds * 1e6,
            derived=(f"rounds_per_s={s_rounds / dt:.2f} "
                     f"srv_acc={hist.final_server_acc:.3f} "
                     f"devices={d if label == 'shard' else 1}")))
    return rows


if __name__ == "__main__":
    emit(run())
