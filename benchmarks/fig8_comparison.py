"""Paper Fig. 8 / Fig. 10: accuracy vs cumulative communication for
SCARLET against DS-FL / CFD / COMET / Selective-FD / Individual.
Derived: final server/client accuracy + cumulative MB."""
from __future__ import annotations

from benchmarks._common import default_cfg, emit
from repro.fl.engine import run_method

METHODS = [
    ("scarlet", dict(cache_duration=10, beta=1.5)),
    ("dsfl", dict(T=0.1)),
    ("cfd", dict()),
    ("comet", dict(n_clusters=2)),
    ("selective_fd", dict(tau_client=0.0625)),
    ("individual", dict()),
]


def run(rounds: int = 60, alpha: float = 0.05):
    cfg = default_cfg(alpha=alpha, rounds=rounds)
    rows = []
    for name, kw in METHODS:
        h = run_method(name, cfg, **kw)
        s = h.ledger.summary()
        # individual has no server model: final_server_acc is None
        sa = "n/a" if h.final_server_acc is None else \
            f"{h.final_server_acc:.3f}"
        rows.append({
            "name": f"fig8_{name}_alpha{alpha}",
            "us_per_call": 0.0,
            "derived": f"server_acc={sa};"
                       f"client_acc={h.final_client_acc:.3f};"
                       f"cum_MB={s['cumulative_total']/1e6:.2f};"
                       f"up_KB_rnd={s['uplink_mean']/1e3:.1f}",
        })
    return rows


def main():
    emit(run())


if __name__ == "__main__":
    main()
