"""Paper Fig. 5: aggregation-stability comparison — DS-FL framework with
conventional ERA vs with Enhanced ERA, caching disabled in both, under
strong and moderate non-IID.  Derived: final server accuracy gap."""
from __future__ import annotations

from benchmarks._common import default_cfg, emit, timeit
from repro.fl.engine import run_method


def run(rounds: int = 60):
    rows = []
    for alpha, beta, T in ((0.05, 2.5, 0.1), (0.3, 1.0, 0.2)):
        cfg = default_cfg(alpha=alpha, rounds=rounds)
        h_era = run_method("dsfl", cfg, T=T)
        h_enh = run_method("scarlet", cfg, use_cache=False, beta=beta)
        gap = h_enh.final_server_acc - h_era.final_server_acc
        rows.append({
            "name": f"fig5_alpha{alpha}",
            "us_per_call": 0.0,
            "derived": f"era_acc={h_era.final_server_acc:.3f};"
                       f"enhanced_acc={h_enh.final_server_acc:.3f};"
                       f"gap_pp={100*gap:.1f}",
        })
    return rows


def main():
    emit(run())


if __name__ == "__main__":
    main()
