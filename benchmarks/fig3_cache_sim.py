"""Paper Fig. 3: cache-hit-ratio simulation vs cache duration D (Alg. 3).

Setting matches the paper: |P^t| = 10% of |P| sampled per round.
Derived metric: steady-state hit ratio per D + analytic prediction.
"""
from __future__ import annotations

import numpy as np

from benchmarks._common import emit, timeit
from repro.core.cache_sim import expected_steady_state_hit_rate, simulate_hit_rate


def run():
    P, m, T = 10_000, 1_000, 2_000
    rows = []
    for D in (10, 25, 50, 100, 200, 400, 800):
        us = timeit(lambda: simulate_hit_rate(P, m, D, 200), n=3, warmup=1)
        sim = simulate_hit_rate(P, m, D, T)
        steady = float(sim[T // 2:].mean())
        analytic = expected_steady_state_hit_rate(P, m, D)
        rows.append({
            "name": f"fig3_cache_sim_D{D}",
            "us_per_call": us,
            "derived": f"steady_hit={steady:.3f};analytic={analytic:.3f};"
                       f"comm_saving={steady:.0%}",
        })
    return rows


def main():
    emit(run())


if __name__ == "__main__":
    main()
