"""Shared tracing machinery for the static analyzer.

Everything here operates on :func:`jax.make_jaxpr` output — functions
are traced on ``ShapeDtypeStruct`` arguments and never executed, so the
passes are cheap enough for CI and cannot be fooled by lucky concrete
inputs.
"""
from __future__ import annotations

import contextlib
from typing import Iterator, List, Optional, Tuple

import jax
import numpy as np

# Primitives that escape the traced graph back to the host: fatal inside
# lax.scan (the scan-safe contract) and invisible to AOT cost models.
CALLBACK_PRIMITIVES = frozenset({
    "pure_callback", "io_callback", "debug_callback", "callback",
    "host_callback_call", "outside_call",
})


def subjaxprs(eqn) -> Iterator[jax.core.Jaxpr]:
    """Immediate sub-jaxprs of one equation (scan/cond/while/pjit/...)."""
    for v in eqn.params.values():
        for x in (v if isinstance(v, (tuple, list)) else [v]):
            if isinstance(x, jax.core.ClosedJaxpr):
                yield x.jaxpr
            elif isinstance(x, jax.core.Jaxpr):
                yield x


def iter_eqns(jaxpr: jax.core.Jaxpr):
    """Every equation in ``jaxpr``, recursing through sub-jaxprs."""
    for e in jaxpr.eqns:
        yield e
        for sub in subjaxprs(e):
            yield from iter_eqns(sub)


def primitive_names(jaxpr: jax.core.Jaxpr) -> set:
    return {e.primitive.name for e in iter_eqns(jaxpr)}


def find_eqns(jaxpr: jax.core.Jaxpr, name: str) -> List:
    return [e for e in iter_eqns(jaxpr) if e.primitive.name == name]


@contextlib.contextmanager
def record_host_rng(record: List[str]):
    """Monkeypatch the ``np.random`` constructors for the duration of a
    trace: host RNG draws are invisible in the jaxpr (numpy runs at
    trace time and bakes constants in), so the only reliable static
    detector is catching the constructor call itself."""
    orig_rng, orig_rs = np.random.default_rng, np.random.RandomState

    def spy_rng(*a, **k):
        record.append("np.random.default_rng")
        return orig_rng(*a, **k)

    def spy_rs(*a, **k):
        record.append("np.random.RandomState")
        return orig_rs(*a, **k)

    np.random.default_rng, np.random.RandomState = spy_rng, spy_rs
    try:
        yield record
    finally:
        np.random.default_rng, np.random.RandomState = orig_rng, orig_rs


class TraceResult:
    """Outcome of one abstract trace: the jaxpr (or the exception) plus
    what the host-side spies observed."""

    def __init__(self, jaxpr, error: Optional[BaseException],
                 host_rng: List[str]):
        self.jaxpr = jaxpr
        self.error = error
        self.host_rng = host_rng

    @property
    def ok(self) -> bool:
        return self.error is None

    @property
    def callbacks(self) -> set:
        if self.jaxpr is None:
            return set()
        return primitive_names(self.jaxpr.jaxpr) & CALLBACK_PRIMITIVES

    def scan_safety_violations(self) -> List[str]:
        """Why this trace is NOT scan-safe (empty list = safe)."""
        out = []
        if self.error is not None:
            out.append(f"trace failed: {type(self.error).__name__}: "
                       f"{_first_line(self.error)}")
        if self.callbacks:
            out.append(f"host callback primitives in graph: "
                       f"{sorted(self.callbacks)}")
        if self.host_rng:
            out.append(f"host numpy RNG constructed during trace: "
                       f"{sorted(set(self.host_rng))}")
        return out


def _first_line(exc: BaseException) -> str:
    return str(exc).strip().splitlines()[0][:200] if str(exc) else ""


def trace(fn, *args) -> TraceResult:
    """Trace ``fn`` on abstract args, capturing failure + host RNG use."""
    rec: List[str] = []
    with record_host_rng(rec):
        try:
            jaxpr = jax.make_jaxpr(fn)(*args)
        except Exception as e:  # noqa: BLE001 — any trace failure is data
            return TraceResult(None, e, rec)
    return TraceResult(jaxpr, None, rec)
