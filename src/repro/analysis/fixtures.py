"""Deliberately broken components for analyzer self-tests.

Never registered anywhere — these exist so ``python -m repro.analysis
--selftest`` (and ``tests/test_analysis.py``) can prove each pass
actually fires: a silent analyzer that flags nothing is
indistinguishable from a working one on a healthy repo.

One fixture per bug class the analyzer exists to catch:

- :class:`CallbackSmugglerStrategy` — claims ``scan_safe`` with a host
  callback in the aggregation graph;
- :class:`HostRNGStrategy` — claims ``scan_safe`` while constructing a
  host numpy Generator mid-trace (invisible in the jaxpr: only the
  constructor spy catches it);
- :class:`StaleFlagStrategy` — pure jnp but declares
  ``scan_safe=False`` (the stale-conservative-flag warning);
- :class:`FalseFusedStrategy` — advertises ``supports_fused_round``
  without implementing the fused hooks;
- :func:`broken_kernel_cases` — Pallas entry points with a misaligned
  row block, a scalar parameter in VMEM, and a VMEM-overflowing block;
- :func:`broken_carry_fn` / :func:`fixed_carry_fn` — a shard_map whose
  replicated-carry claim is violated by ``axis_index`` taint (the PR 5
  ``last_sync`` bug, distilled) and its repaired twin;
- :func:`telemetry_callback_engine` — a telemetry-enabled scan engine
  whose ``telemetry_hook`` smuggles a ``jax.debug.callback`` into the
  round body (the "just log it from the hook" mistake that would turn
  the single-compilation engine into a per-round host round-trip);
- :func:`leaky_active_engine` — an active-set engine whose gathered
  O(m) client step folds the device-resident ``(K,)`` ``last_sync``
  mirror into a cost term (weighted by 0.0, so every K = 100 numeric
  test still passes) — the exact leak the K-separation pass exists to
  catch before it voids the O(m) device-memory claim at K = 10^6;
- :func:`async_staleness_callback_engine` — an async engine whose
  strategy overrides ``staleness_weight`` with a ``jax.pure_callback``
  (the "compute the decay curve in numpy" mistake): numerically
  correct, but it plants a host round-trip inside the scanned round
  body.  Built with ``staleness_decay != 1`` so the engine cannot
  statically skip the hook.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.sharding import PartitionSpec as P

from repro.fl.strategies.base import Strategy


class CallbackSmugglerStrategy(Strategy):
    name = "fixture_callback_smuggler"
    scan_safe = True  # LIE: aggregate_masked escapes to the host

    def aggregate(self, z, um, t):
        return jnp.mean(z, axis=0), None

    def aggregate_masked(self, z, part, um, t):
        out = jax.ShapeDtypeStruct(z.shape[1:], z.dtype)
        return jax.pure_callback(
            lambda zz: np.mean(zz, axis=0).astype(zz.dtype), out, z)


class HostRNGStrategy(Strategy):
    name = "fixture_host_rng"
    scan_safe = True  # LIE: transmit draws from host numpy RNG

    def transmit(self, z, key=None):
        # numpy array + tracer broadcasts fine, so the TRACE SUCCEEDS
        # and the jaxpr looks pure — the draw is baked in as a constant
        # and every scan iteration reuses it (wrong), which only the
        # constructor spy can see statically.
        noise = np.random.default_rng(0).normal(0.0, 1e-3, (1,))
        return z + jnp.float32(noise[0])

    def aggregate(self, z, um, t):
        return jnp.mean(z, axis=0), None


class StaleFlagStrategy(Strategy):
    name = "fixture_stale_flag"
    scan_safe = False  # stale: everything below is pure traceable jnp

    def aggregate(self, z, um, t):
        return jnp.mean(z, axis=0), None


class FalseFusedStrategy(Strategy):
    name = "fixture_false_fused"
    scan_safe = True
    supports_fused_round = True  # LIE: fused hooks are not implemented

    def aggregate(self, z, um, t):
        return jnp.mean(z, axis=0), None


BROKEN_STRATEGIES = {
    "fixture_callback_smuggler": CallbackSmugglerStrategy,
    "fixture_host_rng": HostRNGStrategy,
    "fixture_stale_flag": StaleFlagStrategy,
    "fixture_false_fused": FalseFusedStrategy,
}

# level the jaxpr pass must emit for each broken strategy
EXPECTED_STRATEGY_LEVEL = {
    "fixture_callback_smuggler": "error",
    "fixture_host_rng": "error",
    "fixture_stale_flag": "warn",
    "fixture_false_fused": "error",
}


# ---------------------------------------------------------------------------
# Pallas fixtures
# ---------------------------------------------------------------------------

def _copy_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def _scale_kernel(x_ref, s_ref, o_ref):
    o_ref[...] = x_ref[...] * s_ref[0]


def _misaligned(x):
    # 10-row blocks over f32: interprets fine, mis-tiles natively
    return pl.pallas_call(
        _copy_kernel, grid=(10,),
        in_specs=[pl.BlockSpec((10, 128), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((10, 128), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((100, 128), jnp.float32),
        interpret=False)(x)


def _vmem_scalar(x, s):
    # the scalar rides in VMEM instead of SMEM
    return pl.pallas_call(
        _scale_kernel, grid=(2,),
        in_specs=[pl.BlockSpec((8, 128), lambda i: (i, 0)),
                  pl.BlockSpec((1,), lambda i: (0,))],
        out_specs=pl.BlockSpec((8, 128), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((16, 128), jnp.float32),
        interpret=False)(x, s)


def _vmem_hog(x):
    # 16 MiB in + 16 MiB out per block: cannot fit a core's VMEM
    return pl.pallas_call(
        _copy_kernel, grid=(1,),
        in_specs=[pl.BlockSpec((4096, 1024), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((4096, 1024), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((4096, 1024), jnp.float32),
        interpret=False)(x)


def broken_kernel_cases():
    """(label, fn, abstract args, expected level) for the Pallas lint."""
    S, f32 = jax.ShapeDtypeStruct, jnp.float32
    return [
        ("fixture/misaligned-rows", _misaligned,
         (S((100, 128), f32),), "error"),
        ("fixture/scalar-in-vmem", _vmem_scalar,
         (S((16, 128), f32), S((1,), f32)), "error"),
        ("fixture/vmem-hog", _vmem_hog,
         (S((4096, 1024), f32),), "error"),
    ]


def analysis_cases():
    """Same triples without the expectation, matching the kernel-module
    protocol so the fixture file can be linted like a real module."""
    return [(label, fn, args) for label, fn, args, _ in broken_kernel_cases()]


# ---------------------------------------------------------------------------
# Telemetry fixtures
# ---------------------------------------------------------------------------

def telemetry_callback_engine():
    """A telemetry-enabled scan engine whose hook escapes to the host.

    The hook looks innocent — it returns the row unchanged — but the
    ``jax.debug.callback`` it calls plants a callback primitive inside
    the compiled round body.  ``repro.analysis.obs_checks.
    check_round_body`` must flag it as an error.
    """
    from repro.fl.config import FLConfig
    from repro.fl.scan_engine import ScannedFederatedDistillation
    from repro.fl.strategies import STRATEGIES

    cfg = FLConfig(n_clients=4, rounds=2, public_size=32, public_per_round=8,
                   n_classes=4, dim=8, hidden=8, private_size=32,
                   local_steps=1, distill_steps=1, seed=0, telemetry=True)
    eng = ScannedFederatedDistillation(cfg, STRATEGIES["mean"]())

    def leaky_hook(tel, t):
        jax.debug.callback(lambda h: None, tel.cache_hits)
        return tel

    eng.telemetry_hook = leaky_hook
    return eng


# ---------------------------------------------------------------------------
# Active-set fixtures
# ---------------------------------------------------------------------------

def leaky_active_engine():
    """An active-set engine whose O(m) client step touches O(K) state.

    The leak is numerically invisible — ``0.0 * sum(last_sync)`` — so
    every conformance cell still passes bit-exactly, but the compiled
    client step now closes over a ``(K,)`` array and device cost scales
    with the population again.  ``repro.analysis.active_checks.
    check_engine`` must flag it as an error.
    """
    from repro.analysis.active_checks import analysis_config
    from repro.fl.active_engine import ActiveSetFederatedDistillation
    from repro.fl.scenarios import Scenario, bernoulli_participation
    from repro.fl.strategies import STRATEGIES

    class LeakyActiveEngine(ActiveSetFederatedDistillation):
        def _client_step(self, args):
            out = super()._client_step(args)
            out["uplink"] = out["uplink"] + 0.0 * jnp.sum(
                self._get_last_sync_dev().astype(jnp.float32))
            return out

    return LeakyActiveEngine(
        analysis_config(), STRATEGIES["scarlet"](), cache_duration=2,
        scenario=Scenario(participation=bernoulli_participation(0.3)))


# ---------------------------------------------------------------------------
# Async-engine fixtures
# ---------------------------------------------------------------------------

def async_staleness_callback_engine():
    """An async engine whose staleness hook escapes to the host.

    The override is numerically identical to the stock exponential
    decay — it just computes it via ``jax.pure_callback`` — so every
    metric test passes, but the compiled round body now carries a
    callback primitive.  ``staleness_decay=0.5`` keeps the hook
    on-path (unit decay is statically skipped).
    ``repro.analysis.async_checks.check_engine`` must flag it as an
    error.
    """
    from repro.analysis.async_checks import analysis_config
    from repro.fl.async_engine import AsyncFederatedDistillation
    from repro.fl.strategies import EnhancedERAStrategy
    from repro.fl.traffic import ArrivalProcess, LatencyModel, TrafficModel

    class CallbackStalenessStrategy(EnhancedERAStrategy):
        name = "fixture_callback_staleness"

        def staleness_weight(self, staleness):
            decay = float(self.opts.get("staleness_decay", 1.0))
            out = jax.ShapeDtypeStruct(jnp.shape(staleness), jnp.float32)
            return jax.pure_callback(
                lambda s: (decay ** np.asarray(s, np.float32)).astype(
                    np.float32), out, staleness)

    traffic = TrafficModel(arrivals=ArrivalProcess("poisson", rate=1.5),
                           latency=LatencyModel("uniform", lo=0, hi=2))
    return AsyncFederatedDistillation(
        analysis_config(), CallbackStalenessStrategy(staleness_decay=0.5),
        traffic=traffic, cache_duration=2)


# ---------------------------------------------------------------------------
# Replication fixtures
# ---------------------------------------------------------------------------

def _shard_map():
    try:
        from jax import shard_map as f
    except ImportError:
        from jax.experimental.shard_map import shard_map as f
    return f


def _fixture_mesh():
    from repro.launch.mesh import make_test_mesh
    return make_test_mesh(2, 4)


def broken_carry_fn():
    """The PR 5 ``last_sync`` bug, distilled: a carry leaf declared
    replicated (out_specs P()) whose update is keyed on the shard-local
    participation slice — shards disagree after one round."""
    mesh = _fixture_mesh()

    def body(last_sync, t):
        six = jax.lax.axis_index("data")
        kloc = last_sync.shape[0]
        part_local = (jnp.arange(kloc) + t + six) % 2 > 0  # shard-varying
        return jnp.where(part_local, t, last_sync)

    fn = _shard_map()(body, mesh=mesh, in_specs=(P(), P()),
                      out_specs=P(), check_rep=False)
    abstract = (jax.ShapeDtypeStruct((8,), jnp.int32),
                jax.ShapeDtypeStruct((), jnp.int32))
    return fn, abstract


def fixed_carry_fn():
    """The repaired twin: the shard-varying signal is psum'd over the
    mesh before touching the replicated carry."""
    mesh = _fixture_mesh()

    def body(last_sync, t):
        six = jax.lax.axis_index("data")
        kloc = last_sync.shape[0]
        part_local = (jnp.arange(kloc) + t + six) % 2 > 0
        # reduce to a replicated global view before the carry update
        part_global = jax.lax.psum(
            part_local.astype(jnp.int32), ("data", "model")) > 0
        return jnp.where(part_global, t, last_sync)

    fn = _shard_map()(body, mesh=mesh, in_specs=(P(), P()),
                      out_specs=P(), check_rep=False)
    abstract = (jax.ShapeDtypeStruct((8,), jnp.int32),
                jax.ShapeDtypeStruct((), jnp.int32))
    return fn, abstract
