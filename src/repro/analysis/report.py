"""Structured findings for the static analyzer (jax-free module).

Severity ladder:

``error``  a declared contract is provably violated — the build fails;
``warn``   suspicious but not provably wrong (e.g. a stale conservative
           flag, an over-budget VMEM block) — fails under ``--strict``;
``info``   observations with no action required (sub-128 lane dims on
           small class counts, interpreter-path cases);
``ok``     a contract that was checked and held (kept in the report so
           "pass" is distinguishable from "never ran").
"""
from __future__ import annotations

import json
from dataclasses import asdict, dataclass, field
from typing import Dict, List

LEVELS = ("error", "warn", "info", "ok")


@dataclass
class Finding:
    level: str           # one of LEVELS
    pass_name: str       # "jaxpr" | "replication" | "pallas" | ...
    subject: str         # what was checked ("strategy:scarlet", "era/B10-N10")
    message: str

    def __post_init__(self):
        if self.level not in LEVELS:
            raise ValueError(f"unknown level {self.level!r}")

    def __str__(self):
        return f"[{self.level.upper():5s}] {self.pass_name}: {self.subject}: {self.message}"


@dataclass
class Report:
    findings: List[Finding] = field(default_factory=list)

    def add(self, level: str, pass_name: str, subject: str, message: str):
        self.findings.append(Finding(level, pass_name, subject, message))

    def extend(self, findings):
        self.findings.extend(findings)

    def counts(self) -> Dict[str, int]:
        c = {lv: 0 for lv in LEVELS}
        for f in self.findings:
            c[f.level] += 1
        return c

    @property
    def errors(self) -> List[Finding]:
        return [f for f in self.findings if f.level == "error"]

    @property
    def warnings(self) -> List[Finding]:
        return [f for f in self.findings if f.level == "warn"]

    def exit_code(self, strict: bool = False) -> int:
        """Nonzero on any error; under ``--strict`` warnings fail too."""
        if self.errors:
            return 1
        if strict and self.warnings:
            return 1
        return 0

    def render(self, verbose: bool = False) -> str:
        """Human-readable report; ``verbose`` includes ok/info lines."""
        shown = [f for f in self.findings
                 if verbose or f.level in ("error", "warn")]
        lines = [str(f) for f in shown]
        c = self.counts()
        lines.append("analysis: {error} error(s), {warn} warning(s), "
                     "{info} info, {ok} ok".format(**c))
        return "\n".join(lines)

    def to_dict(self) -> Dict:
        return {"findings": [asdict(f) for f in self.findings],
                "counts": self.counts()}

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=2)
