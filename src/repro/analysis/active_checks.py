"""Pass 5: active-set engine contracts (O(m)/O(K) separation).

The active-set engine (:mod:`repro.fl.active_engine`) promises two
structural properties that nothing at runtime checks:

1. **Scan safety** of both jitted round-body steps — the O(K)
   bookkeeping step and the O(m) gathered client step must stay free
   of host callbacks and host RNG.  (They run under ``jax.jit``, not
   ``lax.scan``, but the same contract is what keeps each round a
   fixed small number of device launches.)
2. **K-separation**: the gathered client step's jaxpr must contain
   **no K-sized array** — neither as an argument nor as a closed-over
   constant nor as an intermediate.  One leaked ``(K,)`` operand (say,
   the device ``last_sync`` mirror folded into a cost expression) and
   the "device memory independent of K" claim is silently void at
   K = 10^6 while every K = 100 test still passes.  The bookkeeping
   step, conversely, MUST mention K — tracing the wrong function would
   otherwise vacuously "prove" the property.

The analysis engine uses a **prime** population (K = 193) so no other
dimension — public subset, class count, hidden width, power-of-two
gather capacity — can collide with K and false-positive the scan.

Everything is trace-only (``jax.make_jaxpr`` on shapes): no rounds run.
"""
from __future__ import annotations

from typing import List

from repro.analysis.report import Finding

# prime, so gather capacities (powers of two), data dims, and public
# sizes can never equal it by coincidence
K_ANALYSIS = 193

# (label, strategy, strategy kwargs, engine kwargs, uplink codec):
# cover cache-on/off and the delta+quant codec path — the cache arrays
# are O(|P|) and must stay legal inside the client step while the
# O(K) bookkeeping stays out
ANALYSIS_VARIANTS = (
    ("scarlet", "scarlet", {}, {"cache_duration": 2}, "identity"),
    ("scarlet+cache_delta+quant8", "scarlet", {}, {"cache_duration": 2},
     "cache_delta+quant8"),
    ("dsfl", "dsfl", {}, {}, "identity"),
)


def analysis_config(codec: str = "identity"):
    from repro.fl.config import FLConfig

    return FLConfig(
        n_clients=K_ANALYSIS, rounds=2, public_size=32, public_per_round=8,
        n_classes=4, dim=8, hidden=8, private_size=2 * K_ANALYSIS,
        local_steps=1, distill_steps=1, seed=0, partition="uniform",
        uplink_codec=codec)


def build_engine(strategy: str, strat_kw: dict, eng_kw: dict, codec: str):
    from repro.fl.active_engine import ActiveSetFederatedDistillation
    from repro.fl.scenarios import Scenario, bernoulli_participation
    from repro.fl.strategies import STRATEGIES

    return ActiveSetFederatedDistillation(
        analysis_config(codec), STRATEGIES[strategy](**strat_kw),
        scenario=Scenario(participation=bernoulli_participation(0.3)),
        **eng_kw)


def _avals(jaxpr) -> list:
    """Every aval in the jaxpr: top-level binders + all equation vars,
    recursing through sub-jaxprs."""
    from repro.analysis import traceutil

    out = list(jaxpr.invars) + list(jaxpr.constvars) + list(jaxpr.outvars)
    for eqn in traceutil.iter_eqns(jaxpr):
        out.extend(eqn.invars)
        out.extend(eqn.outvars)
    return [v.aval for v in out if hasattr(v, "aval")]


def _k_dimensioned(jaxpr, K: int) -> List[str]:
    """Distinct shapes in the jaxpr with a K-sized dimension."""
    hits = set()
    for aval in _avals(jaxpr):
        shape = tuple(getattr(aval, "shape", ()))
        if K in shape:
            hits.add(str(shape))
    return sorted(hits)


def check_engine(subject: str, eng) -> List[Finding]:
    """Trace both round-body steps of one active engine: scan safety on
    each, K absent from the client step, K present in bookkeeping."""
    import jax
    import jax.numpy as jnp

    from repro.analysis import traceutil

    K = eng.cfg.n_clients
    findings: List[Finding] = []
    for label, fn, args in eng.active_round_fns():
        abstract = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(jnp.shape(x), jnp.result_type(x)),
            args)
        tr = traceutil.trace(fn, *abstract)
        for v in tr.scan_safety_violations():
            findings.append(Finding("error", "active", f"{subject}/{label}", v))
        if tr.jaxpr is None:
            continue
        hits = _k_dimensioned(tr.jaxpr.jaxpr, K)
        if label == "client-step" and hits:
            findings.append(Finding(
                "error", "active", f"{subject}/{label}",
                f"K-sized arrays (K={K}) inside the gathered O(m) client "
                f"step: {hits} — O(K) bookkeeping leaked into the per-round "
                "device hot path, so device cost scales with the population "
                "again"))
        if label == "bookkeeping" and not hits:
            findings.append(Finding(
                "error", "active", f"{subject}/{label}",
                f"bookkeeping step mentions no K-sized array (K={K}) — the "
                "K-separation check is tracing the wrong function and "
                "proves nothing"))
    if not findings:
        findings.append(Finding(
            "ok", "active", subject,
            f"both round-body steps scan-safe; no K={K} array in the "
            "gathered client step (bookkeeping carries the O(K) state)"))
    return findings


def run() -> List[Finding]:
    findings: List[Finding] = []
    for label, strategy, strat_kw, eng_kw, codec in ANALYSIS_VARIANTS:
        eng = build_engine(strategy, strat_kw, eng_kw, codec)
        findings.extend(check_engine(f"active[{label}]", eng))
    return findings
