"""CLI: ``python -m repro.analysis [--strict] [--fast] [--selftest]``.

Runs the static passes over the real registries and prints a
structured report.  Exit code: nonzero on any error; ``--strict`` also
fails on warnings.  ``--selftest`` instead runs the passes over the
deliberately broken fixtures and fails unless every one is flagged at
its expected level.

Everything is trace-only (``jax.make_jaxpr`` on abstract shapes): no
kernels execute, no training runs.
"""
from __future__ import annotations

import argparse
import os
import sys


def _ensure_devices(n: int = 8) -> None:
    """The replication pass builds a 2x4 test mesh; give the CPU backend
    enough host devices BEFORE jax initializes (same flag the test
    suite's conftest forces)."""
    flag = f"--xla_force_host_platform_device_count={n}"
    prev = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in prev:
        os.environ["XLA_FLAGS"] = (prev + " " + flag).strip()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static contract analyzer (trace-time proofs)")
    ap.add_argument("--strict", action="store_true",
                    help="warnings also fail the build")
    ap.add_argument("--fast", action="store_true",
                    help="skip the engine-construction replication pass "
                         "(jaxpr + pallas only; suits tier-1 CI)")
    ap.add_argument("--selftest", action="store_true",
                    help="run the passes over the broken fixtures and "
                         "verify each is flagged")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write the structured report as JSON")
    ap.add_argument("-v", "--verbose", action="store_true",
                    help="include ok/info findings in the printed report")
    args = ap.parse_args(argv)

    _ensure_devices()
    # imports AFTER the device flag: repro.analysis.__init__ is jax-free
    from repro.analysis.report import Report

    report = Report()
    if args.selftest:
        rc = _selftest(report, fast=args.fast)
        print(report.render(verbose=True))
        if args.json:
            _dump(report, args.json)
        return rc

    from repro.analysis import jaxpr_checks, pallas_checks

    report.extend(jaxpr_checks.run())
    report.extend(pallas_checks.run())
    if not args.fast:
        from repro.analysis import (
            active_checks,
            async_checks,
            obs_checks,
            replication_checks,
        )
        report.extend(obs_checks.run())
        report.extend(replication_checks.run())
        report.extend(active_checks.run())
        report.extend(async_checks.run())
    print(report.render(verbose=args.verbose))
    if args.json:
        _dump(report, args.json)
    return report.exit_code(strict=args.strict)


def _dump(report, path: str) -> None:
    with open(path, "w") as f:
        f.write(report.to_json())


def _selftest(report, fast: bool = False) -> int:
    """Every broken fixture must be flagged at its expected level."""
    from repro.analysis import fixtures, jaxpr_checks, pallas_checks
    from repro.analysis.report import Report

    failures = []

    # strategies: each must yield >= 1 finding at the expected level
    for name, ctor in fixtures.BROKEN_STRATEGIES.items():
        want = fixtures.EXPECTED_STRATEGY_LEVEL[name]
        got = jaxpr_checks.check_strategy(name, ctor)
        hit = [f for f in got if f.level == want]
        if hit:
            report.add("ok", "selftest", name,
                       f"flagged as expected ({want}): {hit[0].message}")
        else:
            failures.append(name)
            report.add("error", "selftest", name,
                       f"NOT flagged at level {want!r} "
                       f"(got {[f.level for f in got]})")

    # pallas fixtures
    for label, fn, fargs, want in fixtures.broken_kernel_cases():
        got = pallas_checks.check_case(label, fn, fargs)
        hit = [f for f in got if f.level == want]
        if hit:
            report.add("ok", "selftest", label,
                       f"flagged as expected ({want}): {hit[0].message}")
        else:
            failures.append(label)
            report.add("error", "selftest", label,
                       f"NOT flagged at level {want!r} "
                       f"(got {[f.level for f in got]})")

    # telemetry fixture: a hook that smuggles a debug_callback into the
    # instrumented round body must be caught by the obs pass
    if not fast:
        from repro.analysis import obs_checks
        got = obs_checks.check_round_body(
            "fixture/telemetry-callback", fixtures.telemetry_callback_engine())
        hit = [f for f in got if f.level == "error"]
        if hit:
            report.add("ok", "selftest", "fixture/telemetry-callback",
                       f"flagged as expected: {hit[0].message}")
        else:
            failures.append("fixture/telemetry-callback")
            report.add("error", "selftest", "fixture/telemetry-callback",
                       "debug_callback-smuggling telemetry hook NOT flagged")

    # active-set fixture: the numerically invisible O(K) leak into the
    # gathered O(m) client step must be caught by the K-separation pass,
    # and the real engine must pass (no false positive)
    if not fast:
        from repro.analysis import active_checks
        got = active_checks.check_engine(
            "fixture/active-k-leak", fixtures.leaky_active_engine())
        hit = [f for f in got if f.level == "error"]
        if hit:
            report.add("ok", "selftest", "fixture/active-k-leak",
                       f"flagged as expected: {hit[0].message}")
        else:
            failures.append("fixture/active-k-leak")
            report.add("error", "selftest", "fixture/active-k-leak",
                       "O(K) state leaked into the client step NOT flagged")
        clean = active_checks.run()
        bad = [f for f in clean if f.level == "error"]
        if bad:
            failures.append("fixture/active-clean")
            report.add("error", "selftest", "fixture/active-clean",
                       "real active engine falsely flagged: " + bad[0].message)
        else:
            report.add("ok", "selftest", "fixture/active-clean",
                       "real active engines pass (no false positive)")

    # async fixture: a staleness hook that smuggles a pure_callback into
    # the scanned round body must be caught by the async pass, and the
    # real async engines must pass (no false positive)
    if not fast:
        from repro.analysis import async_checks
        got = async_checks.check_engine(
            "fixture/async-staleness-callback",
            fixtures.async_staleness_callback_engine())
        hit = [f for f in got if f.level == "error"]
        if hit:
            report.add("ok", "selftest", "fixture/async-staleness-callback",
                       f"flagged as expected: {hit[0].message}")
        else:
            failures.append("fixture/async-staleness-callback")
            report.add("error", "selftest", "fixture/async-staleness-callback",
                       "pure_callback-smuggling staleness hook NOT flagged")
        clean = async_checks.run()
        bad = [f for f in clean if f.level == "error"]
        if bad:
            failures.append("fixture/async-clean")
            report.add("error", "selftest", "fixture/async-clean",
                       "real async engine falsely flagged: " + bad[0].message)
        else:
            report.add("ok", "selftest", "fixture/async-clean",
                       "real async engines pass (no false positive)")

    # replication fixtures (skipped under --fast: needs the 8-device mesh)
    if not fast:
        from repro.analysis import replication_checks
        broken = Report()
        broken.extend(replication_checks.check_shard_map_fn(
            *fixtures.broken_carry_fn(), subject_prefix="fixture-broken:"))
        if broken.errors:
            report.add("ok", "selftest", "fixture/broken-carry",
                       f"flagged as expected: {broken.errors[0].message}")
        else:
            failures.append("fixture/broken-carry")
            report.add("error", "selftest", "fixture/broken-carry",
                       "axis_index-tainted replicated carry NOT flagged")
        fixed = Report()
        fixed.extend(replication_checks.check_shard_map_fn(
            *fixtures.fixed_carry_fn(), subject_prefix="fixture-fixed:"))
        if fixed.errors:
            failures.append("fixture/fixed-carry")
            report.add("error", "selftest", "fixture/fixed-carry",
                       "psum-cleaned carry falsely flagged: "
                       + fixed.errors[0].message)
        else:
            report.add("ok", "selftest", "fixture/fixed-carry",
                       "psum-cleaned twin passes (no false positive)")

    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
