"""Static contract analyzer: prove declared invariants at trace time.

Three passes, one CLI (``python -m repro.analysis``):

``jaxpr_checks``
    traces every registered strategy hook and codec ``encode``/
    ``decode`` on abstract shapes (:func:`jax.make_jaxpr` over
    ``ShapeDtypeStruct`` — nothing executes) and diffs the traced
    reality against the declared contract flags (``scan_safe``,
    ``supports_fused_round``, ``codec_kernel_spec``).

``replication_checks``
    walks the shard engine's one-round ``shard_map`` jaxpr tracking
    ``axis_index`` / sharded-input taint to prove every carry leaf the
    out_specs declare replicated really is replicated over non-client
    mesh axes (the engine runs ``check_rep=False``, so nothing else
    checks this — the PR 5 ``last_sync`` bug class).

``pallas_checks``
    lints every kernel entry point's native BlockSpecs (via each kernel
    module's ``analysis_cases()``): sublane-aligned row blocks, SMEM
    scalar operands, per-block VMEM footprint within budget.

This ``__init__`` stays import-light (no jax): ``__main__`` must set
``XLA_FLAGS`` before anything pulls jax in.
"""
from __future__ import annotations

from repro.analysis.report import Finding, Report

__all__ = ["Finding", "Report"]
