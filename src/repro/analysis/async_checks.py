"""Pass 6: async-engine contracts (scan safety under traffic).

The async engine (:mod:`repro.fl.async_engine`) extends the scanned
round body with dispatch/arrival bookkeeping and an open extension
point — ``Strategy.staleness_weight`` — that experiments override to
decay late reports.  Two things must stay true, and nothing at runtime
checks either:

1. **Scan safety**: the async round body (including the staleness
   hook and, when enabled, the telemetry instrumentation) must stay
   free of host-callback primitives and host RNG.  The traffic model
   itself is host-side *by design* — it precompiles to fixed-shape
   ``(T, K)`` arrays before the scan — so the compiled body must not
   re-import any of it.  One ``pure_callback`` smuggled through
   ``staleness_weight`` and the single-compilation engine silently
   becomes a per-round host round-trip.
2. **Hook reachability**: with ``staleness_decay != 1`` the hook's
   arithmetic must actually appear in the traced graph.  The engine
   statically skips the hook at unit decay (part of the zero-delay
   byte-identity contract), so a trace that never reaches the hook
   would vacuously "prove" any override safe.  This pass traces a
   decayed variant precisely so the hook is on-path.

Everything is trace-only (``jax.make_jaxpr`` on abstract shapes): no
rounds run.
"""
from __future__ import annotations

from typing import List

from repro.analysis.report import Finding

# (label, strategy, strategy kwargs, engine kwargs, uplink codec):
# cover the decayed-staleness hook on-path, the unit-decay statically
# skipped path, cache on/off, the delta+quant codec path, and a
# telemetry-instrumented body (the staleness histogram rides there)
ANALYSIS_VARIANTS = (
    ("scarlet", "scarlet", {}, {"cache_duration": 2}, "identity", False),
    ("scarlet+decay", "scarlet", {"staleness_decay": 0.5},
     {"cache_duration": 2}, "identity", False),
    ("scarlet+cache_delta+quant8", "scarlet", {}, {"cache_duration": 2},
     "cache_delta+quant8", False),
    ("scarlet+decay+telemetry", "scarlet", {"staleness_decay": 0.5},
     {"cache_duration": 2}, "identity", True),
    ("dsfl", "dsfl", {}, {}, "identity", False),
)


def analysis_config(codec: str = "identity", telemetry: bool = False):
    from repro.fl.config import FLConfig

    return FLConfig(n_clients=4, rounds=2, public_size=32, public_per_round=8,
                    n_classes=4, dim=8, hidden=8, private_size=32,
                    local_steps=1, distill_steps=1, seed=0,
                    uplink_codec=codec, telemetry=telemetry)


def build_engine(strategy: str, strat_kw: dict, eng_kw: dict, codec: str,
                 telemetry: bool = False):
    from repro.fl.async_engine import AsyncFederatedDistillation
    from repro.fl.strategies import STRATEGIES
    from repro.fl.traffic import ArrivalProcess, LatencyModel, TrafficModel

    # a genuinely asynchronous model: Poisson arrivals, 0-2 window
    # report latency — the compiled body must handle in-flight state
    traffic = TrafficModel(arrivals=ArrivalProcess("poisson", rate=1.5),
                           latency=LatencyModel("uniform", lo=0, hi=2))
    return AsyncFederatedDistillation(
        analysis_config(codec, telemetry), STRATEGIES[strategy](**strat_kw),
        traffic=traffic, **eng_kw)


def _round_abstract(eng):
    """Abstract (carry, xs) for one async ``_round_device`` invocation.

    xs is the async 5-tuple: (t, offline, do_eval, available, delay).
    """
    import jax
    import jax.numpy as jnp

    K = eng.cfg.n_clients
    concrete = (eng._initial_carry(),
                (jnp.int32(1), jnp.zeros(K, bool), jnp.asarray(False),
                 jnp.ones(K, bool), jnp.zeros(K, jnp.int32)))
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(jnp.shape(x), jnp.result_type(x)),
        concrete)


def check_engine(subject: str, eng) -> List[Finding]:
    """Scan-safety of one async engine's round body (staleness hook and
    telemetry instrumentation included in the traced graph)."""
    from repro.analysis import traceutil

    carry, xs = _round_abstract(eng)
    tr = traceutil.trace(lambda c, x: eng._round_device(c, x), carry, xs)
    violations = tr.scan_safety_violations()
    if violations:
        return [Finding("error", "async", subject, v) for v in violations]
    return [Finding("ok", "async", subject,
                    "async round body is scan-safe "
                    "(no callbacks, no host RNG)")]


def run() -> List[Finding]:
    findings: List[Finding] = []
    for label, strategy, strat_kw, eng_kw, codec, tel in ANALYSIS_VARIANTS:
        eng = build_engine(strategy, strat_kw, eng_kw, codec, telemetry=tel)
        findings.extend(check_engine(f"async[{label}]", eng))
    return findings
