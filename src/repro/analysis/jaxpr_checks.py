"""Pass 1: strategy and codec contracts, proved by abstract tracing.

Every registered strategy declares flags the engines trust blindly at
construction time (``scan_safe``, ``supports_fused_round``); every
codec declares ``scan_safe`` and may advertise a fused-kernel
equivalent via ``round_kernel.codec_kernel_spec``.  This pass traces
the actual hooks on ``ShapeDtypeStruct`` inputs and diffs reality
against the declarations:

- ``scan_safe=True`` demands: every scanned hook traces on abstract
  shapes (no host round trips / data-dependent python), the graph has
  no host-callback primitives, and no host numpy RNG is constructed
  mid-trace.  A violation is an **error** — the flag would crash (or
  silently constant-fold) inside ``lax.scan``.
- ``scan_safe=False`` on a strategy whose hooks all trace clean is a
  **warn** — a stale conservative flag that locks the strategy out of
  the scanned engines for no reason.
- ``supports_fused_round=True`` demands the fused hooks trace for the
  kernel-supported codec modes and actually hit a ``pallas_call``.
- a codec with a non-None kernel spec must be expressible by
  ``round_kernel.fused_round`` under that spec.
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp

from repro.analysis.report import Finding
from repro.analysis.traceutil import find_eqns, trace

# Abstract shapes for the trace: small but non-degenerate (K clients,
# m public samples per round, N classes).  Values never materialize.
_K, _M, _N = 8, 16, 10


def _sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _strategy_args():
    z = _sds((_K, _M, _N))
    part = _sds((_K,))
    key = _sds((2,), jnp.uint32)   # legacy PRNGKey layout, as the engines pass
    t = _sds((), jnp.int32)
    return z, part, key, t


def _upload_mask_struct(s, z):
    """Abstract upload_mask output (None for strategies without one)."""
    return jax.eval_shape(lambda zz: s.upload_mask(zz), z)


def _scan_hooks(s, um):
    """(hook name, fn, args) for everything the scanned engines trace."""
    z, part, key, t = _strategy_args()
    hooks = [
        ("transmit", lambda z_, k_: s.transmit(z_, k_), (z, key)),
        ("upload_mask", lambda z_: s.upload_mask(z_), (z,)),
        ("aggregate_masked",
         lambda z_, p_, u_, t_: s.aggregate_masked(z_, p_, u_, t_),
         (z, part, um, t)),
        ("two_phase",
         lambda z_, p_, u_, t_: s.finalize_aggregate(
             s.partial_aggregate(z_, p_, u_, t_), t_),
         (z, part, um, t)),
    ]
    if um is None:
        # jax.make_jaxpr can't take None positionally; close over it
        hooks[2] = ("aggregate_masked",
                    lambda z_, p_, t_: s.aggregate_masked(z_, p_, None, t_),
                    (z, part, t))
        hooks[3] = ("two_phase",
                    lambda z_, p_, t_: s.finalize_aggregate(
                        s.partial_aggregate(z_, p_, None, t_), t_),
                    (z, part, t))
    return hooks


# codec modes the fused round kernel supports, in codec_kernel_spec form
_FUSED_SPECS = (
    {"mode": "identity", "bits": None},
    {"mode": "quant", "bits": 8},
    {"mode": "delta", "bits": 8},
)


def check_strategy(name: str, ctor) -> List[Finding]:
    """All contract findings for one registered strategy class."""
    findings: List[Finding] = []
    variants = tuple(getattr(ctor, "analysis_variants", ({},)))
    for kw in variants:
        subject = f"strategy:{name}" + (f"{kw!r}" if kw else "")
        try:
            s = ctor(**dict(kw))
        except Exception as e:  # noqa: BLE001
            findings.append(Finding(
                "error", "jaxpr", subject,
                f"analysis_variants kwargs rejected by constructor: {e}"))
            continue
        findings.extend(_check_instance(subject, s))
    return findings


def _check_instance(subject, s) -> List[Finding]:
    findings: List[Finding] = []
    z, part, key, t = _strategy_args()
    contract = s.declared_contract()

    try:
        um = _upload_mask_struct(s, z)
    except Exception as e:  # noqa: BLE001
        findings.append(Finding("error", "jaxpr", subject,
                                f"upload_mask failed abstract eval: {e}"))
        um = None

    # --- scan-safety -------------------------------------------------
    violations = []
    shape_probs = []
    for hook, fn, args in _scan_hooks(s, um):
        tr = trace(fn, *args)
        for v in tr.scan_safety_violations():
            violations.append(f"{hook}: {v}")
        if tr.ok and hook in ("aggregate_masked", "two_phase"):
            out = tr.jaxpr.out_avals[0]
            if tuple(out.shape) != (_M, _N):
                shape_probs.append(
                    f"{hook}: teacher shape {tuple(out.shape)} != {(_M, _N)}")
    findings.extend(Finding("error", "jaxpr", subject, p)
                    for p in shape_probs)

    if contract["scan_safe"]:
        if violations:
            for v in violations:
                findings.append(Finding(
                    "error", "jaxpr", subject,
                    f"declared scan_safe=True but {v}"))
        else:
            findings.append(Finding("ok", "jaxpr", subject,
                                    "scan_safe=True verified by trace"))
    else:
        # a declared-unsafe strategy should have *something* unsafe:
        # check the scanned hooks above plus the dynamic-subset
        # ``aggregate`` (where e.g. COMET's host k-means lives)
        agg = trace(lambda z_, t_: s.aggregate(z_, None, t_), z, t)
        agg_viol = agg.scan_safety_violations()
        if not agg_viol and agg.ok:
            # per-client second output is scan-hostile too (dynamic K)
            per_client = agg.jaxpr.out_avals[1:] if len(
                agg.jaxpr.out_avals) > 1 else []
            if any(a.shape and a.shape[0] == _K for a in per_client):
                agg_viol = ["aggregate returns per-client teachers "
                            "(K-leading output, not scannable as-is)"]
        if violations or agg_viol:
            findings.append(Finding(
                "ok", "jaxpr", subject,
                "scan_safe=False justified: "
                + "; ".join((violations + agg_viol)[:2])))
        else:
            findings.append(Finding(
                "warn", "jaxpr", subject,
                "declared scan_safe=False but every hook traces clean on "
                "abstract shapes — stale flag? (locks the strategy out of "
                "the scanned engines)"))

    # --- fused round -------------------------------------------------
    declared_fused = contract["supports_fused_round"]
    fused_ok, fused_errs = _trace_fused(s, z, part, t)
    if declared_fused:
        if fused_errs:
            for msg in fused_errs:
                findings.append(Finding(
                    "error", "jaxpr", subject,
                    f"declared supports_fused_round=True but {msg}"))
        else:
            findings.append(Finding(
                "ok", "jaxpr", subject,
                "supports_fused_round=True verified (fused hooks trace to "
                "pallas_call for all kernel codec modes)"))
    elif fused_ok:
        findings.append(Finding(
            "info", "jaxpr", subject,
            "supports_fused_round=False but the fused hooks trace clean — "
            "consider advertising the fast path"))
    return findings


def _trace_fused(s, z, part, t):
    """(all_modes_trace_to_pallas, error messages) for the fused hooks."""
    errs = []
    any_ok = False
    for spec in _FUSED_SPECS:
        base = _sds((_M, _N)) if spec["mode"] == "delta" else None
        for hook in ("aggregate_masked_fused", "partial_aggregate_fused"):
            fn = getattr(s, hook)
            if base is None:
                tr = trace(lambda z_, p_, t_: fn(z_, p_, spec, None, t_),
                           z, part, t)
            else:
                tr = trace(lambda z_, p_, b_, t_: fn(z_, p_, spec, b_, t_),
                           z, part, base, t)
            if not tr.ok:
                errs.append(f"{hook}[{spec['mode']}] failed to trace: "
                            f"{type(tr.error).__name__}")
                continue
            if not find_eqns(tr.jaxpr.jaxpr, "pallas_call"):
                errs.append(f"{hook}[{spec['mode']}] traces but contains no "
                            "pallas_call — not actually fused")
                continue
            any_ok = True
    return any_ok and not errs, errs


def check_codec(name: str, factory) -> List[Finding]:
    """Contract findings for one registered codec."""
    from repro.kernels.round_kernel import MODES, codec_kernel_spec, fused_round

    findings: List[Finding] = []
    subject = f"codec:{name}"
    try:
        codec = factory()
    except Exception as e:  # noqa: BLE001
        return [Finding("error", "jaxpr", subject,
                        f"factory failed: {e}")]

    z = _sds((_M, _N))
    base = _sds((_M, _N))
    present = _sds((_M,), jnp.bool_)

    viol = []
    for hook, fn, args in (
            ("encode", lambda z_: codec.encode(z_), (z,)),
            ("decode(encode)", lambda z_: codec.decode(codec.encode(z_)), (z,)),
            ("roundtrip", lambda z_: codec.roundtrip(z_), (z,)),
            ("roundtrip+base",
             lambda z_, b_, p_: codec.roundtrip(z_, base=b_, present=p_),
             (z, base, present)),
    ):
        tr = trace(fn, *args)
        viol.extend(f"{hook}: {v}" for v in tr.scan_safety_violations())
        if hook in ("decode(encode)", "roundtrip", "roundtrip+base") and tr.ok:
            out = tr.jaxpr.out_avals[0]
            if tuple(out.shape) != (_M, _N):
                findings.append(Finding(
                    "error", "jaxpr", subject,
                    f"{hook} output shape {tuple(out.shape)} != input "
                    f"{(_M, _N)} (receiver view must be shape-preserving)"))

    if codec.scan_safe and viol:
        findings.extend(Finding("error", "jaxpr", subject,
                                f"declared scan_safe=True but {v}")
                        for v in viol)
    elif not codec.scan_safe and not viol:
        findings.append(Finding(
            "warn", "jaxpr", subject,
            "declared scan_safe=False but encode/decode trace clean — "
            "stale flag?"))
    else:
        findings.append(Finding("ok", "jaxpr", subject,
                                f"scan_safe={codec.scan_safe} verified"))

    # --- kernel spec consistency -------------------------------------
    spec = codec_kernel_spec(codec)
    if spec is not None:
        if spec["mode"] not in MODES:
            findings.append(Finding(
                "error", "jaxpr", subject,
                f"codec_kernel_spec mode {spec['mode']!r} not in kernel "
                f"MODES {MODES}"))
        elif (spec["mode"] == "identity") != codec.is_identity:
            findings.append(Finding(
                "error", "jaxpr", subject,
                f"codec_kernel_spec mode {spec['mode']!r} disagrees with "
                f"is_identity={codec.is_identity}"))
        else:
            z3, w = _sds((_K, _M, _N)), _sds((_K,))
            if spec["mode"] == "delta":
                tr = trace(lambda z_, w_, b_: fused_round(
                    z_, w_, None, b_, mode=spec["mode"], bits=spec["bits"],
                    sharpen=False), z3, w, base)
            else:
                tr = trace(lambda z_, w_: fused_round(
                    z_, w_, None, mode=spec["mode"], bits=spec["bits"],
                    sharpen=False), z3, w)
            if not tr.ok:
                findings.append(Finding(
                    "error", "jaxpr", subject,
                    f"codec_kernel_spec {spec} rejected by fused_round: "
                    f"{type(tr.error).__name__}: {tr.error}"))
            else:
                findings.append(Finding(
                    "ok", "jaxpr", subject,
                    f"codec_kernel_spec {spec} accepted by fused_round"))
    return findings


def run(strategies=None, codecs=None) -> List[Finding]:
    """The full pass over both registries (or explicit dict overrides —
    the fixture self-tests inject deliberately broken entries here)."""
    if strategies is None:
        from repro.fl.strategies import STRATEGIES
        strategies = STRATEGIES
    if codecs is None:
        from repro.compress.codecs import CODECS
        codecs = CODECS
    findings: List[Finding] = []
    for name, ctor in strategies.items():
        findings.extend(check_strategy(name, ctor))
    for name, factory in codecs.items():
        findings.extend(check_codec(name, factory))
    return findings
