"""Pass 4: telemetry-plane contracts for the scanned engines.

``FLConfig.telemetry`` threads a ``RoundTelemetry`` pytree through the
``lax.scan`` round body.  Two things must stay true, and neither is
checked anywhere at runtime:

1. **Scan safety**: the instrumented round body (including any
   ``telemetry_hook`` an experiment installs) must stay free of
   host-callback primitives and host RNG — one smuggled
   ``debug_callback`` silently turns the single-compilation engine
   into a per-round host round-trip.  This pass traces the telemetry-
   enabled round body of representative engine variants on abstract
   shapes and fails on any :data:`~repro.analysis.traceutil.
   CALLBACK_PRIMITIVES` hit.
2. **Off-path inertness**: with telemetry off, the round body's carry
   and ``ys`` trees must not mention telemetry at all, and the
   telemetry-on trees must differ from the off trees by EXACTLY the
   ``telemetry`` entry — the structural form of the "off path leaves
   golden ledgers byte-identical" guarantee.

Everything is trace-only (``jax.make_jaxpr`` / ``jax.eval_shape`` on
``ShapeDtypeStruct``): no training runs.
"""
from __future__ import annotations

from typing import List, Tuple

from repro.analysis.report import Finding

# engine variants traced with telemetry on: strategy name, constructor
# kwargs, engine kwargs, uplink codec — chosen so the instrumented
# graph covers the distinct telemetry paths (static vs adaptive beta
# gauge, identity vs delta+quant codec-error path, cache on/off)
ANALYSIS_VARIANTS: Tuple[Tuple[str, dict, dict, str], ...] = (
    ("scarlet", {}, {"cache_duration": 2}, "identity"),
    ("scarlet", {"beta": "adaptive"}, {"cache_duration": 2}, "identity"),
    ("scarlet", {}, {"cache_duration": 2}, "cache_delta+quant8"),
    ("dsfl", {}, {}, "identity"),
)


def _build_engine(strategy: str, strat_kw: dict, eng_kw: dict,
                  codec: str, telemetry: bool):
    from repro.fl.config import FLConfig
    from repro.fl.scan_engine import ScannedFederatedDistillation
    from repro.fl.strategies import STRATEGIES

    cfg = FLConfig(n_clients=4, rounds=2, public_size=32, public_per_round=8,
                   n_classes=4, dim=8, hidden=8, private_size=32,
                   local_steps=1, distill_steps=1, seed=0,
                   uplink_codec=codec, telemetry=telemetry)
    return ScannedFederatedDistillation(cfg, STRATEGIES[strategy](**strat_kw),
                                        **eng_kw)


def _round_abstract(eng):
    """Abstract (carry, xs) for one ``_round_device`` invocation."""
    import jax
    import jax.numpy as jnp

    concrete = (eng._initial_carry(),
                (jnp.int32(1), jnp.zeros(eng.cfg.n_clients, bool),
                 jnp.asarray(False)))
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(jnp.shape(x), jnp.result_type(x)),
        concrete)


def check_round_body(subject: str, eng) -> List[Finding]:
    """Scan-safety of one engine's (telemetry-instrumented) round body."""
    from repro.analysis import traceutil

    carry, xs = _round_abstract(eng)
    tr = traceutil.trace(lambda c, x: eng._round_device(c, x), carry, xs)
    violations = tr.scan_safety_violations()
    if violations:
        return [Finding("error", "obs", subject, v) for v in violations]
    return [Finding("ok", "obs", subject,
                    "telemetry round body is scan-safe "
                    "(no callbacks, no host RNG)")]


def check_off_on_structure(subject: str, make) -> List[Finding]:
    """Telemetry must be structurally additive: off-trees contain no
    telemetry entry, on-trees differ from off by exactly that entry."""
    import jax

    findings: List[Finding] = []
    shapes = {}
    for tel in (False, True):
        eng = make(tel)
        carry, xs = _round_abstract(eng)
        out_carry, ys = jax.eval_shape(
            lambda c, x: eng._round_device(c, x), carry, xs)
        shapes[tel] = (dict(out_carry), dict(ys))
    for tree_name, i in (("carry", 0), ("ys", 1)):
        off, on = shapes[False][i], shapes[True][i]
        if "telemetry" in off:
            findings.append(Finding(
                "error", "obs", subject,
                f"telemetry-OFF round body emits a telemetry entry in "
                f"{tree_name} — the off path must be untouched"))
        if "telemetry" not in on:
            findings.append(Finding(
                "error", "obs", subject,
                f"telemetry-ON round body missing the telemetry entry "
                f"in {tree_name}"))
        off_rest = {k: v for k, v in off.items()}
        on_rest = {k: v for k, v in on.items() if k != "telemetry"}
        if off_rest != on_rest:
            findings.append(Finding(
                "error", "obs", subject,
                f"telemetry changes the {tree_name} structure beyond its "
                f"own entry (off={sorted(off_rest)}, "
                f"on-minus-telemetry={sorted(on_rest)}) — the off-path "
                "byte-identity guarantee is at risk"))
    if not findings:
        findings.append(Finding(
            "ok", "obs", subject,
            "telemetry is structurally additive (off trees untouched; "
            "on trees differ by exactly the telemetry entry)"))
    return findings


def run() -> List[Finding]:
    findings: List[Finding] = []
    for strategy, strat_kw, eng_kw, codec in ANALYSIS_VARIANTS:
        label = strategy + ("+" + "adaptive" if strat_kw.get("beta") ==
                            "adaptive" else "") + (
            f"+{codec}" if codec != "identity" else "")
        eng = _build_engine(strategy, strat_kw, eng_kw, codec, telemetry=True)
        findings.extend(check_round_body(f"telemetry[{label}]", eng))
    # one structural off/on diff is enough: the wiring is shared
    findings.extend(check_off_on_structure(
        "telemetry[structure]",
        lambda tel: _build_engine("scarlet", {}, {"cache_duration": 2},
                                  "identity", telemetry=tel)))
    return findings
