"""Pass 2: prove shard_map replication claims by axis taint analysis.

The client-sharded engine wraps its round body in ``shard_map(...,
check_rep=False)`` — the scan carry defeats the partitioner's own
replication inference, so *nothing* verifies that carry leaves declared
``P()`` (replicated) really are bit-identical across shards.  A leaf
that silently varies per shard (the PR 5 ``last_sync`` bug: an update
keyed on the shard-local participation slice) corrupts state on the
gather at scan exit.

This pass walks the shard_map body jaxpr with a standard taint
interpreter over mesh axis names:

- an input sharded over axis ``a`` (``in_names`` mentions ``a``) is
  tainted by ``a`` — its values differ across ``a``-shards;
- ``axis_index(a)`` introduces taint ``{a}`` from nothing;
- reducing collectives over ``a`` (``psum``/``pmax``/``pmin``/
  ``all_gather``) *clear* ``a``-taint — after the reduction every
  ``a``-shard holds the same value;
- everything else unions its input taints; control flow recurses
  (scan/while to fixpoint, cond unions branches + predicate taint).

An output whose ``out_names`` omit axis ``a`` (claiming replication
over ``a``) but whose taint contains ``a`` is a proven contract
violation: **error**.
"""
from __future__ import annotations

from typing import Dict, FrozenSet, List, Sequence, Tuple

import jax

from repro.analysis.report import Finding

Taint = FrozenSet[str]
_EMPTY: Taint = frozenset()

# collective -> (axis param name, clears taint?)
_COLLECTIVES = {
    "psum": ("axes", True),
    "pmax": ("axes", True),
    "pmin": ("axes", True),
    "all_gather": ("axis_name", True),
    # outputs still differ per shard: the axis taint must survive
    "psum_scatter": ("axes", False),
    "ppermute": ("axis_name", False),
    "all_to_all": ("axis_name", False),
    "pbroadcast": ("axes", False),
}


def _axes_param(v) -> Tuple[str, ...]:
    if isinstance(v, (tuple, list)):
        return tuple(str(a) for a in v)
    return (str(v),)


def _read(env: Dict, atom) -> Taint:
    if isinstance(atom, jax.core.Literal):
        return _EMPTY
    return env.get(atom, _EMPTY)


def taint_jaxpr(jaxpr: jax.core.Jaxpr,
                in_taints: Sequence[Taint]) -> List[Taint]:
    """Propagate axis taints through ``jaxpr``; returns output taints."""
    env: Dict = {}
    for v, t in zip(jaxpr.invars, in_taints):
        env[v] = frozenset(t)
    for v in jaxpr.constvars:
        env[v] = _EMPTY

    for e in jaxpr.eqns:
        prim = e.primitive.name
        ins = [_read(env, a) for a in e.invars]
        base: Taint = frozenset().union(*ins) if ins else _EMPTY

        if prim == "axis_index":
            outs = [frozenset({str(e.params["axis_name"])})]
        elif prim in _COLLECTIVES:
            pname, clears = _COLLECTIVES[prim]
            axes = frozenset(_axes_param(e.params[pname]))
            outs = [(base - axes) if clears else (base | axes)
                    for _ in e.outvars]
        elif prim in ("pjit", "closed_call", "core_call", "remat",
                      "checkpoint", "custom_jvp_call", "custom_vjp_call",
                      "custom_vjp_call_jaxpr", "shard_map"):
            inner = _inner_jaxpr(e)
            if inner is None:
                outs = [base for _ in e.outvars]
            else:
                outs = taint_jaxpr(inner, ins[:len(inner.invars)])
        elif prim == "scan":
            outs = _taint_scan(e, ins)
        elif prim == "while":
            outs = _taint_while(e, ins)
        elif prim == "cond":
            outs = _taint_cond(e, ins)
        else:
            outs = [base for _ in e.outvars]

        for v, t in zip(e.outvars, outs):
            env[v] = t
    return [_read(env, v) for v in jaxpr.outvars]


def _inner_jaxpr(e):
    j = e.params.get("jaxpr") or e.params.get("call_jaxpr")
    if isinstance(j, jax.core.ClosedJaxpr):
        return j.jaxpr
    return j


def _taint_scan(e, ins: List[Taint]) -> List[Taint]:
    body = e.params["jaxpr"].jaxpr
    nc, ncarry = e.params["num_consts"], e.params["num_carry"]
    consts, carry, xs = ins[:nc], ins[nc:nc + ncarry], ins[nc + ncarry:]
    carry = list(carry)
    # fixpoint: a taint acquired in round t contaminates round t+1's carry
    for _ in range(len(carry) + 1):
        outs = taint_jaxpr(body, consts + carry + xs)
        new_carry = [c | o for c, o in zip(carry, outs[:ncarry])]
        if new_carry == carry:
            break
        carry = new_carry
    outs = taint_jaxpr(body, consts + carry + xs)
    return list(outs[:ncarry]) + list(outs[ncarry:])


def _taint_while(e, ins: List[Taint]) -> List[Taint]:
    cj, bj = e.params["cond_jaxpr"].jaxpr, e.params["body_jaxpr"].jaxpr
    cn, bn = e.params["cond_nconsts"], e.params["body_nconsts"]
    cconsts, bconsts, carry = ins[:cn], ins[cn:cn + bn], list(ins[cn + bn:])
    for _ in range(len(carry) + 1):
        pred = taint_jaxpr(cj, cconsts + carry)[0]
        outs = taint_jaxpr(bj, bconsts + carry)
        # a shard-varying predicate varies the trip count per shard:
        # every carry leaf inherits its taint
        new_carry = [c | o | pred for c, o in zip(carry, outs)]
        if new_carry == carry:
            break
        carry = new_carry
    return carry


def _taint_cond(e, ins: List[Taint]) -> List[Taint]:
    branches = e.params["branches"]
    pred, ops = ins[0], ins[1:]
    per_branch = [taint_jaxpr(b.jaxpr, ops) for b in branches]
    return [frozenset().union(pred, *[br[i] for br in per_branch])
            for i in range(len(per_branch[0]))]


# ---------------------------------------------------------------------------
# shard_map-level check
# ---------------------------------------------------------------------------

def _names_taint(names: dict) -> Taint:
    """in_names/out_names entry -> axes the value varies over."""
    out = set()
    for axes in names.values():
        out.update(str(a) for a in axes)
    return frozenset(out)


def check_shard_map_fn(fn, abstract_args, pass_name: str = "replication",
                       subject_prefix: str = "") -> List[Finding]:
    """Trace ``fn`` (must contain exactly one shard_map) and verify every
    output's declared replication against its taint."""
    findings: List[Finding] = []
    closed = jax.make_jaxpr(fn)(*abstract_args)
    eqns = [e for e in closed.jaxpr.eqns if e.primitive.name == "shard_map"]
    # shard_map may sit under a pjit wrapper
    if not eqns:
        from repro.analysis.traceutil import find_eqns
        eqns = find_eqns(closed.jaxpr, "shard_map")
    if len(eqns) != 1:
        findings.append(Finding(
            "error", pass_name, subject_prefix or "shard_map",
            f"expected exactly one shard_map equation, found {len(eqns)}"))
        return findings
    e = eqns[0]
    inner = e.params["jaxpr"]
    in_names, out_names = e.params["in_names"], e.params["out_names"]
    mesh_axes = tuple(str(a) for a in e.params["mesh"].shape)

    in_taints = [_names_taint(n) for n in in_names]
    out_taints = taint_jaxpr(inner, in_taints)

    labels = _output_labels(fn, abstract_args, len(out_taints))
    ok = True
    for i, (taint, names) in enumerate(zip(out_taints, out_names)):
        declared = _names_taint(names)
        leaked = (taint - declared) & frozenset(mesh_axes)
        if leaked:
            ok = False
            findings.append(Finding(
                "error", pass_name, f"{subject_prefix}{labels[i]}",
                f"declared replicated over axes {sorted(leaked)} but the "
                f"carry update is tainted by them (taint={sorted(taint)}, "
                f"out_names={names}) — shards will disagree at the gather"))
    if ok:
        findings.append(Finding(
            "ok", pass_name, subject_prefix or "shard_map",
            f"all {len(out_taints)} outputs replicated as declared over "
            f"mesh axes {mesh_axes}"))
    return findings


def _output_labels(fn, abstract_args, n: int) -> List[str]:
    """Pytree paths for the flat shard_map outputs (best effort)."""
    try:
        out = jax.eval_shape(fn, *abstract_args)
        leaves = jax.tree_util.tree_flatten_with_path(out)[0]
        if len(leaves) == n:
            return [jax.tree_util.keystr(path) for path, _ in leaves]
    except Exception:  # noqa: BLE001 — labels are cosmetic
        pass
    return [f"out[{i}]" for i in range(n)]


def check_engine(mesh: str = "2x4", n_clients: int = 8) -> List[Finding]:
    """Build a small client-sharded engine and prove its carry-update
    replication claims (the repo-level entry point for this pass)."""
    from repro.fl.config import FLConfig
    from repro.fl.shard_engine import ShardedFederatedDistillation
    from repro.fl.strategies import STRATEGIES

    findings: List[Finding] = []
    # telemetry=True variants prove the RoundTelemetry carry leaves —
    # declared replicated (P()) like last_sync — really are shard-
    # invariant: counters from the replicated full-width draw, gauges
    # psum'd over the client axis before entering the row
    for name in ("scarlet", "mean"):
        for telemetry in (False, True):
            cfg = FLConfig(n_clients=n_clients, rounds=1, public_size=32,
                           public_per_round=8, n_classes=4, seed=0,
                           telemetry=telemetry)
            eng = ShardedFederatedDistillation(cfg, STRATEGIES[name](),
                                               mesh=mesh)
            fn, abstract = eng.carry_update_fn()
            label = name + ("+telemetry" if telemetry else "")
            findings.extend(check_shard_map_fn(
                fn, abstract, subject_prefix=f"engine[{label}]:"))
    return findings


def run() -> List[Finding]:
    return check_engine()
