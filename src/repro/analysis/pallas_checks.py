"""Pass 3: static BlockSpec lint over every kernel entry point.

Each kernel module exports ``analysis_cases()`` — (label, fn, abstract
args) triples covering its entry points at representative and
known-awkward shapes (small/odd rows, huge K, bf16).  The lint traces
each case with ``interpret=False`` forced (the BlockSpecs a native TPU
compile would see) and checks, without executing anything:

- **sublane alignment** (error): every VMEM block's second-minor dim
  must be a multiple of the dtype's sublane tile (8 for f32, 16 for
  bf16, 32 for int8).  Misaligned blocks interpret fine on CPU but
  mis-tile on real hardware — the ``era_kernel``/``attn_kernel``
  ``min(block, n)`` bug class.
- **lane alignment** (info): a last dim off the 128-lane tile is legal
  (Mosaic pads) but wastes lanes; surfaced for visibility only since
  small FL class counts make it routine.
- **SMEM scalars** (error): a tiny (<= 8 element) *input* operand in
  VMEM is almost certainly a scalar parameter missing its SMEM spec —
  a (1,) VMEM vector is not a valid compiled layout.
- **VMEM footprint**: single-buffered block bytes (all VMEM operands +
  scratch) over ~16 MB is an error (cannot fit a core's VMEM), over
  8 MB a warning (no headroom for double buffering).
"""
from __future__ import annotations

import importlib
import math
from typing import Iterable, List, Tuple

import jax
import jax.numpy as jnp

from repro.analysis.report import Finding
from repro.analysis.traceutil import find_eqns
from repro.kernels.runtime import (
    LANES,
    VMEM_LIMIT_NATIVE,
    sublanes_for_dtype,
)

KERNEL_MODULES = (
    "repro.kernels.era_kernel",
    "repro.kernels.quant_kernel",
    "repro.kernels.round_kernel",
    "repro.kernels.distill_kernel",
    "repro.kernels.attn_kernel",
)

# single-buffer warn threshold: half of VMEM, leaving the compiler room
# to double-buffer the grid pipeline
_VMEM_WARN = VMEM_LIMIT_NATIVE // 2
_SCALAR_ELEMS = 8  # inputs at or below this are "scalar parameters"


def iter_cases(modules: Iterable[str] = KERNEL_MODULES):
    for modname in modules:
        mod = importlib.import_module(modname)
        for label, fn, args in mod.analysis_cases():
            yield label, fn, args


def _is_smem(bm) -> bool:
    aval = getattr(bm, "block_aval", None)
    return aval is not None and "smem" in str(
        getattr(aval, "memory_space", "")).lower()


def _block_dims(bm) -> Tuple[int, ...]:
    return tuple(int(d) if isinstance(d, int) else 1
                 for d in bm.block_shape)


def check_case(label: str, fn, args) -> List[Finding]:
    findings: List[Finding] = []
    try:
        closed = jax.make_jaxpr(fn)(*args)
    except Exception as e:  # noqa: BLE001
        return [Finding("error", "pallas", label,
                        f"case failed to trace: {type(e).__name__}: {e}")]
    eqns = find_eqns(closed.jaxpr, "pallas_call")
    if not eqns:
        return [Finding("warn", "pallas", label,
                        "no pallas_call in traced graph — nothing to lint")]
    clean = True
    for k, e in enumerate(eqns):
        tag = label if len(eqns) == 1 else f"{label}#call{k}"
        if e.params.get("interpret", False):
            findings.append(Finding(
                "info", "pallas", tag,
                "traced with interpret=True — BlockSpecs below are the "
                "interpreter's, not a native compile's"))
        gm = e.params["grid_mapping"]
        total_vmem = 0
        for i, bm in enumerate(gm.block_mappings):
            is_input = i < gm.num_inputs
            kind = "in" if is_input else "out"
            arr = bm.array_shape_dtype
            dims = _block_dims(bm)
            if _is_smem(bm):
                continue  # scalar memory: no tiling/VMEM constraints
            nbytes = math.prod(dims) * jnp.dtype(arr.dtype).itemsize
            total_vmem += nbytes
            if is_input and math.prod(dims) <= _SCALAR_ELEMS:
                clean = False
                findings.append(Finding(
                    "error", "pallas", tag,
                    f"{kind}[{i}] {dims} {arr.dtype}: scalar-sized operand "
                    "in VMEM — needs a pltpu.SMEM BlockSpec (a tiny VMEM "
                    "vector is not a valid compiled layout)"))
                continue
            if len(dims) >= 2:
                sub = sublanes_for_dtype(arr.dtype)
                if dims[-2] % sub:
                    clean = False
                    findings.append(Finding(
                        "error", "pallas", tag,
                        f"{kind}[{i}] block {dims} {arr.dtype}: sublane dim "
                        f"{dims[-2]} not a multiple of {sub} — misaligned "
                        "row block (interprets on CPU, mis-tiles on TPU)"))
                if dims[-1] % LANES and dims[-1] != arr.shape[-1]:
                    # a chosen tile off the lane grid; spanning the full
                    # array dim is exempt (nothing the kernel can do)
                    findings.append(Finding(
                        "info", "pallas", tag,
                        f"{kind}[{i}] block {dims}: lane dim {dims[-1]} off "
                        f"the {LANES}-lane tile (legal, padded by Mosaic)"))
        # scratch operands: trailing invars of the kernel jaxpr
        kjaxpr = e.params["jaxpr"]
        n_blocked = gm.num_inputs + gm.num_outputs
        for sv in kjaxpr.invars[len(kjaxpr.invars) - gm.num_scratch_operands:]:
            aval = sv.aval
            if "smem" in str(getattr(aval, "memory_space", "")).lower():
                continue
            total_vmem += (math.prod(aval.shape)
                           * jnp.dtype(aval.dtype).itemsize)
        del n_blocked
        if total_vmem > VMEM_LIMIT_NATIVE:
            clean = False
            findings.append(Finding(
                "error", "pallas", tag,
                f"per-block VMEM footprint {total_vmem / 2**20:.1f} MiB "
                f"exceeds the {VMEM_LIMIT_NATIVE / 2**20:.0f} MiB core "
                "limit — the kernel cannot compile natively"))
        elif total_vmem > _VMEM_WARN:
            findings.append(Finding(
                "warn", "pallas", tag,
                f"per-block VMEM footprint {total_vmem / 2**20:.1f} MiB "
                "leaves no room for double buffering "
                f"(warn threshold {_VMEM_WARN / 2**20:.0f} MiB)"))
    if clean:
        findings.append(Finding(
            "ok", "pallas", label,
            f"{len(eqns)} pallas_call(s): blocks aligned, scalars in SMEM, "
            "VMEM within budget"))
    return findings


def run(modules: Iterable[str] = KERNEL_MODULES) -> List[Finding]:
    findings: List[Finding] = []
    for label, fn, args in iter_cases(modules):
        findings.extend(check_case(label, fn, args))
    return findings
