"""Whisper-large-v3-style encoder-decoder (arXiv:2212.04356).

The mel-spectrogram + conv feature extractor is a STUB per the assignment
carve-out: ``input_specs`` provides precomputed audio frame embeddings of
shape (B, encoder_len, d_model).  We implement the transformer backbone:
bidirectional encoder, causal decoder with cross-attention, learned
positional embeddings, GELU MLP (Whisper uses MHA without GQA: kv=20).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common as cm


def _max_pos(cfg: ModelConfig) -> int:
    # decoder learned positions; sized for the largest assigned decode shape
    return 128 if cfg.vocab_size <= 512 else 32_768


def init(cfg: ModelConfig, key: jax.Array) -> Tuple[cm.Params, cm.Axes]:
    D, V = cfg.d_model, cfg.padded_vocab
    H, Hkv, dh, F = cfg.n_heads, cfg.n_kv_heads, cfg.dh, cfg.d_ff
    Le, Ld = cfg.n_encoder_layers, cfg.n_layers
    b = cm.Builder(key, jnp.dtype(cfg.param_dtype))
    b.param("embed", (V, D), ("vocab", "embed"), scale=1.0)
    b.param("enc_pos", (cfg.encoder_len, D), (None, "embed"), scale=0.02)
    b.param("dec_pos", (_max_pos(cfg), D), (None, "embed"), scale=0.02)
    eb = b.child("encoder")
    eb.param("ln1", (Le, D), ("layers", None), init="zeros")
    eb.param("wq", (Le, D, H, dh), ("layers", "embed", "heads", None))
    eb.param("wk", (Le, D, Hkv, dh), ("layers", "embed", "kv", None))
    eb.param("wv", (Le, D, Hkv, dh), ("layers", "embed", "kv", None))
    eb.param("wo", (Le, H, dh, D), ("layers", "heads", None, "embed"))
    eb.param("ln2", (Le, D), ("layers", None), init="zeros")
    eb.param("mlp_in", (Le, D, F), ("layers", "embed", "ffn"))
    eb.param("mlp_out", (Le, F, D), ("layers", "ffn", "embed"))
    b.param("enc_final_norm", (D,), (None,), init="zeros")
    db = b.child("decoder")
    db.param("ln1", (Ld, D), ("layers", None), init="zeros")
    db.param("wq", (Ld, D, H, dh), ("layers", "embed", "heads", None))
    db.param("wk", (Ld, D, Hkv, dh), ("layers", "embed", "kv", None))
    db.param("wv", (Ld, D, Hkv, dh), ("layers", "embed", "kv", None))
    db.param("wo", (Ld, H, dh, D), ("layers", "heads", None, "embed"))
    db.param("lnx", (Ld, D), ("layers", None), init="zeros")
    db.param("xwq", (Ld, D, H, dh), ("layers", "embed", "heads", None))
    db.param("xwk", (Ld, D, Hkv, dh), ("layers", "embed", "kv", None))
    db.param("xwv", (Ld, D, Hkv, dh), ("layers", "embed", "kv", None))
    db.param("xwo", (Ld, H, dh, D), ("layers", "heads", None, "embed"))
    db.param("ln2", (Ld, D), ("layers", None), init="zeros")
    db.param("mlp_in", (Ld, D, F), ("layers", "embed", "ffn"))
    db.param("mlp_out", (Ld, F, D), ("layers", "ffn", "embed"))
    b.param("final_norm", (D,), (None,), init="zeros")
    b.param("lm_head", (V, D), ("vocab", "embed"))
    return b.params, b.axes


def _mlp(h, w_in, w_out):
    return jnp.einsum("...f,fd->...d", jax.nn.gelu(jnp.einsum("...d,df->...f", h, w_in)), w_out)


def encode(cfg: ModelConfig, params: cm.Params, audio_embeds: jnp.ndarray,
           remat: bool = False) -> jnp.ndarray:
    """audio_embeds: (B, enc_len, D) stub frontend output -> encoder states."""
    x = audio_embeds.astype(jnp.dtype(cfg.compute_dtype))
    x = x + params["enc_pos"][None, : x.shape[1]].astype(x.dtype)

    def body(x, lp):
        h = cm.rms_norm(x, lp["ln1"], cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", h, lp["wq"])
        k = jnp.einsum("bsd,dhk->bshk", h, lp["wk"])
        v = jnp.einsum("bsd,dhk->bshk", h, lp["wv"])
        o = cm.attention(q, k, v, causal=False)
        x = x + jnp.einsum("bshk,hkd->bsd", o, lp["wo"])
        h = cm.rms_norm(x, lp["ln2"], cfg.norm_eps)
        return x + _mlp(h, lp["mlp_in"], lp["mlp_out"])

    if remat:
        body = cm.remat_wrap(body, cfg.remat_policy)
    x, _ = cm.scan(lambda c, lp: (body(c, lp), None), x, params["encoder"])
    return cm.rms_norm(x, params["enc_final_norm"], cfg.norm_eps)


def _dec_layer(cfg, lp, x, enc, positions, chunk_q, self_kv=None, pos=None):
    h = cm.rms_norm(x, lp["ln1"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", h, lp["wq"])
    k = jnp.einsum("bsd,dhk->bshk", h, lp["wk"])
    v = jnp.einsum("bsd,dhk->bshk", h, lp["wv"])
    if self_kv is None:
        o = cm.attention(q, k, v, causal=True, chunk_q=chunk_q)
        new_kv = None
    else:
        k_l, v_l = self_kv
        k_l = jax.lax.dynamic_update_slice(k_l, k.astype(k_l.dtype), (0, pos, 0, 0))
        v_l = jax.lax.dynamic_update_slice(v_l, v.astype(v_l.dtype), (0, pos, 0, 0))
        o = cm.attention(q, k_l, v_l, causal=False, q_offset=pos, kv_len=pos + 1)
        new_kv = (k_l, v_l)
    x = x + jnp.einsum("bshk,hkd->bsd", o, lp["wo"])
    # cross-attention
    h = cm.rms_norm(x, lp["lnx"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", h, lp["xwq"])
    xk = jnp.einsum("bsd,dhk->bshk", enc, lp["xwk"])
    xv = jnp.einsum("bsd,dhk->bshk", enc, lp["xwv"])
    o = cm.attention(q, xk, xv, causal=False)
    x = x + jnp.einsum("bshk,hkd->bsd", o, lp["xwo"])
    h = cm.rms_norm(x, lp["ln2"], cfg.norm_eps)
    return x + _mlp(h, lp["mlp_in"], lp["mlp_out"]), new_kv


def forward(cfg: ModelConfig, params: cm.Params, tokens: jnp.ndarray,
            audio_embeds: jnp.ndarray, remat: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    enc = encode(cfg, params, audio_embeds, remat=remat)
    B, S = tokens.shape
    x = params["embed"][tokens].astype(jnp.dtype(cfg.compute_dtype))
    x = x + params["dec_pos"][None, :S].astype(x.dtype)
    positions = jnp.arange(S)
    chunk_q = 1024 if S >= 8192 else 0

    def body(x, lp):
        out, _ = _dec_layer(cfg, lp, x, enc, positions, chunk_q)
        return out

    if remat:
        body = cm.remat_wrap(body, cfg.remat_policy)
    x, _ = cm.scan(lambda c, lp: (body(c, lp), None), x, params["decoder"])
    x = cm.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", x, params["lm_head"]).astype(cm.logits_dtype(cfg))
    return logits, jnp.zeros((), jnp.float32)


def init_decode_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict[str, jnp.ndarray]:
    dt = jnp.dtype(cfg.param_dtype)
    Ld, Hkv, dh = cfg.n_layers, cfg.n_kv_heads, cfg.dh
    return {
        "k": jnp.zeros((Ld, batch, max_len, Hkv, dh), dt),
        "v": jnp.zeros((Ld, batch, max_len, Hkv, dh), dt),
        "xk": jnp.zeros((Ld, batch, cfg.encoder_len, Hkv, dh), dt),
        "xv": jnp.zeros((Ld, batch, cfg.encoder_len, Hkv, dh), dt),
    }


def precompute_cross_kv(cfg: ModelConfig, params: cm.Params, enc: jnp.ndarray):
    xk = jnp.einsum("bsd,ldhk->lbshk", enc, params["decoder"]["xwk"])
    xv = jnp.einsum("bsd,ldhk->lbshk", enc, params["decoder"]["xwv"])
    return xk, xv


def cache_axes(cfg: ModelConfig, shape_name: str = "") -> Dict[str, Tuple]:
    kv = ("layers", "batch", None, "kv", None)
    return {"k": kv, "v": kv, "xk": kv, "xv": kv}


def decode_step(cfg, params, cache, token, pos):
    x = params["embed"][token].astype(jnp.dtype(cfg.compute_dtype))
    x = x + jax.lax.dynamic_slice_in_dim(params["dec_pos"], pos, 1)[None].astype(x.dtype)

    def step(x, xs):
        lp, k_l, v_l, xk_l, xv_l = xs
        h = cm.rms_norm(x, lp["ln1"], cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", h, lp["wq"])
        k = jnp.einsum("bsd,dhk->bshk", h, lp["wk"])
        v = jnp.einsum("bsd,dhk->bshk", h, lp["wv"])
        k_l = jax.lax.dynamic_update_slice(k_l, k.astype(k_l.dtype), (0, pos, 0, 0))
        v_l = jax.lax.dynamic_update_slice(v_l, v.astype(v_l.dtype), (0, pos, 0, 0))
        o = cm.attention(q, k_l, v_l, causal=False, q_offset=pos, kv_len=pos + 1)
        x = x + jnp.einsum("bshk,hkd->bsd", o, lp["wo"])
        h = cm.rms_norm(x, lp["lnx"], cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", h, lp["xwq"])
        o = cm.attention(q, xk_l, xv_l, causal=False)
        x = x + jnp.einsum("bshk,hkd->bsd", o, lp["xwo"])
        h = cm.rms_norm(x, lp["ln2"], cfg.norm_eps)
        x = x + _mlp(h, lp["mlp_in"], lp["mlp_out"])
        return x, (k_l, v_l)

    x, (ks, vs) = cm.scan(
        step, x, (params["decoder"], cache["k"], cache["v"], cache["xk"], cache["xv"]))
    x = cm.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", x, params["lm_head"]).astype(jnp.float32)
    return logits[:, 0], {"k": ks, "v": vs, "xk": cache["xk"], "xv": cache["xv"]}


def lm_loss(cfg: ModelConfig, params: cm.Params, batch: Dict[str, Any],
            remat: bool = True) -> jnp.ndarray:
    logits, _ = forward(cfg, params, batch["tokens"], batch["audio_embeds"], remat=remat)
    return cm.next_token_ce(cfg, logits, batch["labels"])
