"""Shared model machinery: parameter builder with logical sharding axes,
norms, RoPE, (chunked/flash-style) attention, SwiGLU and sort-based
token-choice MoE dispatch.

Every parameter leaf is created through :class:`Builder`, which records a
matching pytree of *logical axis names* (e.g. ``("experts", "embed",
"ffn")``).  ``launch/sharding.py`` maps logical names onto mesh axes with
divisibility fallbacks — model code stays mesh-agnostic.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Params = Dict[str, Any]
Axes = Dict[str, Any]

# Optional PartitionSpec tuple for the MoE dispatched buffer (E, C, D),
# e.g. ("data", None, "model"). None = let SPMD propagation decide.
MOE_DISPATCH_SPEC = None

# When set to a Mesh, MoE layers route through the shard_map all-to-all
# dispatch (models/moe_a2a.py) with experts sharded over "data".
MOE_A2A_MESH = None

# Attention execution path: "xla" (einsum softmax; lowering/analysis) or
# "pallas" (fused flash kernel; the TPU runtime path, interpret on CPU).
# Only exercised for the plain causal/windowed case without softcap.
ATTN_IMPL = "xla"

# When True, layer-stack scans fully unroll.  The dry-run sets this so
# XLA's cost_analysis sees every layer (while-loop bodies are otherwise
# counted ONCE, silently under-reporting FLOPs/bytes by ~n_layers x).
SCAN_UNROLL = False


def remat_wrap(body, policy_name: str):
    """Apply jax.checkpoint with a named policy ('none' disables)."""
    if policy_name == "none":
        return body
    policies = {
        "nothing_saveable": jax.checkpoint_policies.nothing_saveable,
        "dots_saveable": jax.checkpoint_policies.dots_saveable,
        "dots_with_no_batch_dims_saveable":
            jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
    }
    return jax.checkpoint(body, policy=policies[policy_name])


def next_token_ce(cfg, logits, labels):
    """Mean next-token CE. ``cfg.ce_impl='lse'`` avoids materializing the
    (B,S,V) log-softmax: loss = logsumexp(logits) - logits[label]."""
    logits = logits[:, :-1]
    labels = labels[:, 1:]
    if cfg.ce_impl == "lse":
        l32 = logits.astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(l32, axis=-1)
        picked = jnp.take_along_axis(l32, labels[..., None], axis=-1)[..., 0]
        return jnp.mean(lse - picked)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def logits_dtype(cfg):
    import jax.numpy as _jnp
    return _jnp.float32 if cfg.fp32_logits else _jnp.dtype(cfg.compute_dtype)


def scan(f, init, xs, length=None):
    """lax.scan that fully unrolls when SCAN_UNROLL is set (dry-run mode)."""
    if SCAN_UNROLL:
        if length is None:
            length = jax.tree_util.tree_leaves(xs)[0].shape[0]
        return jax.lax.scan(f, init, xs, length=length, unroll=length)
    return jax.lax.scan(f, init, xs, length=length)


class Builder:
    """Accumulates (params, logical_axes) pytrees with matched structure."""

    def __init__(self, key: jax.Array, dtype=jnp.float32):
        self._key = key
        self.dtype = dtype
        self.params: Params = {}
        self.axes: Axes = {}

    def _split(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub

    def param(self, name: str, shape: Tuple[int, ...], axes: Tuple[Optional[str], ...],
              scale: Optional[float] = None, init: str = "normal") -> jnp.ndarray:
        assert len(shape) == len(axes), (name, shape, axes)
        if init == "zeros":
            val = jnp.zeros(shape, self.dtype)
        elif init == "ones":
            val = jnp.ones(shape, self.dtype)
        else:
            if scale is None:
                # fan-in scaling over the last dim by default
                fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
                scale = 1.0 / math.sqrt(fan_in)
            val = (jax.random.normal(self._split(), shape, jnp.float32) * scale).astype(self.dtype)
        self.params[name] = val
        self.axes[name] = axes
        return val

    def child(self, name: str) -> "Builder":
        sub = Builder(self._split(), self.dtype)
        self.params[name] = sub.params
        self.axes[name] = sub.axes
        return sub


# ---------------------------------------------------------------------------
# Normalization / activations
# ---------------------------------------------------------------------------

def rms_norm(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + weight.astype(jnp.float32))).astype(dt)


def softcap(x: jnp.ndarray, cap: float) -> jnp.ndarray:
    return cap * jnp.tanh(x / cap) if cap else x


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(dh: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, dh, 2, dtype=jnp.float32) / dh))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (B, S, H, dh); positions: (S,) or (B, S)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)  # (dh//2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, dh//2)
    if ang.ndim == 2:  # (S, dh//2) -> broadcast over batch
        ang = ang[None]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA, causal, sliding window, softcap, chunked-q flash-style)
# ---------------------------------------------------------------------------

def attention(
    q: jnp.ndarray,              # (B, Sq, H, dh)
    k: jnp.ndarray,              # (B, Sk, Hkv, dh)
    v: jnp.ndarray,              # (B, Sk, Hkv, dh)
    *,
    causal: bool = True,
    window: int = 0,             # 0 = full
    cap: float = 0.0,
    q_offset: jnp.ndarray | int = 0,
    kv_len: jnp.ndarray | int | None = None,  # valid prefix of k/v (decode)
    chunk_q: int = 0,            # 0 = no chunking
    score_dtype=jnp.float32,     # S x S chain dtype (perf knob)
) -> jnp.ndarray:
    """Grouped-query attention without materializing repeated KV.

    ``chunk_q`` scans over query chunks with online accumulation so the
    score tensor never exceeds (B, G, R, chunk, Sk) — the jnp analog of
    flash attention used for long-sequence lowering (the Pallas kernel
    is the TPU execution path).
    """
    B, Sq, H, dh = q.shape
    Hkv = k.shape[2]
    R = H // Hkv
    if (ATTN_IMPL == "pallas" and causal and not cap and kv_len is None
            and isinstance(q_offset, int) and q_offset == 0
            and isinstance(window, int) and Sq == k.shape[1]
            and Sq % 128 == 0 and dh % 8 == 0):
        from repro.kernels import ops as _kops

        return _kops.flash_attention(q, k, v, causal=True, window=window)
    qg = q.reshape(B, Sq, Hkv, R, dh)
    scale = 1.0 / math.sqrt(dh)

    def _block(q_blk: jnp.ndarray, q_pos: jnp.ndarray) -> jnp.ndarray:
        # q_blk: (B, sq, Hkv, R, dh); q_pos: (sq,)
        s = jnp.einsum("bqgrd,bkgd->bgrqk", q_blk.astype(score_dtype),
                       k.astype(score_dtype)) * jnp.asarray(scale, score_dtype)
        s = softcap(s, cap)
        k_pos = jnp.arange(k.shape[1])
        mask = jnp.ones((q_blk.shape[1], k.shape[1]), dtype=bool)
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        use_window = not (isinstance(window, int) and window == 0)
        if use_window:
            w = jnp.asarray(window)
            # w <= 0 disables windowing (lets a traced per-layer window
            # array mix local and global layers in one scanned stack)
            mask &= jnp.logical_or(w <= 0, k_pos[None, :] > q_pos[:, None] - w)
        if kv_len is not None:
            mask &= k_pos[None, :] < jnp.asarray(kv_len)
        s = jnp.where(mask[None, None, None], s, jnp.asarray(-1e30, score_dtype))
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bgrqk,bkgd->bqgrd", p, v.astype(score_dtype))
        return o.astype(q.dtype)

    q_positions = q_offset + jnp.arange(Sq)
    if chunk_q and Sq % chunk_q == 0 and Sq > chunk_q:
        nc = Sq // chunk_q
        qc = qg.reshape(B, nc, chunk_q, Hkv, R, dh).transpose(1, 0, 2, 3, 4, 5)
        pc = q_positions.reshape(nc, chunk_q)

        def body(_, qp):
            qi, pi = qp
            return None, _block(qi, pi)

        _, oc = scan(body, None, (qc, pc))
        out = oc.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, Hkv, R, dh)
    else:
        out = _block(qg, q_positions)
    return out.reshape(B, Sq, H, dh)


# ---------------------------------------------------------------------------
# Feed-forward
# ---------------------------------------------------------------------------

def swiglu(x: jnp.ndarray, w1: jnp.ndarray, w3: jnp.ndarray, w2: jnp.ndarray) -> jnp.ndarray:
    h = jnp.einsum("...d,df->...f", x, w1)
    g = jnp.einsum("...d,df->...f", x, w3)
    return jnp.einsum("...f,fd->...d", jax.nn.silu(h) * g, w2)


# ---------------------------------------------------------------------------
# Sort-based token-choice MoE with capacity (production TPU pattern:
# FLOPs scale with top_k, not n_experts; dispatch is gather/scatter +
# batched expert matmuls -> all-to-all under expert sharding).
# ---------------------------------------------------------------------------

def moe_ffn(
    x: jnp.ndarray,               # (B, S, D)
    router: jnp.ndarray,          # (D, E)
    w1: jnp.ndarray,              # (E, D, F)
    w3: jnp.ndarray,              # (E, D, F)
    w2: jnp.ndarray,              # (E, F, D)
    *,
    top_k: int,
    capacity_factor: float = 1.25,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (output (B,S,D), router aux load-balance loss scalar)."""
    B, S, D = x.shape
    E = router.shape[1]
    if MOE_A2A_MESH is not None and E % MOE_A2A_MESH.shape.get("data", 1) == 0 \
            and B % MOE_A2A_MESH.shape.get("data", 1) == 0:
        from repro.models import moe_a2a

        return moe_a2a.moe_ffn_a2a(
            x, router, w1, w3, w2, top_k=top_k, mesh=MOE_A2A_MESH,
            capacity_factor=capacity_factor)
    T = B * S
    xt = x.reshape(T, D)
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = jax.lax.top_k(probs, top_k)          # (T, k)
    gate = gate / (jnp.sum(gate, -1, keepdims=True) + 1e-9)

    # load-balance auxiliary loss (Switch-style)
    assign = jnp.zeros((T, E), jnp.float32).at[jnp.arange(T)[:, None], eidx].add(1.0)
    aux = E * jnp.mean(jnp.mean(assign, 0) * jnp.mean(probs, 0))

    C = max(int(math.ceil(T * top_k / E * capacity_factor)), top_k)
    C = (C + 7) // 8 * 8  # MXU-friendly

    flat_e = eidx.reshape(-1)                          # (T*k,)
    sort_idx = jnp.argsort(flat_e)                     # stable
    sorted_e = flat_e[sort_idx]
    counts = jnp.bincount(flat_e, length=E)
    starts = jnp.cumsum(counts) - counts
    pos_in_e = jnp.arange(T * top_k) - starts[sorted_e]
    keep = pos_in_e < C
    token_of = sort_idx // top_k
    buf_idx = sorted_e * C + jnp.clip(pos_in_e, 0, C - 1)
    safe_idx = jnp.where(keep, buf_idx, E * C)         # OOB -> dropped

    buf = jnp.zeros((E * C, D), x.dtype)
    buf = buf.at[safe_idx].set(xt[token_of], mode="drop")
    ebuf = buf.reshape(E, C, D)
    if MOE_DISPATCH_SPEC is not None:
        # perf knob: pin the dispatched buffer's sharding (expert axis ->
        # data => all-to-all dispatch instead of gather); set by the
        # dry-run perf pass.
        from jax.sharding import PartitionSpec as _P
        ebuf = jax.lax.with_sharding_constraint(ebuf, _P(*MOE_DISPATCH_SPEC))

    h = jnp.einsum("ecd,edf->ecf", ebuf, w1)
    g = jnp.einsum("ecd,edf->ecf", ebuf, w3)
    y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * g, w2).reshape(E * C, D)

    y_tok = jnp.where(keep[:, None], y[jnp.clip(buf_idx, 0, E * C - 1)], 0)
    gate_sorted = gate.reshape(-1)[sort_idx].astype(x.dtype)
    out = jnp.zeros((T, D), x.dtype).at[token_of].add(y_tok * gate_sorted[:, None])
    return out.reshape(B, S, D), aux


# ---------------------------------------------------------------------------
# Attention projection params
# ---------------------------------------------------------------------------

def attn_params(b: Builder, d_model: int, n_heads: int, n_kv: int, dh: int) -> None:
    b.param("wq", (d_model, n_heads, dh), ("embed", "heads", None))
    b.param("wk", (d_model, n_kv, dh), ("embed", "kv", None))
    b.param("wv", (d_model, n_kv, dh), ("embed", "kv", None))
    b.param("wo", (n_heads, dh, d_model), ("heads", None, "embed"))


def attn_project_qkv(p: Params, x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    return q, k, v


def attn_out(p: Params, o: jnp.ndarray) -> jnp.ndarray:
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"])
