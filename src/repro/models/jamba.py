"""Jamba-style hybrid (arXiv:2403.19887): attention:Mamba 1:7 interleave
with MoE on every other layer.

The 32-layer stack is organized as ``n_blocks = L / attn_layer_period``
scanned blocks.  Inside a block the 8 sublayers are statically unrolled:
sublayer 0 is attention, 1..7 are Mamba2 mixers; every sublayer is
followed by an FFN — dense on even sublayers, MoE (16e top-2) on odd
ones (Jamba's moe_every=2).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common as cm
from repro.models import mamba2 as m2


def _block_counts(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    period = cfg.attn_layer_period
    nb = cfg.n_layers // period
    n_mamba = period - 1
    n_moe = period // cfg.moe_every       # odd sublayers
    n_dense = period - n_moe
    return nb, n_mamba, n_dense, n_moe


def init(cfg: ModelConfig, key: jax.Array) -> Tuple[cm.Params, cm.Axes]:
    D, V = cfg.d_model, cfg.padded_vocab
    H, Hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.dh
    F, E, Fe = cfg.d_ff, cfg.n_experts, cfg.expert_d_ff
    nb, n_mamba, n_dense, n_moe = _block_counts(cfg)

    b = cm.Builder(key, jnp.dtype(cfg.param_dtype))
    b.param("embed", (V, D), ("vocab", "embed"), scale=1.0)
    bb = b.child("blocks")
    # attention sublayer (one per block)
    bb.param("attn_ln", (nb, D), ("layers", None), init="zeros")
    bb.param("wq", (nb, D, H, dh), ("layers", "embed", "heads", None))
    bb.param("wk", (nb, D, Hkv, dh), ("layers", "embed", "kv", None))
    bb.param("wv", (nb, D, Hkv, dh), ("layers", "embed", "kv", None))
    bb.param("wo", (nb, H, dh, D), ("layers", "heads", None, "embed"))
    # mamba sublayers: built flat (nb*n_mamba, ...) then reshaped (nb, n_mamba, ...)
    mb = bb.child("mamba")
    m2.mixer_params(mb, cfg, nb * n_mamba)
    for k in list(mb.params):
        leaf = mb.params[k]
        mb.params[k] = leaf.reshape((nb, n_mamba) + leaf.shape[1:])
        mb.axes[k] = ("layers", None) + mb.axes[k][1:]
    mb.params["ln"] = jnp.zeros((nb, n_mamba, D), jnp.dtype(cfg.param_dtype))
    mb.axes["ln"] = ("layers", None, None)
    # dense FFNs (even sublayers)
    bb.param("ffn_ln", (nb, n_dense, D), ("layers", None, None), init="zeros")
    bb.param("w1", (nb, n_dense, D, F), ("layers", None, "embed", "ffn"))
    bb.param("w3", (nb, n_dense, D, F), ("layers", None, "embed", "ffn"))
    bb.param("w2", (nb, n_dense, F, D), ("layers", None, "ffn", "embed"))
    # MoE FFNs (odd sublayers)
    bb.param("moe_ln", (nb, n_moe, D), ("layers", None, None), init="zeros")
    bb.param("router", (nb, n_moe, D, E), ("layers", None, "embed", None))
    bb.param("mw1", (nb, n_moe, E, D, Fe), ("layers", None, "experts", "embed", "ffn"))
    bb.param("mw3", (nb, n_moe, E, D, Fe), ("layers", None, "experts", "embed", "ffn"))
    bb.param("mw2", (nb, n_moe, E, Fe, D), ("layers", None, "experts", "ffn", "embed"))
    b.param("final_norm", (D,), (None,), init="zeros")
    b.param("lm_head", (V, D), ("vocab", "embed"))
    return b.params, b.axes


def _ffn(cfg, bp, x, sub: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """FFN for sublayer ``sub``; dense on even, MoE on odd."""
    if sub % cfg.moe_every == 0:
        i = sub // 2
        h = cm.rms_norm(x, bp["ffn_ln"][i], cfg.norm_eps)
        return x + cm.swiglu(h, bp["w1"][i], bp["w3"][i], bp["w2"][i]), jnp.zeros((), jnp.float32)
    i = (sub - 1) // 2
    h = cm.rms_norm(x, bp["moe_ln"][i], cfg.norm_eps)
    y, aux = cm.moe_ffn(h, bp["router"][i], bp["mw1"][i], bp["mw3"][i], bp["mw2"][i],
                        top_k=cfg.top_k, capacity_factor=cfg.capacity_factor)
    return x + y, aux


def _attn_sub(cfg, bp, x, positions, chunk_q, cache_kv=None, pos=None):
    h = cm.rms_norm(x, bp["attn_ln"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", h, bp["wq"])
    k = jnp.einsum("bsd,dhk->bshk", h, bp["wk"])
    v = jnp.einsum("bsd,dhk->bshk", h, bp["wv"])
    q = cm.apply_rope(q, positions, cfg.rope_theta)
    k = cm.apply_rope(k, positions, cfg.rope_theta)
    if cache_kv is None:
        o = cm.attention(q, k, v, causal=True, chunk_q=chunk_q)
        new_cache = None
    else:
        k_l, v_l = cache_kv
        k_l = jax.lax.dynamic_update_slice(k_l, k.astype(k_l.dtype), (0, pos, 0, 0))
        v_l = jax.lax.dynamic_update_slice(v_l, v.astype(v_l.dtype), (0, pos, 0, 0))
        o = cm.attention(q, k_l, v_l, causal=False, q_offset=pos, kv_len=pos + 1)
        new_cache = (k_l, v_l)
    return x + jnp.einsum("bshk,hkd->bsd", o, bp["wo"]), new_cache


def forward(cfg: ModelConfig, params: cm.Params, tokens: jnp.ndarray,
            remat: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    nb, n_mamba, _, _ = _block_counts(cfg)
    x = params["embed"][tokens].astype(jnp.dtype(cfg.compute_dtype))
    S = x.shape[1]
    positions = jnp.arange(S)
    chunk_q = 1024 if S >= 8192 else 0

    def block(x, bp):
        aux_t = jnp.zeros((), jnp.float32)
        x, _ = _attn_sub(cfg, bp, x, positions, chunk_q)
        x, a = _ffn(cfg, bp, x, 0)
        aux_t += a
        for j in range(n_mamba):
            mp = {k: v[j] for k, v in bp["mamba"].items() if k != "ln"}
            h = cm.rms_norm(x, bp["mamba"]["ln"][j], cfg.norm_eps)
            x = x + m2.mixer_forward(cfg, mp, h)
            x, a = _ffn(cfg, bp, x, j + 1)
            aux_t += a
        return x, aux_t

    body = block
    if remat:
        body = cm.remat_wrap(body, cfg.remat_policy)

    def step(carry, bp):
        x, aux = carry
        x, a = body(x, bp)
        return (x, aux + a), None

    (x, aux), _ = cm.scan(step, (x, jnp.zeros((), jnp.float32)), params["blocks"])
    x = cm.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", x, params["lm_head"]).astype(cm.logits_dtype(cfg))
    return logits, aux


def init_decode_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict[str, Any]:
    nb, n_mamba, _, _ = _block_counts(cfg)
    dt = jnp.dtype(cfg.param_dtype)
    kv = (nb, batch, max_len, cfg.n_kv_heads, cfg.dh)
    ssm = m2.mixer_cache(cfg, nb * n_mamba, batch)
    return {
        "k": jnp.zeros(kv, dt),
        "v": jnp.zeros(kv, dt),
        "ssm": ssm["ssm"].reshape((nb, n_mamba) + ssm["ssm"].shape[1:]),
        "conv": ssm["conv"].reshape((nb, n_mamba) + ssm["conv"].shape[1:]),
    }


def cache_axes(cfg: ModelConfig, shape_name: str = "") -> Dict[str, Tuple]:
    if shape_name == "long_500k":
        kv = ("layers", None, "ctx", "kv", None)
        bt = None
    else:
        kv = ("layers", "batch", None, "kv", None)
        bt = "batch"
    return {
        "k": kv,
        "v": kv,
        "ssm": ("layers", None, bt, "heads", None, None),
        "conv": ("layers", None, bt, None, "ffn"),
    }


def decode_step(cfg, params, cache, token, pos):
    nb, n_mamba, _, _ = _block_counts(cfg)
    x = params["embed"][token].astype(jnp.dtype(cfg.compute_dtype))
    positions = pos + jnp.arange(1)

    def step(x, xs):
        bp, k_l, v_l, ssm_l, conv_l = xs
        x, (k_l, v_l) = _attn_sub(cfg, bp, x, positions, 0, cache_kv=(k_l, v_l), pos=pos)
        x, _ = _ffn(cfg, bp, x, 0)
        ssm_out, conv_out = [], []
        for j in range(n_mamba):
            mp = {k: v[j] for k, v in bp["mamba"].items() if k != "ln"}
            h = cm.rms_norm(x, bp["mamba"]["ln"][j], cfg.norm_eps)
            out, s_n, c_n = m2.mixer_decode(cfg, mp, ssm_l[j], conv_l[j], h)
            x = x + out
            ssm_out.append(s_n)
            conv_out.append(c_n)
            x, _ = _ffn(cfg, bp, x, j + 1)
        return x, (k_l, v_l, jnp.stack(ssm_out), jnp.stack(conv_out))

    x, (ks, vs, ssm, conv) = cm.scan(
        step, x, (params["blocks"], cache["k"], cache["v"], cache["ssm"], cache["conv"]))
    x = cm.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", x, params["lm_head"]).astype(jnp.float32)
    return logits[:, 0], {"k": ks, "v": vs, "ssm": ssm, "conv": conv}


def lm_loss(cfg: ModelConfig, params: cm.Params, batch: Dict[str, Any],
            remat: bool = True) -> jnp.ndarray:
    logits, aux = forward(cfg, params, batch["tokens"], remat=remat)
    return cm.next_token_ce(cfg, logits, batch["labels"]) + cfg.router_aux_coef * aux
