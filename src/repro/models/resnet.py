"""CIFAR-style ResNet (He et al. 2016) — the paper's own client/server
architecture (ResNet-20/32 for CIFAR-10/100, ResNet-18 for TinyImageNet).

Pure-JAX functional implementation used by the FL experiments.  We use
GroupNorm in place of BatchNorm: FL clients train on tiny non-IID shards
and we vmap K clients through one program, where per-client BN running
stats are both statistically unsound and structurally awkward — a
standard substitution in FL implementations (documented deviation).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import common as cm


def _conv(x: jnp.ndarray, w: jnp.ndarray, stride: int = 1) -> jnp.ndarray:
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _gn(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray, groups: int = 8) -> jnp.ndarray:
    B, H, W, C = x.shape
    g = min(groups, C)
    xg = x.reshape(B, H, W, g, C // g).astype(jnp.float32)
    mean = xg.mean(axis=(1, 2, 4), keepdims=True)
    var = xg.var(axis=(1, 2, 4), keepdims=True)
    xg = (xg - mean) * jax.lax.rsqrt(var + 1e-5)
    return (xg.reshape(B, H, W, C) * scale + bias).astype(x.dtype)


def init(key: jax.Array, depth: int = 20, n_classes: int = 10,
         in_channels: int = 3, width: int = 16) -> Tuple[cm.Params, cm.Axes]:
    """ResNet-6n+2 (depth in {20, 32, ...}) with widths w, 2w, 4w."""
    assert (depth - 2) % 6 == 0, depth
    n = (depth - 2) // 6
    b = cm.Builder(key, jnp.float32)

    def conv_p(bb, name, kh, kw, cin, cout):
        bb.param(name, (kh, kw, cin, cout), (None, None, None, "ffn"),
                 scale=math.sqrt(2.0 / (kh * kw * cin)))

    conv_p(b, "stem", 3, 3, in_channels, width)
    b.param("stem_scale", (width,), ("ffn",), init="ones")
    b.param("stem_bias", (width,), ("ffn",), init="zeros")
    cin = width
    for s, mult in enumerate([1, 2, 4]):
        cout = width * mult
        for i in range(n):
            bb = b.child(f"s{s}b{i}")
            conv_p(bb, "c1", 3, 3, cin, cout)
            bb.param("g1s", (cout,), ("ffn",), init="ones")
            bb.param("g1b", (cout,), ("ffn",), init="zeros")
            conv_p(bb, "c2", 3, 3, cout, cout)
            bb.param("g2s", (cout,), ("ffn",), init="ones")
            bb.param("g2b", (cout,), ("ffn",), init="zeros")
            if cin != cout:
                conv_p(bb, "proj", 1, 1, cin, cout)
            cin = cout
    b.param("head_w", (cin, n_classes), ("ffn", "vocab"), scale=1.0 / math.sqrt(cin))
    b.param("head_b", (n_classes,), ("vocab",), init="zeros")
    return b.params, b.axes


def apply(params: cm.Params, images: jnp.ndarray, depth: int = 20) -> jnp.ndarray:
    """images: (B, H, W, C) -> logits (B, n_classes)."""
    n = (depth - 2) // 6
    x = _conv(images, params["stem"])
    x = jax.nn.relu(_gn(x, params["stem_scale"], params["stem_bias"]))
    for s in range(3):
        for i in range(n):
            p = params[f"s{s}b{i}"]
            stride = 2 if (s > 0 and i == 0) else 1
            h = jax.nn.relu(_gn(_conv(x, p["c1"], stride), p["g1s"], p["g1b"]))
            h = _gn(_conv(h, p["c2"]), p["g2s"], p["g2b"])
            sc = _conv(x, p["proj"], stride) if "proj" in p else x
            x = jax.nn.relu(h + sc)
    x = x.mean(axis=(1, 2))
    return x @ params["head_w"] + params["head_b"]


def init_mlp(key: jax.Array, in_dim: int, n_classes: int, hidden: int = 128,
             depth: int = 2) -> cm.Params:
    """Small MLP classifier — the fast CPU-scale client model for FL runs."""
    params: Dict[str, Any] = {}
    dims = [in_dim] + [hidden] * depth + [n_classes]
    for i, (a, c) in enumerate(zip(dims[:-1], dims[1:])):
        key, k1 = jax.random.split(key)
        params[f"w{i}"] = jax.random.normal(k1, (a, c)) * math.sqrt(2.0 / a)
        params[f"b{i}"] = jnp.zeros((c,))
    return params


def apply_mlp(params: cm.Params, x: jnp.ndarray) -> jnp.ndarray:
    x = x.reshape(x.shape[0], -1)
    n = len([k for k in params if k.startswith("w")])
    for i in range(n):
        x = x @ params[f"w{i}"] + params[f"b{i}"]
        if i < n - 1:
            x = jax.nn.relu(x)
    return x
