"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) in pure JAX.

Training/prefill uses the *chunked SSD dual form* (matmul-dominated:
intra-chunk attention-like term + inter-chunk recurrence over chunk
states), which is the TPU-friendly formulation — the MXU executes the
(Q x Q) and (N x hd) einsums, and only a tiny ``lax.scan`` over the
``S/Q`` chunk states remains sequential.  Decode keeps the recurrent
state ``(B, nh, N, hd)`` and a depthwise-conv ring buffer.

The mixer is reused by the Jamba hybrid (models/jamba.py).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common as cm


# ---------------------------------------------------------------------------
# Mixer params
# ---------------------------------------------------------------------------

def mixer_params(b: cm.Builder, cfg: ModelConfig, L: int) -> None:
    """Stacked (L, ...) Mamba2 mixer parameters."""
    D, di, N = cfg.d_model, cfg.d_inner, cfg.ssm_state
    nh, ck = cfg.n_ssm_heads, cfg.ssm_conv_kernel
    conv_dim = di + 2 * N
    b.param("in_z", (L, D, di), ("layers", "embed", "ffn"))
    b.param("in_x", (L, D, di), ("layers", "embed", "ffn"))
    b.param("in_B", (L, D, N), ("layers", "embed", None))
    b.param("in_C", (L, D, N), ("layers", "embed", None))
    b.param("in_dt", (L, D, nh), ("layers", "embed", "heads"))
    b.param("conv_w", (L, ck, conv_dim), ("layers", None, "ffn"))
    b.param("conv_b", (L, conv_dim), ("layers", "ffn"), init="zeros")
    b.param("dt_bias", (L, nh), ("layers", "heads"), init="zeros")
    b.param("A_log", (L, nh), ("layers", "heads"), scale=0.5)
    b.param("D_skip", (L, nh), ("layers", "heads"), init="ones")
    b.param("norm", (L, di), ("layers", "ffn"), init="zeros")
    b.param("out", (L, di, D), ("layers", "ffn", "embed"))


def _conv_causal(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv along seq. x: (B,S,Cd); w: (k,Cd)."""
    k = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(k):  # k is tiny (4); unrolled taps
        out = out + pad[:, i : i + x.shape[1]] * w[i]
    return out + b


def _split_proj(cfg: ModelConfig, lp: Dict[str, jnp.ndarray], u: jnp.ndarray):
    z = jnp.einsum("bsd,de->bse", u, lp["in_z"])
    x = jnp.einsum("bsd,de->bse", u, lp["in_x"])
    Bm = jnp.einsum("bsd,dn->bsn", u, lp["in_B"])
    Cm = jnp.einsum("bsd,dn->bsn", u, lp["in_C"])
    dt = jnp.einsum("bsd,dh->bsh", u, lp["in_dt"])
    return z, x, Bm, Cm, dt


def ssd_chunked(
    x: jnp.ndarray,    # (B, S, nh, hd)
    dt: jnp.ndarray,   # (B, S, nh) — post-softplus
    A: jnp.ndarray,    # (nh,) negative
    Bm: jnp.ndarray,   # (B, S, N)
    Cm: jnp.ndarray,   # (B, S, N)
    chunk: int,
    h0: jnp.ndarray | None = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD scan. Returns (y (B,S,nh,hd), final state (B,nh,N,hd))."""
    B_, S, nh, hd = x.shape
    N = Bm.shape[-1]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    f32 = jnp.float32

    xc = x.reshape(B_, nc, chunk, nh, hd).astype(f32)
    dtc = dt.reshape(B_, nc, chunk, nh).astype(f32)
    Bc = Bm.reshape(B_, nc, chunk, N).astype(f32)
    Cc = Cm.reshape(B_, nc, chunk, N).astype(f32)

    a = dtc * A  # (B,nc,Q,nh), negative
    a_cs = jnp.cumsum(a, axis=2)          # inclusive
    a_tot = a_cs[:, :, -1]                # (B,nc,nh)
    x_dt = xc * dtc[..., None]            # (B,nc,Q,nh,hd)

    # intra-chunk (dual / attention-like) term
    cb = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)                 # (B,nc,Q,Q)
    ii = jnp.arange(chunk)
    mask = ii[:, None] >= ii[None, :]
    # mask BEFORE exp: for i<j the exponent is positive and can overflow
    # to inf, and inf * 0 after masking poisons the chunk with NaNs
    diff = jnp.where(mask[None, None, :, :, None],
                     a_cs[:, :, :, None] - a_cs[:, :, None, :], -jnp.inf)
    att = cb[..., None] * jnp.exp(diff)                        # (B,nc,i,j,nh)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", att, x_dt)

    # chunk states
    sdecay = jnp.exp(a_tot[:, :, None, :] - a_cs)               # (B,nc,j,nh)
    states = jnp.einsum("bcjn,bcjh,bcjhp->bchnp", Bc, sdecay, x_dt)  # (B,nc,nh,N,hd)

    # inter-chunk recurrence via log-depth associative scan: the linear
    # recurrence h_c = a_c * h_{c-1} + s_c composes associatively as
    # (a, s) o (a', s') = (a*a', s*a' + s').  This keeps the SSD layer
    # loop-free (no nested while under grad+remat — which blew up SPMD
    # compile time for hybrid stacks) and is the parallel chunk-state
    # propagation the SSD paper prescribes.
    if h0 is not None:  # carry-in folds into the first chunk's state
        states = states.at[:, 0].add(h0 * jnp.exp(a_tot[:, 0])[:, :, None, None])
    a_chunk = jnp.exp(a_tot)[..., None, None]            # (B,nc,nh,1,1)

    def combine(x, y):
        a1, s1 = x
        a2, s2 = y
        return a1 * a2, s1 * a2 + s2

    _, h_inc = jax.lax.associative_scan(
        combine, (jnp.broadcast_to(a_chunk, states.shape), states), axis=1)
    h_final = h_inc[:, -1]
    # state BEFORE each chunk = inclusive result shifted right by one
    first = (jnp.zeros_like(h_inc[:, :1]) if h0 is None
             else h0[:, None].astype(f32))
    h_ins = jnp.concatenate([first, h_inc[:, :-1]], axis=1)

    y_inter = jnp.einsum("bcin,bchnp,bcih->bcihp", Cc, h_ins, jnp.exp(a_cs))
    y = (y_intra + y_inter).reshape(B_, S, nh, hd)
    return y.astype(x.dtype), h_final


def mixer_forward(
    cfg: ModelConfig, lp: Dict[str, jnp.ndarray], u: jnp.ndarray
) -> jnp.ndarray:
    """Full-sequence Mamba2 mixer. u: (B, S, D) -> (B, S, D)."""
    B_, S, D = u.shape
    di, N, nh, hd = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads, cfg.ssm_head_dim
    z, x, Bm, Cm, dt = _split_proj(cfg, lp, u)
    xbc = jnp.concatenate([x, Bm, Cm], axis=-1)
    xbc = jax.nn.silu(_conv_causal(xbc, lp["conv_w"], lp["conv_b"]))
    x, Bm, Cm = xbc[..., :di], xbc[..., di : di + N], xbc[..., di + N :]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + lp["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(lp["A_log"].astype(jnp.float32))
    y, _ = ssd_chunked(x.reshape(B_, S, nh, hd), dt, A, Bm, Cm,
                       chunk=min(cfg.ssm_chunk, S))
    y = y + x.reshape(B_, S, nh, hd) * lp["D_skip"][None, None, :, None].astype(y.dtype)
    y = y.reshape(B_, S, di)
    y = cm.rms_norm(y * jax.nn.silu(z), lp["norm"], cfg.norm_eps)
    return jnp.einsum("bse,ed->bsd", y, lp["out"])


# ---------------------------------------------------------------------------
# Decode (recurrent) path
# ---------------------------------------------------------------------------

def mixer_cache(cfg: ModelConfig, L: int, batch: int) -> Dict[str, jnp.ndarray]:
    di, N = cfg.d_inner, cfg.ssm_state
    nh, hd, ck = cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_conv_kernel
    conv_dim = di + 2 * N
    return {
        "ssm": jnp.zeros((L, batch, nh, N, hd), jnp.float32),
        "conv": jnp.zeros((L, batch, ck - 1, conv_dim), jnp.dtype(cfg.param_dtype)),
    }


def mixer_decode(
    cfg: ModelConfig,
    lp: Dict[str, jnp.ndarray],
    ssm_state: jnp.ndarray,   # (B, nh, N, hd)
    conv_state: jnp.ndarray,  # (B, k-1, conv_dim)
    u: jnp.ndarray,           # (B, 1, D)
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One-token recurrent update. Returns (out (B,1,D), ssm', conv')."""
    B_, _, D = u.shape
    di, N, nh, hd = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads, cfg.ssm_head_dim
    z, x, Bm, Cm, dt = _split_proj(cfg, lp, u)
    xbc = jnp.concatenate([x, Bm, Cm], axis=-1)[:, 0]      # (B, conv_dim)
    window = jnp.concatenate([conv_state, xbc[:, None]], axis=1)  # (B,k,conv)
    conv_out = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                          lp["conv_w"].astype(jnp.float32)) + lp["conv_b"]
    xbc = jax.nn.silu(conv_out)
    x, Bv, Cv = xbc[..., :di], xbc[..., di : di + N], xbc[..., di + N :]
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + lp["dt_bias"].astype(jnp.float32))  # (B,nh)
    A = -jnp.exp(lp["A_log"].astype(jnp.float32))
    xh = x.reshape(B_, nh, hd).astype(jnp.float32)
    decay = jnp.exp(dt * A)                                 # (B,nh)
    upd = jnp.einsum("bn,bhp,bh->bhnp", Bv.astype(jnp.float32), xh, dt)
    ssm_new = ssm_state * decay[..., None, None] + upd
    y = jnp.einsum("bn,bhnp->bhp", Cv.astype(jnp.float32), ssm_new)
    y = y + xh * lp["D_skip"][None, :, None].astype(jnp.float32)
    y = y.reshape(B_, 1, di).astype(u.dtype)
    y = cm.rms_norm(y * jax.nn.silu(z), lp["norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, lp["out"])
    return out, ssm_new, window[:, 1:].astype(conv_state.dtype)


# ---------------------------------------------------------------------------
# Full Mamba2 LM
# ---------------------------------------------------------------------------

def init(cfg: ModelConfig, key: jax.Array) -> Tuple[cm.Params, cm.Axes]:
    D, L, V = cfg.d_model, cfg.n_layers, cfg.padded_vocab
    b = cm.Builder(key, jnp.dtype(cfg.param_dtype))
    b.param("embed", (V, D), ("vocab", "embed"), scale=1.0)
    lb = b.child("layers")
    lb.param("ln", (L, D), ("layers", None), init="zeros")
    mixer_params(lb, cfg, L)
    b.param("final_norm", (D,), (None,), init="zeros")
    b.param("lm_head", (V, D), ("vocab", "embed"))
    return b.params, b.axes


def forward(cfg: ModelConfig, params: cm.Params, tokens: jnp.ndarray,
            remat: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    x = params["embed"][tokens].astype(jnp.dtype(cfg.compute_dtype))

    def body(x, lp):
        h = cm.rms_norm(x, lp["ln"], cfg.norm_eps)
        return x + mixer_forward(cfg, lp, h)

    if remat:
        body = cm.remat_wrap(body, cfg.remat_policy)

    def step(x, lp):
        return body(x, lp), None

    x, _ = cm.scan(step, x, params["layers"])
    x = cm.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", x, params["lm_head"]).astype(cm.logits_dtype(cfg))
    return logits, jnp.zeros((), jnp.float32)


def init_decode_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict[str, jnp.ndarray]:
    del max_len  # constant-size state: the SSM advantage
    return mixer_cache(cfg, cfg.n_layers, batch)


def cache_axes(cfg: ModelConfig, shape_name: str = "") -> Dict[str, Tuple]:
    return {
        "ssm": ("layers", "batch", "heads", None, None),
        "conv": ("layers", "batch", None, "ffn"),
    }


def decode_step(cfg, params, cache, token, pos):
    del pos
    x = params["embed"][token].astype(jnp.dtype(cfg.compute_dtype))

    def step(x, xs):
        lp, ssm_l, conv_l = xs
        h = cm.rms_norm(x, lp["ln"], cfg.norm_eps)
        out, ssm_l, conv_l = mixer_decode(cfg, lp, ssm_l, conv_l, h)
        return x + out, (ssm_l, conv_l)

    x, (ssm, conv) = cm.scan(step, x, (params["layers"], cache["ssm"], cache["conv"]))
    x = cm.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", x, params["lm_head"]).astype(jnp.float32)
    return logits[:, 0], {"ssm": ssm, "conv": conv}


def lm_loss(cfg: ModelConfig, params: cm.Params, batch: Dict[str, Any],
            remat: bool = True) -> jnp.ndarray:
    logits, _ = forward(cfg, params, batch["tokens"], remat=remat)
    return cm.next_token_ce(cfg, logits, batch["labels"])
