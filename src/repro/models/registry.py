"""Uniform model API over families.

Every family module exposes:
  init(cfg, key) -> (params, logical_axes)
  lm_loss(cfg, params, batch, remat) -> scalar
  forward-ish prefill entry (via ``prefill``)
  init_decode_cache(cfg, batch, max_len) / cache_axes(cfg, shape_name)
  decode_step(cfg, params, cache, token, pos) -> (logits, cache)

``batch`` contents per family (see launch/specs.py):
  dense/moe/ssm/hybrid: tokens, labels
  vlm:                  + patch_embeds (stub ViT frontend)
  encdec:               + audio_embeds (stub conv frontend)
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import jamba, mamba2, transformer, whisper

_FAMILY = {
    "dense": transformer,
    "moe": transformer,
    "vlm": transformer,
    "ssm": mamba2,
    "hybrid": jamba,
    "encdec": whisper,
}


def module_for(cfg: ModelConfig):
    try:
        return _FAMILY[cfg.family]
    except KeyError:
        raise ValueError(f"unknown family {cfg.family!r} for {cfg.name}") from None


def init(cfg: ModelConfig, key: jax.Array):
    return module_for(cfg).init(cfg, key)


def loss_fn(cfg: ModelConfig, params, batch: Dict[str, Any], remat: bool = True):
    return module_for(cfg).lm_loss(cfg, params, batch, remat=remat)


def prefill(cfg: ModelConfig, params, batch: Dict[str, Any]):
    """Full-sequence forward returning logits (inference-prefill shape)."""
    mod = module_for(cfg)
    if cfg.family == "encdec":
        logits, _ = mod.forward(cfg, params, batch["tokens"], batch["audio_embeds"])
    elif cfg.family == "vlm":
        logits, _ = mod.forward(cfg, params, batch["tokens"],
                                prefix_embeds=batch.get("patch_embeds"))
    else:
        logits, _ = mod.forward(cfg, params, batch["tokens"])
    return logits


def init_decode_cache(cfg: ModelConfig, batch: int, max_len: int):
    return module_for(cfg).init_decode_cache(cfg, batch, max_len)


def cache_axes(cfg: ModelConfig, shape_name: str = ""):
    return module_for(cfg).cache_axes(cfg, shape_name)


def decode_step(cfg: ModelConfig, params, cache, token, pos):
    return module_for(cfg).decode_step(cfg, params, cache, token, pos)
