"""Decoder-only transformer LM covering the dense, MoE and VLM families:

- granite-3-2b / granite-3-8b / phi4-mini (dense GQA + RoPE + SwiGLU)
- gemma2-27b (alternating local/global attention, logit softcapping)
- kimi-k2 (MoE 384e top-8 + shared expert), grok-1 (MoE 8e top-2)
- internvl2 (stub patch-embedding prefix + dense LM)

Layers are stacked along a leading ``L`` dim and executed with
``lax.scan`` (compile-time sanity for 61–64-layer configs); per-layer
heterogeneity (local/global windows) rides along as scanned scalars.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import common as cm


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


def layer_windows(cfg: ModelConfig) -> np.ndarray:
    """Per-layer sliding windows; 0 = full attention."""
    if cfg.local_global_alternating and cfg.sliding_window:
        w = np.zeros(cfg.n_layers, np.int32)
        w[0::2] = cfg.sliding_window  # even layers local, odd global
        return w
    if cfg.sliding_window and not cfg.local_global_alternating:
        return np.full(cfg.n_layers, cfg.sliding_window, np.int32)
    return np.zeros(cfg.n_layers, np.int32)


def init(cfg: ModelConfig, key: jax.Array) -> Tuple[cm.Params, cm.Axes]:
    D, L, V = cfg.d_model, cfg.n_layers, cfg.padded_vocab
    H, Hkv, dh, F = cfg.n_heads, cfg.n_kv_heads, cfg.dh, cfg.d_ff
    b = cm.Builder(key, _dtype(cfg))
    b.param("embed", (V, D), ("vocab", "embed"), scale=1.0)
    lb = b.child("layers")
    lb.param("ln1", (L, D), ("layers", None), init="zeros")
    lb.param("wq", (L, D, H, dh), ("layers", "embed", "heads", None))
    lb.param("wk", (L, D, Hkv, dh), ("layers", "embed", "kv", None))
    lb.param("wv", (L, D, Hkv, dh), ("layers", "embed", "kv", None))
    lb.param("wo", (L, H, dh, D), ("layers", "heads", None, "embed"))
    lb.param("ln2", (L, D), ("layers", None), init="zeros")
    if cfg.n_experts:
        E, Fe = cfg.n_experts, cfg.expert_d_ff
        lb.param("router", (L, D, E), ("layers", "embed", None))
        lb.param("w1", (L, E, D, Fe), ("layers", "experts", "embed", "ffn"))
        lb.param("w3", (L, E, D, Fe), ("layers", "experts", "embed", "ffn"))
        lb.param("w2", (L, E, Fe, D), ("layers", "experts", "ffn", "embed"))
        if cfg.n_shared_experts:
            Fs = cfg.n_shared_experts * Fe
            lb.param("sw1", (L, D, Fs), ("layers", "embed", "ffn"))
            lb.param("sw3", (L, D, Fs), ("layers", "embed", "ffn"))
            lb.param("sw2", (L, Fs, D), ("layers", "ffn", "embed"))
    else:
        lb.param("w1", (L, D, F), ("layers", "embed", "ffn"))
        lb.param("w3", (L, D, F), ("layers", "embed", "ffn"))
        lb.param("w2", (L, F, D), ("layers", "ffn", "embed"))
    b.param("final_norm", (D,), (None,), init="zeros")
    b.param("lm_head", (V, D), ("vocab", "embed"))
    return b.params, b.axes


def _layer_body(
    cfg: ModelConfig,
    x: jnp.ndarray,
    lp: Dict[str, jnp.ndarray],
    window: jnp.ndarray,
    positions: jnp.ndarray,
    chunk_q: int,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One transformer layer. Returns (x, aux_loss)."""
    h = cm.rms_norm(x, lp["ln1"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", h, lp["wq"])
    k = jnp.einsum("bsd,dhk->bshk", h, lp["wk"])
    v = jnp.einsum("bsd,dhk->bshk", h, lp["wv"])
    q = cm.apply_rope(q, positions, cfg.rope_theta)
    k = cm.apply_rope(k, positions, cfg.rope_theta)
    o = cm.attention(q, k, v, causal=True, window=window,
                     cap=cfg.attn_softcap, chunk_q=chunk_q,
                     score_dtype=jnp.float32 if cfg.attn_f32
                     else jnp.dtype(cfg.compute_dtype))
    x = x + jnp.einsum("bshk,hkd->bsd", o, lp["wo"])
    h = cm.rms_norm(x, lp["ln2"], cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if cfg.n_experts:
        y, aux = cm.moe_ffn(h, lp["router"], lp["w1"], lp["w3"], lp["w2"],
                            top_k=cfg.top_k, capacity_factor=cfg.capacity_factor)
        if cfg.n_shared_experts:
            y = y + cm.swiglu(h, lp["sw1"], lp["sw3"], lp["sw2"])
    else:
        y = cm.swiglu(h, lp["w1"], lp["w3"], lp["w2"])
    return x + y, aux


def forward(
    cfg: ModelConfig,
    params: cm.Params,
    tokens: jnp.ndarray,                       # (B, S) int32
    prefix_embeds: Optional[jnp.ndarray] = None,  # (B, P, D) VLM/stub
    remat: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence forward. Returns (logits (B, S_total, V), aux_loss)."""
    x = params["embed"][tokens].astype(jnp.dtype(cfg.compute_dtype))
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    S = x.shape[1]
    positions = jnp.arange(S)
    windows = jnp.asarray(layer_windows(cfg))
    chunk_q = 1024 if S >= 8192 else 0

    body = functools.partial(_layer_body, cfg, positions=positions, chunk_q=chunk_q)
    if remat:
        body = cm.remat_wrap(body, cfg.remat_policy)

    def step(carry, xs):
        x, aux = carry
        lp, w = xs
        x, a = body(x, lp, w)
        return (x, aux + a), None

    (x, aux), _ = cm.scan(step, (x, jnp.zeros((), jnp.float32)),
                               (params["layers"], windows))
    x = cm.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", x, params["lm_head"]).astype(cm.logits_dtype(cfg))
    logits = cm.softcap(logits, cfg.final_softcap)
    return logits, aux


# ---------------------------------------------------------------------------
# Decode path (single-token serve_step with KV cache)
# ---------------------------------------------------------------------------

def init_decode_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict[str, jnp.ndarray]:
    dt = jnp.dtype(cfg.param_dtype)
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.dh)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}


def cache_axes(cfg: ModelConfig, shape_name: str = "") -> Dict[str, Tuple]:
    """Logical axes for the KV cache: shard kv heads over model; for
    batch=1 long-context decode, shard the sequence over data instead."""
    if shape_name == "long_500k":
        ax = ("layers", None, "ctx", "kv", None)
    else:
        ax = ("layers", "batch", None, "kv", None)
    return {"k": ax, "v": ax}


def decode_step(
    cfg: ModelConfig,
    params: cm.Params,
    cache: Dict[str, jnp.ndarray],
    token: jnp.ndarray,     # (B, 1) int32
    pos: jnp.ndarray,       # scalar int32 — current position
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """One decode step: logits for the next token + updated cache."""
    x = params["embed"][token].astype(jnp.dtype(cfg.compute_dtype))  # (B,1,D)
    positions = pos + jnp.arange(1)
    windows = jnp.asarray(layer_windows(cfg))

    def step(x, xs):
        lp, w, k_l, v_l = xs
        h = cm.rms_norm(x, lp["ln1"], cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", h, lp["wq"])
        k = jnp.einsum("bsd,dhk->bshk", h, lp["wk"])
        v = jnp.einsum("bsd,dhk->bshk", h, lp["wv"])
        q = cm.apply_rope(q, positions, cfg.rope_theta)
        k = cm.apply_rope(k, positions, cfg.rope_theta)
        k_l = jax.lax.dynamic_update_slice(k_l, k.astype(k_l.dtype), (0, pos, 0, 0))
        v_l = jax.lax.dynamic_update_slice(v_l, v.astype(v_l.dtype), (0, pos, 0, 0))
        o = cm.attention(q, k_l, v_l, causal=False, window=w,
                         cap=cfg.attn_softcap, q_offset=pos, kv_len=pos + 1)
        x = x + jnp.einsum("bshk,hkd->bsd", o, lp["wo"])
        h = cm.rms_norm(x, lp["ln2"], cfg.norm_eps)
        if cfg.n_experts:
            y, _ = cm.moe_ffn(h, lp["router"], lp["w1"], lp["w3"], lp["w2"],
                              top_k=cfg.top_k, capacity_factor=cfg.capacity_factor)
            if cfg.n_shared_experts:
                y = y + cm.swiglu(h, lp["sw1"], lp["sw3"], lp["sw2"])
        else:
            y = cm.swiglu(h, lp["w1"], lp["w3"], lp["w2"])
        return x + y, (k_l, v_l)

    x, (ks, vs) = cm.scan(step, x, (params["layers"], windows, cache["k"], cache["v"]))
    x = cm.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", x, params["lm_head"]).astype(jnp.float32)
    logits = cm.softcap(logits, cfg.final_softcap)
    return logits[:, 0], {"k": ks, "v": vs}


# ---------------------------------------------------------------------------
# Loss / train-step building blocks
# ---------------------------------------------------------------------------

def lm_loss(cfg: ModelConfig, params: cm.Params, batch: Dict[str, jnp.ndarray],
            remat: bool = True) -> jnp.ndarray:
    """Next-token CE (+ router aux).  VLM prefix positions carry no loss."""
    prefix = batch.get("patch_embeds")
    logits, aux = forward(cfg, params, batch["tokens"], prefix_embeds=prefix, remat=remat)
    if prefix is not None:
        logits = logits[:, prefix.shape[1]:]
    return cm.next_token_ce(cfg, logits, batch["labels"]) + cfg.router_aux_coef * aux
