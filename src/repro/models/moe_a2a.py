"""Expert-parallel MoE dispatch with a TRUE all-to-all (shard_map).

The §Perf hillclimb showed that pinning the dispatched buffer to an
expert-sharded layout (`MOE_DISPATCH_SPEC`) removes the 16x compute
replication of the TP baseline, but XLA implements the token scatter as
all-gather(tokens)+select (~14 GB/layer/pass on kimi) — collective
became the dominant term.  This module is the next rung: an explicit
``shard_map`` dispatch where each data shard

  1. routes its local tokens (router weights are replicated),
  2. sorts them by destination expert shard (expert e lives on shard
     e // E_loc) into fixed-capacity per-destination send buffers,
  3. exchanges buffers with ``jax.lax.all_to_all`` (bytes moved =
     tokens x D x top_k x overflow factor — NOT the full token tensor),
  4. runs its local experts with the standard capacity dispatch,
  5. all-to-alls results back, unsorts, and combines with gates.

Per-device moved bytes on kimi train drop from ~14 GB/layer/pass
(all-gather) to ~0.9 GB (2 x T_loc·top_k·D·cap_factor / n_shards),
projected collective term 299 s -> ~20 s.

Expert weights must be sharded over the "data" axis on their leading
(expert) dim — the FSDP rule already does this (`experts -> data`).
"""
from __future__ import annotations

import functools
import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models import common as cm


def _local_expert_ffn(xb: jnp.ndarray, w1, w3, w2, model_axis=None) -> jnp.ndarray:
    # w1/w3 carry F/model_size columns and w2 F/model_size rows inside the
    # shard_map body: partial contributions are psum-reduced over "model".
    h = jnp.einsum("ecd,edf->ecf", xb, w1)
    g = jnp.einsum("ecd,edf->ecf", xb, w3)
    y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * g, w2)
    if model_axis is not None:
        y = jax.lax.psum(y, model_axis)
    return y


def moe_ffn_a2a(
    x: jnp.ndarray,        # (B, S, D) — sharded over axis_name on B
    router: jnp.ndarray,   # (D, E)    — replicated
    w1: jnp.ndarray,       # (E, D, F) — experts sharded over axis_name
    w3: jnp.ndarray,
    w2: jnp.ndarray,       # (E, F, D)
    *,
    top_k: int,
    mesh: jax.sharding.Mesh,
    axis_name: str = "data",
    capacity_factor: float = 1.25,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Expert-parallel token-choice MoE with explicit all-to-all.

    Returns (out (B,S,D), aux load-balance loss).  Call under jit with
    ``mesh``; inputs may carry any sharding — shard_map re-partitions.
    """
    from jax.sharding import PartitionSpec as P

    E = router.shape[1]
    F = w1.shape[-1]
    n_shards = mesh.shape[axis_name]
    assert E % n_shards == 0, (E, n_shards)
    e_loc = E // n_shards
    # keep the FFN dim tensor-parallel inside the body when divisible
    model_axis = "model" if ("model" in mesh.axis_names
                             and F % mesh.shape["model"] == 0
                             and mesh.shape["model"] > 1) else None

    def local_fn(xs, router, w1_l, w3_l, w2_l):
        # xs: (B_loc, S, D); w*_l: (E_loc, D, F)
        Bl, S, D = xs.shape
        T = Bl * S
        xt = xs.reshape(T, D)
        logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                            router.astype(jnp.float32))
        probs = jax.nn.softmax(logits, axis=-1)
        gate, eidx = jax.lax.top_k(probs, top_k)           # (T, k)
        gate = gate / (jnp.sum(gate, -1, keepdims=True) + 1e-9)
        # aux loss from local stats (psum-averaged)
        assign = jnp.zeros((T, E), jnp.float32).at[
            jnp.arange(T)[:, None], eidx].add(1.0)
        aux = E * jnp.mean(jnp.mean(assign, 0) * jnp.mean(probs, 0))
        aux = jax.lax.pmean(aux, axis_name)

        # ---- single-stage dispatch: sort by GLOBAL expert id -----------
        # Because experts are contiguous per shard (expert e lives on
        # shard e // e_loc), an expert-major send buffer is also
        # shard-major: one sort covers both the inter-shard exchange and
        # the per-expert grouping — after the all-to-all a transpose
        # (not a second sort/scatter chain) feeds the expert matmuls.
        flat_e = eidx.reshape(-1)                          # (T*k,)
        order = jnp.argsort(flat_e)                        # stable
        exp_s = flat_e[order]
        tok_s = order // top_k                             # source token id

        cap_e = int(math.ceil(T * top_k / E * capacity_factor))
        cap_e = max((cap_e + 7) // 8 * 8, 8)
        counts = jnp.bincount(flat_e, length=E)
        starts = jnp.cumsum(counts) - counts
        pos = jnp.arange(T * top_k) - starts[exp_s]
        keep = pos < cap_e
        slot = exp_s * cap_e + jnp.clip(pos, 0, cap_e - 1)
        slot = jnp.where(keep, slot, E * cap_e)            # OOB -> dropped

        send_x = jnp.zeros((E * cap_e, D), xs.dtype
                           ).at[slot].set(xt[tok_s], mode="drop")

        # ---- exchange: (n_shards, e_loc*cap_e, D) split along axis 0 ----
        recv_x = jax.lax.all_to_all(
            send_x.reshape(n_shards, e_loc * cap_e, D),
            axis_name, 0, 0, tiled=False)                  # (src, e_loc*cap_e, D)
        # regroup per local expert: (src, e_loc, cap_e, D) -> (e_loc, src*cap_e, D)
        buf = recv_x.reshape(n_shards, e_loc, cap_e, D) \
                    .transpose(1, 0, 2, 3).reshape(e_loc, n_shards * cap_e, D)
        yb = _local_expert_ffn(buf, w1_l, w3_l, w2_l, model_axis)

        # ---- return path (inverse transpose + all-to-all) ---------------
        back = yb.reshape(e_loc, n_shards, cap_e, D) \
                 .transpose(1, 0, 2, 3).reshape(n_shards, e_loc * cap_e, D)
        y_home = jax.lax.all_to_all(back, axis_name, 0, 0, tiled=False)
        y_flat = y_home.reshape(E * cap_e, D)
        # gather back to sorted token-slots, unsort, gate-combine over k
        y_slot = jnp.where(keep[:, None],
                           y_flat[jnp.clip(slot, 0, E * cap_e - 1)], 0)
        contrib = jnp.zeros((T * top_k, D), xs.dtype).at[order].set(y_slot)
        gate_f = gate.reshape(-1).astype(xs.dtype)
        out = jnp.sum((contrib * gate_f[:, None]).reshape(T, top_k, D), axis=1)
        return out.reshape(Bl, S, D), aux

    from jax.experimental.shard_map import shard_map

    w1_spec = P(axis_name, None, model_axis)
    w2_spec = P(axis_name, model_axis, None)
    fn = shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(axis_name), P(), w1_spec, w1_spec, w2_spec),
        out_specs=(P(axis_name), P()),
        check_rep=False,
    )
    return fn(x, router, w1, w3, w2)
