"""Soft-label wire codecs: quantization, sparsification, cache-delta.

The paper's entire value proposition is bytes-on-the-wire, so the wire
format deserves its own subsystem.  A :class:`Codec` models one lossy
soft-label payload format with three obligations:

- ``encode(z, ...) -> payload`` / ``decode(payload, ...) -> z_hat``:
  the wire round trip, pure jnp and fixed-shape (scan-safe — both
  engines apply codecs inside jitted round bodies);
- ``roundtrip(z, ...)``: ``decode(encode(z))`` fused where a kernel
  exists (the quant codecs run the Pallas
  :func:`repro.kernels.ops.quantize_dequantize` round trip in one VMEM
  pass);
- ``payload_bytes(n_samples, n_classes)``: the *analytic* per-client
  payload size, a pure arithmetic function of counts so the comm ledger
  stays bit-true in both the host loop and the traced ``lax.scan``
  engine.

Accounting convention (documented deviation): min-max quantizers charge
only the value bits (``n * N * bits / 8``), excluding the per-row
min/scale side info — the same convention the repo (and the paper's
Table V) already uses for CFD's quantized uplink, which keeps the two
ledgers comparable.

``CacheDeltaCodec`` is the SCARLET-specific one: clients transmit the
residual against the synchronized cache entry (``cache.cached_at``)
instead of the full label.  Since prediction and base both live on the
simplex the residual sums to zero, so one class is dropped on the wire
and reconstructed from the constraint — any inner quantizer therefore
pays for ``N - 1`` classes.

Registry: :func:`get_codec` first parses parameterized specs
(``"quant6"``, ``"topk4"``) and delta compositions
(``"cache_delta+quant8"``), then falls back to ``CODECS`` — a name ->
zero-arg-constructor map, the extension point for custom codecs
(``CODECS["my_codec"] = MyCodec`` makes ``get_codec("my_codec")`` and
the ``FLConfig`` codec fields resolve it).
"""
from __future__ import annotations

import re
from typing import Callable, Dict, Optional, Union

import jax
import jax.numpy as jnp

from repro.core import comm as comm_lib
from repro.kernels import ops as kops

__all__ = [
    "Codec",
    "IdentityCodec",
    "QuantCodec",
    "TopKCodec",
    "CacheDeltaCodec",
    "CODECS",
    "get_codec",
]

_EPS = 1e-9


def _simplex(z: jnp.ndarray) -> jnp.ndarray:
    """Project decoded labels back onto the simplex (clip + renorm)."""
    z = jnp.maximum(z, 0.0)
    return z / jnp.maximum(jnp.sum(z, axis=-1, keepdims=True), _EPS)


class Codec:
    """One soft-label wire format.  Subclasses override the hooks.

    ``z`` is ``(..., N)`` — codecs are applied to ``(K, m, N)`` client
    stacks on the uplink and ``(m, N)`` teachers on the downlink.
    ``base``/``present`` carry the synchronized cache entry at the
    round's request positions (``cache.cached_at``); codecs that don't
    delta-code ignore them.
    """

    name = "base"
    scan_safe = True  # pure jnp, fixed shapes: usable inside lax.scan

    @property
    def is_identity(self) -> bool:
        return False

    # wire round trip --------------------------------------------------
    def encode(self, z: jnp.ndarray, base: Optional[jnp.ndarray] = None,
               present: Optional[jnp.ndarray] = None):
        raise NotImplementedError

    def decode(self, payload, base: Optional[jnp.ndarray] = None,
               present: Optional[jnp.ndarray] = None) -> jnp.ndarray:
        raise NotImplementedError

    def roundtrip(self, z: jnp.ndarray, base: Optional[jnp.ndarray] = None,
                  present: Optional[jnp.ndarray] = None) -> jnp.ndarray:
        """What the receiver sees; fused override point for kernels."""
        return self.decode(self.encode(z, base, present), base, present)

    # analytic accounting ----------------------------------------------
    def payload_bytes(self, n_samples, n_classes: int):
        """Per-client payload bytes for ``n_samples`` labels.

        ``n_samples`` may be a python number or a traced jnp scalar
        (fractional under upload gating) — arithmetic only.
        """
        raise NotImplementedError


class IdentityCodec(Codec):
    """Dense fp32 labels — the no-compression reference point."""

    name = "identity"

    @property
    def is_identity(self) -> bool:
        return True

    def encode(self, z, base=None, present=None):
        return z

    def decode(self, payload, base=None, present=None):
        return payload

    def roundtrip(self, z, base=None, present=None):
        return z

    def payload_bytes(self, n_samples, n_classes):
        return n_samples * n_classes * comm_lib.BYTES_F32


class QuantCodec(Codec):
    """Per-row min-max uniform quantization to ``bits`` bits.

    The transform is exactly CFD's quantizer (Sattler et al.):
    ``2**bits - 1`` levels spanning each row's [min, max], round to
    nearest, dequantize.  ``renormalize=True`` (top-level use on
    probability rows) re-projects the dequantized row onto the simplex;
    residual use (inside :class:`CacheDeltaCodec`) turns it off.

    ``payload_bytes`` charges value bits only (see the module note on
    the side-info accounting convention).
    """

    def __init__(self, bits: int, renormalize: bool = True):
        if bits < 1:
            raise ValueError(f"need at least 1 bit, got {bits}")
        self.bits = int(bits)
        self.renormalize = renormalize
        self.name = f"quant{self.bits}"

    def encode(self, z, base=None, present=None):
        levels = float(2 ** self.bits - 1)
        zmin = z.min(axis=-1, keepdims=True)
        zmax = z.max(axis=-1, keepdims=True)
        scale = jnp.maximum(zmax - zmin, _EPS)
        q = jnp.round((z - zmin) / scale * levels)
        return {"q": q, "zmin": zmin, "scale": scale}

    def decode(self, payload, base=None, present=None):
        levels = float(2 ** self.bits - 1)
        deq = payload["q"] / levels * payload["scale"] + payload["zmin"]
        return _simplex(deq) if self.renormalize else deq

    def roundtrip(self, z, base=None, present=None):
        deq = kops.quantize_dequantize(z, self.bits)
        return _simplex(deq) if self.renormalize else deq

    def payload_bytes(self, n_samples, n_classes):
        return n_samples * n_classes * self.bits / 8.0


class TopKCodec(Codec):
    """Keep the ``k`` largest entries per row, zero the rest.

    The wire carries k fp32 values + k class indices per row
    (``index_bytes`` wide — uint8 suffices for every class count in the
    paper; pass :func:`repro.core.comm.index_bytes_for` of the class
    count, default the conservative 4-byte constant).  Top-level use
    renormalizes the survivors back onto the simplex; residual use
    (``renormalize=False``) selects by magnitude instead, since
    residuals are signed.
    """

    def __init__(self, k: int = 2, renormalize: bool = True,
                 index_bytes: float = comm_lib.BYTES_INDEX):
        if k < 1:
            raise ValueError(f"need k >= 1, got {k}")
        self.k = int(k)
        self.renormalize = renormalize
        self.index_bytes = float(index_bytes)
        self.name = f"topk{self.k}"

    def encode(self, z, base=None, present=None):
        score = z if self.renormalize else jnp.abs(z)
        _, idx = jax.lax.top_k(score, self.k)          # (..., k)
        values = jnp.take_along_axis(z, idx, axis=-1)
        # n_classes is the static dense width (a python int at trace
        # time), carried so decode can scatter without out-of-band state
        return {"values": values, "indices": idx, "n_classes": z.shape[-1]}

    def decode(self, payload, base=None, present=None):
        values, idx = payload["values"], payload["indices"]
        onehot = jax.nn.one_hot(idx, payload["n_classes"], dtype=values.dtype)
        dense = jnp.sum(values[..., None] * onehot, axis=-2)
        return _simplex(dense) if self.renormalize else dense

    def payload_bytes(self, n_samples, n_classes):
        return n_samples * self.k * (comm_lib.BYTES_F32 + self.index_bytes)


class CacheDeltaCodec(Codec):
    """Residual coding against the synchronized soft-label cache.

    SCARLET's cache is mirrored bit-exactly on every client (Alg. 2/3),
    so both ends of the wire share a prediction base for each request
    position: the cached entry where one exists (``present`` — including
    the stale value of an EXPIRED entry awaiting refresh), the uniform
    prior ``1/N`` where none does.  Clients encode ``z - base`` with the
    inner codec instead of ``z`` itself; after distillation on cached
    teachers the residuals are small, so coarse inner quantizers lose
    far less signal than they would on raw labels.

    Wire-size win: prediction and base both sum to one, so the residual
    sums to zero — the last class is dropped on the wire and
    reconstructed from the constraint, making the payload an
    ``(N-1)/N`` fraction of the inner codec's (exactly
    ``inner.payload_bytes(n, N - 1)``).

    ``inner`` composes any codec in residual mode (``renormalize=False``
    — residuals are signed and not on the simplex); identity inner gives
    pure delta coding (lossless, fp32 residuals, the byte win reduced to
    the dropped class).
    """

    def __init__(self, inner: Optional[Codec] = None):
        self.inner = inner if inner is not None else IdentityCodec()
        self.name = ("cache_delta" if self.inner.is_identity
                     else f"cache_delta+{self.inner.name}")
        self.scan_safe = self.inner.scan_safe

    def _base(self, z, base, present):
        n = z.shape[-1]
        if base is None:
            return jnp.full_like(z, 1.0 / n)
        if present is not None:
            base = jnp.where(present[..., None], base, 1.0 / n)
        return jnp.broadcast_to(base, z.shape)

    def encode(self, z, base=None, present=None):
        b = self._base(z, base, present)
        residual = (z - b)[..., :-1]  # last class implied by sum-zero
        return self.inner.encode(residual)

    def decode(self, payload, base=None, present=None):
        r = self.inner.decode(payload)
        r = jnp.concatenate([r, -jnp.sum(r, axis=-1, keepdims=True)], axis=-1)
        b = self._base(r, base, present)
        return _simplex(b + r)

    def roundtrip(self, z, base=None, present=None):
        b = self._base(z, base, present)
        r = self.inner.roundtrip((z - b)[..., :-1])
        r = jnp.concatenate([r, -jnp.sum(r, axis=-1, keepdims=True)], axis=-1)
        return _simplex(b + r)

    def payload_bytes(self, n_samples, n_classes):
        return self.inner.payload_bytes(n_samples, n_classes - 1)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

# Name -> zero-arg constructor.  The built-in parameterized families
# (quantB, topkK) are handled by get_codec's spec parser before this
# map is consulted; register custom codecs here.
CODECS: Dict[str, Callable[[], Codec]] = {
    "identity": IdentityCodec,
    "quant8": lambda: QuantCodec(8),
    "quant4": lambda: QuantCodec(4),
    "quant1": lambda: QuantCodec(1),
    "topk": TopKCodec,
    "cache_delta": CacheDeltaCodec,
}

_QUANT_RE = re.compile(r"^quant(\d+)$")
_TOPK_RE = re.compile(r"^topk(\d*)$")


def _make(spec: str, renormalize: bool = True,
          index_bytes: Optional[float] = None) -> Codec:
    m = _QUANT_RE.match(spec)
    if m:
        return QuantCodec(int(m.group(1)), renormalize=renormalize)
    m = _TOPK_RE.match(spec)
    if m:
        k = int(m.group(1)) if m.group(1) else 2
        return TopKCodec(k, renormalize=renormalize,
                         index_bytes=(comm_lib.BYTES_INDEX
                                      if index_bytes is None else index_bytes))
    factory = CODECS.get(spec)
    if factory is not None:
        return factory()
    raise ValueError(f"unknown codec spec: {spec!r} "
                     f"(known: {sorted(CODECS)}, or quantB / topkK)")


def get_codec(spec: Union[str, Codec, None], *,
              index_bytes: Optional[float] = None) -> Codec:
    """Resolve a codec spec: a Codec instance (returned as-is), ``None``
    (identity), a parameterized form (``"quant6"``, ``"topk4"``), a
    delta composition (``"cache_delta+quant8"``), or a ``CODECS``
    registry name.  ``index_bytes`` sets the per-index wire width of
    index-bearing codecs (top-k) so it can follow the run's
    ``FLConfig.index_bytes`` instead of the 4-byte default."""
    if spec is None:
        return IdentityCodec()
    if isinstance(spec, Codec):
        return spec
    spec = spec.strip()
    if spec.startswith("cache_delta"):
        rest = spec[len("cache_delta"):]
        if rest == "":
            return CacheDeltaCodec()
        if rest.startswith("+"):
            return CacheDeltaCodec(inner=_make(rest[1:], renormalize=False,
                                               index_bytes=index_bytes))
        raise ValueError(f"unknown codec spec: {spec!r}")
    return _make(spec, index_bytes=index_bytes)
