"""Soft-label codec subsystem: quantization, sparsification, and
cache-delta coding with analytic (bit-true) payload accounting.  See
``repro.compress.codecs`` for the protocol and the registry."""
from repro.compress.codecs import (  # noqa: F401
    CODECS,
    CacheDeltaCodec,
    Codec,
    IdentityCodec,
    QuantCodec,
    TopKCodec,
    get_codec,
)
