import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
on the production meshes, print memory/cost analysis, extract roofline
terms, and write one JSON artifact per combo.

The two os.environ lines above MUST run before any other import (jax
locks the device count on first init).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch granite-3-2b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--scheme fsdp]
"""


import argparse
import json
import sys
import traceback
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import SHAPES_BY_NAME, InputShape, ModelConfig
from repro.configs.registry import ARCHS, ASSIGNED
from repro.launch import roofline as rl
from repro.launch import sharding as sh
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import input_specs
from repro.models import common as cm
from repro.models import registry
from repro.obs.trace import now as _now
from repro.optim import get as get_opt

import contextlib
import dataclasses


@contextlib.contextmanager
def scan_unroll(flag: bool):
    """Fully unroll layer scans so cost/HLO analysis counts every layer
    (while-loop bodies are otherwise counted ONCE)."""
    prev = cm.SCAN_UNROLL
    cm.SCAN_UNROLL = flag
    try:
        yield
    finally:
        cm.SCAN_UNROLL = prev


def depth_of(cfg: ModelConfig) -> int:
    if cfg.family == "hybrid":
        return cfg.n_layers // cfg.attn_layer_period
    return cfg.n_layers


def with_depth(cfg: ModelConfig, d: int) -> ModelConfig:
    if cfg.family == "hybrid":
        return dataclasses.replace(cfg, n_layers=cfg.attn_layer_period * d)
    if cfg.family == "encdec":
        return dataclasses.replace(cfg, n_layers=d, n_encoder_layers=d)
    return dataclasses.replace(cfg, n_layers=d)

# (arch, shape) combos skipped with reasons (see DESIGN.md §Arch-applicability)
SKIPS: Dict[tuple, str] = {
    (a, "long_500k"): "pure full-attention arch: 500k dense KV cache unsupported "
                      "without sliding-window/block-sparse variant"
    for a in ("kimi-k2-1t-a32b", "internvl2-26b", "grok-1-314b",
              "granite-3-2b", "phi4-mini-3.8b", "granite-3-8b",
              "whisper-large-v3")
}


# per-combo config overrides (documented deviations, DESIGN.md §4):
# gemma2 long-context serving runs all layers in local (sliding-window)
# mode — its global layers would otherwise need a dense 500k KV score.
COMBO_OVERRIDES: Dict[tuple, Dict[str, Any]] = {
    ("gemma2-27b", "long_500k"): {"local_global_alternating": False},
}


def _abstract_init(cfg: ModelConfig):
    """Param ShapeDtypeStructs + logical axes without allocating anything."""
    captured: Dict[str, Any] = {}

    def f(key):
        p, axes = registry.init(cfg, key)
        captured["axes"] = axes
        return p

    shapes = jax.eval_shape(f, jax.random.PRNGKey(0))
    return shapes, captured["axes"]


def make_train_step(cfg: ModelConfig, opt):
    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: registry.loss_fn(cfg, p, batch, remat=True))(params)
        params, opt_state = opt.update(grads, opt_state, params, 3e-4)
        return loss, params, opt_state

    return train_step


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        return registry.prefill(cfg, params, batch)

    return prefill_step


def make_serve_step(cfg: ModelConfig):
    def serve_step(params, cache, token, pos):
        return registry.decode_step(cfg, params, cache, token, pos)

    return serve_step


def lower_one(cfg: ModelConfig, shape: InputShape, mesh, scheme: str,
              optimizer: str = "adamw"):
    """Returns (lowered, compiled, specs_meta)."""
    params_shapes, axes = _abstract_init(cfg)
    p_shard = sh.param_shardings(axes, params_shapes, mesh, scheme)
    with mesh:
        if shape.mode == "train":
            opt = get_opt(optimizer, state_dtype="bfloat16") \
                if optimizer == "adamw" else get_opt(optimizer)
            opt_shapes = jax.eval_shape(opt.init, params_shapes)
            o_shard = sh.opt_state_shardings(p_shard, opt_shapes, mesh)
            batch_specs = input_specs(cfg, shape)
            b_shard = {k: NamedSharding(mesh, sh.batch_spec(mesh))
                       for k in batch_specs}
            fn = jax.jit(
                make_train_step(cfg, opt),
                in_shardings=(p_shard, o_shard, b_shard),
                out_shardings=(NamedSharding(mesh, P()), p_shard, o_shard),
                donate_argnums=(0, 1),
            )
            lowered = fn.lower(params_shapes, opt_shapes, batch_specs)
        elif shape.mode == "prefill":
            batch_specs = input_specs(cfg, shape)
            b_shard = {k: NamedSharding(mesh, sh.batch_spec(mesh))
                       for k in batch_specs}
            fn = jax.jit(
                make_prefill_step(cfg),
                in_shardings=(p_shard, b_shard),
                out_shardings=NamedSharding(mesh, sh.batch_spec(mesh)),
            )
            lowered = fn.lower(params_shapes, batch_specs)
        else:  # decode
            token_spec, pos_spec, cache_specs = input_specs(cfg, shape)
            c_axes = registry.cache_axes(cfg, shape.name)
            c_shard = sh.cache_shardings(c_axes, cache_specs, mesh)
            tok_shard = NamedSharding(
                mesh, sh.batch_spec(mesh) if shape.global_batch > 1 else P())
            fn = jax.jit(
                make_serve_step(cfg),
                in_shardings=(p_shard, c_shard, tok_shard, NamedSharding(mesh, P())),
                out_shardings=(tok_shard, c_shard),
                donate_argnums=(1,),
            )
            lowered = fn.lower(params_shapes, cache_specs, token_spec, pos_spec)
        compiled = lowered.compile()
    return lowered, compiled


def run_combo(arch: str, shape_name: str, multi_pod: bool, scheme: str,
              out_dir: str = "experiments/artifacts", optimizer: str = "adamw",
              verbose: bool = True, roofline: bool = True,
              cfg_overrides: Dict[str, Any] | None = None,
              variant: str = "", moe_a2a: bool = False) -> Dict[str, Any]:
    cfg = ARCHS[arch]
    combo_over = COMBO_OVERRIDES.get((arch, shape_name), {})
    if combo_over:
        cfg = dataclasses.replace(cfg, **combo_over)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    shape = SHAPES_BY_NAME[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    result: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name, "scheme": scheme,
        "variant": variant, "cfg_overrides": dict(cfg_overrides or {}),
    }
    if (arch, shape_name) in SKIPS:
        result["status"] = "skipped"
        result["reason"] = SKIPS[(arch, shape_name)]
        _write(result, out_dir)
        if verbose:
            print(f"[SKIP] {arch} x {shape_name}: {result['reason']}")
        return result

    t0 = _now()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        chips = int(np.prod(mesh.devices.shape))
        if moe_a2a:
            cm.MOE_A2A_MESH = mesh

        # (a) FULL config, scanned: proves the combo lowers + compiles on
        # the production mesh and yields the true per-device memory plan.
        with scan_unroll(False):
            _, compiled_full = lower_one(cfg, shape, mesh, scheme, optimizer)
        mem = compiled_full.memory_analysis()
        bytes_per_device = float(
            getattr(mem, "argument_size_in_bytes", 0)
            + getattr(mem, "temp_size_in_bytes", 0)
            + getattr(mem, "output_size_in_bytes", 0))

        if not roofline:
            # multi-pod pass: compile proof + memory plan only (the
            # roofline table is single-pod per the experiment plan)
            result["status"] = "ok"
            result["compile_s"] = _now() - t0
            result["bytes_per_device"] = bytes_per_device
            result["memory_analysis"] = {
                k: float(getattr(mem, k, 0)) for k in (
                    "argument_size_in_bytes", "output_size_in_bytes",
                    "temp_size_in_bytes", "alias_size_in_bytes")
            }
            if verbose:
                print(f"[OK]   {arch} x {shape_name} ({mesh_name}, {scheme}) "
                      f"compile={result['compile_s']:.1f}s "
                      f"per-dev-mem={bytes_per_device/1e9:.2f}GB (compile-proof only)")
            _write(result, out_dir)
            return result

        # (b) two UNROLLED depths: exact per-layer deltas for the
        # linear-in-depth roofline quantities, extrapolated to full depth
        # (layers are homogeneous; embed/head costs live in the base term).
        # Hybrid blocks are 8 sublayers each -> use depths (1, 2).
        depths = (1, 2) if cfg.family == "hybrid" else (2, 4)
        metrics = {}
        for d in depths:
            with scan_unroll(True):
                _, comp_d = lower_one(with_depth(cfg, d), shape, mesh,
                                      scheme, optimizer)
            cost_d = comp_d.cost_analysis()
            if isinstance(cost_d, list):
                cost_d = cost_d[0]
            from repro.launch import hlo_analysis as ha
            summ = ha.analyze(comp_d.as_text())
            metrics[d] = {
                "flops": summ.dot_flops,
                "bytes": float(cost_d.get("bytes accessed",
                                          cost_d.get("bytes_accessed", 0.0))),
                "coll": summ.collective_bytes,
                "coll_by_kind": summ.collective_by_kind,
                "coll_counts": summ.collective_counts,
                "whiles": summ.residual_while_loops,
                "xla_flops": float(cost_d.get("flops", 0.0)),
            }
        D_full = depth_of(cfg)
        d1, d2 = depths
        span = float(d2 - d1)

        def _extrap(key):
            per_layer = (metrics[d2][key] - metrics[d1][key]) / span
            return metrics[d1][key] + per_layer * (D_full - d1)

        kinds = set(metrics[d1]["coll_by_kind"]) | set(metrics[d2]["coll_by_kind"])
        coll_by_kind = {}
        coll_counts = {}
        for k in kinds:
            a1 = metrics[d1]["coll_by_kind"].get(k, 0.0)
            a2 = metrics[d2]["coll_by_kind"].get(k, 0.0)
            coll_by_kind[k] = a1 + (a2 - a1) / span * (D_full - d1)
            c1 = metrics[d1]["coll_counts"].get(k, 0)
            c2 = metrics[d2]["coll_counts"].get(k, 0)
            coll_counts[k] = int(round(c1 + (c2 - c1) / span * (D_full - d1)))

        import repro.launch.hlo_analysis as _ha
        summary = _ha.HloSummary(
            dot_flops=_extrap("flops"),
            transcendental_elems=0.0,
            collective_bytes=_extrap("coll"),
            collective_by_kind=coll_by_kind,
            collective_counts=coll_counts,
            residual_while_loops=max(metrics[d1]["whiles"], metrics[d2]["whiles"]),
        )
        roof = rl.compute_roofline_from_summary(
            arch=arch, shape=shape_name, mesh_name=mesh_name, scheme=scheme,
            chips=chips, summary=summary,
            bytes_accessed=_extrap("bytes"),
            xla_flops=_extrap("xla_flops"),
            model_flops=rl.model_flops_for(cfg, shape),
            bytes_per_device=bytes_per_device,
        )
        result.update(roof.as_dict())
        result["status"] = "ok"
        result["compile_s"] = _now() - t0
        result["memory_analysis"] = {
            k: float(getattr(mem, k, 0)) for k in (
                "argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "alias_size_in_bytes",
                "generated_code_size_in_bytes")
        }
        if verbose:
            print(f"[OK]   {arch} x {shape_name} ({mesh_name}, {scheme}) "
                  f"compile={result['compile_s']:.1f}s "
                  f"flops/dev={roof.hlo_gflops_per_device:.1f}G "
                  f"hbm/dev={roof.hlo_gbytes_per_device:.1f}G "
                  f"coll/dev={roof.collective_gbytes_per_device:.3f}G "
                  f"terms(c/m/n)={roof.compute_s*1e3:.2f}/{roof.memory_s*1e3:.2f}/"
                  f"{roof.collective_s*1e3:.2f}ms bottleneck={roof.bottleneck} "
                  f"useful={roof.useful_flops_ratio:.2f} "
                  f"per-dev-mem={bytes_per_device/1e9:.2f}GB")
            print(f"       memory_analysis: {result['memory_analysis']}")
            print(f"       cost_analysis(xla): flops={roof.cost_analysis_gflops*1e9:.3e}; "
                  f"whiles_left={roof.residual_while_loops}")
    except Exception as e:  # noqa: BLE001 — a failed combo is a bug to record
        result["status"] = "error"
        result["error"] = f"{type(e).__name__}: {e}"
        result["traceback"] = traceback.format_exc()[-2000:]
        result["compile_s"] = _now() - t0
        if verbose:
            print(f"[FAIL] {arch} x {shape_name} ({mesh_name}, {scheme}): "
                  f"{result['error']}")
    finally:
        cm.MOE_A2A_MESH = None
    _write(result, out_dir)
    return result


def _write(result: Dict[str, Any], out_dir: str) -> None:
    os.makedirs(out_dir, exist_ok=True)
    fname = (f"{result['arch']}__{result['shape']}__{result['mesh']}"
             f"__{result['scheme']}"
             + (f"__{result['variant']}" if result.get("variant") else "")
             + ".json")
    with open(os.path.join(out_dir, fname), "w") as f:
        json.dump(result, f, indent=2, default=str)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=sorted(ASSIGNED), default=None)
    ap.add_argument("--shape", choices=sorted(SHAPES_BY_NAME), default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--scheme", choices=("tp", "fsdp"), default="fsdp")
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--out", default="experiments/artifacts")
    ap.add_argument("--no-roofline", action="store_true",
                    help="compile-proof only (skip depth-2/4 roofline pass)")
    args = ap.parse_args(argv)

    combos = []
    if args.all:
        for a in ASSIGNED:
            for s in SHAPES_BY_NAME:
                combos.append((a, s))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        combos = [(args.arch, args.shape)]

    failures = 0
    for a, s in combos:
        r = run_combo(a, s, args.multi_pod, args.scheme, args.out,
                      args.optimizer, roofline=not args.no_roofline)
        failures += r["status"] == "error"
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
