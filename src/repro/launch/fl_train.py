"""Federated-distillation launcher (the paper's training driver).

  PYTHONPATH=src python -m repro.launch.fl_train --method scarlet \
      --rounds 300 --alpha 0.05 --cache-duration 25 --beta 1.5

Runs any implemented method with exact communication accounting and
writes a JSON history (accuracy vs cumulative bytes) for analysis.
``--telemetry`` additionally records device-plane round telemetry
(:mod:`repro.obs`) into the history and exports the host-plane span
trace as a Perfetto-loadable ``*.trace.json`` sibling.
"""
from __future__ import annotations

import argparse
import json
import os

from repro.fl.engine import FLConfig, run_method
from repro.obs import SpanTracer
from repro.obs import export as obs_export

METHOD_DEFAULTS = {
    "scarlet": dict(cache_duration=50, beta=1.5),
    "dsfl": dict(T=0.1),
    "cfd": dict(),
    "comet": dict(n_clusters=2),
    "selective_fd": dict(tau_client=0.0625),
    "mean": dict(),
    "fedavg": dict(),
    "individual": dict(),
}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--method", choices=sorted(METHOD_DEFAULTS), default="scarlet")
    ap.add_argument("--rounds", type=int, default=300)
    ap.add_argument("--clients", type=int, default=12)
    ap.add_argument("--alpha", type=float, default=0.05)
    ap.add_argument("--participation", type=float, default=1.0)
    ap.add_argument("--cache-duration", type=int, default=None)
    ap.add_argument("--beta", type=float, default=None)
    ap.add_argument("--temperature", type=float, default=None)
    ap.add_argument("--use-cache", action="store_true",
                    help="plug the soft-label cache into a non-SCARLET method")
    ap.add_argument("--telemetry", action="store_true",
                    help="record device-plane round telemetry (repro.obs) "
                         "and export the span trace")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="experiments/fl_runs")
    args = ap.parse_args()

    cfg = FLConfig(
        n_clients=args.clients, n_classes=10, dim=16, rounds=args.rounds,
        public_size=1200, public_per_round=120, private_size=1500,
        alpha=args.alpha, participation=args.participation,
        cluster_scale=2.0, noise=2.5,
        eval_every=max(args.rounds // 20, 1), seed=args.seed,
    )
    kw = dict(METHOD_DEFAULTS[args.method])
    if args.beta is not None:
        kw["beta"] = args.beta
    if args.temperature is not None:
        kw["T"] = args.temperature
    if args.cache_duration is not None:
        kw["cache_duration"] = args.cache_duration
    if args.use_cache:
        kw["use_cache"] = True
        kw.setdefault("cache_duration", 25)
    if args.telemetry:
        kw["telemetry"] = True

    # monotonic span clock (obs.trace.now — never jumps on NTP/DST)
    tracer = SpanTracer("fl_train", meta={"method": args.method})
    with tracer.span("run", method=args.method, rounds=args.rounds) as sp:
        hist = run_method(args.method, cfg, **kw)
    dt = sp.dur_s
    s = hist.ledger.summary()

    def _acc(v):  # None = never evaluated (e.g. Individual's server)
        return "n/a" if v is None else f"{v:.3f}"

    print(f"{args.method}: server_acc={_acc(hist.final_server_acc)} "
          f"client_acc={_acc(hist.final_client_acc)} "
          f"uplink={s['uplink_mean']/1e3:.1f}KB/rnd "
          f"cum={s['cumulative_total']/1e6:.2f}MB wall={dt:.1f}s")

    os.makedirs(args.out, exist_ok=True)
    fname = f"{args.method}_a{args.alpha}_p{args.participation}_s{args.seed}.json"
    with open(os.path.join(args.out, fname), "w") as f:
        json.dump({"config": cfg.__dict__, "method": args.method,
                   "strategy_kwargs": {k: v for k, v in kw.items()},
                   "history": hist.as_dict(), "wall_s": dt,
                   "spans": tracer.jsonl_lines()}, f, indent=2)
    print(f"history -> {os.path.join(args.out, fname)}")
    if args.telemetry:
        tpath = os.path.join(args.out, fname[:-5] + ".trace.json")
        obs_export.write_chrome_trace(tpath, tracer)
        print(f"trace -> {tpath}")


if __name__ == "__main__":
    main()
