"""Generate EXPERIMENTS.md §Dry-run / §Roofline tables from the JSON
artifacts written by repro.launch.dryrun.

  PYTHONPATH=src python -m repro.launch.report [--artifacts experiments/artifacts]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from collections import defaultdict
from typing import Dict, List


def load(art_dir: str) -> List[Dict]:
    rows = []
    for f in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(f) as fh:
            rows.append(json.load(fh))
    return rows


def _fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.2f}ms"
    return f"{x*1e6:.0f}us"


def dryrun_table(rows: List[Dict], mesh: str, scheme: str) -> str:
    out = [
        f"### Mesh {mesh}, scheme `{scheme}`\n",
        "| arch | shape | status | compile | per-dev mem (GB) | flops/dev (G) "
        "| HBM/dev (GB) | coll/dev (GB) | collectives |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r["mesh"] != mesh or r["scheme"] != scheme:
            continue
        if r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | SKIP | — | — | — | — | — | "
                       f"{r['reason'][:60]}… |")
            continue
        if r["status"] == "error":
            out.append(f"| {r['arch']} | {r['shape']} | **FAIL** | — | — | — | — | — | "
                       f"{r['error'][:60]} |")
            continue
        if "hlo_gflops_per_device" not in r:  # compile-proof-only artifact
            out.append(
                f"| {r['arch']} | {r['shape']} | ok | {r['compile_s']:.0f}s "
                f"| {r['bytes_per_device']/1e9:.1f} | — | — | — | compile-proof |")
            continue
        colls = ", ".join(f"{k}x{v}" for k, v in sorted(
            r.get("collective_counts", {}).items()))
        out.append(
            f"| {r['arch']} | {r['shape']} | ok | {r['compile_s']:.0f}s "
            f"| {r['bytes_per_device']/1e9:.1f} "
            f"| {r['hlo_gflops_per_device']:.0f} "
            f"| {r['hlo_gbytes_per_device']:.0f} "
            f"| {r['collective_gbytes_per_device']:.2f} "
            f"| {colls} |")
    return "\n".join(out) + "\n"


def roofline_table(rows: List[Dict], mesh: str, scheme: str) -> str:
    out = [
        f"### Roofline — mesh {mesh}, scheme `{scheme}` "
        "(terms per device over per-chip peaks: 197 TF/s bf16, 819 GB/s HBM, 50 GB/s link)\n",
        "| arch | shape | compute | memory | collective | bottleneck "
        "| MODEL_GF | HLO_GF(fleet) | useful | note |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r["mesh"] != mesh or r["scheme"] != scheme or r["status"] != "ok":
            continue
        if "compute_s" not in r:  # compile-proof-only artifact
            continue
        note = _note_for(r)
        out.append(
            f"| {r['arch']} | {r['shape']} | {_fmt_s(r['compute_s'])} "
            f"| {_fmt_s(r['memory_s'])} | {_fmt_s(r['collective_s'])} "
            f"| **{r['bottleneck']}** | {r['model_gflops']:.0f} "
            f"| {r['hlo_gflops']:.0f} | {r['useful_flops_ratio']:.2f} | {note} |")
    return "\n".join(out) + "\n"


def _note_for(r: Dict) -> str:
    """One sentence on what would move the dominant term down."""
    b = r["bottleneck"]
    shape = r["shape"]
    if b == "memory":
        if shape.startswith("decode") or shape.startswith("long"):
            return ("KV/state reads dominate: shard KV heads (or sequence) "
                    "further / quantize cache to int8")
        return ("activation+logit traffic dominates: fused flash-attention "
                "kernel + bf16 logits + saner remat policy")
    if b == "collective":
        return ("comm-bound: move grad sync to reduce-scatter (FSDP), "
                "overlap collectives with compute, shrink TP degree")
    return "MXU-bound: good — increase per-chip batch or sharpen kernels"


def summarize(rows: List[Dict]) -> str:
    counts = defaultdict(int)
    for r in rows:
        counts[(r["mesh"], r["scheme"], r["status"])] += 1
    lines = ["| mesh | scheme | ok | skipped | failed |", "|---|---|---|---|---|"]
    seen = sorted({(r["mesh"], r["scheme"]) for r in rows})
    for mesh, scheme in seen:
        lines.append(
            f"| {mesh} | {scheme} | {counts[(mesh, scheme, 'ok')]} "
            f"| {counts[(mesh, scheme, 'skipped')]} "
            f"| {counts[(mesh, scheme, 'error')]} |")
    return "\n".join(lines) + "\n"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--artifacts", default="experiments/artifacts")
    ap.add_argument("--out", default="")
    args = ap.parse_args()
    rows = load(args.artifacts)
    chunks = ["## Dry-run summary\n", summarize(rows)]
    meshes = sorted({(r["mesh"], r["scheme"]) for r in rows})
    for mesh, scheme in meshes:
        chunks.append(dryrun_table(rows, mesh, scheme))
    chunks.append("\n## Roofline\n")
    for mesh, scheme in meshes:
        chunks.append(roofline_table(rows, mesh, scheme))
    text = "\n".join(chunks)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text)
    else:
        print(text)


if __name__ == "__main__":
    main()
