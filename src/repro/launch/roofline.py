"""Roofline-term extraction from compiled dry-run artifacts.

  compute term    = HLO_FLOPs / (chips * PEAK_FLOPS)
  memory term     = HLO_bytes / (chips * HBM_BW)
  collective term = collective_bytes / (chips * LINK_BW)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()`` (whole-
program, all chips).  collective_bytes is parsed from the (post-SPMD)
HLO text: we sum the max inline shape per all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute instruction (the max of
output/operand shapes printed on the line = bytes a participant moves).

Hardware peaks are a :class:`HardwareSpec` parameter (``HW_PRESETS``
has the named chips); the default stays TPU v5e — 197 TFLOP/s bf16,
819 GB/s HBM, ~50 GB/s/link ICI — which the legacy module constants
alias for back-compat.
"""
from __future__ import annotations

import re
from dataclasses import asdict, dataclass
from typing import Dict, List, Optional, Tuple, Union


@dataclass(frozen=True)
class HardwareSpec:
    """Per-chip peaks the roofline terms divide by."""
    name: str
    peak_flops: float        # FLOP/s / chip (dense bf16)
    hbm_bw: float            # bytes/s / chip
    link_bw: float           # bytes/s / link (ICI / host interconnect)


HW_PRESETS: Dict[str, HardwareSpec] = {
    "tpu_v5e": HardwareSpec("tpu_v5e", 197e12, 819e9, 50e9),
    "tpu_v4": HardwareSpec("tpu_v4", 275e12, 1228e9, 100e9),
    "tpu_v5p": HardwareSpec("tpu_v5p", 459e12, 2765e9, 100e9),
    # CPU host numbers for dev-container dry runs: the absolute seconds
    # are nonsense there, but the *ratios* (which term dominates) still
    # rank program variants
    "cpu_host": HardwareSpec("cpu_host", 1e12, 100e9, 25e9),
}

DEFAULT_HW = HW_PRESETS["tpu_v5e"]


def resolve_hw(hw: Union[str, HardwareSpec, None]) -> HardwareSpec:
    """A HardwareSpec from a preset name, a spec, or None (default)."""
    if hw is None:
        return DEFAULT_HW
    if isinstance(hw, HardwareSpec):
        return hw
    if hw not in HW_PRESETS:
        raise ValueError(f"unknown hardware preset {hw!r} "
                         f"(want one of {sorted(HW_PRESETS)})")
    return HW_PRESETS[hw]


# legacy aliases: the pre-HardwareSpec module constants (TPU v5e peaks)
PEAK_FLOPS = DEFAULT_HW.peak_flops
HBM_BW = DEFAULT_HW.hbm_bw
LINK_BW = DEFAULT_HW.link_bw

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> float:
    if dtype not in _DTYPE_BYTES:
        return 0.0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> Tuple[float, Dict[str, float], Dict[str, int]]:
    """Sum of per-instruction max inline shape over collective ops.

    Returns (total_bytes, bytes_by_kind, count_by_kind)."""
    by_kind: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    counts: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if "=" not in stripped:
            continue
        rhs = stripped.split("=", 1)[1]
        kind = None
        for k in _COLLECTIVES:
            # match the opcode, not fused names: " all-reduce(" or "all-reduce-start("
            if re.search(rf"\b{k}(-start)?\(", rhs):
                kind = k
                break
        if kind is None:
            continue
        sizes = [_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(stripped)]
        if sizes:
            by_kind[kind] += max(sizes)
            counts[kind] += 1
    return sum(by_kind.values()), by_kind, counts


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    scheme: str
    chips: int
    hlo_gflops: float            # whole-fleet dot FLOPs (per-dev x chips)
    hlo_gflops_per_device: float
    hlo_gbytes_per_device: float  # HBM bytes accessed per device
    collective_gbytes_per_device: float
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_gflops: float          # 6*N*D (or 6*N_active*D)
    useful_flops_ratio: float    # model / hlo (whole-fleet)
    bytes_per_device: float      # peak per-device memory (args+temps)
    collective_counts: Dict[str, int]
    collective_by_kind_gb: Dict[str, float]
    residual_while_loops: int
    cost_analysis_gflops: float  # XLA's own (unreliable on CPU) number
    hw: str = DEFAULT_HW.name    # HardwareSpec the rate terms divide by

    def as_dict(self):
        return asdict(self)


def compute_roofline(
    *,
    arch: str,
    shape: str,
    mesh_name: str,
    scheme: str,
    chips: int,
    cost: Dict[str, float],
    hlo_text: str,
    model_flops: float,
    bytes_per_device: float,
    hw: Union[str, HardwareSpec, None] = None,
) -> Roofline:
    """All rate terms are per-device over per-chip peaks (the SPMD module
    is the per-device program); whole-fleet figures are x chips."""
    from repro.launch import hlo_analysis as ha

    hw = resolve_hw(hw)
    summary = ha.analyze(hlo_text)
    flops_dev = summary.dot_flops
    # 'bytes accessed' from cost_analysis is per-device (elementwise +
    # fusion operands); reliable because layer scans are fully unrolled.
    bytes_dev = float(cost.get("bytes accessed", cost.get("bytes_accessed", 0.0)))
    coll_dev = summary.collective_bytes
    compute_s = flops_dev / hw.peak_flops
    memory_s = bytes_dev / hw.hbm_bw
    coll_s = coll_dev / hw.link_bw
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    bottleneck = max(terms, key=terms.get)
    fleet_flops = flops_dev * chips
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, scheme=scheme, chips=chips,
        hlo_gflops=fleet_flops / 1e9,
        hlo_gflops_per_device=flops_dev / 1e9,
        hlo_gbytes_per_device=bytes_dev / 1e9,
        collective_gbytes_per_device=coll_dev / 1e9,
        compute_s=compute_s, memory_s=memory_s, collective_s=coll_s,
        bottleneck=bottleneck,
        model_gflops=model_flops / 1e9,
        useful_flops_ratio=(model_flops / fleet_flops) if fleet_flops else 0.0,
        bytes_per_device=bytes_per_device,
        collective_counts=summary.collective_counts,
        collective_by_kind_gb={k: v / 1e9 for k, v in summary.collective_by_kind.items() if v},
        residual_while_loops=summary.residual_while_loops,
        cost_analysis_gflops=float(cost.get("flops", 0.0)) / 1e9,
        hw=hw.name,
    )


def compute_roofline_from_summary(
    *,
    arch: str,
    shape: str,
    mesh_name: str,
    scheme: str,
    chips: int,
    summary,                    # hlo_analysis.HloSummary (possibly extrapolated)
    bytes_accessed: float,      # per-device HBM bytes
    xla_flops: float,
    model_flops: float,
    bytes_per_device: float,
    hw: Union[str, HardwareSpec, None] = None,
) -> Roofline:
    hw = resolve_hw(hw)
    flops_dev = summary.dot_flops
    compute_s = flops_dev / hw.peak_flops
    memory_s = bytes_accessed / hw.hbm_bw
    coll_s = summary.collective_bytes / hw.link_bw
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    bottleneck = max(terms, key=terms.get)
    fleet_flops = flops_dev * chips
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, scheme=scheme, chips=chips,
        hlo_gflops=fleet_flops / 1e9,
        hlo_gflops_per_device=flops_dev / 1e9,
        hlo_gbytes_per_device=bytes_accessed / 1e9,
        collective_gbytes_per_device=summary.collective_bytes / 1e9,
        compute_s=compute_s, memory_s=memory_s, collective_s=coll_s,
        bottleneck=bottleneck,
        model_gflops=model_flops / 1e9,
        useful_flops_ratio=(model_flops / fleet_flops) if fleet_flops else 0.0,
        bytes_per_device=bytes_per_device,
        collective_counts=summary.collective_counts,
        collective_by_kind_gb={k: v / 1e9 for k, v in summary.collective_by_kind.items() if v},
        residual_while_loops=summary.residual_while_loops,
        cost_analysis_gflops=xla_flops / 1e9,
        hw=hw.name,
    )


def model_flops_for(cfg, shape) -> float:
    """MODEL_FLOPS: 6*N*D for training; 2*N*D for inference (per forward);
    MoE uses active params."""
    n = cfg.active_param_count()
    if shape.mode == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch
