"""Production mesh construction.

Defined as FUNCTIONS (not module-level constants) so importing never
touches jax device state.  The production pod is 16x16 = 256 chips
(TPU v5e); multi-pod doubles it with a leading "pod" axis (2x16x16 =
512 chips) carrying pure data parallelism across the DCN/ICI boundary.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 4) -> jax.sharding.Mesh:
    """Small mesh for CI-scale sharding tests (requires
    xla_force_host_platform_device_count >= data*model)."""
    return jax.make_mesh((data, model), ("data", "model"))


def mesh_axis_sizes(mesh: jax.sharding.Mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
