import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Perf hillclimb driver (§Perf): run named optimization variants for a
given (arch x shape), record roofline terms per variant, and append the
hypothesis -> change -> before -> after log.

  PYTHONPATH=src python -m repro.launch.perf --arch kimi-k2-1t-a32b \
      --shape train_4k --variants baseline-tp,fsdp,fsdp-bf16logits

Variants (cumulative experiments, not stacked automatically):
  baseline-tp       paper-faithful analog: Megatron TP + pure DP
  fsdp              + shard params/grads/opt over the data axis
  fsdp-bf16logits   fsdp + bf16 logits end-to-end (no f32 (B,S,V) buffer)
  fsdp-dots-remat   fsdp + dots_saveable remat (recompute elementwise only)
  fsdp-ep           fsdp + MoE dispatch buffer pinned to expert-parallel
                    sharding (all-to-all dispatch)  [MoE archs only]
  fsdp-all          fsdp + bf16 logits + dots remat (+ ep for MoE)
"""

import argparse  # noqa: E402
import sys  # noqa: E402
from typing import Any, Dict, Tuple  # noqa: E402

from repro.launch import dryrun  # noqa: E402
from repro.models import common as cm  # noqa: E402


def variant_plan(name: str, is_moe: bool) -> Tuple[str, Dict[str, Any], Any, bool]:
    """-> (scheme, cfg_overrides, moe_dispatch_spec, moe_a2a)"""
    if name == "ep-a2a":
        # shard_map all-to-all dispatch + experts sharded over data
        return "ep", {}, None, True
    if name == "baseline-tp":
        return "tp", {}, None, False
    if name == "tp-ep":
        return "tp", {}, ("data", None, "model"), False
    if name == "tp-dots-remat":
        return "tp", {"remat_policy": "dots_saveable"}, None, False
    if name == "tp-lse-ce":
        return "tp", {"ce_impl": "lse"}, None, False
    if name == "tp-bf16logits":
        return "tp", {"fp32_logits": False, "ce_impl": "lse"}, None, False
    if name == "tp-bf16attn":
        return "tp", {"attn_f32": False}, None, False
    if name == "tp-all":
        over = {"remat_policy": "dots_saveable", "ce_impl": "lse",
                "attn_f32": False}
        return "tp", over, (("data", None, "model") if is_moe else None), False
    if name == "fsdp":
        return "fsdp", {}, None, False
    if name == "fsdp-bf16logits":
        return "fsdp", {"fp32_logits": False}, None, False
    if name == "fsdp-dots-remat":
        return "fsdp", {"remat_policy": "dots_saveable"}, None, False
    if name == "fsdp-ep":
        return "fsdp", {}, ("data", None, "model"), False
    if name == "fsdp-all":
        over = {"fp32_logits": False, "remat_policy": "dots_saveable"}
        return "fsdp", over, (("data", None, "model") if is_moe else None), False
    raise ValueError(name)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variants", default="baseline-tp,fsdp,fsdp-all")
    ap.add_argument("--out", default="experiments/perf")
    args = ap.parse_args()

    from repro.configs.registry import ARCHS

    is_moe = ARCHS[args.arch].n_experts > 0
    rows = []
    for name in [v.strip() for v in args.variants.split(",")]:
        scheme, overrides, moe_spec, moe_a2a = variant_plan(name, is_moe)
        cm.MOE_DISPATCH_SPEC = moe_spec
        try:
            r = dryrun.run_combo(args.arch, args.shape, multi_pod=False,
                                 scheme=scheme, out_dir=args.out,
                                 cfg_overrides=overrides, variant=name,
                                 moe_a2a=moe_a2a)
        finally:
            cm.MOE_DISPATCH_SPEC = None
        rows.append((name, r))

    print("\n=== perf summary:", args.arch, "x", args.shape, "===")
    print(f"{'variant':18s} {'compute':>10s} {'memory':>10s} {'coll':>10s} "
          f"{'bottleneck':>11s} {'mem/dev GB':>11s}")
    for name, r in rows:
        if r["status"] != "ok":
            print(f"{name:18s} FAILED: {r.get('error', '')[:80]}")
            continue
        print(f"{name:18s} {r['compute_s']*1e3:9.2f}ms {r['memory_s']*1e3:9.2f}ms "
              f"{r['collective_s']*1e3:9.2f}ms {r['bottleneck']:>11s} "
              f"{r['bytes_per_device']/1e9:11.2f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
