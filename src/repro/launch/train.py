"""Single-host LM training driver (end-to-end example: data pipeline ->
model -> AdamW -> checkpointing), used to train a reduced assigned-arch
model for a few hundred steps on CPU and, unchanged, a full config under
pjit on a real mesh (the dry-run lowers exactly this step).

  PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \
      --steps 200 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_pytree
from repro.configs.registry import ARCHS, ASSIGNED
from repro.models import registry
from repro.obs.trace import now as _now
from repro.optim import get as get_opt


def token_stream(vocab: int, batch: int, seq: int, seed: int):
    """Synthetic Zipf-ish token pipeline with a learnable bigram structure
    (so the loss has signal to descend)."""
    rng = np.random.default_rng(seed)
    trans = rng.dirichlet(np.full(vocab, 0.05), size=vocab)  # bigram table
    cum = np.cumsum(trans, axis=1)
    while True:
        toks = np.empty((batch, seq), np.int32)
        toks[:, 0] = rng.integers(0, vocab, batch)
        u = rng.random((batch, seq))
        for t in range(1, seq):
            toks[:, t] = np.array(
                [np.searchsorted(cum[toks[b, t - 1]], u[b, t]) for b in range(batch)],
                np.int32).clip(0, vocab - 1)
        yield {"tokens": jnp.asarray(toks), "labels": jnp.asarray(toks)}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=sorted(ASSIGNED), default="granite-3-2b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--full-config", action="store_true",
                    help="use the FULL assigned config (requires a real mesh)")
    ap.add_argument("--ckpt", default="")
    args = ap.parse_args()

    cfg = ARCHS[args.arch] if args.full_config else ARCHS[args.arch].reduced()
    print(f"training {cfg.name}: {cfg.n_layers}L d={cfg.d_model} "
          f"vocab={cfg.vocab_size} family={cfg.family}")
    params, _ = registry.init(cfg, jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"params: {n_params/1e6:.1f}M")

    opt = get_opt("adamw", weight_decay=0.01)
    opt_state = opt.init(params)

    @jax.jit
    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: registry.loss_fn(cfg, p, batch, remat=False))(params)
        params, opt_state = opt.update(grads, opt_state, params, args.lr)
        return loss, params, opt_state

    stream = token_stream(cfg.vocab_size, args.batch, args.seq, seed=1)
    losses = []
    t0 = _now()
    for step in range(args.steps):
        batch = next(stream)
        if cfg.family == "vlm":
            batch["patch_embeds"] = jnp.zeros(
                (args.batch, cfg.n_patches, cfg.d_model), cfg.compute_dtype)
        if cfg.family == "encdec":
            batch["audio_embeds"] = jnp.zeros(
                (args.batch, cfg.encoder_len, cfg.d_model), cfg.compute_dtype)
        loss, params, opt_state = train_step(params, opt_state, batch)
        losses.append(float(loss))
        if step % max(args.steps // 10, 1) == 0 or step == args.steps - 1:
            tok_s = args.batch * args.seq * (step + 1) / (_now() - t0)
            print(f"step {step:5d}  loss {losses[-1]:.4f}  {tok_s:.0f} tok/s")
    first, last = np.mean(losses[:10]), np.mean(losses[-10:])
    print(f"loss {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NO IMPROVEMENT'})")
    if args.ckpt:
        save_pytree(args.ckpt, {"params": params, "opt": opt_state})
        print(f"checkpoint -> {args.ckpt}")


if __name__ == "__main__":
    main()
