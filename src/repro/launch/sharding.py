"""Logical-axis -> mesh-axis sharding rules.

Model code annotates every param dim with a logical name ("vocab",
"ffn", "heads", "kv", "experts", "embed", "layers", ...).  This module
turns those into PartitionSpecs for a concrete mesh under a named
scheme:

- ``ep``   tp + experts sharded over "data" (pairs with the shard_map
  all-to-all dispatch, models/moe_a2a.py).
- ``tp``   (paper-faithful baseline analog): Megatron-style tensor
  parallelism on the "model" axis (vocab/ffn/heads/kv; expert FFN inner
  dim), parameters REPLICATED over the "data"/"pod" axes (pure DP).
- ``fsdp`` (beyond-paper optimized): additionally shards a suitable
  param dim over "data" (experts first — expert parallelism — then
  embed/vocab rows), which also shards gradients and optimizer state
  (same specs), cutting per-device state by the data-axis size.

Divisibility fallbacks are explicit: a dim that does not divide evenly
is left replicated (e.g. kv_heads=8 on model=16 => replicated KV,
standard GQA-TP practice; whisper heads=20 => attention stays
replicated and only FFN is TP).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# candidates for the "model" (TP) axis, in priority order
_MODEL_CANDIDATES = ("vocab", "ffn", "heads", "kv")
# candidates for the "data" (FSDP) axis, in priority order
_DATA_CANDIDATES = ("experts", "embed", "vocab", "ffn")


def _axis_size(mesh: Mesh, name: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)


def spec_for_param(
    axes: Tuple[Optional[str], ...],
    shape: Tuple[int, ...],
    mesh: Mesh,
    scheme: str = "tp",
) -> P:
    """Build a PartitionSpec for one param from its logical dim names."""
    msize = _axis_size(mesh, "model")
    dsize = _axis_size(mesh, "data")
    assign: list = [None] * len(axes)

    def place(mesh_axis: str, size: int, candidates) -> None:
        for cand in candidates:
            for i, name in enumerate(axes):
                if name == cand and assign[i] is None and shape[i] % size == 0 and size > 1:
                    assign[i] = mesh_axis
                    return

    place("model", msize, _MODEL_CANDIDATES)
    if scheme == "fsdp":
        place("data", dsize, _DATA_CANDIDATES)
    elif scheme == "ep":
        # expert parallelism only: shard the expert dim over data; dense
        # params stay replicated over data (no loop-hoisted gathers)
        place("data", dsize, ("experts",))
    elif scheme != "tp":
        raise ValueError(f"unknown scheme {scheme!r}")
    return P(*assign)


def batch_spec(mesh: Mesh) -> P:
    """Global-batch sharding over (pod, data)."""
    names = [n for n in ("pod", "data") if _axis_size(mesh, n) > 1]
    return P(tuple(names) if names else None)


def spec_for_activation(
    axes: Tuple[Optional[str], ...], shape: Tuple[int, ...], mesh: Mesh
) -> P:
    """Cache / activation specs: 'batch' -> (pod,data); 'ctx' -> data
    (context-parallel long decode); 'kv'/'heads' -> model."""
    assign: list = [None] * len(axes)
    for i, name in enumerate(axes):
        if name == "batch":
            bnames = [n for n in ("pod", "data") if _axis_size(mesh, n) > 1]
            total = int(np.prod([_axis_size(mesh, n) for n in bnames])) if bnames else 1
            if bnames and shape[i] % total == 0:
                assign[i] = tuple(bnames)
        elif name == "ctx" and shape[i] % _axis_size(mesh, "data") == 0:
            assign[i] = "data"
        elif name in ("kv", "heads", "ffn") and shape[i] % _axis_size(mesh, "model") == 0 \
                and _axis_size(mesh, "model") > 1:
            assign[i] = "model"
    return P(*assign)


def param_shardings(axes_tree: Any, shapes_tree: Any, mesh: Mesh,
                    scheme: str = "tp") -> Any:
    """NamedSharding pytree for params (matched structure with axes)."""
    return jax.tree_util.tree_map(
        lambda ax, sh: NamedSharding(mesh, spec_for_param(tuple(ax), sh.shape, mesh, scheme)),
        axes_tree, shapes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x),
    )


def cache_shardings(cache_axes_tree: Any, shapes_tree: Any, mesh: Mesh) -> Any:
    return jax.tree_util.tree_map(
        lambda ax, sh: NamedSharding(mesh, spec_for_activation(tuple(ax), sh.shape, mesh)),
        cache_axes_tree, shapes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x),
    )


def opt_state_shardings(param_shardings_tree: Any, opt_state_shapes: Any, mesh: Mesh) -> Any:
    """AdamW m/v mirror param shardings; scalars replicated."""
    def build(shape_leaf, path_hint=None):
        return NamedSharding(mesh, P())

    # match structure: {"m": params-like, "v": params-like, "t": scalar}
    if isinstance(opt_state_shapes, dict) and set(opt_state_shapes) == {"m", "v", "t"}:
        return {
            "m": param_shardings_tree,
            "v": param_shardings_tree,
            "t": NamedSharding(mesh, P()),
        }
    if isinstance(opt_state_shapes, tuple) and opt_state_shapes == ():
        return ()
    # momentum: params-like
    return param_shardings_tree
