"""HLO-text analysis for the dry-run roofline.

XLA:CPU's ``cost_analysis()`` under-reports matmul FLOPs for this use
case (dots live inside fusion computations / get custom-call'd depending
on backend version), so we parse the post-SPMD optimized HLO text
ourselves:

- build a name -> shape table per computation,
- accumulate dot FLOPs (2 * prod(output) * prod(contracted dims)),
- accumulate collective bytes with the standard conventions
  (all-reduce 2x input, all-gather = output, reduce-scatter = input,
  all-to-all / collective-permute = size),
- weight every computation by its call multiplicity from the ENTRY
  call graph (fusions / calls / while bodies; the dry-run fully unrolls
  layer scans so while-loop trip counts do not hide work — any residual
  while body is counted once and flagged).

All quantities are PER-DEVICE (the SPMD module is the per-device
program); the roofline terms divide by per-chip peaks directly.
"""
from __future__ import annotations

import math
import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*(.*)$")
_CALLED_RE = re.compile(
    r"(?:calls=|to_apply=|body=|condition=)\s*(\{[^}]*\}|%[\w.\-]+)")
_OPERAND_RE = re.compile(r"\((%[\w.\-]+(?:,\s*%[\w.\-]+)*)?\)")

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_elems_bytes(dtype: str, dim_str: str) -> Tuple[int, float]:
    n = 1
    for d in dim_str.split(","):
        if d:
            n *= int(d)
    return n, n * _DTYPE_BYTES.get(dtype, 0)


@dataclass
class _Instr:
    name: str
    out_bytes: float
    out_elems: int
    opcode: str
    line: str
    operands: List[str] = field(default_factory=list)


@dataclass
class _Computation:
    name: str
    instrs: Dict[str, _Instr] = field(default_factory=dict)
    called: List[str] = field(default_factory=list)  # per call site
    dot_flops: float = 0.0
    transcendental_elems: float = 0.0
    coll_bytes: Dict[str, float] = field(default_factory=lambda: defaultdict(float))
    coll_counts: Dict[str, int] = field(default_factory=lambda: defaultdict(int))
    has_while: bool = False


def _first_opcode(rhs: str) -> str:
    # rhs like: "f32[8,16]{1,0} dot(%a, %b), ..."
    m = re.match(r"\S+\s+([a-z0-9\-]+)", rhs)
    return m.group(1) if m else ""


def parse_hlo(text: str) -> Dict[str, _Computation]:
    comps: Dict[str, _Computation] = {}
    cur: _Computation | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        # computation header: "%name (args) -> type {" or "ENTRY %name ..."
        if (stripped.endswith("{") and ("(" in stripped)
                and ("->" in stripped or stripped.startswith("ENTRY"))):
            m = re.search(r"(%[\w.\-]+)", stripped)
            header_name = m.group(1) if m else f"comp{len(comps)}"
            cur = _Computation(name=header_name)
            comps[header_name] = cur
            continue
        if stripped == "}":
            cur = None
            continue
        if cur is None:
            continue
        dm = _DEF_RE.match(stripped)
        if not dm:
            continue
        name, rhs = dm.groups()
        opcode = _first_opcode(rhs)
        shapes = _SHAPE_RE.findall(stripped)
        out_elems, out_bytes = _shape_elems_bytes(*shapes[0]) if shapes else (0, 0.0)
        ins = _Instr(name=name, out_bytes=out_bytes, out_elems=out_elems,
                     opcode=opcode, line=stripped)
        # operand names (first parenthesized group after opcode)
        paren = stripped.split(opcode + "(", 1)
        if len(paren) == 2:
            args = paren[1].split(")", 1)[0]
            ins.operands = re.findall(r"%[\w.\-]+", args)
        cur.instrs[name] = ins
        # called computations
        for cm_ in _CALLED_RE.findall(stripped):
            names = re.findall(r"%[\w.\-]+", cm_)
            cur.called.extend(names)
        if opcode == "while":
            cur.has_while = True
    return comps


def _analyze_comp(comp: _Computation) -> None:
    """Fill per-computation dot flops + collective bytes (own instrs)."""
    for ins in comp.instrs.values():
        if ins.opcode == "dot":
            m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.line)
            cdims = [int(x) for x in m.group(1).split(",")] if m and m.group(1) else []
            shapes = _SHAPE_RE.findall(ins.line)
            out_elems = 1
            for d in shapes[0][1].split(","):
                if d:
                    out_elems *= int(d)
            # lhs shape: look up operand 0 in same computation; fall back
            # to inline shapes if present
            contracted = 1
            lhs_dims: List[int] = []
            if ins.operands:
                op0 = comp.instrs.get(ins.operands[0])
                if op0 is not None:
                    lm = _SHAPE_RE.findall(op0.line)
                    if lm:
                        lhs_dims = [int(x) for x in lm[0][1].split(",") if x]
            if not lhs_dims and len(shapes) >= 2:
                lhs_dims = [int(x) for x in shapes[1][1].split(",") if x]
            for i in cdims:
                if i < len(lhs_dims):
                    contracted *= lhs_dims[i]
            comp.dot_flops += 2.0 * out_elems * contracted
        elif ins.opcode in ("exponential", "tanh", "log", "rsqrt", "power",
                            "logistic", "sine", "cosine"):
            comp.transcendental_elems += ins.out_elems
        else:
            for kind in _COLLECTIVES:
                if ins.opcode == kind or ins.opcode == kind + "-start":
                    # bytes convention per participant
                    in_bytes = 0.0
                    if ins.operands:
                        op0 = comp.instrs.get(ins.operands[0])
                        if op0 is not None:
                            in_bytes = op0.out_bytes
                    out_bytes = ins.out_bytes
                    if kind == "all-reduce":
                        b = 2.0 * max(in_bytes, out_bytes)
                    elif kind == "all-gather":
                        b = out_bytes
                    elif kind == "reduce-scatter":
                        b = max(in_bytes, out_bytes)
                    else:
                        b = max(in_bytes, out_bytes)
                    comp.coll_bytes[kind] += b
                    comp.coll_counts[kind] += 1
                    break


@dataclass
class HloSummary:
    dot_flops: float                 # per-device
    transcendental_elems: float
    collective_bytes: float          # per-device
    collective_by_kind: Dict[str, float]
    collective_counts: Dict[str, int]
    residual_while_loops: int        # >0 => some work hidden in loops


def analyze(text: str) -> HloSummary:
    comps = parse_hlo(text)
    if not comps:  # empty / comment-only module: a zero summary, not a crash
        return HloSummary(0.0, 0.0, 0.0, {}, {}, 0)
    for c in comps.values():
        _analyze_comp(c)
    # call multiplicities from the entry computation
    entry = None
    for name, c in comps.items():
        if "entry" in name.lower() or name.lower().startswith("%main"):
            entry = name
    if entry is None:  # fall back: computation never called by others
        called_sets = {n for c in comps.values() for n in c.called}
        roots = [n for n in comps if n not in called_sets]
        entry = roots[0] if roots else next(iter(comps))
    mult: Dict[str, float] = defaultdict(float)

    def walk(name: str, m: float, depth=0):
        if name not in comps or depth > 64:
            return
        mult[name] += m
        counts: Dict[str, int] = defaultdict(int)
        for cal in comps[name].called:
            counts[cal] += 1
        for cal, k in counts.items():
            walk(cal, m * k, depth + 1)

    walk(entry, 1.0)
    flops = sum(c.dot_flops * mult[c.name] for c in comps.values())
    trans = sum(c.transcendental_elems * mult[c.name] for c in comps.values())
    by_kind: Dict[str, float] = defaultdict(float)
    counts_total: Dict[str, int] = defaultdict(int)
    for c in comps.values():
        for k, v in c.coll_bytes.items():
            by_kind[k] += v * mult[c.name]
        for k, v in c.coll_counts.items():
            counts_total[k] += int(v * max(mult[c.name], 1))
    n_while = sum(1 for c in comps.values() if c.has_while and mult[c.name] > 0)
    return HloSummary(
        dot_flops=flops,
        transcendental_elems=trans,
        collective_bytes=sum(by_kind.values()),
        collective_by_kind=dict(by_kind),
        collective_counts=dict(counts_total),
        residual_while_loops=n_while,
    )
