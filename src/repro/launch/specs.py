"""Input specs per (architecture x input shape).

``input_specs`` returns jax.ShapeDtypeStruct stand-ins (no allocation)
for dry-run lowering; ``make_batch`` materializes small concrete batches
for smoke tests.  Modality frontends are stubs per the assignment:
VLM patch embeddings and audio frame embeddings arrive precomputed.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import SHAPES_BY_NAME, InputShape, ModelConfig
from repro.models import registry


def train_specs(cfg: ModelConfig, batch: int, seq: int) -> Dict[str, jax.ShapeDtypeStruct]:
    sd = jax.ShapeDtypeStruct
    ct = jnp.dtype(cfg.compute_dtype)
    specs = {
        "tokens": sd((batch, seq), jnp.int32),
        "labels": sd((batch, seq), jnp.int32),
    }
    if cfg.family == "vlm":
        specs["patch_embeds"] = sd((batch, cfg.n_patches, cfg.d_model), ct)
    if cfg.family == "encdec":
        specs["audio_embeds"] = sd((batch, cfg.encoder_len, cfg.d_model), ct)
    return specs


def decode_specs(cfg: ModelConfig, batch: int, seq: int) -> Tuple[Any, ...]:
    """(token, pos, cache) ShapeDtypeStructs for serve_step.

    ``eval_shape`` keeps the (potentially hundreds-of-GB) cache abstract —
    no allocation ever happens on the host."""
    sd = jax.ShapeDtypeStruct
    cache_specs = jax.eval_shape(
        lambda: registry.init_decode_cache(cfg, batch, seq))
    return sd((batch, 1), jnp.int32), sd((), jnp.int32), cache_specs


def input_specs(cfg: ModelConfig, shape: InputShape | str):
    if isinstance(shape, str):
        shape = SHAPES_BY_NAME[shape]
    if shape.mode in ("train", "prefill"):
        return train_specs(cfg, shape.global_batch, shape.seq_len)
    return decode_specs(cfg, shape.global_batch, shape.seq_len)


def make_batch(cfg: ModelConfig, batch: int, seq: int, seed: int = 0) -> Dict[str, jnp.ndarray]:
    """Concrete random batch (smoke tests)."""
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, cfg.vocab_size, size=(batch, seq)).astype(np.int32)
    out: Dict[str, jnp.ndarray] = {
        "tokens": jnp.asarray(toks),
        "labels": jnp.asarray(toks),
    }
    ct = jnp.dtype(cfg.compute_dtype)
    if cfg.family == "vlm":
        out["patch_embeds"] = jnp.asarray(
            rng.normal(size=(batch, cfg.n_patches, cfg.d_model)), ct)
    if cfg.family == "encdec":
        out["audio_embeds"] = jnp.asarray(
            rng.normal(size=(batch, cfg.encoder_len, cfg.d_model)), ct)
    return out
