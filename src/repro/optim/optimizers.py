"""SGD / momentum / AdamW as (init, update) pairs over pytrees."""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, Any], Tuple[Any, Any]]  # (grads, state, params, lr)


def sgd() -> Optimizer:
    def init(params):
        return ()

    def update(grads, state, params, lr):
        new = jax.tree_util.tree_map(lambda p, g: p - lr * g.astype(p.dtype), params, grads)
        return new, state

    return Optimizer(init, update)


def momentum(beta: float = 0.9) -> Optimizer:
    def init(params):
        return jax.tree_util.tree_map(jnp.zeros_like, params)

    def update(grads, state, params, lr):
        state = jax.tree_util.tree_map(
            lambda m, g: beta * m + g.astype(m.dtype), state, grads)
        new = jax.tree_util.tree_map(lambda p, m: p - lr * m.astype(p.dtype), params, state)
        return new, state

    return Optimizer(init, update)


def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0, state_dtype=None) -> Optimizer:
    """AdamW with optional reduced-precision moments (state_dtype='bfloat16'
    is the memory-optimized beyond-paper variant used in §Perf)."""

    def init(params):
        def z(p):
            dt = jnp.dtype(state_dtype) if state_dtype else p.dtype
            return jnp.zeros(p.shape, dt)

        return {
            "m": jax.tree_util.tree_map(z, params),
            "v": jax.tree_util.tree_map(z, params),
            "t": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params, lr):
        t = state["t"] + 1
        c1 = 1.0 - b1 ** t.astype(jnp.float32)
        c2 = 1.0 - b2 ** t.astype(jnp.float32)

        def upd(p, g, m, v):
            g32 = g.astype(jnp.float32)
            m_n = b1 * m.astype(jnp.float32) + (1 - b1) * g32
            v_n = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
            step = lr * (m_n / c1) / (jnp.sqrt(v_n / c2) + eps)
            if weight_decay:
                step = step + lr * weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - step).astype(p.dtype), m_n.astype(m.dtype), v_n.astype(v.dtype)

        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state["m"])
        flat_v = treedef.flatten_up_to(state["v"])
        out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        new_v = treedef.unflatten([o[2] for o in out])
        return new_p, {"m": new_m, "v": new_v, "t": t}

    return Optimizer(init, update)


def get(name: str, **kw) -> Optimizer:
    if name == "sgd":
        return sgd()
    if name == "momentum":
        return momentum(**kw)
    if name == "adamw":
        return adamw(**kw)
    raise ValueError(f"unknown optimizer {name!r}")
