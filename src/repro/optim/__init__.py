"""Pure-pytree optimizers: SGD / momentum / AdamW.

Minimal optax-free implementations so the framework is dependency-light;
states are pytrees matching params, so they shard with the same
PartitionSpecs (FSDP shards optimizer state for free).
"""
from repro.optim.optimizers import adamw, get, momentum, sgd, Optimizer  # noqa: F401
