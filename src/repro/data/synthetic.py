"""Synthetic data substrate for the FL experiments.

The paper uses disjoint private/public image datasets (CIFAR-10 private vs
CIFAR-100 public, etc.).  Offline we synthesize the same *structure*: a
labeled private dataset drawn from N gaussian class clusters, and an
unlabeled public dataset drawn from a *shifted/overlapping* mixture
(related but non-identical distribution — the paper's key realism point),
plus Dirichlet non-IID partitioning over clients (Hsu et al. 2019).
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np


def make_classification_data(
    n_samples: int,
    n_classes: int,
    dim: int,
    seed: int = 0,
    cluster_scale: float = 3.0,
    noise: float = 1.0,
    centers: np.ndarray | None = None,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Gaussian-mixture classification data. Returns (x, y, centers)."""
    rng = np.random.default_rng(seed)
    if centers is None:
        centers = rng.normal(size=(n_classes, dim)) * cluster_scale
    y = rng.integers(0, n_classes, size=n_samples)
    x = centers[y] + rng.normal(size=(n_samples, dim)) * noise
    return x.astype(np.float32), y.astype(np.int32), centers


def make_public_private(
    n_private: int,
    n_public: int,
    n_classes: int,
    dim: int,
    seed: int = 0,
    public_shift: float = 1.0,
    cluster_scale: float = 3.0,
    noise: float = 1.0,
) -> Dict[str, np.ndarray]:
    """Private labeled + public unlabeled sets from *related but distinct*
    distributions (public centers = private centers + shift), mirroring the
    paper's CIFAR-10-private / CIFAR-100-public setup."""
    rng = np.random.default_rng(seed)
    xp, yp, centers = make_classification_data(
        n_private, n_classes, dim, seed=seed,
        cluster_scale=cluster_scale, noise=noise)
    pub_centers = centers + rng.normal(size=centers.shape) * public_shift
    xu, yu, _ = make_classification_data(
        n_public, n_classes, dim, seed=seed + 1, centers=pub_centers, noise=noise)
    # held-out test set from the private distribution
    xt, yt, _ = make_classification_data(
        max(n_private // 5, 200), n_classes, dim, seed=seed + 2,
        centers=centers, noise=noise)
    return {
        "x_private": xp, "y_private": yp,
        "x_public": xu, "y_public_true": yu,  # true labels never used in training
        "x_test": xt, "y_test": yt,
        "centers": centers,
    }


def dirichlet_partition(
    labels: np.ndarray,
    n_clients: int,
    alpha: float,
    seed: int = 0,
    min_per_client: int = 2,
) -> list[np.ndarray]:
    """Dirichlet non-IID split (Hsu et al., 2019). Smaller alpha => more skew."""
    rng = np.random.default_rng(seed)
    n_classes = int(labels.max()) + 1
    idx_by_class = [np.where(labels == c)[0] for c in range(n_classes)]
    for idx in idx_by_class:
        rng.shuffle(idx)
    client_idx: list[list[int]] = [[] for _ in range(n_clients)]
    for c, idx in enumerate(idx_by_class):
        props = rng.dirichlet([alpha] * n_clients)
        cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
        for k, part in enumerate(np.split(idx, cuts)):
            client_idx[k].extend(part.tolist())
    # ensure every client has a floor of samples (move from the largest)
    sizes = [len(ci) for ci in client_idx]
    for k in range(n_clients):
        while len(client_idx[k]) < min_per_client:
            donor = int(np.argmax([len(ci) for ci in client_idx]))
            client_idx[k].append(client_idx[donor].pop())
    out = [np.array(sorted(ci), dtype=np.int64) for ci in client_idx]
    return out


def uniform_client_shards(
    x: np.ndarray, y: np.ndarray, n_clients: int
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Round-robin split straight into the dense ``(K, n_max, ...)``
    layout — sample ``i`` goes to client ``i % K``, slot ``i // K``.

    Fully vectorized (one pad + reshape, no Python loop over clients),
    which is what makes it tractable at the active-set engine's
    K = 10^6 benchmark scale where :func:`dirichlet_partition` +
    :func:`pad_client_shards`'s per-client loops are not.  Returns the
    same ``(xs, ys, mask)`` triple as :func:`pad_client_shards`.
    """
    n = len(y)
    n_max = -(-n // n_clients)  # ceil
    total = n_clients * n_max
    xs = np.zeros((total,) + x.shape[1:], x.dtype)
    ys = np.zeros((total,), y.dtype)
    mask = np.zeros((total,), bool)
    xs[:n], ys[:n], mask[:n] = x, y, True
    # (slot, client, ...) -> (client, slot, ...): client k's slot j holds
    # global sample j*K + k
    perm = (1, 0) + tuple(range(2, xs.ndim + 1))
    xs = xs.reshape((n_max, n_clients) + x.shape[1:]).transpose(perm)
    ys = ys.reshape(n_max, n_clients).T
    mask = mask.reshape(n_max, n_clients).T
    return np.ascontiguousarray(xs), np.ascontiguousarray(ys), \
        np.ascontiguousarray(mask)


def pad_client_shards(
    x: np.ndarray, y: np.ndarray, parts: list[np.ndarray]
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Stack ragged client shards into dense (K, n_max, ...) arrays with a
    boolean validity mask — the layout consumed by the vmapped FL engine."""
    K = len(parts)
    n_max = max(len(p) for p in parts)
    xs = np.zeros((K, n_max) + x.shape[1:], x.dtype)
    ys = np.zeros((K, n_max), y.dtype)
    mask = np.zeros((K, n_max), bool)
    for k, p in enumerate(parts):
        xs[k, : len(p)] = x[p]
        ys[k, : len(p)] = y[p]
        mask[k, : len(p)] = True
    return xs, ys, mask
