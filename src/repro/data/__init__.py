from repro.data.synthetic import (  # noqa: F401
    dirichlet_partition,
    make_classification_data,
    make_public_private,
)
