"""Pallas TPU flash attention (causal, GQA, optional sliding window).

Client forward passes dominate distillation-based FL compute when the
clients are LMs (every round runs inference over the public subset plus
local training).  This kernel is the TPU execution path for the model
zoo's attention: online-softmax over KV blocks with running (m, l, acc)
accumulators in VMEM scratch, (block_q x d) x (block_k x d) MXU matmuls.

Grid = (batch, q_heads, q_blocks, k_blocks), k minor (sequential).  GQA
maps query head h to KV head h // (H // Hkv) in the BlockSpec index_map
— KV is never materialized per-query-head (HBM traffic stays at Hkv).
Hardware alignment: block_q/block_k are kept sublane-aligned (8 rows
for f32, 16 for bf16) via ``runtime.align_block_rows``; ragged sequence
lengths are padded up to the block multiple, with padded KV positions
masked to -inf in-kernel and padded query rows sliced off.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.runtime import (
    align_block_rows,
    resolve_interpret,
    sublanes_for_dtype,
)

_NEG = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                  *, nk: int, block_q: int, block_k: int, causal: bool,
                  window: int, scale: float, sk: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)                  # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)                  # (bk, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)

    q_idx = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_idx = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), bool)
    if causal:
        mask &= k_idx <= q_idx
    if window:
        mask &= k_idx > q_idx - window
    if sk % block_k:  # KV padded up to the block multiple: mask the tail
        mask &= k_idx < sk
    s = jnp.where(mask, s, _NEG)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot(p, v)
    m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _fin():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_k", "interpret"))
def flash_attention(
    q: jnp.ndarray,   # (B, Sq, H, d)
    k: jnp.ndarray,   # (B, Sk, Hkv, d)
    v: jnp.ndarray,
    causal: bool = True,
    window: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool | None = None,
) -> jnp.ndarray:
    interpret = resolve_interpret(interpret)
    B, Sq, H, d = q.shape
    _, Sk, Hkv, _ = k.shape
    rep = H // Hkv
    # Shrink-to-input must stay sublane-aligned (8 rows for f32, 16 for
    # bf16): a bare min() produced blocks like 4 or 10 for small/odd
    # sequence lengths, which interpret fine on CPU but mis-tile on
    # native TPU (the era_kernel bug class).  Sequences are padded up to
    # the block multiple instead; padded KV positions are masked to -inf
    # in-kernel and padded query rows are sliced off.
    sub = sublanes_for_dtype(q.dtype)
    block_q = align_block_rows(block_q, Sq, align=sub)
    block_k = align_block_rows(block_k, Sk, align=sub)
    sq_pad = (-Sq) % block_q
    sk_pad = (-Sk) % block_k
    nq, nk = (Sq + sq_pad) // block_q, (Sk + sk_pad) // block_k
    scale = 1.0 / math.sqrt(d)

    # (B, H, S, d) layout for clean 2D tiles
    qt = jnp.pad(q.transpose(0, 2, 1, 3), ((0, 0), (0, 0), (0, sq_pad), (0, 0)))
    kt = jnp.pad(k.transpose(0, 2, 1, 3), ((0, 0), (0, 0), (0, sk_pad), (0, 0)))
    vt = jnp.pad(v.transpose(0, 2, 1, 3), ((0, 0), (0, 0), (0, sk_pad), (0, 0)))

    out = pl.pallas_call(
        functools.partial(_flash_kernel, nk=nk, block_q=block_q,
                          block_k=block_k, causal=causal, window=window,
                          scale=scale, sk=Sk),
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b, h, i, j: (b, h // rep, j, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b, h, i, j: (b, h // rep, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq + sq_pad, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out[:, :, :Sq].transpose(0, 2, 1, 3)


def analysis_cases():
    """(label, fn, abstract args) triples for the static BlockSpec lint
    (:mod:`repro.analysis.pallas_checks`); traced with
    ``interpret=False``, never executed.  Includes the small/odd
    sequence-length cases whose ``min(block, S)`` shrink used to emit
    misaligned blocks."""
    S, f32, bf16 = jax.ShapeDtypeStruct, jnp.float32, jnp.bfloat16

    def case(B, Sq, Sk, H, Hkv, d, dtype=f32, **kw):
        fn = lambda q, k, v: flash_attention(q, k, v, interpret=False, **kw)
        return fn, (S((B, Sq, H, d), dtype), S((B, Sk, Hkv, d), dtype),
                    S((B, Sk, Hkv, d), dtype))

    return [
        ("attn/S128-gqa-d64", *case(2, 128, 128, 4, 2, 64)),
        ("attn/small-Sq4", *case(1, 4, 4, 2, 2, 64)),
        ("attn/odd-S100-window", *case(1, 100, 100, 2, 1, 64, window=7)),
        ("attn/bf16-S64", *case(1, 64, 64, 2, 2, 64, dtype=bf16)),
    ]


# ---------------------------------------------------------------------------
# Differentiable wrapper: flash forward + recompute-style backward.
# The forward never materializes the S x S probabilities in HBM; the
# backward recomputes them blockwise from (q, k, v, o, delta) — the
# standard flash-attention VJP contract.  On CPU the backward runs the
# jnp reference formulation (exact same math; the Pallas backward kernel
# is a TPU-phase optimization and the recompute keeps memory O(S·d)).
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def flash_attention_diff(q, k, v, causal=True, window=0,
                         block_q=128, block_k=128, interpret=None):
    return flash_attention(q, k, v, causal=causal, window=window,
                           block_q=block_q, block_k=block_k,
                           interpret=interpret)


def _flash_fwd(q, k, v, causal, window, block_q, block_k, interpret):
    o = flash_attention(q, k, v, causal=causal, window=window,
                        block_q=block_q, block_k=block_k, interpret=interpret)
    return o, (q, k, v)


def _flash_bwd(causal, window, block_q, block_k, interpret, res, do):
    q, k, v = res
    B, Sq, H, d = q.shape
    Hkv = k.shape[2]
    rep = H // Hkv
    f32 = jnp.float32
    kr = jnp.repeat(k, rep, axis=2).astype(f32)
    vr = jnp.repeat(v, rep, axis=2).astype(f32)
    qf = q.astype(f32)
    scale = 1.0 / math.sqrt(d)
    s = jnp.einsum("bqhd,bkhd->bhqk", qf, kr) * scale
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    dof = do.astype(f32)
    dv_r = jnp.einsum("bhqk,bqhd->bkhd", p, dof)
    dp = jnp.einsum("bqhd,bkhd->bhqk", dof, vr)
    delta = jnp.sum(p * dp, axis=-1, keepdims=True)
    ds = p * (dp - delta) * scale
    dq = jnp.einsum("bhqk,bkhd->bqhd", ds, kr)
    dk_r = jnp.einsum("bhqk,bqhd->bkhd", ds, qf)
    # fold repeated-KV grads back onto the Hkv heads
    dk = dk_r.reshape(B, k.shape[1], Hkv, rep, d).sum(axis=3)
    dv = dv_r.reshape(B, k.shape[1], Hkv, rep, d).sum(axis=3)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


flash_attention_diff.defvjp(_flash_fwd, _flash_bwd)
