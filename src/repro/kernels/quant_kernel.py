"""Pallas TPU kernel for fused min-max quantize-dequantize.

The soft-label codecs (``repro.compress``) simulate lossy wire formats:
what the receiver sees is ``decode(encode(z))``.  Running that as two
separate jnp passes (reduce for min/max, then round, then dequantize,
then renormalize) makes three HBM round trips over the ``(K*m, N)``
soft-label stack every round; this kernel fuses the whole round trip —
per-row min/max, level rounding, and dequantization — into one VMEM
pass per row block (VPU-bound, like the ERA kernel).

Tiling: rows are blocked by ``block_b`` (8-aligned); the class dim N is
kept whole per tile and padded to a 128-lane multiple by the wrapper.
Because padding lanes would corrupt the per-row min/max, the kernel
masks reductions to the first ``n_valid`` lanes (a ``broadcasted_iota``
lane predicate); padded output lanes hold garbage and are sliced off by
the wrapper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.runtime import align_block_rows, resolve_interpret

_EPS_SCALE = 1e-9


def _qdq_kernel(z_ref, o_ref, *, levels: float, n_valid: int):
    z = z_ref[...].astype(jnp.float32)                         # (bb, Np)
    lane = jax.lax.broadcasted_iota(jnp.int32, z.shape, 1)
    valid = lane < n_valid
    zmin = jnp.min(jnp.where(valid, z, jnp.inf), axis=-1, keepdims=True)
    zmax = jnp.max(jnp.where(valid, z, -jnp.inf), axis=-1, keepdims=True)
    scale = jnp.maximum(zmax - zmin, _EPS_SCALE)
    # clamp to the level range: valid in-range lanes land in [0, 1] by
    # construction, but padded lanes and eps-scale degenerate rows
    # (constant rows, N=1) can fall outside and would dequantize beyond
    # [row_min, row_max] — the clamp pins the round trip to the row range
    q = jnp.clip(jnp.round((z - zmin) / scale * levels) / levels, 0.0, 1.0)
    o_ref[...] = (q * scale + zmin).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bits", "block_b", "interpret"))
def quantize_dequantize(z: jnp.ndarray, bits: int, block_b: int = 256,
                        interpret: bool | None = None) -> jnp.ndarray:
    """(B, N) -> (B, N): per-row min-max uniform quantization to ``bits``
    bits (``2**bits - 1`` levels spanning [row min, row max]) followed by
    dequantization — the lossy round trip a receiver observes.

    ``interpret=None`` auto-detects the backend (native on TPU,
    interpreter elsewhere).
    """
    interpret = resolve_interpret(interpret)
    B, N = z.shape
    # shrink the block to the input, kept 8-aligned (f32 sublane tiling)
    block_b = align_block_rows(block_b, B)
    n_pad = (-N) % 128
    b_pad = (-B) % block_b
    zp = jnp.pad(z, ((0, b_pad), (0, n_pad)))  # pad lanes masked in-kernel
    Bp, Np = zp.shape
    levels = float(2 ** bits - 1)
    out = pl.pallas_call(
        functools.partial(_qdq_kernel, levels=levels, n_valid=N),
        grid=(Bp // block_b,),
        in_specs=[pl.BlockSpec((block_b, Np), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_b, Np), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Bp, Np), z.dtype),
        interpret=interpret,
    )(zp)
    return out[:B, :N]


def analysis_cases():
    """(label, fn, abstract args) triples for the static BlockSpec lint
    (:mod:`repro.analysis.pallas_checks`); traced with
    ``interpret=False``, never executed."""
    S, f32 = jax.ShapeDtypeStruct, jnp.float32
    return [
        ("quant/B1000-N10-bits8",
         lambda z: quantize_dequantize(z, 8, interpret=False),
         (S((1000, 10), f32),)),
        ("quant/B10-N1-bits1",
         lambda z: quantize_dequantize(z, 1, interpret=False),
         (S((10, 1), f32),)),
    ]
