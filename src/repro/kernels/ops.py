"""jit'd public wrappers over the Pallas kernels.

On TPU the kernels compile natively; on CPU (this container) they run in
``interpret=True`` mode, executing the kernel body in Python for
correctness validation against ``ref.py``.  Higher layers call these
entry points (``repro.core.era`` / ``repro.core.losses`` with
``impl="pallas"``).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import (
    attn_kernel,
    distill_kernel,
    era_kernel,
    quant_kernel,
    round_kernel,
)
from repro.kernels.runtime import align_block_rows
from repro.kernels.runtime import default_interpret as _interpret


def enhanced_era(z_mean: jnp.ndarray, beta, block_b: int = 256) -> jnp.ndarray:
    """(..., N) -> sharpened (..., N); leading dims flattened to rows."""
    shape = z_mean.shape
    flat = z_mean.reshape(-1, shape[-1])
    # shrink-to-input must stay 8-aligned (f32 sublane tiling): a bare
    # min() produced blocks like 10 that mis-tile on native TPU
    out = era_kernel.enhanced_era(flat, beta,
                                  block_b=align_block_rows(block_b, flat.shape[0]),
                                  interpret=_interpret())
    return out.reshape(shape)


def enhanced_era_fused(z_clients: jnp.ndarray, beta) -> jnp.ndarray:
    """(K, B, N) -> (B, N): fused client-mean + sharpening."""
    return era_kernel.enhanced_era_fused(z_clients, beta, interpret=_interpret())


def fused_round(z_clients: jnp.ndarray, weights: jnp.ndarray, beta=None,
                base: jnp.ndarray | None = None, *, mode: str = "identity",
                bits: int | None = None, sharpen: bool = True) -> jnp.ndarray:
    """(K, m, N) client stack -> (m, N): uplink codec round trip +
    participation-weighted reduction + (optional) Enhanced-ERA
    sharpening, all in one VMEM pass per row block.  See
    :mod:`repro.kernels.round_kernel` for the mode/weight semantics."""
    return round_kernel.fused_round(z_clients, weights, beta, base,
                                    mode=mode, bits=bits, sharpen=sharpen,
                                    interpret=_interpret())


def quantize_dequantize(z: jnp.ndarray, bits: int, block_b: int = 256) -> jnp.ndarray:
    """(..., N) -> (..., N): fused per-row min-max quantization round trip
    (what a ``bits``-bit receiver sees); leading dims flattened to rows."""
    shape = z.shape
    flat = z.reshape(-1, shape[-1])
    out = quant_kernel.quantize_dequantize(flat, bits, block_b=block_b,
                                           interpret=_interpret())
    return out.reshape(shape)


def distill_loss(logits: jnp.ndarray, teacher: jnp.ndarray) -> jnp.ndarray:
    """Mean soft-target CE over all rows; supports (..., V) inputs."""
    V = logits.shape[-1]
    flat_l = logits.reshape(-1, V)
    flat_t = teacher.reshape(-1, V)
    per_row = distill_kernel.distill_loss(flat_l, flat_t, interpret=_interpret())
    return jnp.mean(per_row)


def flash_attention(q, k, v, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128) -> jnp.ndarray:
    return attn_kernel.flash_attention(q, k, v, causal=causal, window=window,
                                       block_q=block_q, block_k=block_k,
                                       interpret=_interpret())
