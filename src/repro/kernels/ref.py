"""Pure-jnp oracles for every Pallas kernel (the correctness reference
against which interpret-mode kernel sweeps assert allclose)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

_EPS = 1e-12


def enhanced_era(z_mean: jnp.ndarray, beta: float) -> jnp.ndarray:
    """SCARLET Eq. 4 over the last axis: z^beta / sum z^beta."""
    z = jnp.clip(z_mean.astype(jnp.float32), _EPS, None)
    logits = beta * jnp.log(z)
    return jax.nn.softmax(logits, axis=-1).astype(z_mean.dtype)


def enhanced_era_fused(z_clients: jnp.ndarray, beta: float) -> jnp.ndarray:
    """Fused mean-over-clients + sharpen: (K, B, N) -> (B, N)."""
    return enhanced_era(jnp.mean(z_clients.astype(jnp.float32), axis=0), beta)


def fused_round(z_clients: jnp.ndarray, weights: jnp.ndarray, beta=None,
                base: jnp.ndarray | None = None, *, mode: str = "identity",
                bits: int | None = None, sharpen: bool = True) -> jnp.ndarray:
    """Oracle for the fused round hot path: per-client uplink codec
    round trip, weighted reduction, optional Enhanced-ERA sharpening —
    composed from the per-op oracles / codec math (see
    ``repro.kernels.round_kernel`` for the contract)."""
    z = z_clients.astype(jnp.float32)
    K, M, N = z.shape
    if mode == "quant":
        z = quantize_dequantize(z, bits)
        z = jnp.maximum(z, 0.0)
        z = z / jnp.maximum(z.sum(axis=-1, keepdims=True), 1e-9)
    elif mode == "delta":
        b = base.astype(jnp.float32)[None]          # (1, M, N)
        r = z - b
        r = r[..., :-1]                             # last class sum-implied
        if bits is not None:
            r = quantize_dequantize(r, bits)
        r = jnp.concatenate([r, -r.sum(axis=-1, keepdims=True)], axis=-1)
        z = b + r
        z = jnp.maximum(z, 0.0)
        z = z / jnp.maximum(z.sum(axis=-1, keepdims=True), 1e-9)
    zsum = jnp.tensordot(weights.astype(jnp.float32), z, axes=(0, 0))
    if sharpen:
        return enhanced_era(zsum / K, beta).astype(z_clients.dtype)
    return zsum.astype(z_clients.dtype)


def quantize_dequantize(z: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Per-row min-max uniform quantization round trip over the last axis."""
    levels = float(2 ** bits - 1)
    z32 = z.astype(jnp.float32)
    zmin = z32.min(axis=-1, keepdims=True)
    zmax = z32.max(axis=-1, keepdims=True)
    scale = jnp.maximum(zmax - zmin, 1e-9)
    q = jnp.round((z32 - zmin) / scale * levels) / levels
    return (q * scale + zmin).astype(z.dtype)


def distill_loss(logits: jnp.ndarray, teacher: jnp.ndarray) -> jnp.ndarray:
    """Per-row soft-target CE: -sum_j t_j log_softmax(l)_j -> (B,)."""
    l32 = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(l32, axis=-1)
    return -jnp.sum(teacher.astype(jnp.float32) * logp, axis=-1)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True, window: int = 0) -> jnp.ndarray:
    """Naive attention oracle. q: (B,Sq,H,dh); k/v: (B,Sk,Hkv,dh)."""
    B, Sq, H, dh = q.shape
    Hkv = k.shape[2]
    rep = H // Hkv
    kr = jnp.repeat(k, rep, axis=2)
    vr = jnp.repeat(v, rep, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), kr.astype(jnp.float32))
    s = s / jnp.sqrt(jnp.float32(dh))
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, vr.astype(jnp.float32))
    return o.astype(q.dtype)
