"""Pallas TPU kernel for Enhanced ERA (SCARLET Eq. 4).

The aggregation sharpening is the server's per-round hot loop:
``|P^t| x N`` soft-labels pass through ``z^beta / sum(z^beta)``.  A naive
jnp chain (clip -> log -> mul -> exp -> sum -> div) makes 3 HBM round
trips; this kernel fuses everything in one VMEM pass per row block (VPU
transcendental-bound), including the optional mean over the K client
axis so the (K, B, N) stack is reduced on the fly.

Tiling: rows are blocked by ``block_b`` (8-aligned); the class dim N is
kept whole per tile (FL class counts are <= a few thousand; padded to a
128-lane multiple by the wrapper).  Softmax-style max-subtraction in
log-space keeps large beta stable.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.runtime import (
    VMEM_BUDGET_INTERPRET,
    VMEM_BUDGET_NATIVE,
    align_block_rows,
    fit_block_rows,
    resolve_interpret,
)

_EPS = 1e-12

# beta rides along as a (1,) array pinned to SMEM: scalar parameters
# live in scalar memory on TPU (a VMEM/ANY spec for a 1-element vector
# is not a valid compiled layout), and every grid step reads the same
# whole array (no blocking).
_BETA_SPEC = pl.BlockSpec(memory_space=pltpu.SMEM)


def _era_kernel(z_ref, beta_ref, o_ref):
    z = z_ref[...].astype(jnp.float32)          # (bb, N)
    beta = beta_ref[0]
    logz = jnp.log(jnp.maximum(z, _EPS)) * beta  # (bb, N)
    m = jnp.max(logz, axis=-1, keepdims=True)
    e = jnp.exp(logz - m)
    o_ref[...] = (e / jnp.sum(e, axis=-1, keepdims=True)).astype(o_ref.dtype)


def _era_fused_kernel(z_ref, beta_ref, o_ref, *, k_clients: int):
    z = z_ref[...].astype(jnp.float32)           # (K, bb, N)
    zbar = jnp.sum(z, axis=0) / k_clients
    beta = beta_ref[0]
    logz = jnp.log(jnp.maximum(zbar, _EPS)) * beta
    m = jnp.max(logz, axis=-1, keepdims=True)
    e = jnp.exp(logz - m)
    o_ref[...] = (e / jnp.sum(e, axis=-1, keepdims=True)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def enhanced_era(z_mean: jnp.ndarray, beta, block_b: int = 256,
                 interpret: bool | None = None) -> jnp.ndarray:
    """z_mean: (B, N) -> sharpened (B, N).  N padded to 128 lanes.

    ``interpret=None`` auto-detects the backend (native on TPU,
    interpreter elsewhere).
    """
    interpret = resolve_interpret(interpret)
    B, N = z_mean.shape
    # shrink the block to the input, kept 8-aligned (f32 sublane tiling)
    block_b = align_block_rows(block_b, B)
    n_pad = (-N) % 128
    b_pad = (-B) % block_b
    z = jnp.pad(z_mean, ((0, b_pad), (0, n_pad)))  # pad rows with zeros
    # zero-padding the class dim is safe: log(eps)*beta underflows the pad
    Bp, Np = z.shape
    beta_arr = jnp.asarray([beta], jnp.float32)
    out = pl.pallas_call(
        _era_kernel,
        grid=(Bp // block_b,),
        in_specs=[
            pl.BlockSpec((block_b, Np), lambda i: (i, 0)),
            _BETA_SPEC,
        ],
        out_specs=pl.BlockSpec((block_b, Np), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Bp, Np), z_mean.dtype),
        interpret=interpret,
    )(z, beta_arr)
    return out[:B, :N]


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def enhanced_era_fused(z_clients: jnp.ndarray, beta, block_b: int = 128,
                       interpret: bool | None = None) -> jnp.ndarray:
    """(K, B, N) client soft-labels -> aggregated + sharpened (B, N)."""
    interpret = resolve_interpret(interpret)
    K, B, N = z_clients.shape
    n_pad = (-N) % 128
    # shrink the (default 128-row) block to small B, kept 8-aligned —
    # and to the per-block VMEM budget: the whole K axis is resident per
    # block ((K, bb, Np) BlockSpec), so bb must shrink as K grows or
    # large-K stacks blow the ~16 MB VMEM on native TPU.  Row blocking
    # never changes results (every row is reduced/sharpened
    # independently), only the grid.
    budget = VMEM_BUDGET_INTERPRET if interpret else VMEM_BUDGET_NATIVE
    block_b = fit_block_rows(block_b, B, K * (N + n_pad) * 4, budget)
    b_pad = (-B) % block_b
    z = jnp.pad(z_clients, ((0, 0), (0, b_pad), (0, n_pad)))
    _, Bp, Np = z.shape
    beta_arr = jnp.asarray([beta], jnp.float32)
    out = pl.pallas_call(
        functools.partial(_era_fused_kernel, k_clients=K),
        grid=(Bp // block_b,),
        in_specs=[
            pl.BlockSpec((K, block_b, Np), lambda i: (0, i, 0)),
            _BETA_SPEC,
        ],
        out_specs=pl.BlockSpec((block_b, Np), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Bp, Np), z_clients.dtype),
        interpret=interpret,
    )(z, beta_arr)
    return out[:B, :N]


def analysis_cases():
    """(label, fn, abstract args) triples for the static BlockSpec lint
    (:mod:`repro.analysis.pallas_checks`): each is traced with
    ``interpret=False`` — never executed — so the lint inspects the
    exact BlockSpecs a native-TPU compile would use."""
    S, f32 = jax.ShapeDtypeStruct, jnp.float32
    return [
        ("era/B1000-N10",
         lambda z: enhanced_era(z, 1.5, interpret=False),
         (S((1000, 10), f32),)),
        ("era/B10-N10",
         lambda z: enhanced_era(z, 1.5, interpret=False),
         (S((10, 10), f32),)),
        ("era_fused/K200-B100-N10",
         lambda z: enhanced_era_fused(z, 1.5, interpret=False),
         (S((200, 100, 10), f32),)),
        ("era_fused/K1000-B1000-N100",
         lambda z: enhanced_era_fused(z, 1.5, interpret=False),
         (S((1000, 1000, 100), f32),)),
    ]
