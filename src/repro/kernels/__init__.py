"""Pallas TPU kernels for the paper's compute hot spots:

- era_kernel:     fused Enhanced-ERA aggregation sharpening (VPU-bound)
- quant_kernel:   fused min-max quantize-dequantize round trip (the
                  lossy wire-format simulation used by repro.compress)
- distill_kernel: soft-target CE over large (LM-vocab) class dims
                  (flash-softmax block accumulation)
- attn_kernel:    causal GQA flash attention for client forward passes

ops.py = jit'd wrappers (interpret mode on CPU); ref.py = jnp oracles.
"""
