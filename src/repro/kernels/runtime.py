"""Kernel runtime dispatch helpers.

Single source of truth for the interpret-mode decision: Pallas kernels
compile natively on TPU and fall back to interpreter execution (jnp
semantics, traceable/jittable) everywhere else.  Kernel modules default
``interpret=None`` and resolve it here at trace time, so direct callers
get the right mode for the backend they are actually on instead of
silently running the interpreter on TPU.
"""
from __future__ import annotations

from typing import Optional

import jax


def default_interpret() -> bool:
    """True when the default backend cannot compile Pallas TPU kernels."""
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """Resolve an ``interpret`` kwarg: ``None`` -> backend detection."""
    return default_interpret() if interpret is None else bool(interpret)
