"""Kernel runtime dispatch helpers.

Single source of truth for the interpret-mode decision: Pallas kernels
compile natively on TPU and fall back to interpreter execution (jnp
semantics, traceable/jittable) everywhere else.  Kernel modules default
``interpret=None`` and resolve it here at trace time, so direct callers
get the right mode for the backend they are actually on instead of
silently running the interpreter on TPU.

Also the single source of truth for row-block alignment: every kernel
that tiles a flattened row axis must round its block size up to the f32
sublane multiple (8) — a block like 10 interprets fine on CPU but
mis-tiles on native TPU, which is exactly the class of bug interpret
mode cannot catch.
"""
from __future__ import annotations

from typing import Optional

import jax

# f32 sublane count: the second-to-last tile dim every f32 VMEM block
# must be a multiple of (the lane dim is handled by 128-padding in the
# wrappers).
SUBLANES_F32 = 8


def default_interpret() -> bool:
    """True when the default backend cannot compile Pallas TPU kernels."""
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """Resolve an ``interpret`` kwarg: ``None`` -> backend detection."""
    return default_interpret() if interpret is None else bool(interpret)


def align_block_rows(block_b: int, n_rows: int,
                     align: int = SUBLANES_F32) -> int:
    """Shrink a row-block size to the actual row count, rounded **up**
    to the sublane multiple.

    ``min(block_b, n_rows)`` alone produces illegal blocks (e.g. 10) for
    odd row counts; the round-up keeps the block a valid f32 tile while
    the wrappers' row padding covers the overhang.  Always >= ``align``.
    """
    return -(-max(align, min(block_b, n_rows)) // align) * align
