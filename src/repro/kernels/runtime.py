"""Kernel runtime dispatch helpers.

Single source of truth for the interpret-mode decision: Pallas kernels
compile natively on TPU and fall back to interpreter execution (jnp
semantics, traceable/jittable) everywhere else.  Kernel modules default
``interpret=None`` and resolve it here at trace time, so direct callers
get the right mode for the backend they are actually on instead of
silently running the interpreter on TPU.

Also the single source of truth for row-block alignment: every kernel
that tiles a flattened row axis must round its block size up to the f32
sublane multiple (8) — a block like 10 interprets fine on CPU but
mis-tiles on native TPU, which is exactly the class of bug interpret
mode cannot catch.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

# f32 sublane count: the second-to-last tile dim every f32 VMEM block
# must be a multiple of (the lane dim is handled by 128-padding in the
# wrappers).
SUBLANES_F32 = 8

# lane count: the minor tile dim of every VMEM block, dtype-independent.
LANES = 128

# Per-block VMEM budgets shared by every kernel whose block size is
# auto-sized (round_kernel, era_kernel fused): the K/client axis is
# resident per block, so the row block must shrink as it grows.  Native
# TPU keeps headroom below the ~16 MB/core VMEM for Mosaic's double
# buffering; the interpreter has no VMEM, so a larger budget just means
# fewer grid steps.  VMEM_LIMIT_NATIVE is the hard per-core capacity
# the static lint (repro.analysis.pallas_checks) enforces.
VMEM_BUDGET_NATIVE = 4 * 2 ** 20
VMEM_BUDGET_INTERPRET = 16 * 2 ** 20
VMEM_LIMIT_NATIVE = 16 * 2 ** 20


def sublanes_for_dtype(dtype) -> int:
    """Minimum sublane multiple (second-to-last tile dim) for ``dtype``:
    8 for 4-byte types, 16 for 2-byte, 32 for 1-byte — the (sublane,
    128) native tile shapes."""
    itemsize = jnp.dtype(dtype).itemsize
    return max(SUBLANES_F32, 32 // max(itemsize, 1))


def default_interpret() -> bool:
    """True when the default backend cannot compile Pallas TPU kernels."""
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """Resolve an ``interpret`` kwarg: ``None`` -> backend detection."""
    return default_interpret() if interpret is None else bool(interpret)


def align_block_rows(block_b: int, n_rows: int,
                     align: int = SUBLANES_F32) -> int:
    """Shrink a row-block size to the actual row count, rounded **up**
    to the sublane multiple.

    ``min(block_b, n_rows)`` alone produces illegal blocks (e.g. 10) for
    odd row counts; the round-up keeps the block a valid f32 tile while
    the wrappers' row padding covers the overhang.  Always >= ``align``.
    """
    return -(-max(align, min(block_b, n_rows)) // align) * align


def fit_block_rows(block_b: int, n_rows: int, bytes_per_row: float,
                   budget: int, align: int = SUBLANES_F32) -> int:
    """Shrink an (aligned) row block until its resident footprint fits
    ``budget``: halve while ``block_b * bytes_per_row`` exceeds it,
    keeping the block ``align``-row aligned and >= ``align``.

    ``bytes_per_row`` is everything resident per row of the block —
    e.g. ``K * n_lanes * 4`` for a kernel that keeps the whole client
    axis in VMEM per row block (round_kernel, era_kernel fused).
    """
    bb = align_block_rows(block_b, n_rows, align=align)
    while bb > align and bb * bytes_per_row > budget:
        bb = align_block_rows(bb // 2, n_rows, align=align)
    return bb
