"""Pallas TPU kernel for the distillation loss (soft-target cross
entropy) over LARGE class dims.

Per row: ``loss = logsumexp(l) * sum(t) - sum(t * l)``.  The assigned LM
vocabs (163 840 / 200 064 / 256 000) do not fit one VMEM tile, so the
kernel runs a flash-softmax style ONE-pass over vocab blocks with
running-max / rescaled-sum accumulators in VMEM scratch, accumulating
``sum(t*l)`` and ``sum(t)`` in the same sweep.  Grid = (row blocks,
vocab blocks) with the vocab dim minor => sequential accumulation per
row block on TPU.

This is the TPU adaptation of the paper's distillation step: on GPU one
would fuse softmax+CE per threadblock; on TPU the constraint is VMEM
tiling and (8,128) register lanes, hence the block-accumulator design.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.runtime import (
    LANES,
    align_block_rows,
    resolve_interpret,
    sublanes_for_dtype,
)

_NEG = -1e30


def _distill_kernel(l_ref, t_ref, o_ref, m_ref, s_ref, dot_ref, tsum_ref, *, nv: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        s_ref[...] = jnp.zeros_like(s_ref)
        dot_ref[...] = jnp.zeros_like(dot_ref)
        tsum_ref[...] = jnp.zeros_like(tsum_ref)

    l = l_ref[...].astype(jnp.float32)   # (bb, bv)
    t = t_ref[...].astype(jnp.float32)

    m_prev = m_ref[...]
    m_blk = jnp.max(l, axis=-1)
    m_new = jnp.maximum(m_prev, m_blk)
    scale = jnp.exp(m_prev - m_new)
    s_ref[...] = s_ref[...] * scale + jnp.sum(jnp.exp(l - m_new[:, None]), axis=-1)
    m_ref[...] = m_new
    dot_ref[...] += jnp.sum(t * l, axis=-1)
    tsum_ref[...] += jnp.sum(t, axis=-1)

    @pl.when(j == nv - 1)
    def _fin():
        lse = m_ref[...] + jnp.log(s_ref[...])
        o_ref[...] = (lse * tsum_ref[...] - dot_ref[...]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_b", "block_v", "interpret"))
def distill_loss(logits: jnp.ndarray, teacher: jnp.ndarray,
                 block_b: int = 128, block_v: int = 2048,
                 interpret: bool | None = None) -> jnp.ndarray:
    """Row-wise soft-target CE. logits/teacher: (B, V) -> (B,).

    Padding: vocab pad gets logits=-1e30 (excluded from logsumexp) and
    teacher=0 (no dot contribution); row pad is sliced off.
    ``interpret=None`` auto-detects the backend (native on TPU).
    """
    interpret = resolve_interpret(interpret)
    B, V = logits.shape
    # same alignment audit as attn_kernel: caller-supplied block sizes
    # are clamped to the input but kept sublane-aligned (rows) and
    # lane-aligned (vocab) so odd blocks like 10 or 100 cannot reach the
    # BlockSpecs — they interpret fine on CPU but mis-tile natively
    block_b = align_block_rows(block_b, B,
                               align=sublanes_for_dtype(logits.dtype))
    block_v = align_block_rows(block_v, V, align=LANES)
    b_pad = (-B) % block_b
    v_pad = (-V) % block_v
    l = jnp.pad(logits, ((0, b_pad), (0, v_pad)), constant_values=_NEG)
    t = jnp.pad(teacher, ((0, b_pad), (0, v_pad)))
    Bp, Vp = l.shape
    nb, nv = Bp // block_b, Vp // block_v
    out = pl.pallas_call(
        functools.partial(_distill_kernel, nv=nv),
        grid=(nb, nv),
        in_specs=[
            pl.BlockSpec((block_b, block_v), lambda i, j: (i, j)),
            pl.BlockSpec((block_b, block_v), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((block_b,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((Bp,), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((block_b,), jnp.float32),
            pltpu.VMEM((block_b,), jnp.float32),
            pltpu.VMEM((block_b,), jnp.float32),
            pltpu.VMEM((block_b,), jnp.float32),
        ],
        interpret=interpret,
    )(l, t)
    return out[:B]


def analysis_cases():
    """(label, fn, abstract args) triples for the static BlockSpec lint
    (:mod:`repro.analysis.pallas_checks`); traced with
    ``interpret=False``, never executed."""
    S, f32 = jax.ShapeDtypeStruct, jnp.float32
    return [
        ("distill/B100-V163840",
         lambda l, t: distill_loss(l, t, interpret=False),
         (S((100, 163840), f32), S((100, 163840), f32))),
        ("distill/B13-V1000-oddblocks",
         lambda l, t: distill_loss(l, t, block_b=10, block_v=100,
                                   interpret=False),
         (S((13, 1000), f32), S((13, 1000), f32))),
    ]
