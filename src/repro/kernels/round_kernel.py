"""Pallas TPU kernel for the fused SCARLET round hot path.

Every round the server pulls a ``(K, m, N)`` soft-label stack through
the same op chain: uplink codec round trip (per-row min-max
quantize-dequantize, optionally residual-coded against the synchronized
cache), participation-weighted client reduction, and Enhanced-ERA power
sharpening (Eq. 4).  Run as separate ops (``quant_kernel`` +
``_simplex`` + weighted mean + ``era_kernel``) the stack crosses HBM
three-plus times per round; this kernel streams each row block through
VMEM exactly once — codec, reduction, and sharpening applied back to
back while the block is resident.

Per ``m``-row block the kernel sees the full client axis
(``(K, bm, Np)`` BlockSpec, like ``era_kernel.enhanced_era_fused``), so
the client reduction completes inside the block and the sharpening
nonlinearity can fuse behind it.  ``bm`` is auto-shrunk to a VMEM
budget as K grows (the K axis is resident per block) and kept 8-aligned
(``runtime.align_block_rows``); the class dim is padded to 128 lanes
and masked in-kernel with ``broadcasted_iota`` lane predicates, exactly
as in the per-op kernels it replaces.

Codec modes (must mirror ``repro.compress.codecs`` bit for bit — the
engines' comm ledger is analytic, so values may drift only within one
quantization step, and in interpret mode they do not drift at all):

- ``"identity"``: no wire loss;
- ``"quant"``: per-row min-max round trip to ``bits`` bits over the N
  valid lanes + simplex re-projection (``QuantCodec(renormalize=True)``);
- ``"delta"``: residual vs the resolved cache base, last class dropped
  (sum-zero constraint), inner min-max round trip over the first
  ``N - 1`` lanes when ``bits`` is set, reconstruction + simplex
  re-projection (``CacheDeltaCodec[+quantB]``).

Weighting: the kernel computes ``sum_k w_k * z_k`` and, when
``sharpen=True``, divides by K before sharpening — so the scan engine
passes ``w = part * (K / n_part)`` to reproduce
``scarlet.aggregate_masked`` exactly, while the shard engine passes the
raw participation mask with ``sharpen=False`` to get the two-phase
contract's linear moment ``zsum`` (psum'd across shards before
``finalize_aggregate`` sharpens once).

The total-outage uniform-teacher guard stays *outside* the kernel (a
``jnp.where`` on the tiny ``(m, N)`` output) so it matches
``scarlet.aggregate_masked`` bit for bit.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.runtime import (
    SUBLANES_F32,
    VMEM_BUDGET_INTERPRET,
    VMEM_BUDGET_NATIVE,
    align_block_rows,
    fit_block_rows,
    resolve_interpret,
)

# numeric constants mirrored from the per-op path: one-quantization-step
# parity depends on using the *same* epsilons
_EPS_ERA = 1e-12       # era_kernel._EPS / core.era._EPS
_EPS_SCALE = 1e-9      # quant_kernel._EPS_SCALE
_EPS_SIMPLEX = 1e-9    # compress.codecs._EPS

MODES = ("identity", "quant", "delta")

# beta rides in SMEM as a (1,) array (scalar memory; see era_kernel)
_BETA_SPEC = pl.BlockSpec(memory_space=pltpu.SMEM)

# VMEM budget for the (K, bm, Np) block: the K axis is resident per
# block, so bm must shrink as K grows.  Shared constants in
# kernels/runtime.py (era_kernel's fused path sizes against the same
# budget, and repro.analysis.pallas_checks lints against the limit).
_VMEM_BUDGET_NATIVE = VMEM_BUDGET_NATIVE
_VMEM_BUDGET_INTERPRET = VMEM_BUDGET_INTERPRET


def _qdq(r, valid, levels):
    """In-block min-max round trip over the ``valid`` lanes of each row
    — the exact ``quant_kernel._qdq_kernel`` math (incl. the [0, 1]
    level clamp), applied to an already-resident (K, bm, Np) block."""
    rmin = jnp.min(jnp.where(valid, r, jnp.inf), axis=-1, keepdims=True)
    rmax = jnp.max(jnp.where(valid, r, -jnp.inf), axis=-1, keepdims=True)
    scale = jnp.maximum(rmax - rmin, _EPS_SCALE)
    q = jnp.clip(jnp.round((r - rmin) / scale * levels) / levels, 0.0, 1.0)
    return q * scale + rmin


def _simplex(z, valid):
    """codecs._simplex with the padded lanes zeroed (so they neither
    count in the row sum nor leak into the reduction)."""
    z = jnp.where(valid, jnp.maximum(z, 0.0), 0.0)
    return z / jnp.maximum(jnp.sum(z, axis=-1, keepdims=True), _EPS_SIMPLEX)


def _fused_round_kernel(*refs, k_clients: int, n_valid: int,
                        levels: float | None, mode: str, sharpen: bool):
    it = iter(refs)
    z_ref, w_ref = next(it), next(it)
    base_ref = next(it) if mode == "delta" else None
    beta_ref = next(it) if sharpen else None
    o_ref = next(it)

    z = z_ref[...].astype(jnp.float32)                   # (K, bm, Np)
    lane = jax.lax.broadcasted_iota(jnp.int32, z.shape, 2)
    valid = lane < n_valid

    if mode == "delta":
        b = base_ref[...].astype(jnp.float32)            # (bm, Np)
        r = z - b[None]
        res_valid = lane < (n_valid - 1)  # last class implied by sum-zero
        if levels is not None:
            r = _qdq(r, res_valid, levels)
        r = jnp.where(res_valid, r, 0.0)
        last = -jnp.sum(r, axis=-1, keepdims=True)
        r = jnp.where(lane == n_valid - 1, last, r)
        z = _simplex(b[None] + r, valid)
    elif mode == "quant":
        z = _simplex(_qdq(z, valid, levels), valid)
    else:
        z = jnp.where(valid, z, 0.0)

    w = w_ref[...].astype(jnp.float32)                   # (K, 1)
    zsum = jnp.sum(z * w[:, :, None], axis=0)            # (bm, Np)
    if sharpen:
        # identical to era_kernel._era_fused_kernel on the weighted stack
        zbar = zsum / k_clients
        beta = beta_ref[0]
        logz = jnp.log(jnp.maximum(zbar, _EPS_ERA)) * beta
        m = jnp.max(logz, axis=-1, keepdims=True)
        e = jnp.exp(logz - m)
        out = e / jnp.sum(e, axis=-1, keepdims=True)
    else:
        out = zsum
    o_ref[...] = out.astype(o_ref.dtype)


def _auto_block_m(m: int, k: int, n_padded: int, interpret: bool) -> int:
    budget = _VMEM_BUDGET_INTERPRET if interpret else _VMEM_BUDGET_NATIVE
    return fit_block_rows(128, m, k * n_padded * 4, budget)


@functools.partial(jax.jit, static_argnames=("mode", "bits", "sharpen",
                                             "block_m", "interpret"))
def fused_round(z_clients: jnp.ndarray, weights: jnp.ndarray, beta=None,
                base: jnp.ndarray | None = None, *, mode: str = "identity",
                bits: int | None = None, sharpen: bool = True,
                block_m: int | None = None,
                interpret: bool | None = None) -> jnp.ndarray:
    """Fused round hot path: (K, m, N) -> (m, N).

    ``weights`` is the (K,) per-client reduction weight (see module
    docs); ``base`` is the *resolved* delta base (``(m, N)``, required
    for ``mode="delta"`` — use :func:`resolve_delta_base`).  ``beta`` is
    required when ``sharpen=True``.  ``interpret=None`` auto-detects the
    backend.
    """
    if mode not in MODES:
        raise ValueError(f"unknown mode {mode!r} (want one of {MODES})")
    if mode == "quant" and bits is None:
        raise ValueError("mode='quant' requires bits")
    if sharpen and beta is None:
        raise ValueError("sharpen=True requires beta")
    if mode == "delta" and base is None:
        raise ValueError("mode='delta' requires a resolved base "
                         "(resolve_delta_base)")
    interpret = resolve_interpret(interpret)
    K, M, N = z_clients.shape
    n_pad = (-N) % 128
    Np = N + n_pad
    # The client axis is padded to the sublane tile: the (K, 1) weights
    # operand makes K a *sublane* dim, so an unaligned client count
    # (e.g. K=50) mis-tiles natively — caught by the static BlockSpec
    # lint (repro.analysis.pallas_checks).  Padded clients carry zero
    # weight, so the reduction (and the /K mean) is unchanged.
    k_pad = (-K) % SUBLANES_F32
    Kp = K + k_pad
    bm = (align_block_rows(block_m, M) if block_m is not None
          else _auto_block_m(M, Kp, Np, interpret))
    m_pad = (-M) % bm
    z = jnp.pad(z_clients, ((0, k_pad), (0, m_pad), (0, n_pad)))
    Mp = M + m_pad
    w = jnp.pad(jnp.reshape(weights.astype(jnp.float32), (K, 1)),
                ((0, k_pad), (0, 0)))
    levels = float(2 ** bits - 1) if bits is not None else None

    operands = [z, w]
    in_specs = [
        pl.BlockSpec((Kp, bm, Np), lambda i: (0, i, 0)),
        pl.BlockSpec((Kp, 1), lambda i: (0, 0)),
    ]
    if mode == "delta":
        operands.append(jnp.pad(base.astype(jnp.float32),
                                ((0, m_pad), (0, n_pad))))
        in_specs.append(pl.BlockSpec((bm, Np), lambda i: (i, 0)))
    if sharpen:
        operands.append(jnp.asarray([beta], jnp.float32))
        in_specs.append(_BETA_SPEC)

    out = pl.pallas_call(
        functools.partial(_fused_round_kernel, k_clients=K, n_valid=N,
                          levels=levels, mode=mode, sharpen=sharpen),
        grid=(Mp // bm,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, Np), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), z_clients.dtype),
        interpret=interpret,
    )(*operands)
    return out[:M, :N]


def analysis_cases():
    """(label, fn, abstract args) triples for the static BlockSpec lint
    (:mod:`repro.analysis.pallas_checks`); traced with
    ``interpret=False``, never executed."""
    S, f32 = jax.ShapeDtypeStruct, jnp.float32
    return [
        ("round/identity-sharpen-K200",
         lambda z, w: fused_round(z, w, 1.5, mode="identity",
                                  sharpen=True, interpret=False),
         (S((200, 100, 10), f32), S((200,), f32))),
        ("round/quant8-sharpen-K1000",
         lambda z, w: fused_round(z, w, 1.5, mode="quant", bits=8,
                                  sharpen=True, interpret=False),
         (S((1000, 64, 10), f32), S((1000,), f32))),
        ("round/delta8-linear-K50",
         lambda z, w, b: fused_round(z, w, None, b, mode="delta", bits=8,
                                     sharpen=False, interpret=False),
         (S((50, 24, 10), f32), S((50,), f32), S((24, 10), f32))),
    ]


# ---------------------------------------------------------------------------
# Engine-facing plumbing
# ---------------------------------------------------------------------------

def resolve_delta_base(base, present, m: int, n: int) -> jnp.ndarray:
    """The delta base as ``CacheDeltaCodec._base`` resolves it: the
    cached entry where one exists, the uniform prior elsewhere."""
    if base is None:
        return jnp.full((m, n), 1.0 / n, jnp.float32)
    if present is not None:
        base = jnp.where(present[..., None], base, 1.0 / n)
    return base


def codec_kernel_spec(codec) -> dict | None:
    """Kernel parameters for an uplink codec, or ``None`` when the codec
    has no fused equivalent (top-k, exotic compositions) and the per-op
    path must run."""
    from repro.compress.codecs import CacheDeltaCodec, IdentityCodec, QuantCodec

    if isinstance(codec, IdentityCodec):
        return {"mode": "identity", "bits": None}
    if isinstance(codec, QuantCodec) and codec.renormalize:
        return {"mode": "quant", "bits": codec.bits}
    if isinstance(codec, CacheDeltaCodec):
        if isinstance(codec.inner, IdentityCodec):
            return {"mode": "delta", "bits": None}
        if isinstance(codec.inner, QuantCodec) and not codec.inner.renormalize:
            return {"mode": "delta", "bits": codec.inner.bits}
    return None
