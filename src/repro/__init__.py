"""repro: production-grade JAX reproduction of SCARLET (soft-label
caching + Enhanced ERA for communication-efficient federated
distillation), with a multi-architecture model zoo, multi-pod
pjit/shard_map distribution and Pallas TPU kernels."""
__version__ = "1.0.0"
