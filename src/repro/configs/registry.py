"""Config registry: --arch <id> -> ModelConfig."""
from repro.configs import (
    gemma2_27b,
    granite_3_2b,
    granite_3_8b,
    grok_1_314b,
    internvl2_26b,
    jamba_v01_52b,
    kimi_k2_1t_a32b,
    mamba2_1_3b,
    phi4_mini_3_8b,
    resnet20_cifar,
    whisper_large_v3,
)

ARCHS = {
    m.CONFIG.name: m.CONFIG
    for m in (
        kimi_k2_1t_a32b, internvl2_26b, jamba_v01_52b, grok_1_314b,
        gemma2_27b, granite_3_2b, phi4_mini_3_8b, granite_3_8b,
        whisper_large_v3, mamba2_1_3b, resnet20_cifar,
    )
}

ASSIGNED = [n for n in ARCHS if n != "resnet20-cifar"]


def get(name: str):
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]
