"""Model / run configuration dataclasses.

Each assigned architecture gets a ``configs/<id>.py`` exporting
``CONFIG`` (the exact full-size config) built from :class:`ModelConfig`.
``ModelConfig.reduced()`` returns the smoke-test variant (2 layers,
d_model <= 512, <= 4 experts) exercised on CPU.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@dataclass(frozen=True)
class ModelConfig:
    # identity ------------------------------------------------------------
    name: str = "model"
    family: str = "dense"  # dense | moe | ssm | hybrid | encdec | vlm | resnet
    source: str = ""       # citation ([arXiv:...] / [hf:...])

    # transformer backbone --------------------------------------------------
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 0          # 0 -> d_model // n_heads
    d_ff: int = 1024
    vocab_size: int = 1000
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False

    # gemma2-style options --------------------------------------------------
    attn_softcap: float = 0.0      # 0 disables
    final_softcap: float = 0.0
    sliding_window: int = 0        # 0 disables; used by "local" layers
    local_global_alternating: bool = False  # [local, global] layer pairs

    # MoE -------------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0              # expert hidden size (0 -> d_ff)
    n_shared_experts: int = 0      # always-on experts (Kimi K2 style)
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    moe_every: int = 1             # MoE every k-th layer (Jamba: 2)

    # SSM (Mamba2 / SSD) ------------------------------------------------------
    ssm_state: int = 0             # d_state; 0 disables SSM
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_kernel: int = 4
    ssm_chunk: int = 256
    attn_layer_period: int = 0     # hybrid: 1 attention layer every k (Jamba: 8)

    # encoder-decoder (Whisper) ----------------------------------------------
    n_encoder_layers: int = 0
    encoder_len: int = 0           # audio frame-embedding length (stub frontend)

    # VLM (InternVL) ----------------------------------------------------------
    n_patches: int = 0             # patch-embedding prefix length (stub frontend)

    # numerics ---------------------------------------------------------------
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    fp32_logits: bool = True       # cast LM logits to f32 (baseline); False
                                   # keeps bf16 end-to-end (perf variant)
    remat_policy: str = "nothing_saveable"  # none|nothing_saveable|dots_saveable
    ce_impl: str = "logp"          # logp: materialize log_softmax (B,S,V);
                                   # lse: logsumexp - gathered logit (no
                                   # (B,S,V) f32 intermediate) — perf variant
    attn_f32: bool = True          # f32 score/softmax chain (baseline);
                                   # False halves S x S HBM traffic (the
                                   # Pallas flash kernel removes it fully)

    # ------------------------------------------------------------------
    @property
    def dh(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 so TP-16 / TP-32 shards evenly."""
        return _round_up(self.vocab_size, 256)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def expert_d_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    def param_count(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS and FedAvg comm)."""
        D, V = self.d_model, self.padded_vocab
        emb = V * D * (1 if self.tie_embeddings else 2)
        total = emb
        H, Hkv, dh = self.n_heads, self.n_kv_heads, self.dh
        attn = D * H * dh + 2 * D * Hkv * dh + H * dh * D
        dense_ffn = 3 * D * self.d_ff
        moe_ffn = self.n_experts * 3 * D * self.expert_d_ff + D * self.n_experts
        shared = self.n_shared_experts * 3 * D * self.expert_d_ff
        if self.family in ("dense", "vlm"):
            total += self.n_layers * (attn + dense_ffn)
        elif self.family == "moe":
            total += self.n_layers * (attn + moe_ffn + shared)
        elif self.family == "ssm":
            di, ns, nh = self.d_inner, self.ssm_state, self.n_ssm_heads
            conv_dim = di + 2 * ns
            ssm = D * (2 * di + 2 * ns + nh) + conv_dim * self.ssm_conv_kernel + di * D + 2 * nh
            total += self.n_layers * ssm
        elif self.family == "hybrid":
            di, ns, nh = self.d_inner, self.ssm_state, self.n_ssm_heads
            conv_dim = di + 2 * ns
            ssm = D * (2 * di + 2 * ns + nh) + conv_dim * self.ssm_conv_kernel + di * D + 2 * nh
            n_attn = self.n_layers // max(self.attn_layer_period, 1)
            n_ssm = self.n_layers - n_attn
            n_moe = self.n_layers // max(self.moe_every, 1)
            n_dense = self.n_layers - n_moe
            total += n_attn * attn + n_ssm * ssm + n_moe * moe_ffn + n_dense * dense_ffn
        elif self.family == "encdec":
            enc = self.n_encoder_layers * (attn + dense_ffn)
            dec = self.n_layers * (2 * attn + dense_ffn)  # self + cross
            total += enc + dec + self.encoder_len * D  # learned enc pos
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k + shared experts only)."""
        if self.n_experts == 0:
            return self.param_count()
        full = self.param_count()
        D = self.d_model
        expert_p = 3 * D * self.expert_d_ff
        n_moe = (
            self.n_layers // max(self.moe_every, 1)
            if self.family == "hybrid"
            else self.n_layers
        )
        inactive = n_moe * (self.n_experts - self.top_k) * expert_p
        return int(full - inactive)

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: 2 layers (blocks), d_model<=512, <=4 experts."""
        changes = dict(
            name=self.name + "-smoke",
            d_model=min(self.d_model, 256),
            n_heads=4,
            n_kv_heads=2 if self.n_kv_heads < self.n_heads else 4,
            head_dim=64,
            d_ff=min(self.d_ff, 512) or 0,
            vocab_size=min(self.vocab_size, 512),
            param_dtype="float32",
            compute_dtype="float32",
        )
        if self.family == "hybrid":
            changes["n_layers"] = max(self.attn_layer_period, 2)  # one block
            changes["attn_layer_period"] = max(self.attn_layer_period, 2)
        elif self.local_global_alternating:
            changes["n_layers"] = 2  # one [local, global] pair
        else:
            changes["n_layers"] = 2
        if self.n_experts:
            changes["n_experts"] = min(self.n_experts, 4)
            changes["top_k"] = min(self.top_k, 2)
            changes["moe_d_ff"] = min(self.expert_d_ff, 256)
            changes["n_shared_experts"] = min(self.n_shared_experts, 1)
        if self.ssm_state:
            changes["ssm_state"] = min(self.ssm_state, 64)
            changes["ssm_head_dim"] = 32
            changes["ssm_chunk"] = 32
        if self.n_encoder_layers:
            changes["n_encoder_layers"] = 2
            changes["encoder_len"] = 64
        if self.n_patches:
            changes["n_patches"] = 16
        if self.sliding_window:
            changes["sliding_window"] = 64
        return dataclasses.replace(self, **changes)


@dataclass(frozen=True)
class InputShape:
    """An assigned (name, seq_len, global_batch, mode) input shape."""

    name: str
    seq_len: int
    global_batch: int
    mode: str  # "train" | "prefill" | "decode"


INPUT_SHAPES: Tuple[InputShape, ...] = (
    InputShape("train_4k", 4_096, 256, "train"),
    InputShape("prefill_32k", 32_768, 32, "prefill"),
    InputShape("decode_32k", 32_768, 128, "decode"),
    InputShape("long_500k", 524_288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in INPUT_SHAPES}
