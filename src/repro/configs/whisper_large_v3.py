"""Whisper large-v3 — enc-dec audio; conv frontend STUBBED [arXiv:2212.04356]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="encdec",
    source="[arXiv:2212.04356]",
    n_layers=32,
    n_encoder_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab_size=51866,
    encoder_len=1500,
)
