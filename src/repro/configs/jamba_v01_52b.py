"""Jamba v0.1 52B — Mamba+attention 1:7 interleave, MoE 16e top-2 [arXiv:2403.19887]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    source="[arXiv:2403.19887]",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    moe_d_ff=14336,
    vocab_size=65536,
    n_experts=16,
    top_k=2,
    moe_every=2,
    attn_layer_period=8,
    ssm_state=16,
    ssm_head_dim=64,
    ssm_expand=2,
)
