"""ResNet-20 on CIFAR-10 — the paper's own client/server model (Table III)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="resnet20-cifar",
    family="resnet",
    source="[SCARLET paper, Table III]",
    n_layers=20,
    d_model=16,   # base width
    vocab_size=10,  # classes
)
