"""InternVL2-26B — InternViT + InternLM2 [arXiv:2404.16821].

Vision frontend (InternViT + projector) is a STUB per the assignment
carve-out: input_specs supplies precomputed patch embeddings (B, 256, D).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    source="[arXiv:2404.16821]",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=92553,
    n_patches=256,
)
