"""Kimi K2 — trillion-param MoE (paper-table) [arXiv:2501.kimi2]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    source="[arXiv:2501.kimi2]",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_ff=2048,
    moe_d_ff=2048,
    vocab_size=163840,
    n_experts=384,
    top_k=8,
    n_shared_experts=1,
)
