"""Granite 3.0 2B — dense GQA [hf:ibm-granite/granite-3.0-2b-base]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-2b",
    family="dense",
    source="[hf:ibm-granite/granite-3.0-2b-base]",
    n_layers=40,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    head_dim=64,
    d_ff=8192,
    vocab_size=49155,
)
