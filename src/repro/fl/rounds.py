"""Generic round loop + jitted client primitives.

The client axis is fully vmapped *per cohort*: client parameters are a
short static list of stacked pytrees (one per model cohort, see
:mod:`repro.fl.cohorts`; a homogeneous run is a one-element list whose
ops are bit-identical to a single stack), private shards are dense
``(K, n_max)`` arrays with validity masks, and every per-client
primitive below is a single jitted program over each cohort's axis — a
200-client scenario sweep runs without any Python loop over clients.
Scenario heterogeneity (per-client local-step counts / learning rates)
stays vmapped too, via ``local_train_masked``: every client scans the
same ``max_steps`` and masks out its tail steps.

Workflow per round t (SCARLET Alg. 1, any participation scenario):
  1. server picks the public subset P^t and computes the request list
     (cache miss mask) when caching is enabled;
  2. participating clients distill on the *previous* round's teacher
     (z-hat^{t-1}), then train locally on their private shard;
  3. clients emit soft-labels for requested samples (uplink);
  4. server aggregates via the round's Strategy, assembles the teacher
     from fresh + cached entries, updates the global cache and signals,
     distills the server model;
  5. the communication ledger records exact uplink/downlink bytes,
     including cache signals and catch-up packages for stale clients.

Cache semantics follow Alg. 3 (expiry checked at request time); see
``repro.core.cache`` and ``src/repro/fl/README.md``.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.compress import get_codec
from repro.core import cache as cache_lib
from repro.core import comm as comm_lib
from repro.obs import device as obs_device
from repro.data.synthetic import (
    dirichlet_partition,
    make_public_private,
    pad_client_shards,
    uniform_client_shards,
)
from repro.fl.cohorts import ClientModels, resolve_cohorts
from repro.fl.config import FLConfig
from repro.fl.scenarios import Scenario
from repro.fl.strategies import base as strat_base
from repro.fl.strategies.base import Strategy
from repro.models.resnet import apply_mlp, init_mlp


# ---------------------------------------------------------------------------
# jitted per-client primitives
# ---------------------------------------------------------------------------

def _ce(params, x, y, mask):
    logits = apply_mlp(params, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, y[:, None], axis=-1)[:, 0]
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def _kl(params, x, teacher):
    logits = apply_mlp(params, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    t = jnp.clip(teacher, 1e-12, 1.0)
    return jnp.mean(jnp.sum(t * (jnp.log(t) - logp), axis=-1))


@functools.partial(jax.jit, static_argnames=("steps",))
def local_train(params, x, y, mask, lr, steps: int):
    def body(p, _):
        g = jax.grad(_ce)(p, x, y, mask)
        return jax.tree_util.tree_map(lambda a, b: a - lr * b, p, g), None

    params, _ = jax.lax.scan(body, params, None, length=steps)
    return params


@functools.partial(jax.jit, static_argnames=("max_steps",))
def local_train_masked(params, x, y, mask, lr, n_steps, max_steps: int):
    """Heterogeneous-schedule variant: runs ``max_steps`` gradient steps
    but applies only the first ``n_steps`` (per-client, dynamic).  vmap
    this with per-client ``lr``/``n_steps`` arrays to give every client
    its own schedule inside one jitted program."""

    def body(p, i):
        g = jax.grad(_ce)(p, x, y, mask)
        step = jnp.where(i < n_steps, lr, 0.0)
        return jax.tree_util.tree_map(lambda a, b: a - step * b, p, g), None

    params, _ = jax.lax.scan(body, params, jnp.arange(max_steps))
    return params


@functools.partial(jax.jit, static_argnames=("steps",))
def distill(params, x, teacher, lr, steps: int):
    def body(p, _):
        g = jax.grad(_kl)(p, x, teacher)
        return jax.tree_util.tree_map(lambda a, b: a - lr * b, p, g), None

    params, _ = jax.lax.scan(body, params, None, length=steps)
    return params


@jax.jit
def predict_soft(params, x):
    return jax.nn.softmax(apply_mlp(params, x), axis=-1)


@jax.jit
def val_loss_soft(params, x, teacher):
    """Server-side proxy metric (App. D): distillation loss on a held-out
    public validation split — no test labels needed."""
    return _kl(params, x, teacher)


@jax.jit
def val_loss_hard(params, x, y, mask):
    """Client-side proxy metric (App. D): CE on a held-out private
    validation split."""
    return _ce(params, x, y, mask)


@jax.jit
def accuracy(params, x, y, mask):
    pred = jnp.argmax(apply_mlp(params, x), axis=-1)
    ok = (pred == y) * mask
    return jnp.sum(ok) / jnp.maximum(jnp.sum(mask), 1.0)


val_loss_hard_v = jax.vmap(val_loss_hard, in_axes=(0, 0, 0, 0))
local_train_v = jax.vmap(local_train, in_axes=(0, 0, 0, 0, None, None))
local_train_masked_v = jax.vmap(local_train_masked,
                                in_axes=(0, 0, 0, 0, 0, 0, None))
distill_v = jax.vmap(distill, in_axes=(0, None, 0, None, None))
predict_v = jax.vmap(predict_soft, in_axes=(0, None))
accuracy_v = jax.vmap(accuracy, in_axes=(0, 0, 0, 0))


def _select(new, old, keep_mask):
    """Per-client parameter update gating (partial participation)."""
    def sel(a, b):
        m = keep_mask.reshape((-1,) + (1,) * (a.ndim - 1))
        return jnp.where(m, a, b)

    return jax.tree_util.tree_map(sel, new, old)


def _select_cohorts(new, old, masks):
    """``_select`` over per-cohort param lists (masks pre-split)."""
    return [_select(n, o, m) for n, o, m in zip(new, old, masks)]


# ---------------------------------------------------------------------------
# History
# ---------------------------------------------------------------------------

@dataclass
class History:
    rounds: List[int] = field(default_factory=list)
    server_acc: List[float] = field(default_factory=list)
    client_acc: List[float] = field(default_factory=list)
    cumulative_mb: List[float] = field(default_factory=list)
    # Appendix-D proxy metrics (no test labels required in deployment)
    server_val_loss: List[float] = field(default_factory=list)
    client_val_loss: List[float] = field(default_factory=list)
    # per-cohort mean client accuracy, one row per eval round (a single
    # column for homogeneous runs) — see repro.fl.cohorts
    cohort_client_acc: List[List[float]] = field(default_factory=list)
    ledger: comm_lib.CommLedger = field(default_factory=comm_lib.CommLedger)
    # Final accuracies are ``None`` when the leg never evaluated that
    # model (a zero-round leg, or Individual's nonexistent server) —
    # "not evaluated" must stay distinguishable from a measured 0.0,
    # since benchmarks read these as real accuracies.
    final_server_acc: Optional[float] = None
    final_client_acc: Optional[float] = None
    # per-round device-plane telemetry (repro.obs.device.TelemetryLog)
    # when the run had FLConfig.telemetry on; None otherwise.  Not part
    # of state_dict: telemetry is a per-run-leg observation, like the
    # ledger.
    telemetry: Optional[obs_device.TelemetryLog] = None

    def as_dict(self) -> Dict[str, Any]:
        out = {
            "rounds": self.rounds,
            "server_acc": self.server_acc,
            "client_acc": self.client_acc,
            "cumulative_mb": self.cumulative_mb,
            "server_val_loss": self.server_val_loss,
            "client_val_loss": self.client_val_loss,
            "cohort_client_acc": self.cohort_client_acc,
            "comm": self.ledger.summary(),
            "final_server_acc": self.final_server_acc,
            "final_client_acc": self.final_client_acc,
        }
        if self.telemetry is not None:
            out["telemetry"] = self.telemetry.as_dict()
        return out


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------

class FederatedDistillation:
    """Generic distillation-based FL run (DS-FL / SCARLET / CFD / COMET /
    Selective-FD / mean), with optional soft-label caching (drop-in for
    any strategy — paper Fig. 11) and arbitrary client scenarios
    (participation sampling, outages, heterogeneous schedules).

    RNG streams are split by concern: ``rng_idx`` drives public-subset
    selection, ``rng_part`` drives participation sampling, ``rng``
    remains for strategy payload transforms.  Runs that differ only in
    scenario therefore see identical P^t sequences, making their
    communication ledgers directly comparable.

    ``track_local_caches=True`` additionally maintains every client's
    mirrored local cache (signals + queue for participants, catch-up
    packages for returning stragglers) so tests can assert the Alg. 2/3
    byte-identity invariant; it is off by default because the simulation
    itself only needs the global cache.

    ``rng_backend="jax"`` draws the P^t subsets and participation masks
    from a split jax key stream instead of the numpy Generators — the
    exact same stream the scanned engine
    (:class:`repro.fl.scan_engine.ScannedFederatedDistillation`) folds
    on-device, which is what makes host-loop and scanned runs directly
    comparable (the parity suite relies on it).

    Wire codecs (``cfg.uplink_codec`` / ``cfg.downlink_codec``,
    :mod:`repro.compress`) apply the lossy encode->decode round trip to
    what each direction actually carries — client soft-labels after
    ``Strategy.transmit`` on the uplink, the freshly aggregated teacher
    on the downlink — and switch the ledger to the codec's analytic
    payload bytes.  The decoded downlink teacher is also what the server
    distills on and what enters the global cache, keeping server and
    client caches bit-identical (clients can only cache what the wire
    delivered).
    """

    def __init__(self, cfg: FLConfig, strategy: Strategy,
                 cache_duration: int = 0, use_cache: Optional[bool] = None,
                 probabilistic_expiry: bool = False,
                 scenario: Optional[Scenario] = None,
                 track_local_caches: bool = False,
                 rng_backend: str = "numpy"):
        self.cfg = cfg
        self.strategy = strategy
        self.D = cache_lib.normalize_cache_duration(cache_duration)
        self.probabilistic_expiry = probabilistic_expiry
        self.use_cache = strategy.uses_cache if use_cache is None else use_cache
        if self.D == 0:
            self.use_cache = self.use_cache and False
        self.scenario = scenario or Scenario.from_participation_rate(cfg.participation)
        self.track_local_caches = track_local_caches
        if rng_backend not in ("numpy", "jax"):
            raise ValueError(f"unknown rng_backend: {rng_backend!r}")
        self.rng_backend = rng_backend
        self.codec_up = get_codec(cfg.uplink_codec,
                                  index_bytes=cfg.index_bytes)
        self.codec_down = get_codec(cfg.downlink_codec,
                                    index_bytes=cfg.index_bytes)
        self.rng = np.random.default_rng(cfg.seed)
        self.rng_idx = np.random.default_rng([cfg.seed, 17])
        self.rng_part = np.random.default_rng([cfg.seed, 29])
        # device-plane telemetry (repro.obs): per-round counters/gauges
        # appended to History.telemetry.  telemetry_hook is an optional
        # pure-jnp transform (tel, t) -> tel applied inside the round
        # body — it must be scan-safe; repro.analysis flags hooks that
        # smuggle host callbacks into the compiled round.
        self._telemetry = bool(cfg.telemetry)
        self.telemetry_hook = None
        self._setup()

    # ------------------------------------------------------------------
    # Placement/init hooks: the active-set engine
    # (repro.fl.active_engine) overrides these to keep O(K)-sized
    # per-client state on the host; for the dense engines they are the
    # identity of the historical code, so traced programs (and golden
    # ledgers) are untouched.
    # ------------------------------------------------------------------
    def _client_array(self, x):
        """Placement for O(K) per-client data arrays (one row per
        client: private/test shards, masks, per-client schedules)."""
        return jnp.asarray(x)

    def _eval_array(self, x):
        """Placement for eval-only arrays whose size tracks the
        population (the held-out test set is ``~private_size/5``)."""
        return jnp.asarray(x)

    def _init_client_params(self, keys) -> None:
        """Materialize per-client parameters from the ``(K, ...)``
        stacked key slice (one key per client, global order)."""
        self.client_params = self.models.init_params(keys)

    def _partition_clients(self, x, y, seed: int):
        """Per-client shards in the dense ``(xs, ys, mask)`` layout."""
        c = self.cfg
        if c.partition == "uniform":
            return uniform_client_shards(x, y, c.n_clients)
        if c.partition != "dirichlet":
            raise ValueError(f"unknown partition {c.partition!r} "
                             "(want 'dirichlet' or 'uniform')")
        parts = dirichlet_partition(y, c.n_clients, c.alpha, seed=seed)
        return pad_client_shards(x, y, parts)

    # ------------------------------------------------------------------
    def _setup(self) -> None:
        c = self.cfg
        data = make_public_private(c.private_size, c.public_size, c.n_classes,
                                   c.dim, seed=c.seed,
                                   cluster_scale=c.cluster_scale, noise=c.noise)
        self.data = data
        self.xs, self.ys, self.mask = map(
            self._client_array,
            self._partition_clients(data["x_private"], data["y_private"],
                                    seed=c.seed))
        self.xts, self.yts, self.tmask = map(
            self._client_array,
            self._partition_clients(data["x_test"], data["y_test"],
                                    seed=c.seed + 7))
        self.x_pub = jnp.asarray(data["x_public"])
        self.x_test = self._eval_array(data["x_test"])
        self.y_test = self._eval_array(data["y_test"])

        # Client-model cohorts: client_params is a LIST with one stacked
        # pytree per cohort (architectures differ, so one stacked tree is
        # impossible); a homogeneous config yields a one-element list
        # whose ops are bit-identical to the legacy single-stack path.
        # Clients keep their global key regardless of the cohort split.
        self.models = ClientModels(resolve_cohorts(c), c.dim, c.n_classes)
        key = jax.random.PRNGKey(c.seed)
        keys = jax.random.split(key, c.n_clients + 1)
        self._init_client_params(keys[:-1])
        self.server_params = init_mlp(keys[-1], c.dim, c.n_classes, c.hidden, c.mlp_depth)

        # Appendix-D validation splits: 10% of public for the server proxy,
        # 10% of each client's private shard for the client proxy
        n_pub_val = max(c.public_size // 10, 10)
        self.pub_val_idx = jnp.asarray(
            np.random.default_rng(c.seed + 99).choice(
                c.public_size, n_pub_val, replace=False))
        val_cut = jnp.maximum((jnp.sum(self.mask, 1) * 0.9).astype(jnp.int32), 1)
        pos = jnp.arange(self.mask.shape[1])[None, :]
        self.val_mask = self._client_array(
            jnp.logical_and(self.mask, pos >= val_cut[:, None]))
        self.train_mask = self._client_array(
            jnp.logical_and(self.mask, pos < val_cut[:, None]))
        # per-cohort views of every per-client array (identity for a
        # single cohort); the data partition itself is cohort-agnostic
        m = self.models
        self.xs_c, self.ys_c = m.split(self.xs), m.split(self.ys)
        self.train_mask_c = m.split(self.train_mask)
        self.val_mask_c = m.split(self.val_mask)
        self.xts_c, self.yts_c = m.split(self.xts), m.split(self.yts)
        self.tmask_c = m.split(self.tmask)
        self.last_teacher_val: Optional[jnp.ndarray] = None

        self.cache_g = cache_lib.init_cache(c.public_size, c.n_classes)
        self.local_caches: List[cache_lib.CacheState] = [
            cache_lib.init_cache(c.public_size, c.n_classes)
            for _ in range(c.n_clients)
        ] if self.track_local_caches else []
        self.prev_teacher: Optional[Tuple[np.ndarray, jnp.ndarray]] = None  # (idx, z)
        self.last_sync = np.full(c.n_clients, 0, np.int64)  # last participated round
        self.t_done = 0  # rounds completed so far (run() continues from here)
        self.n_params = sum(x.size for x in jax.tree_util.tree_leaves(self.server_params))
        # per-round key stream shared with the scanned engine (jax mode)
        self._key_rounds = jax.random.fold_in(jax.random.PRNGKey(c.seed), 43)

        het = self.scenario.heterogeneity
        if het is not None:
            lr_k, steps_k, max_steps = het.resolve(c.n_clients, c.lr, c.local_steps)
            self._lr_k = self._client_array(jnp.asarray(lr_k, jnp.float32))
            self._steps_k = self._client_array(jnp.asarray(steps_k, jnp.int32))
            self._lr_k_c = self.models.split(self._lr_k)
            self._steps_k_c = self.models.split(self._steps_k)
            self._max_steps = max_steps
            self._lr_decay = het.lr_decay

    # ------------------------------------------------------------------
    def run(self, rounds: Optional[int] = None) -> History:
        """Run ``rounds`` more rounds (default: the configured count).

        Rounds are numbered absolutely: a second ``run()`` — or a run on
        an engine restored via :meth:`load_state_dict` — continues at
        ``t_done + 1`` with the *same* per-round key stream a single
        uninterrupted run would have used, so split runs are bit-
        identical to unsplit ones per round (``tests/test_checkpoint.py``).
        Each ``run()`` returns a *fresh* :class:`History`, so cumulative
        quantities (``ledger`` totals, ``cumulative_mb``) cover only that
        leg — stitch legs by concatenating their ledgers, as the
        checkpoint tests do; the ledger is not part of ``state_dict``.
        """
        c = self.cfg
        hist = History()
        if self._telemetry:
            hist.telemetry = obs_device.TelemetryLog()
        # ``rounds=0`` is an honest zero-round leg (useful for state-only
        # restarts), not a fall-through to the full configured run
        T = c.rounds if rounds is None else rounds
        t_end = self.t_done + T
        for t in range(self.t_done + 1, t_end + 1):
            self._round(t, hist)
            if t % c.eval_every == 0 or t == t_end:
                self._eval(t, hist)
        self.t_done = t_end
        hist.final_server_acc = hist.server_acc[-1] if hist.server_acc else None
        hist.final_client_acc = hist.client_acc[-1] if hist.client_acc else None
        return hist

    # ------------------------------------------------------------------
    # Checkpointing: the engine state that evolves across rounds, as one
    # fixed-structure pytree (repro.checkpoint.save_pytree-compatible).
    # ------------------------------------------------------------------
    def state_dict(self) -> Dict[str, Any]:
        """Snapshot of all cross-round simulation state.

        Covers params, cache, sync bookkeeping, the previous-round
        teacher, and the round counter — everything ``run()`` reads that
        a fresh engine would not reconstruct from the config.  The
        structure is fixed (absent optionals become zero placeholders +
        ``have_*`` flags) so ``checkpoint.load_pytree`` can use a fresh
        engine's ``state_dict()`` as the ``like`` tree.  Mirrored local
        caches (``track_local_caches``, a host-only verification mode)
        are not included, and neither are the legacy stateful numpy
        Generators — bit-identical continuation therefore requires the
        stateless ``rng_backend="jax"`` key stream (any engine).
        """
        c = self.cfg
        m = c.public_per_round
        if self.prev_teacher is not None:
            pidx, pteach = self.prev_teacher
            if jnp.ndim(pteach) == 3:
                # per-client (K, m, N) teachers (COMET) don't fit the
                # fixed (m, N) slot a fresh engine's like-tree declares,
                # so the npz round trip would fail on restore — reject
                # at save time with a diagnosable error instead
                raise ValueError(
                    "per-client prev_teacher stacks (COMET) are not "
                    "checkpointable; state_dict supports shared-teacher "
                    "strategies only")
            prev_idx = jnp.asarray(pidx, jnp.int32)
            prev_teacher = jnp.asarray(pteach, jnp.float32)
            have_prev = jnp.asarray(True)
        else:
            prev_idx = jnp.zeros((m,), jnp.int32)
            prev_teacher = jnp.zeros((m, c.n_classes), jnp.float32)
            have_prev = jnp.asarray(False)
        if self.last_teacher_val is not None:
            teacher_val = jnp.asarray(self.last_teacher_val, jnp.float32)
            have_tv = jnp.asarray(True)
        else:
            teacher_val = jnp.zeros((len(self.pub_val_idx), c.n_classes),
                                    jnp.float32)
            have_tv = jnp.asarray(False)
        return dict(
            t_done=jnp.asarray(self.t_done, jnp.int32),
            client_params=self.client_params,
            server_params=self.server_params,
            cache=self.cache_g,
            prev_idx=prev_idx,
            prev_teacher=prev_teacher,
            have_prev=have_prev,
            teacher_val=teacher_val,
            have_tv=have_tv,
            last_sync=jnp.asarray(self.last_sync, jnp.int32),
        )

    def load_state_dict(self, state: Dict[str, Any]) -> None:
        """Restore a :meth:`state_dict` snapshot; the next ``run()``
        continues bit-identically to an uninterrupted run."""
        if self.rng_backend != "jax":
            # the numpy Generators are stateful and not captured by
            # state_dict — a restored numpy-backend run would silently
            # replay virgin streams and diverge from the original
            raise ValueError(
                "restoring requires the stateless rng_backend='jax' key "
                "stream (construct the engine with rng_backend='jax')")
        if self.track_local_caches:
            # mirrored per-client caches are not captured either: a
            # restored engine would verify cold mirrors against a warm
            # global cache and report false divergence
            raise ValueError(
                "track_local_caches state is not checkpointed; restore "
                "into an engine with track_local_caches=False")
        self.t_done = int(state["t_done"])
        self.client_params = state["client_params"]
        self.server_params = state["server_params"]
        self.cache_g = cache_lib.CacheState(*state["cache"])
        self.prev_teacher = ((np.asarray(state["prev_idx"]),
                              jnp.asarray(state["prev_teacher"]))
                             if bool(state["have_prev"]) else None)
        self.last_teacher_val = (jnp.asarray(state["teacher_val"])
                                 if bool(state["have_tv"]) else None)
        self.last_sync = np.asarray(state["last_sync"]).astype(np.int64)

    # ------------------------------------------------------------------
    def _distill_all(self, params, x_prev, pteach):
        """Per-cohort client distillation on a shared ``(m, N)`` teacher
        or per-client ``(K, m, N)`` teacher stack (COMET)."""
        c = self.cfg
        if jnp.ndim(pteach) == 3:
            teach_c = self.models.split(pteach)
        else:
            teach_c = [jnp.broadcast_to(pteach, (n,) + pteach.shape)
                       for n in self.models.sizes]
        return [distill_v(p, x_prev, teach_c[i], c.lr_dist, c.distill_steps)
                for i, p in enumerate(params)]

    def _predict_all(self, params, x):
        """Cohort-collapsing soft predictions: ``(K, |x|, N)`` in global
        client order — the boundary where architecture heterogeneity
        becomes invisible to strategies/codecs/cache/ledger."""
        return self.models.concat([predict_v(p, x) for p in params])

    # ------------------------------------------------------------------
    def _local_train_all(self, params, t):
        """Per-cohort local training over the ``params`` list.  ``t``
        may be a python int (host loop) or traced (scan)."""
        c = self.cfg
        if self.scenario.heterogeneity is None:
            return [local_train_v(p, self.xs_c[i], self.ys_c[i],
                                  self.train_mask_c[i].astype(jnp.float32),
                                  c.lr, c.local_steps)
                    for i, p in enumerate(params)]
        decay = jnp.asarray(self._lr_decay, jnp.float32) ** (
            jnp.asarray(t, jnp.float32) - 1.0)
        return [local_train_masked_v(p, self.xs_c[i], self.ys_c[i],
                                     self.train_mask_c[i].astype(jnp.float32),
                                     self._lr_k_c[i] * decay,
                                     self._steps_k_c[i], self._max_steps)
                for i, p in enumerate(params)]

    # ------------------------------------------------------------------
    def _draw_round(self, t: int) -> Tuple[np.ndarray, np.ndarray]:
        """(participation mask, sorted P^t indices) for round ``t``.

        numpy mode: two dedicated Generators (legacy stream).  jax mode:
        the per-round fold of ``_key_rounds`` — identical draws to the
        scanned engine's on-device sampling.
        """
        c = self.cfg
        K = c.n_clients
        if self.rng_backend == "jax":
            kt = jax.random.fold_in(self._key_rounds, t)
            k_idx, k_part = jax.random.split(kt)
            idx = np.asarray(jnp.sort(jax.random.choice(
                k_idx, c.public_size, (c.public_per_round,), replace=False)))
            part = np.asarray(self.scenario.participation_mask_device(
                k_part, jnp.asarray(self.scenario.offline_mask(t, K))))
            return part, idx
        part = self.scenario.participation_mask(t, K, self.rng_part)
        # P^t is drawn from its own stream *before* any participation
        # branching so every scenario sees the identical subset sequence.
        idx = np.sort(self.rng_idx.choice(c.public_size, c.public_per_round,
                                          replace=False))
        return part, idx

    # ------------------------------------------------------------------
    def _telemetry_row(self, *, t, part_full, miss, base_present, z_tx,
                       z_srv, fresh, last_sync, uplink, downlink, catch_up,
                       axis_name: Optional[str] = None,
                       part_local=None) -> obs_device.RoundTelemetry:
        """One :class:`repro.obs.device.RoundTelemetry` row.

        Shared by all three engines — the single expression is what
        makes the counter stacks byte-equal by construction.  Integer
        counters derive from the REPLICATED full-width inputs
        (``part_full``, the pre-update ``miss``/``base_present``/
        ``last_sync``); participant-mean gauges use the (possibly
        shard-local) ``z``/``part_local`` with a psum over
        ``axis_name`` on the sharded engine.  ``z_tx`` is the stack as
        transmitted, ``z_srv`` the server's post-uplink-codec view,
        ``fresh`` the aggregated teacher after sharpening and the
        downlink codec.
        """
        part_f = jnp.asarray(
            part_local if part_local is not None else part_full,
            jnp.float32)
        n_part = jnp.sum(jnp.asarray(part_full, jnp.float32))
        hits, new, expired = obs_device.cache_signal_counts(
            base_present, miss)
        if self.codec_up.is_identity:
            cerr = jnp.float32(0.0)
        else:
            cerr = obs_device.codec_error_mean(z_srv, z_tx, part_f, n_part,
                                               axis_name=axis_name)
        zbar = obs_device.participant_mean(z_srv, part_f, n_part,
                                           axis_name=axis_name)
        tel = obs_device.RoundTelemetry(
            participants=obs_device.participants_per_cohort(
                part_full, self.models.offsets, self.models.sizes),
            cache_hits=hits, cache_miss_new=new, cache_expired=expired,
            catch_up_clients=obs_device.returning_client_count(
                part_full, last_sync, t),
            staleness_hist=obs_device.staleness_histogram(
                part_full, last_sync, t),
            uplink_bytes=jnp.asarray(uplink, jnp.float32),
            downlink_bytes=jnp.asarray(downlink, jnp.float32),
            catch_up_bytes=jnp.asarray(catch_up, jnp.float32),
            teacher_entropy_pre=obs_device.mean_entropy(zbar),
            teacher_entropy_post=obs_device.mean_entropy(fresh),
            beta=jnp.asarray(self.strategy.sharpen_gauge(zbar, t),
                             jnp.float32),
            codec_quant_error=cerr)
        if self.telemetry_hook is not None:
            tel = self.telemetry_hook(tel, t)
        return tel

    # ------------------------------------------------------------------
    def _round(self, t: int, hist: History) -> None:
        c, s = self.cfg, self.strategy
        K = c.n_clients
        part, idx = self._draw_round(t)
        n_part = int(part.sum())
        idx_j = jnp.asarray(idx)

        if n_part == 0:  # total outage: nothing moves, the cache ages
            hist.ledger.record(comm_lib.RoundCost(0.0, 0.0))
            if self._telemetry:  # all-zero row, matching the device
                # engines' gated (zeroed) telemetry on outage rounds
                hist.telemetry.append(obs_device.zeros(self.models.n_cohorts))
            return
        part_j = jnp.asarray(part)

        # --- clients: distill on previous teacher, then local training ----
        part_c = self.models.split(part_j)
        new_params = self.client_params
        if self.prev_teacher is not None:
            pidx, pteach = self.prev_teacher
            x_prev = self.x_pub[jnp.asarray(pidx)]
            upd = self._distill_all(new_params, x_prev, pteach)
            new_params = _select_cohorts(upd, new_params, part_c)
        upd = self._local_train_all(new_params, t)
        self.client_params = _select_cohorts(upd, new_params, part_c)

        # --- request list (cache) ----------------------------------------
        if self.use_cache:
            miss = cache_lib.miss_mask(
                self.cache_g, idx_j, t, self.D,
                probabilistic=self.probabilistic_expiry,
                key=jax.random.fold_in(jax.random.PRNGKey(c.seed), t)
                if self.probabilistic_expiry else None)
        else:
            miss = jnp.ones(len(idx), bool)
        n_req = int(jnp.sum(miss))
        # shared delta-coding base: the synchronized cache at P^t (pre-update)
        base, base_present = cache_lib.cached_at(self.cache_g, idx_j)

        # --- uplink: soft-labels on requested samples ---------------------
        # predict_soft collapses the cohort axis: soft-label shapes are
        # architecture-independent, so everything from here down (wire
        # codecs, strategy aggregation, cache, ledger) sees one (K, m, N)
        # stack in global client order regardless of the cohort mix.
        x_round = self.x_pub[idx_j]
        z_all = self._predict_all(self.client_params, x_round)  # (K, m, N)
        # jax mode matches the device engines' per-round transmit key;
        # numpy mode has no key stream (strategies must tolerate None)
        tkey = (jax.random.fold_in(jax.random.fold_in(self._key_rounds, t),
                                   strat_base.TRANSMIT_SALT)
                if self.rng_backend == "jax" else None)
        z_all = s.transmit(z_all, tkey)
        z_tx = z_all  # as transmitted (pre uplink codec): telemetry's
        # reference for the codec quantization-error gauge
        if not self.codec_up.is_identity:  # lossy wire: what the server sees
            z_all = self.codec_up.roundtrip(z_all, base=base,
                                            present=base_present)
        um = s.upload_mask(z_all)
        # only participating clients contribute
        zsel = z_all[part_j] if n_part < K else z_all
        umsel = None if um is None else (um[part_j] if n_part < K else um)

        fresh, per_client = s.aggregate(zsel, umsel, t)
        if not self.codec_down.is_identity:
            # clients receive (and cache) the decoded broadcast; the server
            # uses the same decoded teacher so both caches stay bit-identical
            fresh = self.codec_down.roundtrip(fresh, base=base,
                                              present=base_present)
            if per_client is not None:
                per_client = self.codec_down.roundtrip(
                    per_client, base=base, present=base_present)

        # --- assemble teacher + cache update ------------------------------
        cache_prev = self.cache_g  # pre-round state: catch-up covers <= t-1
        signals = None
        if self.use_cache:
            teacher = cache_lib.assemble_teacher(self.cache_g, idx_j, fresh, miss)
            self.cache_g, signals = cache_lib.update_global_cache(
                self.cache_g, idx_j, teacher, miss, t)
        else:
            teacher = fresh

        # --- server distillation ------------------------------------------
        self.server_params = distill(self.server_params, x_round, teacher,
                                     c.lr_dist, c.distill_steps)
        # App.-D proxy teacher on the public validation split: the clients'
        # (server-visible) aggregated predictions on held-out public data
        zv = self._predict_all(self.client_params, self.x_pub[self.pub_val_idx])
        self.last_teacher_val = jnp.mean(zv, axis=0)
        if per_client is not None:  # COMET: personalized teachers
            if per_client.shape[0] != K:  # partial participation: clients
                # without a cluster this round fall back to the global teacher
                fallback = jnp.broadcast_to(teacher, (K,) + teacher.shape)
                per_client = fallback.at[jnp.asarray(np.nonzero(part)[0])].set(per_client)
            teach_next = per_client
        else:
            teach_next = teacher
        self.prev_teacher = (idx, teach_next)

        # --- catch-up packages for returning stragglers --------------------
        catch_up = 0.0
        catch_up_pkgs = {}
        if self.use_cache:
            for k in np.nonzero(part)[0]:
                if self.last_sync[k] < t - 1:
                    pkg = cache_lib.make_catch_up(cache_prev, int(self.last_sync[k]))
                    catch_up_pkgs[k] = pkg
                    catch_up += cache_lib.catch_up_bytes(pkg)

        # --- mirrored local caches (verification mode) ---------------------
        if self.track_local_caches and self.use_cache:
            miss_np = np.asarray(miss)
            queue = cache_lib.pack_queue(teacher, miss_np)
            dense = cache_lib.unpack_queue(queue, miss, c.n_classes)
            for k in np.nonzero(part)[0]:
                ck = self.local_caches[k]
                if k in catch_up_pkgs:  # returning straggler
                    ck = cache_lib.apply_catch_up(ck, catch_up_pkgs[k])
                ck, _ = cache_lib.update_local_cache(ck, idx_j, signals, dense, t)
                self.local_caches[k] = ck

        # --- communication accounting --------------------------------------
        # Selective-FD: the confidence filter masks only the *uplink* —
        # each client withholds its unconfident entries among the
        # requested samples — while the server still broadcasts
        # aggregated labels for every requested sample, so the downlink
        # count stays at n_req.  Uplink is exact (possibly fractional
        # per-client average), not a rounded whole-mask fraction.
        uploaded_up = float(n_req)
        if umsel is not None:
            miss_f = jnp.asarray(miss, jnp.float32)
            uploaded_total = float(jnp.sum(
                umsel.astype(jnp.float32) * miss_f[None, :]))
            uploaded_up = uploaded_total / max(n_part, 1)
        cost = comm_lib.distillation_round_cost(
            n_clients=n_part,
            n_selected=len(idx),
            n_up_samples=uploaded_up,
            n_down_samples=n_req,
            n_classes=c.n_classes,
            uplink_bits=s.uplink_bits,
            downlink_bits=s.downlink_bits,
            with_cache_signals=self.use_cache,
            catch_up_down=catch_up,
            bytes_index=c.index_bytes,
            uplink_codec=self.codec_up,
            downlink_codec=self.codec_down,
        )
        hist.ledger.record(cost)
        if self._telemetry:
            hist.telemetry.append(self._telemetry_row(
                t=t, part_full=part_j, miss=miss, base_present=base_present,
                z_tx=z_tx, z_srv=z_all, fresh=fresh,
                last_sync=jnp.asarray(self.last_sync, jnp.int32),
                uplink=cost.uplink, downlink=cost.downlink,
                catch_up=catch_up))
        self.last_sync[part] = t

    # ------------------------------------------------------------------
    def _eval(self, t: int, hist: History) -> None:
        sa = float(accuracy(self.server_params, self.x_test, self.y_test,
                            jnp.ones(len(self.y_test))))
        accs = [accuracy_v(p, self.xts_c[i], self.yts_c[i],
                           self.tmask_c[i].astype(jnp.float32))
                for i, p in enumerate(self.client_params)]
        ca = float(jnp.mean(self.models.concat(accs)))
        hist.rounds.append(t)
        hist.server_acc.append(sa)
        hist.client_acc.append(ca)
        hist.cohort_client_acc.append([float(jnp.mean(a)) for a in accs])
        hist.cumulative_mb.append(hist.ledger.cumulative_total / 1e6)
        # Appendix-D proxies (computable in deployment without test labels)
        if self.last_teacher_val is not None:
            hist.server_val_loss.append(float(val_loss_soft(
                self.server_params, self.x_pub[self.pub_val_idx],
                self.last_teacher_val)))
        hist.client_val_loss.append(float(jnp.mean(self.models.concat(
            [val_loss_hard_v(p, self.xs_c[i], self.ys_c[i],
                             self.val_mask_c[i].astype(jnp.float32))
             for i, p in enumerate(self.client_params)]))))
