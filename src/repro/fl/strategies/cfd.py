"""CFD (Sattler et al. 2020): quantized uplink soft-labels."""
from __future__ import annotations

import jax.numpy as jnp

from repro.compress import QuantCodec
from repro.fl.strategies.base import Strategy

__all__ = ["CFDStrategy"]


class CFDStrategy(Strategy):
    """CFD: quantized uplink soft-labels (b_up bits), plain averaging.

    The quantizer is the shared :class:`repro.compress.QuantCodec`
    (per-vector min-max, simplex renormalization — the exact transform
    this class used to inline), running through the fused Pallas
    quantize-dequantize kernel.  Byte accounting stays on the legacy
    ``uplink_bits`` path (b_up bits/value, Table V), which the identity
    default of the engine-level codecs leaves untouched.
    """

    name = "cfd"
    scan_safe = True  # transmit() is deterministic jnp; mean aggregation
    analysis_variants = ({}, {"b_up": 8})

    def __init__(self, b_up: int = 1, b_down: int = 32, **kw):
        super().__init__(**kw)
        self.uplink_bits = float(b_up)
        self.downlink_bits = float(b_down)
        self.b_up = b_up
        self._codec = QuantCodec(b_up)

    def transmit(self, z, key=None):
        return self._codec.roundtrip(z)

    def aggregate(self, z, um, t):
        return jnp.mean(z, axis=0), None
