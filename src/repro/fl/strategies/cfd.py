"""CFD (Sattler et al. 2020): quantized uplink soft-labels."""
from __future__ import annotations

import jax.numpy as jnp

from repro.fl.strategies.base import Strategy

__all__ = ["CFDStrategy"]


class CFDStrategy(Strategy):
    """CFD: quantized uplink soft-labels (b_up bits), plain averaging."""

    name = "cfd"
    scan_safe = True  # transmit() is deterministic jnp; mean aggregation

    def __init__(self, b_up: int = 1, b_down: int = 32, **kw):
        super().__init__(**kw)
        self.uplink_bits = float(b_up)
        self.downlink_bits = float(b_down)
        self.b_up = b_up

    def transmit(self, z, rng):
        # per-vector min-max uniform quantization to b_up bits
        levels = 2 ** self.b_up - 1
        zmin = z.min(axis=-1, keepdims=True)
        zmax = z.max(axis=-1, keepdims=True)
        scale = jnp.maximum(zmax - zmin, 1e-9)
        q = jnp.round((z - zmin) / scale * levels) / levels
        deq = q * scale + zmin
        return deq / jnp.maximum(deq.sum(-1, keepdims=True), 1e-9)

    def aggregate(self, z, um, t):
        return jnp.mean(z, axis=0), None
