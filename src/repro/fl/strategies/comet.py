"""COMET: clustered co-distillation with per-cluster teachers."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.fl.strategies.base import Strategy

__all__ = ["COMETStrategy"]


class COMETStrategy(Strategy):
    """COMET: cluster clients by soft-label similarity; each client
    distills from its cluster's teacher (+ server uses the global mean)."""

    name = "comet"
    # scan_safe stays False: ``aggregate`` clusters with host numpy
    # k-means (np.asarray on traced values + np.random.default_rng),
    # which the analyzer's trace of ``aggregate`` confirms.
    analysis_variants = ({}, {"n_clusters": 3})

    def __init__(self, n_clusters: int = 2, **kw):
        super().__init__(**kw)
        self.c = n_clusters

    def aggregate(self, z, um, t):
        K = z.shape[0]
        n_clusters = min(self.c, K)
        feats = np.asarray(z.reshape(K, -1), np.float64)
        # lightweight k-means
        rng = np.random.default_rng(1234 + t)
        cent = feats[rng.choice(K, n_clusters, replace=False)]
        for _ in range(10):
            d = ((feats[:, None] - cent[None]) ** 2).sum(-1)
            assign = d.argmin(1)
            for j in range(n_clusters):
                sel = feats[assign == j]
                if len(sel):
                    cent[j] = sel.mean(0)
        assign = jnp.asarray(assign)
        one = jax.nn.one_hot(assign, n_clusters, dtype=z.dtype)      # (K, c)
        csum = jnp.einsum("kc,kmn->cmn", one, z)
        cnt = jnp.maximum(one.sum(0), 1.0)[:, None, None]
        cteach = csum / cnt                                           # (c, m, N)
        per_client = cteach[assign]                                   # (K, m, N)
        return jnp.mean(z, axis=0), per_client
