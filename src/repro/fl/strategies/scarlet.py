"""SCARLET: Enhanced ERA power sharpening (Eq. 4) + synchronized cache."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import era as era_lib
from repro.fl.strategies.base import Strategy
from repro.kernels import ops as kops

__all__ = ["EnhancedERAStrategy"]


class EnhancedERAStrategy(Strategy):
    """SCARLET: power sharpening (Eq. 4).

    The hot aggregation path runs through the fused Pallas kernel
    (:func:`repro.kernels.ops.enhanced_era_fused`): the (K, B, N) client
    stack is mean-reduced over clients and power-sharpened in one VMEM
    pass (native on TPU, interpreter/XLA elsewhere).  Adaptive beta
    needs the client mean twice (entropy then sharpening), so it uses
    the two-pass jnp path.

    ``beta="adaptive"`` implements the paper's §V future direction:
    the server tunes beta each round from a server-visible signal — the
    mean normalized entropy of the averaged soft-labels.  Flat teachers
    (H_norm near 1, strong non-IID mixing) get sharpened harder; already
    confident teachers are preserved:
        beta_t = 1 + (beta_max - 1) * H_norm(z_mean)
    beta=1 is recovered exactly when teachers are one-hot, matching the
    near-IID optimum the paper measures (Fig. 15).
    """

    name = "scarlet"
    uses_cache = True
    scan_safe = True
    # adaptive beta flips supports_fused_round off — trace both graphs
    analysis_variants = ({}, {"beta": "adaptive"})

    def _adaptive_beta(self, zbar):
        n = zbar.shape[-1]
        h_norm = jnp.mean(era_lib.entropy(zbar)) / jnp.log(n)
        return 1.0 + (self.opts.get("beta_max", 2.5) - 1.0) * h_norm

    def sharpen_gauge(self, zbar, t):
        beta = self.opts.get("beta", 1.5)
        if beta == "adaptive":
            return jnp.asarray(self._adaptive_beta(zbar), jnp.float32)
        return jnp.float32(beta)

    def aggregate(self, z, um, t):
        beta = self.opts.get("beta", 1.5)
        if beta == "adaptive":
            zbar = jnp.mean(z, axis=0)
            return era_lib.enhanced_era(zbar, self._adaptive_beta(zbar)), None
        return kops.enhanced_era_fused(z, beta), None

    # Two-phase contract: the linear phase is the participation-weighted
    # sum (inherited); the sharpening nonlinearity runs once on the
    # cross-shard-reduced mean, so shards never exchange full stacks.
    def finalize_aggregate(self, partials, t):
        zbar = super().finalize_aggregate(partials, t)
        beta = self.opts.get("beta", 1.5)
        if beta == "adaptive":
            beta = self._adaptive_beta(zbar)
        return era_lib.enhanced_era(zbar, beta)

    def aggregate_masked(self, z, part, um, t):
        beta = self.opts.get("beta", 1.5)
        if beta == "adaptive":  # needs zbar twice -> two-phase path
            return super().aggregate_masked(z, part, um, t)
        # Single-device fast path: the fused kernel computes sum/K +
        # sharpening in one VMEM pass; rescale so its sum/K over the
        # full stack equals the participant mean: z_k*part_k*(K/n_part).
        k_clients = z.shape[0]
        n_part = jnp.maximum(jnp.sum(part), 1.0)
        zw = z * (part * (k_clients / n_part))[:, None, None]
        out = kops.enhanced_era_fused(zw, beta)
        # total outage: the kernel's zero-input behavior differs from the
        # two-phase path's uniform teacher.  Engines gate these rounds
        # out entirely, but the two-phase contract is total, so align.
        return jnp.where(jnp.sum(part) > 0, out,
                         jnp.full_like(out, 1.0 / out.shape[-1]))

    # ------------------------------------------------------------------
    # Fused round fast path (FLConfig.fused_round): codec round trip +
    # masked aggregation + sharpening in one round_kernel pass.  Static
    # beta only — adaptive beta needs the client mean before sharpening,
    # which the fused kernel never materializes.

    @property
    def supports_fused_round(self):
        return self.opts.get("beta", 1.5) != "adaptive"

    def aggregate_masked_fused(self, z, part, codec_spec, base, t):
        beta = self.opts.get("beta", 1.5)
        # same rescaling as aggregate_masked: the kernel divides its
        # weighted sum by K before sharpening, so weight participants by
        # K/n_part to recover the participant mean
        k_clients = z.shape[0]
        n_part = jnp.maximum(jnp.sum(part), 1.0)
        w = part * (k_clients / n_part)
        out = kops.fused_round(z, w, beta, base, mode=codec_spec["mode"],
                               bits=codec_spec["bits"], sharpen=True)
        # total-outage guard outside the kernel, as in aggregate_masked
        return jnp.where(jnp.sum(part) > 0, out,
                         jnp.full_like(out, 1.0 / out.shape[-1]))

    def partial_aggregate_fused(self, z, part, codec_spec, base, t):
        # linear phase only: codec round trip + participation-weighted
        # sum; sharpening happens once in finalize_aggregate after the
        # cross-shard psum, exactly as in the per-op two-phase path
        zsum = kops.fused_round(z, part, None, base,
                                mode=codec_spec["mode"],
                                bits=codec_spec["bits"], sharpen=False)
        return {"zsum": zsum, "wsum": jnp.sum(part)}
