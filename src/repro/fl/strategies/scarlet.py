"""SCARLET: Enhanced ERA power sharpening (Eq. 4) + synchronized cache."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import era as era_lib
from repro.fl.strategies.base import Strategy

__all__ = ["EnhancedERAStrategy"]


class EnhancedERAStrategy(Strategy):
    """SCARLET: power sharpening (Eq. 4).

    ``beta="adaptive"`` implements the paper's §V future direction:
    the server tunes beta each round from a server-visible signal — the
    mean normalized entropy of the averaged soft-labels.  Flat teachers
    (H_norm near 1, strong non-IID mixing) get sharpened harder; already
    confident teachers are preserved:
        beta_t = 1 + (beta_max - 1) * H_norm(z_mean)
    beta=1 is recovered exactly when teachers are one-hot, matching the
    near-IID optimum the paper measures (Fig. 15).
    """

    name = "scarlet"
    uses_cache = True

    def aggregate(self, z, um, t):
        zbar = jnp.mean(z, axis=0)
        beta = self.opts.get("beta", 1.5)
        if beta == "adaptive":
            n = zbar.shape[-1]
            h_norm = jnp.mean(era_lib.entropy(zbar)) / jnp.log(n)
            beta = 1.0 + (self.opts.get("beta_max", 2.5) - 1.0) * h_norm
        return era_lib.enhanced_era(zbar, beta), None
