"""Plain soft-label averaging (no sharpening) — the FD baseline."""
from __future__ import annotations

import jax.numpy as jnp

from repro.fl.strategies.base import Strategy

__all__ = ["MeanStrategy"]


class MeanStrategy(Strategy):
    """Inherits the base two-phase masked aggregation unchanged: the
    participation-weighted mean is the whole method."""

    name = "mean"
    scan_safe = True

    def aggregate(self, z, um, t):
        return jnp.mean(z, axis=0), None
