"""Strategy protocol: distillation-method-specific behavior.

A Strategy owns the *method* axis of a run — how client soft-labels are
transformed on the wire and aggregated into a teacher — and nothing
else.  Client sampling, outages, and schedule heterogeneity live on the
orthogonal :mod:`repro.fl.scenarios` axis; the round loop composes the
two.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

__all__ = ["Strategy"]


class Strategy:
    """Distillation-method-specific behavior. Subclasses override hooks."""

    name = "base"
    uses_cache = False
    uplink_bits = 32.0
    downlink_bits = 32.0
    # True when every hook is jit/scan-traceable (pure jnp, no host RNG
    # or dynamic shapes): required by the scanned multi-round engine.
    scan_safe = False

    def __init__(self, **kw):
        self.opts = kw

    # uplink payload transform (e.g. CFD quantization). Returns z as the
    # server sees it.
    def transmit(self, z_clients: jnp.ndarray, rng: np.random.Generator) -> jnp.ndarray:
        return z_clients

    # per-(client, sample) upload mask (Selective-FD). True = uploaded.
    def upload_mask(self, z_clients: jnp.ndarray) -> Optional[jnp.ndarray]:
        return None

    # aggregate (K, m, N) -> teacher (m, N) used by the SERVER; may also
    # return per-client teachers (K, m, N) for personalized methods.
    def aggregate(self, z_clients, upload_mask, t) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
        raise NotImplementedError

    # Fixed-shape twin of ``aggregate`` for the scanned engine: the full
    # (K, m, N) stack plus a float {0,1} participation vector ``part``
    # (K,) instead of a dynamically-sized subset.  Must equal
    # ``aggregate(z[part], ...)`` up to float reduction order.  The
    # default participation-weighted mean is correct for any strategy
    # whose aggregate is the plain mean.
    def aggregate_masked(self, z_clients: jnp.ndarray, part: jnp.ndarray,
                         upload_mask: Optional[jnp.ndarray], t) -> jnp.ndarray:
        w = part / jnp.maximum(jnp.sum(part), 1.0)
        return jnp.tensordot(w, z_clients, axes=(0, 0))
