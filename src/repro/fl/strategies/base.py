"""Strategy protocol: distillation-method-specific behavior.

A Strategy owns the *method* axis of a run — how client soft-labels are
transformed on the wire and aggregated into a teacher — and nothing
else.  Client sampling, outages, and schedule heterogeneity live on the
orthogonal :mod:`repro.fl.scenarios` axis; the round loop composes the
two.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["Strategy", "TRANSMIT_SALT"]

# Engines derive the per-round transmit key as
# ``fold_in(fold_in(key_rounds, t), TRANSMIT_SALT)`` — an extra fold off
# the round key rather than a wider ``split`` so strategies that ignore
# the key (the common case) leave the legacy key stream untouched (the
# unused fold_in is dead code; golden ledgers stay byte-identical).
TRANSMIT_SALT = 71


class Strategy:
    """Distillation-method-specific behavior. Subclasses override hooks."""

    name = "base"
    uses_cache = False
    uplink_bits = 32.0
    downlink_bits = 32.0
    # True when every hook is jit/scan-traceable (pure jnp, no host RNG
    # or dynamic shapes): required by the scanned multi-round engine.
    # ``repro.analysis.jaxpr_checks`` verifies the declaration by tracing
    # every hook on abstract shapes — a True flag on a strategy that
    # calls back to the host (or a stale False on a pure-jnp one) is a
    # build failure, not a latent engine crash.
    scan_safe = False

    # Constructor-kwarg variants the static analyzer instantiates when
    # tracing this class (each entry is one ``cls(**kw)`` call).  Cover
    # the option combinations that change the traced graph — e.g. both
    # values of a flag that switches the fused path on or off.
    analysis_variants: Tuple[Dict[str, Any], ...] = ({},)

    def __init__(self, **kw):
        self.opts = kw

    def declared_contract(self) -> Dict[str, Any]:
        """The machine-checkable contract this instance claims.

        ``repro.analysis`` traces the hooks and diffs the trace against
        these declarations; engines trust them at construction time."""
        return {
            "name": self.name,
            "scan_safe": bool(self.scan_safe),
            "supports_fused_round": bool(self.supports_fused_round),
            "uses_cache": bool(self.uses_cache),
        }

    # uplink payload transform (e.g. CFD quantization). Returns z as the
    # server sees it.  ``key`` is a per-round jax PRNG key (or None on
    # the legacy numpy host path) — the scan-safe contract forbids host
    # RNG here, so stochastic transforms must draw from ``key``.
    def transmit(self, z_clients: jnp.ndarray,
                 key: Optional[jax.Array] = None) -> jnp.ndarray:
        return z_clients

    # per-(client, sample) upload mask (Selective-FD). True = uploaded.
    def upload_mask(self, z_clients: jnp.ndarray) -> Optional[jnp.ndarray]:
        return None

    # aggregate (K, m, N) -> teacher (m, N) used by the SERVER; may also
    # return per-client teachers (K, m, N) for personalized methods.
    def aggregate(self, z_clients, upload_mask, t) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
        raise NotImplementedError

    # telemetry gauge (repro.obs): the resolved sharpening knob for
    # round ``t`` given the participant-mean soft labels ``zbar`` —
    # Enhanced ERA reports its (possibly adaptive) beta, ERA its
    # temperature, strategies without a sharpener report 0.  Must be
    # pure jnp (it runs inside the scanned round body when telemetry is
    # on) and must not mutate state: it is an observation, not a hook.
    def sharpen_gauge(self, zbar: jnp.ndarray, t) -> jnp.ndarray:
        return jnp.float32(0.0)

    # ------------------------------------------------------------------
    # Async staleness weighting (repro.fl.async_engine).
    #
    # Under buffered aggregation a report can land ``s`` rounds after
    # its dispatch, computed against a cache ``s`` rounds stale.  The
    # async engine multiplies each arriving client's aggregation weight
    # by ``staleness_weight(s)`` before the two-phase contract —
    # ``part`` is a float weight vector throughout, so decayed labels
    # flow through ``partial_aggregate``/``finalize_aggregate``
    # unchanged on every engine.  Weighting changes metrics only, never
    # the byte ledger (weights multiply soft-labels, not counts).
    #
    # Default policy: exponential decay ``staleness_decay ** s``, with
    # ``staleness_decay`` read from the constructor options.  At the
    # default 1.0 the engine skips the multiply entirely (a static
    # python check), which is part of the zero-latency byte-identity
    # contract with the scan engine.  Must be pure jnp — it runs inside
    # the scanned round body, and ``repro.analysis.async_checks`` flags
    # overrides that smuggle host callbacks.

    def staleness_weight(self, staleness: jnp.ndarray) -> jnp.ndarray:
        decay = jnp.float32(self.opts.get("staleness_decay", 1.0))
        return decay ** jnp.asarray(staleness, jnp.float32)

    # ------------------------------------------------------------------
    # Fixed-shape masked aggregation: the two-phase contract.
    #
    # Sharded engines cannot run ``aggregate`` (dynamic subset) or even a
    # monolithic masked aggregate (the client stack never exists on one
    # device), so masked aggregation is split into:
    #
    #   ``partial_aggregate``  per-shard LINEAR moments of the local
    #                          (K_loc, m, N) stack — a dict of arrays
    #                          whose entries sum across shards;
    #   (cross-shard psum of every dict entry, done by the engine —
    #    a no-op on a single device);
    #   ``finalize_aggregate`` the nonlinearity (Enhanced-ERA power
    #                          sharpening, DS-FL temperature softmax,
    #                          Selective-FD ratio+fallback), applied once
    #                          on the replicated reduction.
    #
    # Contract (property-tested in tests/test_aggregation_contract.py):
    # for any split of the client axis into shards,
    #   finalize(sum over shards of partial(shard)) ==
    #   aggregate_masked(unsplit stack)                (allclose)
    # and ``aggregate_masked`` itself must equal ``aggregate(z[part])``
    # up to float reduction order.  The defaults below implement the
    # participation-weighted mean, correct for any strategy whose
    # aggregate is the plain mean.

    def partial_aggregate(self, z_clients: jnp.ndarray, part: jnp.ndarray,
                          upload_mask: Optional[jnp.ndarray],
                          t) -> Dict[str, jnp.ndarray]:
        """Per-shard linear moments; every entry sums across shards."""
        return {"zsum": jnp.tensordot(part, z_clients, axes=(0, 0)),
                "wsum": jnp.sum(part)}

    def finalize_aggregate(self, partials: Dict[str, jnp.ndarray],
                           t) -> jnp.ndarray:
        """Teacher from the cross-shard-reduced moments (replicated)."""
        return partials["zsum"] / jnp.maximum(partials["wsum"], 1.0)

    # Fixed-shape twin of ``aggregate``: the full (K, m, N) stack plus a
    # float {0,1} participation vector ``part`` (K,) instead of a
    # dynamically-sized subset.  Default: the two phases composed on one
    # device.  Strategies may override with a fused single-device fast
    # path (e.g. SCARLET's Pallas mean+sharpen kernel) as long as it
    # stays allclose to the two-phase composition.
    def aggregate_masked(self, z_clients: jnp.ndarray, part: jnp.ndarray,
                         upload_mask: Optional[jnp.ndarray], t) -> jnp.ndarray:
        return self.finalize_aggregate(
            self.partial_aggregate(z_clients, part, upload_mask, t), t)

    # ------------------------------------------------------------------
    # Fused round fast path (FLConfig.fused_round).
    #
    # Strategies that can express their codec-roundtrip + masked
    # aggregation as one :func:`repro.kernels.ops.fused_round` call
    # advertise it here; engines validate the flag against this at
    # construction.  ``codec_spec`` is ``round_kernel.codec_kernel_spec``
    # output ({"mode": ..., "bits": ...}); ``base`` is the resolved
    # delta base (None outside delta mode).  The fused variants must
    # match the per-op path bit for bit in interpret mode and to one
    # quantization step natively (tests/test_round_kernel.py).

    supports_fused_round = False

    def aggregate_masked_fused(self, z_clients: jnp.ndarray,
                               part: jnp.ndarray, codec_spec: Dict,
                               base: Optional[jnp.ndarray],
                               t) -> jnp.ndarray:
        """Fused twin of codec.roundtrip + ``aggregate_masked``."""
        raise NotImplementedError(
            f"strategy {self.name!r} has no fused round path")

    def partial_aggregate_fused(self, z_clients: jnp.ndarray,
                                part: jnp.ndarray, codec_spec: Dict,
                                base: Optional[jnp.ndarray],
                                t) -> Dict[str, jnp.ndarray]:
        """Fused twin of codec.roundtrip + ``partial_aggregate``: the
        codec round trip and the linear moments in one kernel pass;
        entries still sum across shards (finalize is unchanged)."""
        raise NotImplementedError(
            f"strategy {self.name!r} has no fused round path")
