"""DS-FL (Itahara et al. 2020): ERA temperature-softmax sharpening."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import era as era_lib
from repro.fl.strategies.base import Strategy

__all__ = ["ERAStrategy"]


class ERAStrategy(Strategy):
    """DS-FL: temperature-softmax sharpening of the average."""

    name = "dsfl"
    scan_safe = True
    analysis_variants = ({}, {"T": 0.5})

    def aggregate(self, z, um, t):
        return era_lib.era(jnp.mean(z, axis=0), self.opts.get("T", 0.1)), None

    def sharpen_gauge(self, zbar, t):
        return jnp.float32(self.opts.get("T", 0.1))

    # Two-phase contract: linear phase inherited (weighted sum); the
    # temperature softmax runs once on the reduced mean.
    def finalize_aggregate(self, partials, t):
        zbar = super().finalize_aggregate(partials, t)
        return era_lib.era(zbar, self.opts.get("T", 0.1))
