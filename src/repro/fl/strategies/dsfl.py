"""DS-FL (Itahara et al. 2020): ERA temperature-softmax sharpening."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import era as era_lib
from repro.fl.strategies.base import Strategy

__all__ = ["ERAStrategy"]


class ERAStrategy(Strategy):
    """DS-FL: temperature-softmax sharpening of the average."""

    name = "dsfl"
    scan_safe = True

    def aggregate(self, z, um, t):
        return era_lib.era(jnp.mean(z, axis=0), self.opts.get("T", 0.1)), None

    def aggregate_masked(self, z, part, um, t):
        zbar = super().aggregate_masked(z, part, None, t)
        return era_lib.era(zbar, self.opts.get("T", 0.1))
