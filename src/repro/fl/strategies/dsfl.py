"""DS-FL (Itahara et al. 2020): ERA temperature-softmax sharpening."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import era as era_lib
from repro.fl.strategies.base import Strategy

__all__ = ["ERAStrategy"]


class ERAStrategy(Strategy):
    """DS-FL: temperature-softmax sharpening of the average."""

    name = "dsfl"

    def aggregate(self, z, um, t):
        return era_lib.era(jnp.mean(z, axis=0), self.opts.get("T", 0.1)), None
