"""Aggregation-strategy registry: one module per method.

``STRATEGIES`` maps method name -> constructor; ``run_method`` and the
benchmarks resolve methods through it, so adding a strategy is one new
module plus one registry line (see ``src/repro/fl/README.md``).
"""
from __future__ import annotations

from typing import Callable, Dict

from repro.fl.strategies.base import Strategy
from repro.fl.strategies.cfd import CFDStrategy
from repro.fl.strategies.comet import COMETStrategy
from repro.fl.strategies.dsfl import ERAStrategy
from repro.fl.strategies.mean import MeanStrategy
from repro.fl.strategies.scarlet import EnhancedERAStrategy
from repro.fl.strategies.selective_fd import SelectiveFDStrategy

STRATEGIES: Dict[str, Callable[..., Strategy]] = {
    "mean": MeanStrategy,
    "dsfl": ERAStrategy,
    "scarlet": EnhancedERAStrategy,
    "cfd": CFDStrategy,
    "comet": COMETStrategy,
    "selective_fd": SelectiveFDStrategy,
}

__all__ = [
    "Strategy",
    "MeanStrategy",
    "ERAStrategy",
    "EnhancedERAStrategy",
    "CFDStrategy",
    "COMETStrategy",
    "SelectiveFDStrategy",
    "STRATEGIES",
]
