"""Selective-FD: confidence-gated uploads."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import era as era_lib
from repro.fl.strategies.base import Strategy

__all__ = ["SelectiveFDStrategy"]


class SelectiveFDStrategy(Strategy):
    """Selective-FD: clients upload only confident (low-entropy)
    soft-labels; the server averages over uploaders per sample."""

    name = "selective_fd"
    scan_safe = True
    analysis_variants = ({}, {"tau_client": 0.25})

    def __init__(self, tau_client: float = 0.0625, **kw):
        super().__init__(**kw)
        self.tau = tau_client

    def upload_mask(self, z):
        # normalized entropy in [0,1]; upload when confident
        N = z.shape[-1]
        h = era_lib.entropy(z) / jnp.log(N)
        return h <= (1.0 - self.tau)

    def aggregate(self, z, um, t):
        w = um.astype(z.dtype)[..., None]
        num = jnp.sum(z * w, axis=0)
        den = jnp.maximum(jnp.sum(w, axis=0), 1e-9)
        teacher = num / den
        # samples nobody uploaded: fall back to plain mean
        empty = (jnp.sum(um, axis=0) == 0)[:, None]
        return jnp.where(empty, jnp.mean(z, axis=0), teacher), None

    # Two-phase contract: the linear phase carries the upload-weighted
    # sums alongside the inherited participant sums (for the fallback);
    # the ratio + empty-sample fallback run on the reduced moments.
    def partial_aggregate(self, z, part, um, t):
        p = super().partial_aggregate(z, part, None, t)
        w = (um.astype(z.dtype) * part[:, None])[..., None]   # (K, m, 1)
        p["up_num"] = jnp.sum(z * w, axis=0)
        p["up_den"] = jnp.sum(w, axis=0)
        return p

    def finalize_aggregate(self, partials, t):
        den = partials["up_den"]
        teacher = partials["up_num"] / jnp.maximum(den, 1e-9)
        # samples no participant uploaded: participant-mean fallback
        fallback = super().finalize_aggregate(partials, t)
        return jnp.where(den < 0.5, fallback, teacher)
