"""Selective-FD: confidence-gated uploads."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import era as era_lib
from repro.fl.strategies.base import Strategy

__all__ = ["SelectiveFDStrategy"]


class SelectiveFDStrategy(Strategy):
    """Selective-FD: clients upload only confident (low-entropy)
    soft-labels; the server averages over uploaders per sample."""

    name = "selective_fd"
    scan_safe = True

    def __init__(self, tau_client: float = 0.0625, **kw):
        super().__init__(**kw)
        self.tau = tau_client

    def upload_mask(self, z):
        # normalized entropy in [0,1]; upload when confident
        N = z.shape[-1]
        h = era_lib.entropy(z) / jnp.log(N)
        return h <= (1.0 - self.tau)

    def aggregate(self, z, um, t):
        w = um.astype(z.dtype)[..., None]
        num = jnp.sum(z * w, axis=0)
        den = jnp.maximum(jnp.sum(w, axis=0), 1e-9)
        teacher = num / den
        # samples nobody uploaded: fall back to plain mean
        empty = (jnp.sum(um, axis=0) == 0)[:, None]
        return jnp.where(empty, jnp.mean(z, axis=0), teacher), None

    def aggregate_masked(self, z, part, um, t):
        w = (um.astype(z.dtype) * part[:, None])[..., None]   # (K, m, 1)
        num = jnp.sum(z * w, axis=0)
        den = jnp.maximum(jnp.sum(w, axis=0), 1e-9)
        teacher = num / den
        # samples no participant uploaded: participant-mean fallback
        empty = (jnp.sum(w, axis=0) < 0.5)
        fallback = super().aggregate_masked(z, part, None, t)
        return jnp.where(empty, fallback, teacher)
