"""Client-model cohorts: heterogeneous architectures across the client axis.

The central promise of distillation-based FL over parameter sharing is
that clients only exchange *soft-labels*, whose shape ``(m, N)`` is
independent of the client architecture — so clients are free to run
different models (FedMD, Sattler et al., Itahara et al.).  This module
makes that workload first-class:

- :class:`CohortSpec` describes one cohort: how many clients it holds
  and what architecture they run (MLP hidden width / depth; ``family``
  is the seam for richer model families — the vision models in
  ``repro.models`` and the LLM families behind
  ``repro.models.registry`` plug in here once their data modalities
  join the FL substrate).
- :class:`ClientModels` owns the per-cohort *stacked* parameter pytrees
  plus the cohort -> client index maps.  Different architectures cannot
  share one stacked pytree (their leaves have different shapes), so the
  client axis becomes a short static list of cohorts, each of which
  stays fully vmapped — a 3-cohort, 4000-client run is three jitted
  programs per primitive, not a Python loop over clients.

Cohort invariant (pinned by ``tests/test_cohorts.py`` and the cohort
cells of ``tests/test_engine_conformance.py``): everything downstream
of ``predict_soft`` — strategies, wire codecs, the cache, the comm
ledger — sees only the concatenated ``(K, m, N)`` soft-label stack in
global client order and therefore works unchanged for any cohort mix.
A single-cohort spec is *bit-identical* to the legacy homogeneous path:
``split``/``concat`` collapse to identity for one cohort, so the traced
programs are the same.

Client ordering is **cohort-major**: cohort ``c`` owns the contiguous
global client indices ``[offset_c, offset_c + n_clients_c)``.  The
client-sharded engine shards each cohort's block independently over the
mesh "data" axis (every cohort size must divide by the shard count), so
shard ``s`` holds clients ``offset_c + s*k_c .. offset_c + (s+1)*k_c``
of every cohort ``c`` — equal per-cohort composition on every shard,
which is what keeps the ``shard_map`` program uniform (SPMD) across
shards.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.resnet import init_mlp

__all__ = ["CohortSpec", "ClientModels", "resolve_cohorts"]

# architectures ClientModels can instantiate today; "mlp" with depth=0
# degenerates to a linear softmax classifier
_FAMILIES = ("mlp",)


@dataclass(frozen=True)
class CohortSpec:
    """One cohort: ``n_clients`` clients all running the same model.

    ``hidden``/``depth`` parameterize the MLP family (depth = number of
    hidden layers; 0 = linear classifier).  Hashable and frozen so a
    tuple of specs can live in the frozen :class:`repro.fl.FLConfig`.
    """

    n_clients: int
    hidden: int
    depth: int = 2
    family: str = "mlp"

    def validate(self) -> None:
        if self.n_clients < 1:
            raise ValueError(f"cohort needs n_clients >= 1, got {self.n_clients}")
        if self.hidden < 1:
            raise ValueError(f"cohort needs hidden >= 1, got {self.hidden}")
        if self.depth < 0:
            raise ValueError(f"cohort needs depth >= 0, got {self.depth}")
        if self.family not in _FAMILIES:
            raise ValueError(
                f"unknown cohort model family {self.family!r} "
                f"(supported: {_FAMILIES})")


def resolve_cohorts(cfg) -> Tuple[CohortSpec, ...]:
    """Cohort tuple for a config: ``cfg.cohorts`` validated against
    ``cfg.n_clients``, or the implicit single homogeneous cohort built
    from the legacy ``(hidden, mlp_depth)`` fields."""
    if not getattr(cfg, "cohorts", None):
        return (CohortSpec(cfg.n_clients, cfg.hidden, cfg.mlp_depth),)
    cohorts = tuple(cfg.cohorts)
    for spec in cohorts:
        spec.validate()
    total = sum(s.n_clients for s in cohorts)
    if total != cfg.n_clients:
        raise ValueError(
            f"cohort sizes {[s.n_clients for s in cohorts]} sum to {total}, "
            f"but cfg.n_clients={cfg.n_clients}")
    return cohorts


class ClientModels:
    """Per-cohort stacked client parameters + cohort->client index maps.

    The engines hold one :class:`ClientModels` per run and represent
    ``client_params`` as a list with one stacked pytree per cohort
    (leading dim = that cohort's client count).  All index maps are
    static Python ints, so per-cohort loops unroll at trace time and
    every per-cohort op stays a single vmapped XLA computation.
    """

    def __init__(self, cohorts: Sequence[CohortSpec], dim: int, n_classes: int):
        self.cohorts = tuple(cohorts)
        if not self.cohorts:
            raise ValueError("need at least one cohort")
        self.dim = dim
        self.n_classes = n_classes
        self.sizes = tuple(s.n_clients for s in self.cohorts)
        offs = np.concatenate([[0], np.cumsum(self.sizes)])
        self.offsets = tuple(int(o) for o in offs[:-1])
        self.n_clients = int(offs[-1])
        self.slices = tuple(slice(o, o + n)
                            for o, n in zip(self.offsets, self.sizes))

    # ------------------------------------------------------------------
    @property
    def n_cohorts(self) -> int:
        return len(self.cohorts)

    @property
    def homogeneous(self) -> bool:
        return self.n_cohorts == 1

    def cohort_of(self) -> np.ndarray:
        """(K,) global client index -> cohort id."""
        return np.repeat(np.arange(self.n_cohorts), self.sizes)

    # ------------------------------------------------------------------
    def init_params(self, keys: jax.Array) -> List:
        """Per-cohort stacked params from ``(K, ...)`` stacked PRNG keys
        (one key per client, in global client order — the same key
        stream the legacy homogeneous init consumed)."""
        out = []
        for spec, sl in zip(self.cohorts, self.slices):
            out.append(jax.vmap(
                lambda k, s=spec: self._init_one(s, k))(keys[sl]))
        return out

    def _init_one(self, spec: CohortSpec, key: jax.Array):
        # _FAMILIES gate in validate() guarantees family == "mlp" here
        return init_mlp(key, self.dim, self.n_classes, spec.hidden, spec.depth)

    def param_counts(self) -> Tuple[int, ...]:
        """Per-cohort parameter count of ONE client model (derived from
        the real init via ``eval_shape``, so it cannot drift from the
        model family's actual shapes)."""
        key = jax.ShapeDtypeStruct((2,), jnp.uint32)
        counts = []
        for spec in self.cohorts:
            shapes = jax.eval_shape(lambda k, s=spec: self._init_one(s, k),
                                    key)
            counts.append(sum(int(np.prod(x.shape))
                              for x in jax.tree_util.tree_leaves(shapes)))
        return tuple(counts)

    # ------------------------------------------------------------------
    # Cohort-axis plumbing.  For a single cohort both directions are the
    # identity on the SAME array object — no slice/concat ops enter the
    # traced program, which is what makes the homogeneous path
    # bit-identical to the pre-cohort engines.
    # ------------------------------------------------------------------
    def split(self, arr) -> List:
        """Global per-client array ``(K, ...)`` -> per-cohort blocks."""
        if self.homogeneous:
            return [arr]
        return [arr[sl] for sl in self.slices]

    def concat(self, parts: Sequence) -> jnp.ndarray:
        """Per-cohort blocks -> global ``(K, ...)`` array."""
        parts = list(parts)
        if len(parts) == 1:
            return parts[0]
        return jnp.concatenate(parts, axis=0)

    def shard_sizes(self, n_shards: int) -> Tuple[int, ...]:
        """Per-cohort client count on ONE shard; validates divisibility.

        The sharded engine splits every cohort block independently over
        the mesh "data" axis, so each cohort size must divide by the
        shard count (equal per-cohort composition on every shard keeps
        the SPMD program uniform)."""
        for spec, n in zip(self.cohorts, self.sizes):
            if n % n_shards:
                raise ValueError(
                    f"cohort {spec} has {n} clients, not divisible over "
                    f"{n_shards} shards (every cohort must split evenly; "
                    "pick divisible cohort sizes or a narrower mesh)")
        return tuple(n // n_shards for n in self.sizes)

    def describe(self) -> str:
        return " + ".join(
            f"{n}x{s.family}(h={s.hidden},d={s.depth})"
            for s, n in zip(self.cohorts, self.sizes))
