"""Federated-distillation package: strategies x scenarios on a vmapped
client substrate.  See ``src/repro/fl/README.md`` for the layout."""
from repro.fl.active_engine import ActiveSetFederatedDistillation  # noqa: F401
from repro.fl.api import run_method  # noqa: F401
from repro.fl.async_engine import AsyncFederatedDistillation  # noqa: F401
from repro.fl.baselines import FedAvg, Individual  # noqa: F401
from repro.fl.cohorts import ClientModels, CohortSpec, resolve_cohorts  # noqa: F401
from repro.fl.config import FLConfig  # noqa: F401
from repro.fl.rounds import FederatedDistillation, History  # noqa: F401
from repro.fl.scan_engine import ScannedFederatedDistillation  # noqa: F401
from repro.fl.shard_engine import ShardedFederatedDistillation  # noqa: F401
from repro.fl.scenarios import (  # noqa: F401
    Heterogeneity,
    Outage,
    Participation,
    Scenario,
    bernoulli_participation,
    fixed_fraction,
    full_participation,
)
from repro.fl.strategies import STRATEGIES, Strategy  # noqa: F401
from repro.fl.traffic import (  # noqa: F401
    ArrivalProcess,
    ChurnEvent,
    LatencyModel,
    TrafficModel,
)
