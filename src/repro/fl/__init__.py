from repro.fl.engine import FLConfig, FederatedDistillation, History, run_method  # noqa: F401
