"""Async/buffered aggregation engine: dispatch now, aggregate what arrived.

Every other engine is synchronous-round: the clients drawn in round
``t`` train, upload, and are aggregated in round ``t``.  Production
federated servers do not get that luxury — clients arrive on their own
schedule, train against whatever cache state they were handed, and
report late.  This engine models that regime while staying a single
XLA program (it subclasses :class:`ScannedFederatedDistillation` and
keeps the one-``lax.scan`` structure; the traffic model compiles to
fixed-shape per-round scan inputs, see :mod:`repro.fl.traffic`).

Round semantics (one aggregation window per round):

- **dispatch**: the usual participation draw, restricted to clients
  that are reachable this window (traffic availability + churn) and not
  already in flight.  A dispatched client receives a cache catch-up
  package if it is behind (charged now, against the *pre-round* cache —
  it must train against current state), distills on the previous
  teacher, trains locally, and starts computing its report.  Its
  parameters then stay frozen until the report lands (an in-flight
  client cannot be re-dispatched).
- **arrival**: reports dispatched ``d`` rounds ago (``d`` drawn from
  the traffic latency model) land this window, together with this
  window's zero-delay dispatches.  The server aggregates *whatever
  arrived* through the unchanged two-phase
  ``partial_aggregate``/``finalize_aggregate`` contract, with each
  arriving client's weight multiplied by
  :meth:`Strategy.staleness_weight` of its report staleness (dispatch
  round to now).  Teacher assembly, the global cache update, server
  distillation, and the broadcast all happen at arrival, gated exactly
  like scan's total-outage gate on rounds where nothing arrives.

Ledger rule (the staleness-correct accounting this engine exists for):
a stale reporter's **uplink** is charged at *dispatch-time* cache
state — the client answered the request list it was handed, so its
per-client upload size is the miss count of its dispatch round
(tracked in flight as ``flight_nreq``).  **Catch-up** bytes are charged
against the cache *at the time they flow*: the dispatch side against
the pre-round cache, and the arrival side (entries cached while the
report was in flight) against the cache at arrival —
:func:`repro.core.cache.catch_up_bytes_async`.  ``last_sync`` encodes
the handshake: dispatch marks the client synced through ``t - 1``,
arrival through ``t`` (arrival wins when both happen in one round).

**Byte-identity contract** (the conformance anchor,
``tests/test_engine_conformance.py``): with zero latency, full windows
(``TrafficModel.is_synchronous``), and unit staleness weight
(``staleness_decay == 1``, statically skipped), every mask, draw, and
ledger expression reduces bitwise to the scan engine's — same key
stream, ``arrive == dispatch == part``, an exactly-zero arrival-side
catch-up term, and ``(n_arr * n_req) / n_arr == n_req`` exactly in
IEEE for the per-client upload average.  Staleness *weighting* never
changes the ledger at any latency (weights multiply soft-labels, not
byte counts) — pinned in ``tests/test_traffic.py``.

Telemetry: the per-round row reuses the shared ``_telemetry_row``
expression with the arrival mask as the participant mask and the
pre-round ``last_sync`` — under the dispatch handshake,
``staleness_histogram`` buckets then equal the report delay of each
arrival.  Rounds where nothing arrives record an all-zero row (like
scan's total-outage rounds), even when dispatch-side catch-up bytes
flowed — the ledger, not telemetry, is the byte record.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cache as cache_lib
from repro.core import comm as comm_lib
from repro.kernels import round_kernel
from repro.obs import device as obs_device
from repro.fl.scan_engine import ScannedFederatedDistillation
from repro.fl.strategies.base import TRANSMIT_SALT
from repro.fl.rounds import (
    _select_cohorts,
    accuracy,
    accuracy_v,
    distill,
    val_loss_hard_v,
    val_loss_soft,
)
from repro.fl.traffic import TrafficModel

__all__ = ["AsyncFederatedDistillation"]


class AsyncFederatedDistillation(ScannedFederatedDistillation):
    """Buffered-aggregation twin of the scanned engine.

    Same constructor plus ``traffic`` (a
    :class:`repro.fl.traffic.TrafficModel`; the default model — always
    available, zero latency — is the synchronous regime, byte-identical
    to ``engine="scan"``).  The staleness-decay policy rides on the
    strategy: ``STRATEGIES[...](..., staleness_decay=0.9)``.
    """

    def __init__(self, *args, traffic: Optional[TrafficModel] = None,
                 **kwargs):
        super().__init__(*args, **kwargs)
        self.traffic = traffic if traffic is not None else TrafficModel()
        K = self.cfg.n_clients
        # flight state, carried next to last_sync: which clients are
        # mid-report, when each report lands, and the dispatch-time
        # request-list size its uplink will be charged for
        self.in_flight = np.zeros(K, bool)
        self.flight_arrival = np.zeros(K, np.int32)
        self.flight_nreq = np.zeros(K, np.float32)
        # static skip of the staleness multiply: at the default unit
        # decay the aggregation weights are exactly the arrival mask,
        # which keeps the zero-latency metric parity with scan exact
        # rather than "x * 1.0"-shaped
        self._unit_staleness = float(
            self.strategy.opts.get("staleness_decay", 1.0)) == 1.0

    # ------------------------------------------------------------------
    def _round_device(self, carry, xs):
        c, s = self.cfg, self.strategy
        t, offline_t, do_eval, avail_t, delay_t = xs

        # same per-round key stream as scan/host (fold_in by absolute t)
        kt = jax.random.fold_in(self._key_rounds, t)
        k_idx, k_part = jax.random.split(kt)
        idx = jnp.sort(jax.random.choice(
            k_idx, c.public_size, (c.public_per_round,), replace=False))

        # --- dispatch: scan's participation draw with unreachable and
        # in-flight clients folded into the offline mask (conscription
        # then only recruits clients that could actually start work) ----
        busy = carry["in_flight"]
        blocked = jnp.logical_or(
            offline_t, jnp.logical_or(jnp.logical_not(avail_t), busy))
        dispatch = self.scenario.participation_mask_device(k_part, blocked)
        disp_f = dispatch.astype(jnp.float32)
        any_disp = jnp.sum(disp_f) > 0

        # --- arrivals: in-flight reports landing now + zero-delay
        # dispatches (which complete inside their own window) ------------
        arrive = jnp.logical_or(
            jnp.logical_and(busy, carry["flight_arrival"] == t),
            jnp.logical_and(dispatch, delay_t == 0))
        arrive_f = arrive.astype(jnp.float32)
        n_arr = jnp.sum(arrive_f)
        any_arr = n_arr > 0

        def gate(new, old):
            """Keep ``old`` wholesale on arrival-free rounds."""
            return jax.tree_util.tree_map(
                lambda a, b: jnp.where(any_arr, a, b), new, old)

        # --- clients: dispatched clients distill on the teacher they
        # were handed, then train locally; params freeze while in flight
        # (an in-flight client is never dispatched, so its report is
        # evaluated from dispatch-time parameters) -----------------------
        cp = carry["client_params"]
        x_prev = self.x_pub[carry["prev_idx"]]
        upd = self._distill_all(cp, x_prev, carry["prev_teacher"])
        cp = _select_cohorts(upd, cp, self.models.split(
            jnp.logical_and(dispatch, carry["have_prev"])))
        upd = self._local_train_all(cp, t)
        cp = _select_cohorts(upd, cp, self.models.split(dispatch))

        # --- request list at the ARRIVAL round's subset ------------------
        cache_prev = carry["cache"]
        if self.use_cache:
            key_exp = (jax.random.fold_in(jax.random.PRNGKey(c.seed), t)
                       if self.probabilistic_expiry else None)
            miss = cache_lib.miss_mask(cache_prev, idx, t, self.D,
                                       probabilistic=self.probabilistic_expiry,
                                       key=key_exp)
        else:
            miss = jnp.ones(c.public_per_round, bool)
        miss_f = miss.astype(jnp.float32)
        n_req = jnp.sum(miss_f)
        base, base_present = cache_lib.cached_at(cache_prev, idx)

        # --- staleness-weighted aggregation over ARRIVALS ----------------
        # dispatch-updated sync points: staleness of an arrival is the
        # number of rounds its report spent in flight
        ls_mid = jnp.where(dispatch, t - 1, carry["last_sync"])
        x_round = self.x_pub[idx]
        z_all = self._predict_all(cp, x_round)
        z_all = s.transmit(z_all, jax.random.fold_in(kt, TRANSMIT_SALT))
        z_tx = z_all
        if self._unit_staleness:
            w = arrive_f
        else:
            w = arrive_f * s.staleness_weight(t - 1 - ls_mid)
        if self._fused:
            um = s.upload_mask(z_all)
            fbase = (round_kernel.resolve_delta_base(
                         base, base_present, c.public_per_round, c.n_classes)
                     if self._fused_spec["mode"] == "delta" else None)
            fresh = s.aggregate_masked_fused(z_all, w, self._fused_spec,
                                             fbase, t)
        else:
            if not self.codec_up.is_identity:
                z_all = self.codec_up.roundtrip(z_all, base=base,
                                                present=base_present)
            um = s.upload_mask(z_all)
            fresh = s.aggregate_masked(z_all, w, um, t)
        if not self.codec_down.is_identity:
            fresh = self.codec_down.roundtrip(fresh, base=base,
                                              present=base_present)

        # --- teacher + cache + server updates, gated on arrivals ---------
        cache = cache_prev
        if self.use_cache:
            teacher = cache_lib.assemble_teacher(cache_prev, idx, fresh, miss)
            new_cache, _ = cache_lib.update_global_cache(
                cache_prev, idx, teacher, miss, t)
            cache = gate(new_cache, cache_prev)
        else:
            teacher = fresh

        sp = distill(carry["server_params"], x_round, teacher,
                     c.lr_dist, c.distill_steps)
        server_params = gate(sp, carry["server_params"])
        zv = self._predict_all(cp, self.x_pub[self.pub_val_idx])
        teacher_val = jnp.where(any_arr, jnp.mean(zv, axis=0),
                                carry["teacher_val"])
        have_tv = jnp.logical_or(carry["have_tv"], any_arr)
        prev_teacher = jnp.where(any_arr, teacher, carry["prev_teacher"])
        prev_idx = jnp.where(any_arr, idx, carry["prev_idx"])
        have_prev = jnp.logical_or(carry["have_prev"], any_arr)

        # --- ledger: dispatch-time uplink, two-sided catch-up ------------
        catch_up = jnp.float32(0.0)
        catch_disp = jnp.float32(0.0)
        if self.use_cache:
            catch_up, catch_disp = cache_lib.catch_up_bytes_async(
                cache_prev, carry["last_sync"], dispatch, arrive, t)
        # per-arrival upload size is the request-list size of each
        # client's DISPATCH round; the cost model takes the per-client
        # average (exact n_req when everything arrives same-round)
        flight_nreq = jnp.where(dispatch, n_req, carry["flight_nreq"])
        n_up = jnp.sum(arrive_f * flight_nreq) / jnp.maximum(n_arr, 1.0)
        if um is not None:  # Selective-FD gating, applied at arrival
            uploaded_total = jnp.sum(
                um.astype(jnp.float32) * arrive_f[:, None] * miss_f[None, :])
            n_up = uploaded_total / jnp.maximum(n_arr, 1.0)
        uplink, downlink = comm_lib.distillation_round_cost_device(
            n_clients=n_arr,
            n_selected=float(c.public_per_round),
            n_up_samples=n_up,
            n_down_samples=n_req,
            n_classes=c.n_classes,
            uplink_bits=s.uplink_bits,
            downlink_bits=s.downlink_bits,
            with_cache_signals=self.use_cache,
            catch_up_down=catch_up,
            bytes_index=c.index_bytes,
            uplink_codec=self.codec_up,
            downlink_codec=self.codec_down,
        )
        uplink = jnp.where(any_arr, uplink, 0.0)
        # dispatch-side sync bytes flow even when nothing arrives
        downlink = jnp.where(any_arr, downlink,
                             jnp.where(any_disp, catch_disp, 0.0))

        # --- flight + sync bookkeeping -----------------------------------
        last_sync = jnp.where(arrive, t, ls_mid)
        in_flight = jnp.logical_or(
            jnp.logical_and(busy, jnp.logical_not(arrive)),
            jnp.logical_and(dispatch, delay_t > 0))
        flight_arrival = jnp.where(dispatch, t + delay_t,
                                   carry["flight_arrival"])

        # --- telemetry: arrivals are the participants; pre-round
        # last_sync makes staleness buckets equal report delay ------------
        tel = None
        if self._telemetry:
            z_srv = z_all
            if self._fused and not self.codec_up.is_identity:
                z_srv = self.codec_up.roundtrip(z_tx, base=base,
                                                present=base_present)
            tel = obs_device.gate(self._telemetry_row(
                t=t, part_full=arrive, miss=miss, base_present=base_present,
                z_tx=z_tx, z_srv=z_srv, fresh=fresh,
                last_sync=carry["last_sync"], uplink=uplink,
                downlink=downlink, catch_up=catch_up), any_arr)

        # --- eval (scheduled rounds only) --------------------------------
        def _eval():
            sa = accuracy(server_params, self.x_test, self.y_test,
                          jnp.ones(len(self.y_test)))
            accs = [accuracy_v(p, self.xts_c[i], self.yts_c[i],
                               self.tmask_c[i].astype(jnp.float32))
                    for i, p in enumerate(cp)]
            ca = jnp.mean(self.models.concat(accs))
            cacc = jnp.stack([jnp.mean(a) for a in accs])
            sv = val_loss_soft(server_params, self.x_pub[self.pub_val_idx],
                               teacher_val)
            cv = jnp.mean(self.models.concat(
                [val_loss_hard_v(p, self.xs_c[i], self.ys_c[i],
                                 self.val_mask_c[i].astype(jnp.float32))
                 for i, p in enumerate(cp)]))
            return sa, ca, sv, cv, cacc

        sa, ca, sv, cv, cacc = jax.lax.cond(
            do_eval, _eval,
            lambda: (jnp.float32(0),) * 4
            + (jnp.zeros(self.models.n_cohorts, jnp.float32),))

        new_carry = dict(
            client_params=cp,
            server_params=server_params,
            cache=cache,
            prev_teacher=prev_teacher,
            prev_idx=prev_idx,
            have_prev=have_prev,
            teacher_val=teacher_val,
            have_tv=have_tv,
            last_sync=last_sync,
            in_flight=in_flight,
            flight_arrival=flight_arrival,
            flight_nreq=flight_nreq,
        )
        ys = dict(uplink=uplink, downlink=downlink,
                  server_acc=sa, client_acc=ca, server_val=sv, client_val=cv,
                  cohort_acc=cacc, have_tv=have_tv)
        if tel is not None:
            new_carry["telemetry"] = obs_device.accumulate(
                carry["telemetry"], tel)
            ys["telemetry"] = tel
        return new_carry, ys

    # ------------------------------------------------------------------
    def _aot_args(self, ts, offline, do_eval):
        carry, (ts_x, off_x, ev_x) = super()._aot_args(ts, offline, do_eval)
        ts_np = np.asarray(ts)
        start = int(ts_np[0]) if ts_np.size else self.t_done + 1
        compiled = self.traffic.compile(int(ts_np.size), self.cfg.n_clients,
                                        start=start)
        return (carry, (ts_x, off_x, ev_x,
                        jnp.asarray(compiled.available),
                        jnp.asarray(compiled.delay)))

    # ------------------------------------------------------------------
    # flight state joins the checkpointable carry next to last_sync
    # (state_dict feeds _initial_carry, so the scan carry extends
    # automatically and chained/restored runs keep reports in flight)
    # ------------------------------------------------------------------
    def state_dict(self):
        state = super().state_dict()
        state["in_flight"] = jnp.asarray(self.in_flight, bool)
        state["flight_arrival"] = jnp.asarray(self.flight_arrival, jnp.int32)
        state["flight_nreq"] = jnp.asarray(self.flight_nreq, jnp.float32)
        return state

    def load_state_dict(self, state) -> None:
        super().load_state_dict(state)
        self.in_flight = np.asarray(state["in_flight"]).astype(bool)
        self.flight_arrival = np.asarray(
            state["flight_arrival"]).astype(np.int32)
        self.flight_nreq = np.asarray(state["flight_nreq"]).astype(np.float32)

    def _finish_run(self, carry, ys, eval_np, t0):
        self.in_flight = np.asarray(carry["in_flight"]).astype(bool)
        self.flight_arrival = np.asarray(
            carry["flight_arrival"]).astype(np.int32)
        self.flight_nreq = np.asarray(carry["flight_nreq"]).astype(np.float32)
        return super()._finish_run(carry, ys, eval_np, t0)
