"""Backwards-compatible facade for the ``repro.fl`` package.

The former monolithic engine now lives in dedicated modules — see
``src/repro/fl/README.md`` for the package layout and extension points:

- :mod:`repro.fl.config`      — :class:`FLConfig`
- :mod:`repro.fl.rounds`      — jitted client primitives + round loop
- :mod:`repro.fl.scenarios`   — participation / outage / heterogeneity
- :mod:`repro.fl.strategies`  — one module per method + ``STRATEGIES``
- :mod:`repro.fl.baselines`   — FedAvg, Individual
- :mod:`repro.fl.api`         — :func:`run_method`

Every public name that used to be defined here is re-exported so
existing imports (benchmarks, examples, tests) keep working unchanged.
"""
from __future__ import annotations

from repro.fl.api import run_method
from repro.fl.baselines import FedAvg, Individual
from repro.fl.cohorts import ClientModels, CohortSpec, resolve_cohorts
from repro.fl.config import FLConfig
from repro.fl.rounds import (
    FederatedDistillation,
    History,
    _ce,
    _kl,
    _select,
    accuracy,
    accuracy_v,
    distill,
    distill_v,
    local_train,
    local_train_masked,
    local_train_masked_v,
    local_train_v,
    predict_soft,
    predict_v,
    val_loss_hard,
    val_loss_hard_v,
    val_loss_soft,
)
from repro.fl.async_engine import AsyncFederatedDistillation
from repro.fl.scan_engine import ScannedFederatedDistillation
from repro.fl.shard_engine import ShardedFederatedDistillation
from repro.fl.traffic import (
    ArrivalProcess,
    ChurnEvent,
    LatencyModel,
    TrafficModel,
)
from repro.fl.scenarios import (
    Heterogeneity,
    Outage,
    Participation,
    Scenario,
    bernoulli_participation,
    fixed_fraction,
    full_participation,
)
from repro.fl.strategies import (
    STRATEGIES,
    CFDStrategy,
    COMETStrategy,
    ERAStrategy,
    EnhancedERAStrategy,
    MeanStrategy,
    SelectiveFDStrategy,
    Strategy,
)

__all__ = [
    "FLConfig",
    "CohortSpec",
    "ClientModels",
    "resolve_cohorts",
    "History",
    "FederatedDistillation",
    "ScannedFederatedDistillation",
    "ShardedFederatedDistillation",
    "AsyncFederatedDistillation",
    "ArrivalProcess",
    "LatencyModel",
    "ChurnEvent",
    "TrafficModel",
    "FedAvg",
    "Individual",
    "run_method",
    "Strategy",
    "MeanStrategy",
    "ERAStrategy",
    "EnhancedERAStrategy",
    "CFDStrategy",
    "COMETStrategy",
    "SelectiveFDStrategy",
    "STRATEGIES",
    "Scenario",
    "Participation",
    "Outage",
    "Heterogeneity",
    "full_participation",
    "fixed_fraction",
    "bernoulli_participation",
    "local_train",
    "local_train_v",
    "local_train_masked",
    "local_train_masked_v",
    "distill",
    "distill_v",
    "predict_soft",
    "predict_v",
    "val_loss_soft",
    "val_loss_hard",
    "val_loss_hard_v",
    "accuracy",
    "accuracy_v",
]
