"""Federated distillation engine.

Simulates K clients + server with *vmapped* client training (stacked
client params, dense (K, n_max) private shards with validity masks).
One generic round loop hosts every distillation-based method via a
:class:`Strategy`; parameter-sharing FedAvg and the Individual baseline
reuse the same substrate.

Workflow per round t (SCARLET Alg. 1 full/partial participation):
  1. server picks the public subset P^t and computes the request list
     (cache miss mask) when caching is enabled;
  2. participating clients distill on the *previous* round's teacher
     (z-hat^{t-1}), then train locally on their private shard;
  3. clients emit soft-labels for requested samples (uplink);
  4. server aggregates (mean / ERA / Enhanced ERA / clustered /
     selective), assembles the teacher from fresh + cached entries,
     updates the global cache and signals, distills the server model;
  5. the communication ledger records exact uplink/downlink bytes,
     including cache signals and catch-up packages for stale clients.
"""
from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cache as cache_lib
from repro.core import comm as comm_lib
from repro.core import era as era_lib
from repro.data.synthetic import dirichlet_partition, make_public_private, pad_client_shards
from repro.models.resnet import apply_mlp, init_mlp


@dataclass(frozen=True)
class FLConfig:
    n_clients: int = 20
    n_classes: int = 10
    dim: int = 32
    rounds: int = 100
    local_steps: int = 5          # E
    distill_steps: int = 5        # E_dist
    lr: float = 0.1               # eta
    lr_dist: float = 0.1          # eta_dist
    public_size: int = 1000       # |P|
    public_per_round: int = 100   # |P^t|
    private_size: int = 2000
    alpha: float = 0.05           # Dirichlet
    participation: float = 1.0    # p
    hidden: int = 64
    mlp_depth: int = 2
    cluster_scale: float = 3.0   # class-center spread (task difficulty)
    noise: float = 1.0           # within-class noise (task difficulty)
    seed: int = 0
    eval_every: int = 10


# ---------------------------------------------------------------------------
# jitted per-client primitives
# ---------------------------------------------------------------------------

def _ce(params, x, y, mask):
    logits = apply_mlp(params, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, y[:, None], axis=-1)[:, 0]
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def _kl(params, x, teacher):
    logits = apply_mlp(params, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    t = jnp.clip(teacher, 1e-12, 1.0)
    return jnp.mean(jnp.sum(t * (jnp.log(t) - logp), axis=-1))


@functools.partial(jax.jit, static_argnames=("steps",))
def local_train(params, x, y, mask, lr, steps: int):
    def body(p, _):
        g = jax.grad(_ce)(p, x, y, mask)
        return jax.tree_util.tree_map(lambda a, b: a - lr * b, p, g), None

    params, _ = jax.lax.scan(body, params, None, length=steps)
    return params


@functools.partial(jax.jit, static_argnames=("steps",))
def distill(params, x, teacher, lr, steps: int):
    def body(p, _):
        g = jax.grad(_kl)(p, x, teacher)
        return jax.tree_util.tree_map(lambda a, b: a - lr * b, p, g), None

    params, _ = jax.lax.scan(body, params, None, length=steps)
    return params


@jax.jit
def predict_soft(params, x):
    return jax.nn.softmax(apply_mlp(params, x), axis=-1)


@jax.jit
def val_loss_soft(params, x, teacher):
    """Server-side proxy metric (App. D): distillation loss on a held-out
    public validation split — no test labels needed."""
    return _kl(params, x, teacher)


@jax.jit
def val_loss_hard(params, x, y, mask):
    """Client-side proxy metric (App. D): CE on a held-out private
    validation split."""
    return _ce(params, x, y, mask)


@jax.jit
def accuracy(params, x, y, mask):
    pred = jnp.argmax(apply_mlp(params, x), axis=-1)
    ok = (pred == y) * mask
    return jnp.sum(ok) / jnp.maximum(jnp.sum(mask), 1.0)


val_loss_hard_v = jax.vmap(val_loss_hard, in_axes=(0, 0, 0, 0))
local_train_v = jax.vmap(local_train, in_axes=(0, 0, 0, 0, None, None))
distill_v = jax.vmap(distill, in_axes=(0, None, 0, None, None))
predict_v = jax.vmap(predict_soft, in_axes=(0, None))
accuracy_v = jax.vmap(accuracy, in_axes=(0, 0, 0, 0))


def _select(new, old, keep_mask):
    """Per-client parameter update gating (partial participation)."""
    def sel(a, b):
        m = keep_mask.reshape((-1,) + (1,) * (a.ndim - 1))
        return jnp.where(m, a, b)

    return jax.tree_util.tree_map(sel, new, old)


# ---------------------------------------------------------------------------
# Strategy protocol
# ---------------------------------------------------------------------------

class Strategy:
    """Distillation-method-specific behavior. Subclasses override hooks."""

    name = "base"
    uses_cache = False
    uplink_bits = 32.0
    downlink_bits = 32.0

    def __init__(self, **kw):
        self.opts = kw

    # uplink payload transform (e.g. CFD quantization). Returns z as the
    # server sees it.
    def transmit(self, z_clients: jnp.ndarray, rng: np.random.Generator) -> jnp.ndarray:
        return z_clients

    # per-(client, sample) upload mask (Selective-FD). True = uploaded.
    def upload_mask(self, z_clients: jnp.ndarray) -> Optional[jnp.ndarray]:
        return None

    # aggregate (K, m, N) -> teacher (m, N) used by the SERVER; may also
    # return per-client teachers (K, m, N) for personalized methods.
    def aggregate(self, z_clients, upload_mask, t) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
        raise NotImplementedError


class MeanStrategy(Strategy):
    name = "mean"

    def aggregate(self, z, um, t):
        return jnp.mean(z, axis=0), None


class ERAStrategy(Strategy):
    """DS-FL: temperature-softmax sharpening of the average."""

    name = "dsfl"

    def aggregate(self, z, um, t):
        return era_lib.era(jnp.mean(z, axis=0), self.opts.get("T", 0.1)), None


class EnhancedERAStrategy(Strategy):
    """SCARLET: power sharpening (Eq. 4).

    ``beta="adaptive"`` implements the paper's §V future direction:
    the server tunes beta each round from a server-visible signal — the
    mean normalized entropy of the averaged soft-labels.  Flat teachers
    (H_norm near 1, strong non-IID mixing) get sharpened harder; already
    confident teachers are preserved:
        beta_t = 1 + (beta_max - 1) * H_norm(z_mean)
    beta=1 is recovered exactly when teachers are one-hot, matching the
    near-IID optimum the paper measures (Fig. 15).
    """

    name = "scarlet"
    uses_cache = True

    def aggregate(self, z, um, t):
        zbar = jnp.mean(z, axis=0)
        beta = self.opts.get("beta", 1.5)
        if beta == "adaptive":
            n = zbar.shape[-1]
            h_norm = jnp.mean(era_lib.entropy(zbar)) / jnp.log(n)
            beta = 1.0 + (self.opts.get("beta_max", 2.5) - 1.0) * h_norm
        return era_lib.enhanced_era(zbar, beta), None


class CFDStrategy(Strategy):
    """CFD: quantized uplink soft-labels (b_up bits), plain averaging."""

    name = "cfd"

    def __init__(self, b_up: int = 1, b_down: int = 32, **kw):
        super().__init__(**kw)
        self.uplink_bits = float(b_up)
        self.downlink_bits = float(b_down)
        self.b_up = b_up

    def transmit(self, z, rng):
        # per-vector min-max uniform quantization to b_up bits
        levels = 2 ** self.b_up - 1
        zmin = z.min(axis=-1, keepdims=True)
        zmax = z.max(axis=-1, keepdims=True)
        scale = jnp.maximum(zmax - zmin, 1e-9)
        q = jnp.round((z - zmin) / scale * levels) / levels
        deq = q * scale + zmin
        return deq / jnp.maximum(deq.sum(-1, keepdims=True), 1e-9)

    def aggregate(self, z, um, t):
        return jnp.mean(z, axis=0), None


class COMETStrategy(Strategy):
    """COMET: cluster clients by soft-label similarity; each client
    distills from its cluster's teacher (+ server uses the global mean)."""

    name = "comet"

    def __init__(self, n_clusters: int = 2, **kw):
        super().__init__(**kw)
        self.c = n_clusters

    def aggregate(self, z, um, t):
        K = z.shape[0]
        feats = np.asarray(z.reshape(K, -1), np.float64)
        # lightweight k-means
        rng = np.random.default_rng(1234 + t)
        cent = feats[rng.choice(K, self.c, replace=False)]
        for _ in range(10):
            d = ((feats[:, None] - cent[None]) ** 2).sum(-1)
            assign = d.argmin(1)
            for j in range(self.c):
                sel = feats[assign == j]
                if len(sel):
                    cent[j] = sel.mean(0)
        assign = jnp.asarray(assign)
        one = jax.nn.one_hot(assign, self.c, dtype=z.dtype)          # (K, c)
        csum = jnp.einsum("kc,kmn->cmn", one, z)
        cnt = jnp.maximum(one.sum(0), 1.0)[:, None, None]
        cteach = csum / cnt                                           # (c, m, N)
        per_client = cteach[assign]                                   # (K, m, N)
        return jnp.mean(z, axis=0), per_client


class SelectiveFDStrategy(Strategy):
    """Selective-FD: clients upload only confident (low-entropy)
    soft-labels; the server averages over uploaders per sample."""

    name = "selective_fd"

    def __init__(self, tau_client: float = 0.0625, **kw):
        super().__init__(**kw)
        self.tau = tau_client

    def upload_mask(self, z):
        # normalized entropy in [0,1]; upload when confident
        N = z.shape[-1]
        h = era_lib.entropy(z) / jnp.log(N)
        return h <= (1.0 - self.tau)

    def aggregate(self, z, um, t):
        w = um.astype(z.dtype)[..., None]
        num = jnp.sum(z * w, axis=0)
        den = jnp.maximum(jnp.sum(w, axis=0), 1e-9)
        teacher = num / den
        # samples nobody uploaded: fall back to plain mean
        empty = (jnp.sum(um, axis=0) == 0)[:, None]
        return jnp.where(empty, jnp.mean(z, axis=0), teacher), None


STRATEGIES: Dict[str, Callable[..., Strategy]] = {
    "mean": MeanStrategy,
    "dsfl": ERAStrategy,
    "scarlet": EnhancedERAStrategy,
    "cfd": CFDStrategy,
    "comet": COMETStrategy,
    "selective_fd": SelectiveFDStrategy,
}


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------

@dataclass
class History:
    rounds: List[int] = field(default_factory=list)
    server_acc: List[float] = field(default_factory=list)
    client_acc: List[float] = field(default_factory=list)
    cumulative_mb: List[float] = field(default_factory=list)
    # Appendix-D proxy metrics (no test labels required in deployment)
    server_val_loss: List[float] = field(default_factory=list)
    client_val_loss: List[float] = field(default_factory=list)
    ledger: comm_lib.CommLedger = field(default_factory=comm_lib.CommLedger)
    final_server_acc: float = 0.0
    final_client_acc: float = 0.0

    def as_dict(self) -> Dict[str, Any]:
        return {
            "rounds": self.rounds,
            "server_acc": self.server_acc,
            "client_acc": self.client_acc,
            "cumulative_mb": self.cumulative_mb,
            "server_val_loss": self.server_val_loss,
            "client_val_loss": self.client_val_loss,
            "comm": self.ledger.summary(),
            "final_server_acc": self.final_server_acc,
            "final_client_acc": self.final_client_acc,
        }


class FederatedDistillation:
    """Generic distillation-based FL run (DS-FL / SCARLET / CFD / COMET /
    Selective-FD / mean), with optional soft-label caching (drop-in for
    any strategy — paper Fig. 11) and partial participation."""

    def __init__(self, cfg: FLConfig, strategy: Strategy,
                 cache_duration: int = 0, use_cache: Optional[bool] = None,
                 probabilistic_expiry: bool = False):
        self.cfg = cfg
        self.strategy = strategy
        self.D = cache_duration
        self.probabilistic_expiry = probabilistic_expiry
        self.use_cache = strategy.uses_cache if use_cache is None else use_cache
        if self.D == 0:
            self.use_cache = self.use_cache and False
        self.rng = np.random.default_rng(cfg.seed)
        self._setup()

    # ------------------------------------------------------------------
    def _setup(self) -> None:
        c = self.cfg
        data = make_public_private(c.private_size, c.public_size, c.n_classes,
                                   c.dim, seed=c.seed,
                                   cluster_scale=c.cluster_scale, noise=c.noise)
        self.data = data
        parts = dirichlet_partition(data["y_private"], c.n_clients, c.alpha,
                                    seed=c.seed)
        self.xs, self.ys, self.mask = map(
            jnp.asarray, pad_client_shards(data["x_private"], data["y_private"], parts))
        tparts = dirichlet_partition(data["y_test"], c.n_clients, c.alpha,
                                     seed=c.seed + 7)
        self.xts, self.yts, self.tmask = map(
            jnp.asarray, pad_client_shards(data["x_test"], data["y_test"], tparts))
        self.x_pub = jnp.asarray(data["x_public"])
        self.x_test = jnp.asarray(data["x_test"])
        self.y_test = jnp.asarray(data["y_test"])

        key = jax.random.PRNGKey(c.seed)
        keys = jax.random.split(key, c.n_clients + 1)
        self.client_params = jax.vmap(
            lambda k: init_mlp(k, c.dim, c.n_classes, c.hidden, c.mlp_depth))(keys[:-1])
        self.server_params = init_mlp(keys[-1], c.dim, c.n_classes, c.hidden, c.mlp_depth)

        # Appendix-D validation splits: 10% of public for the server proxy,
        # 10% of each client's private shard for the client proxy
        n_pub_val = max(c.public_size // 10, 10)
        self.pub_val_idx = jnp.asarray(
            np.random.default_rng(c.seed + 99).choice(
                c.public_size, n_pub_val, replace=False))
        val_cut = jnp.maximum((jnp.sum(self.mask, 1) * 0.9).astype(jnp.int32), 1)
        pos = jnp.arange(self.mask.shape[1])[None, :]
        self.val_mask = jnp.logical_and(self.mask, pos >= val_cut[:, None])
        self.train_mask = jnp.logical_and(self.mask, pos < val_cut[:, None])
        self.last_teacher_val: Optional[jnp.ndarray] = None

        self.cache_g = cache_lib.init_cache(c.public_size, c.n_classes)
        self.prev_teacher: Optional[Tuple[np.ndarray, jnp.ndarray]] = None  # (idx, z)
        self.last_sync = np.full(c.n_clients, 0, np.int64)  # last participated round
        self.n_params = sum(x.size for x in jax.tree_util.tree_leaves(self.server_params))

    # ------------------------------------------------------------------
    def run(self, rounds: Optional[int] = None) -> History:
        c = self.cfg
        hist = History()
        T = rounds or c.rounds
        for t in range(1, T + 1):
            self._round(t, hist)
            if t % c.eval_every == 0 or t == T:
                self._eval(t, hist)
        hist.final_server_acc = hist.server_acc[-1] if hist.server_acc else 0.0
        hist.final_client_acc = hist.client_acc[-1] if hist.client_acc else 0.0
        return hist

    # ------------------------------------------------------------------
    def _round(self, t: int, hist: History) -> None:
        c, s = self.cfg, self.strategy
        K = c.n_clients
        part = np.zeros(K, bool)
        n_part = max(int(round(c.participation * K)), 1)
        part[self.rng.choice(K, n_part, replace=False)] = True
        part_j = jnp.asarray(part)

        idx = np.sort(self.rng.choice(c.public_size, c.public_per_round, replace=False))
        idx_j = jnp.asarray(idx)

        # --- clients: distill on previous teacher, then local training ----
        new_params = self.client_params
        if self.prev_teacher is not None:
            pidx, pteach = self.prev_teacher
            x_prev = self.x_pub[jnp.asarray(pidx)]
            if pteach.ndim == 3:  # per-client teachers (COMET)
                upd = jax.vmap(distill, in_axes=(0, None, 0, None, None))(
                    new_params, x_prev, pteach, c.lr_dist, c.distill_steps)
            else:
                upd = distill_v(new_params, x_prev, jnp.broadcast_to(
                    pteach, (K,) + pteach.shape), c.lr_dist, c.distill_steps)
            new_params = _select(upd, new_params, part_j)
        upd = local_train_v(new_params, self.xs, self.ys,
                            self.train_mask.astype(jnp.float32), c.lr, c.local_steps)
        self.client_params = _select(upd, new_params, part_j)

        # --- request list (cache) ----------------------------------------
        if self.use_cache:
            miss = cache_lib.miss_mask(
                self.cache_g, idx_j, t, self.D,
                probabilistic=self.probabilistic_expiry,
                key=jax.random.PRNGKey(hash(("expiry", self.cfg.seed, t)) & 0x7FFFFFFF)
                if self.probabilistic_expiry else None)
        else:
            miss = jnp.ones(len(idx), bool)
        n_req = int(jnp.sum(miss))

        # --- uplink: soft-labels on requested samples ---------------------
        x_round = self.x_pub[idx_j]
        z_all = predict_v(self.client_params, x_round)  # (K, m, N)
        z_all = s.transmit(z_all, self.rng)
        um = s.upload_mask(z_all)
        # only participating clients contribute
        zsel = z_all[part_j] if n_part < K else z_all
        umsel = None if um is None else (um[part_j] if n_part < K else um)

        fresh, per_client = s.aggregate(zsel, umsel, t)

        # --- assemble teacher + cache update ------------------------------
        if self.use_cache:
            teacher = cache_lib.assemble_teacher(self.cache_g, idx_j, fresh, miss)
            self.cache_g, signals = cache_lib.update_global_cache(
                self.cache_g, idx_j, teacher, miss, t)
        else:
            teacher = fresh

        # --- server distillation ------------------------------------------
        self.server_params = distill(self.server_params, x_round, teacher,
                                     c.lr_dist, c.distill_steps)
        # App.-D proxy teacher on the public validation split: the clients'
        # (server-visible) aggregated predictions on held-out public data
        zv = predict_v(self.client_params, self.x_pub[self.pub_val_idx])
        self.last_teacher_val = jnp.mean(zv, axis=0)
        if per_client is not None:
            teach_next = per_client  # COMET: personalized teachers
        else:
            teach_next = teacher
        self.prev_teacher = (idx, teach_next)

        # --- communication accounting --------------------------------------
        uploaded = n_req
        if um is not None:  # Selective-FD: only confident entries ride uplink
            frac = float(jnp.mean(um.astype(jnp.float32)))
            uploaded = n_req * frac
        catch_up = 0.0
        if self.use_cache and c.participation < 1.0:
            for k in np.where(part)[0]:
                if self.last_sync[k] < t - 1:
                    pkg = cache_lib.make_catch_up(self.cache_g, int(self.last_sync[k]))
                    catch_up += cache_lib.catch_up_bytes(pkg)
        cost = comm_lib.distillation_round_cost(
            n_clients=n_part,
            n_selected=len(idx),
            n_requested=int(np.ceil(uploaded)) if um is not None else n_req,
            n_classes=c.n_classes,
            uplink_bits=s.uplink_bits,
            downlink_bits=s.downlink_bits,
            with_cache_signals=self.use_cache,
            catch_up_down=catch_up,
        )
        hist.ledger.record(cost)
        self.last_sync[part] = t

    # ------------------------------------------------------------------
    def _eval(self, t: int, hist: History) -> None:
        sa = float(accuracy(self.server_params, self.x_test, self.y_test,
                            jnp.ones(len(self.y_test))))
        ca = float(jnp.mean(accuracy_v(self.client_params, self.xts, self.yts,
                                       self.tmask.astype(jnp.float32))))
        hist.rounds.append(t)
        hist.server_acc.append(sa)
        hist.client_acc.append(ca)
        hist.cumulative_mb.append(hist.ledger.cumulative_total / 1e6)
        # Appendix-D proxies (computable in deployment without test labels)
        if self.last_teacher_val is not None:
            hist.server_val_loss.append(float(val_loss_soft(
                self.server_params, self.x_pub[self.pub_val_idx],
                self.last_teacher_val)))
        hist.client_val_loss.append(float(jnp.mean(val_loss_hard_v(
            self.client_params, self.xs, self.ys,
            self.val_mask.astype(jnp.float32)))))


# ---------------------------------------------------------------------------
# Parameter-sharing / no-collaboration baselines
# ---------------------------------------------------------------------------

class FedAvg:
    def __init__(self, cfg: FLConfig):
        self.cfg = cfg
        fd = FederatedDistillation(cfg, MeanStrategy())
        self.__dict__.update({k: fd.__dict__[k] for k in (
            "xs", "ys", "mask", "xts", "yts", "tmask", "x_test", "y_test",
            "client_params", "server_params", "n_params")})
        self.rng = np.random.default_rng(cfg.seed)

    def run(self, rounds: Optional[int] = None) -> History:
        c = self.cfg
        hist = History()
        sizes = jnp.sum(self.mask, axis=1)
        w = (sizes / jnp.sum(sizes))
        T = rounds or c.rounds
        for t in range(1, T + 1):
            bcast = jax.tree_util.tree_map(
                lambda p: jnp.broadcast_to(p, (c.n_clients,) + p.shape),
                self.server_params)
            trained = local_train_v(bcast, self.xs, self.ys, self.mask, c.lr, c.local_steps)
            self.server_params = jax.tree_util.tree_map(
                lambda p: jnp.tensordot(w, p, axes=(0, 0)), trained)
            self.client_params = trained
            hist.ledger.record(comm_lib.fedavg_round_cost(
                n_clients=c.n_clients, n_params=self.n_params))
            if t % c.eval_every == 0 or t == T:
                sa = float(accuracy(self.server_params, self.x_test, self.y_test,
                                    jnp.ones(len(self.y_test))))
                ca = float(jnp.mean(accuracy_v(self.client_params, self.xts, self.yts,
                                               self.tmask.astype(jnp.float32))))
                hist.rounds.append(t)
                hist.server_acc.append(sa)
                hist.client_acc.append(ca)
                hist.cumulative_mb.append(hist.ledger.cumulative_total / 1e6)
        hist.final_server_acc = hist.server_acc[-1]
        hist.final_client_acc = hist.client_acc[-1]
        return hist


class Individual:
    """Isolated client training — the paper's no-collaboration baseline."""

    def __init__(self, cfg: FLConfig):
        self.cfg = cfg
        fd = FederatedDistillation(cfg, MeanStrategy())
        self.__dict__.update({k: fd.__dict__[k] for k in (
            "xs", "ys", "mask", "xts", "yts", "tmask", "x_test", "y_test",
            "client_params", "server_params")})

    def run(self, rounds: Optional[int] = None) -> History:
        c = self.cfg
        hist = History()
        T = rounds or c.rounds
        for t in range(1, T + 1):
            self.client_params = local_train_v(
                self.client_params, self.xs, self.ys, self.mask, c.lr, c.local_steps)
            hist.ledger.record(comm_lib.RoundCost(0.0, 0.0))
            if t % c.eval_every == 0 or t == T:
                ca = float(jnp.mean(accuracy_v(self.client_params, self.xts, self.yts,
                                               self.tmask.astype(jnp.float32))))
                hist.rounds.append(t)
                hist.server_acc.append(0.0)
                hist.client_acc.append(ca)
                hist.cumulative_mb.append(0.0)
        hist.final_server_acc = 0.0
        hist.final_client_acc = hist.client_acc[-1]
        return hist


# ---------------------------------------------------------------------------
# front door
# ---------------------------------------------------------------------------

def run_method(
    method: str,
    cfg: FLConfig,
    *,
    cache_duration: int = 0,
    use_cache: Optional[bool] = None,
    rounds: Optional[int] = None,
    probabilistic_expiry: bool = False,
    **strategy_kw,
) -> History:
    """Run one FL method end-to-end and return its History.

    method in {scarlet, dsfl, cfd, comet, selective_fd, mean, fedavg,
    individual}.  ``cache_duration``>0 with ``use_cache=True`` plugs the
    soft-label cache into any distillation method (paper Fig. 11).
    """
    if method == "fedavg":
        return FedAvg(cfg).run(rounds)
    if method == "individual":
        return Individual(cfg).run(rounds)
    strat = STRATEGIES[method](**strategy_kw)
    return FederatedDistillation(cfg, strat, cache_duration=cache_duration,
                                 use_cache=use_cache,
                                 probabilistic_expiry=probabilistic_expiry).run(rounds)
