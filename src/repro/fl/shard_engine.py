"""Client-sharded scanned engine: ``shard_map`` over the mesh "data" axis.

The scanned engine (:mod:`repro.fl.scan_engine`) made a full FL run one
XLA program, but the whole client axis lives on one chip — client count
K is capped by a single device's memory.  This engine partitions the
client axis across the mesh defined in :mod:`repro.launch.mesh`: the
scan body runs under ``shard_map`` with every per-client tensor (stacked
params, private shards, eval shards, ``last_sync``) split over the
"data" axis, so each shard trains and predicts only its ``K / n_shards``
clients.

What crosses shards is exactly the strategy's *linear* aggregation
moments plus a handful of scalar reductions:

- aggregation uses the two-phase ``Strategy.partial_aggregate`` /
  ``finalize_aggregate`` contract — per-shard weighted sums, one
  ``psum``, then the nonlinearity (Enhanced-ERA sharpening, DS-FL
  temperature softmax, Selective-FD gating ratio) applied once on the
  replicated reduction;
- byte accounting threads shard-local counts through the shard-aware
  cost functions (``comm.distillation_round_cost_device(axis_name=...)``
  psums the per-shard participant count; catch-up bytes are computed
  from the replicated ``last_sync``/participation state — the identical
  expression the scanned engine evaluates);
- eval metrics psum per-shard (per-cohort) partial sums.

Client-model cohorts (:mod:`repro.fl.cohorts`) shard naturally: every
cohort's contiguous client block is partitioned independently over the
same "data" axis (cohort sizes must divide the shard count), so each
shard holds an equal per-cohort composition and the SPMD program stays
uniform.  Soft-labels collapse the cohort axis before aggregation, so
the two-phase Strategy contract and the psum'd cost functions are
untouched by the mix.

Everything server-side (cache state, teacher assembly, server
distillation, the public dataset) is replicated — redundantly computed
by every shard, which keeps it bit-identical across shards without
communication.

Parity contract: participation and subset sampling fold the *same* key
stream as the scanned engine, with the participation mask drawn over
the full client axis on every shard (replicated — conscription ranks
couple clients across shards) and then sliced locally.  All ledger
inputs are therefore exact small-integer sums, so a sharded run's
per-round comm ledger is byte-identical to ``engine="scan"`` and eval
metrics are allclose (float reduction order differs) — asserted for the
whole strategy x participation x codec matrix by
``tests/test_engine_conformance.py``.
"""
from __future__ import annotations

import math
import re
from typing import Optional, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:  # jax >= 0.6 re-exports it at the top level
    from jax import shard_map as _shard_map_fn
except ImportError:  # pragma: no cover - version-dependent import path
    from jax.experimental.shard_map import shard_map as _shard_map_fn

from repro.core import cache as cache_lib
from repro.core import comm as comm_lib
from repro.obs import device as obs_device
from repro.fl.rounds import (
    _select_cohorts,
    accuracy,
    accuracy_v,
    distill,
    distill_v,
    local_train_masked_v,
    local_train_v,
    val_loss_hard_v,
    val_loss_soft,
)
from repro.fl.scan_engine import ScannedFederatedDistillation
from repro.fl.strategies.base import TRANSMIT_SALT
from repro.kernels import round_kernel
from repro.launch.mesh import (
    make_production_mesh,
    make_test_mesh,
    mesh_axis_sizes,
)

__all__ = ["ShardedFederatedDistillation", "resolve_mesh", "best_data_axis"]

# The mesh axis carrying the client partition — the same "data" axis the
# launch-layer sharding rules use for data parallelism / FSDP.
CLIENT_AXIS = "data"

_SPEC_RE = re.compile(r"^(\d+)(?:x(\d+))?$")


def resolve_mesh(spec: Union[str, Mesh]) -> Mesh:
    """Mesh from a *concrete* ``FLConfig.mesh_spec`` (or a Mesh, as-is).

    ``"DATA"`` or ``"DATAxMODEL"`` (e.g. ``"8"``, ``"2x4"``): a
    :func:`repro.launch.mesh.make_test_mesh` of that shape.
    ``"production"`` / ``"production_multipod"``: the 16x16 (2x16x16)
    pod meshes.

    ``"auto"`` is resolved *before* this function by the engine
    constructor (via :func:`best_data_axis`, which needs the client
    count) and is rejected here so the spelling has exactly one meaning.
    """
    if isinstance(spec, Mesh):
        return spec
    if spec == "production":
        return make_production_mesh()
    if spec == "production_multipod":
        return make_production_mesh(multi_pod=True)
    m = _SPEC_RE.match(spec) if isinstance(spec, str) else None
    if m is None:
        raise ValueError(
            f"unknown mesh_spec {spec!r} (want 'DATA', 'DATAxMODEL', "
            "'production', or 'production_multipod'; 'auto' is only valid "
            "through the engine constructor / FLConfig.mesh_spec)")
    return make_test_mesh(int(m.group(1)), int(m.group(2) or 1))


def best_data_axis(n_clients: int, n_devices: Optional[int] = None) -> int:
    """Largest device count <= ``n_devices`` that divides ``n_clients``
    evenly — the widest legal client partition for a run (benchmarks use
    it to build meshes portable across device counts)."""
    d = min(n_clients, n_devices if n_devices is not None else jax.device_count())
    while n_clients % d:
        d -= 1
    return d


class ShardedFederatedDistillation(ScannedFederatedDistillation):
    """Client-sharded twin of :class:`ScannedFederatedDistillation`.

    Same constructor plus ``mesh``: a concrete :class:`Mesh`, a spec
    string (see :func:`resolve_mesh`), or ``None`` to use
    ``cfg.mesh_spec``.  ``cfg.n_clients`` must divide evenly by the
    mesh's "data"-axis size.  Every mode restriction of the scanned
    engine applies unchanged (jax RNG, scan-safe strategy/codecs, no
    ``track_local_caches``).
    """

    def __init__(self, *args, mesh: Union[str, Mesh, None] = None, **kwargs):
        super().__init__(*args, **kwargs)
        spec = mesh if mesh is not None else self.cfg.mesh_spec
        if spec is None or spec in ("", "auto"):
            # widest client partition over the local devices that splits
            # every cohort block evenly (gcd of the cohort sizes; the
            # whole K for a homogeneous run) — "auto" must never reject
            # a client count or a cohort mix
            spec = f"{best_data_axis(math.gcd(*self.models.sizes))}"
        self.mesh = resolve_mesh(spec)
        if CLIENT_AXIS not in self.mesh.axis_names:
            raise ValueError(
                f"mesh {self.mesh.axis_names} has no {CLIENT_AXIS!r} axis "
                "to partition clients over")
        self.n_shards = mesh_axis_sizes(self.mesh)[CLIENT_AXIS]
        if self.cfg.n_clients % self.n_shards:
            raise ValueError(
                f"n_clients={self.cfg.n_clients} does not divide evenly over "
                f"the {self.n_shards}-way {CLIENT_AXIS!r} axis "
                "(pick a divisible client count or a narrower mesh)")
        # every cohort's block is sharded independently, so each cohort
        # size must split evenly too (equal per-cohort composition on
        # every shard keeps the SPMD program uniform)
        self.kloc_c = self.models.shard_sizes(self.n_shards)
        self._shard_fn = None

    # ------------------------------------------------------------------
    def _consts(self) -> dict:
        """Arrays the round body reads besides the carry: client-sharded
        private/eval shards (per-cohort tuples — each cohort's block is
        partitioned independently over the client axis) and replicated
        public/test data."""
        consts = dict(
            xs=tuple(self.xs_c), ys=tuple(self.ys_c),
            train_mask=tuple(self.train_mask_c),
            xts=tuple(self.xts_c), yts=tuple(self.yts_c),
            tmask=tuple(self.tmask_c), val_mask=tuple(self.val_mask_c),
            x_pub=self.x_pub, x_test=self.x_test, y_test=self.y_test,
            x_pub_val=self.x_pub[self.pub_val_idx],
        )
        if self.scenario.heterogeneity is not None:
            consts.update(lr_k=tuple(self._lr_k_c),
                          steps_k=tuple(self._steps_k_c))
        return consts

    def _specs(self):
        """(carry, xs, consts) PartitionSpec pytrees (prefix form)."""
        cax, rep = P(CLIENT_AXIS), P()
        # last_sync stays REPLICATED: its update depends only on the
        # (replicated) global participation draw, so keeping it global
        # avoids axis_index-tainted dataflow in an int carry — which the
        # SPMD partitioner (check_rep=False) cannot prove replicated
        # over non-client mesh axes and would mis-reduce on the gather.
        carry = dict(
            client_params=cax, server_params=rep, cache=rep,
            prev_teacher=rep, prev_idx=rep, have_prev=rep,
            teacher_val=rep, have_tv=rep, last_sync=rep)
        if self._telemetry:
            # telemetry counters derive from replicated inputs (and the
            # participant-mean gauges psum over the client axis before
            # entering the row), so the whole pytree stays replicated —
            # the replication checker proves it (repro.analysis)
            carry["telemetry"] = rep
        consts = dict(
            xs=cax, ys=cax, train_mask=cax, xts=cax, yts=cax, tmask=cax,
            val_mask=cax, x_pub=rep, x_test=rep, y_test=rep, x_pub_val=rep)
        if self.scenario.heterogeneity is not None:
            consts.update(lr_k=cax, steps_k=cax)
        # xs = (ts, offline, do_eval): offline stays full-width (T, K) on
        # every shard — the participation draw is global (see body)
        return carry, (rep, rep, rep), consts

    # ------------------------------------------------------------------
    def _local_train_shard(self, params, t, consts):
        c = self.cfg
        if self.scenario.heterogeneity is None:
            return [local_train_v(p, consts["xs"][i], consts["ys"][i],
                                  consts["train_mask"][i].astype(jnp.float32),
                                  c.lr, c.local_steps)
                    for i, p in enumerate(params)]
        decay = jnp.asarray(self._lr_decay, jnp.float32) ** (
            jnp.asarray(t, jnp.float32) - 1.0)
        return [local_train_masked_v(p, consts["xs"][i], consts["ys"][i],
                                     consts["train_mask"][i].astype(jnp.float32),
                                     consts["lr_k"][i] * decay,
                                     consts["steps_k"][i], self._max_steps)
                for i, p in enumerate(params)]

    # ------------------------------------------------------------------
    def _round_device_sharded(self, carry, xs, consts):
        """One round on one shard: mirrors ``_round_device`` with the
        client axis shard-local and all cross-client couplings reduced
        via ``psum`` over the client mesh axis."""
        c, s = self.cfg, self.strategy
        K = c.n_clients
        t, offline_t, do_eval = xs

        kt = jax.random.fold_in(self._key_rounds, t)
        k_idx, k_part = jax.random.split(kt)
        idx = jnp.sort(jax.random.choice(
            k_idx, c.public_size, (c.public_per_round,), replace=False))
        # Participation is drawn over the FULL client axis on every shard
        # (replicated: same key -> same draw) — conscription ranks couple
        # clients across shards and key-stream parity with engine="scan"
        # requires the identical global sample — then sliced locally, one
        # block per cohort (cohort c's shard-s clients are the global
        # indices offset_c + s*kloc_c .. offset_c + (s+1)*kloc_c).
        part_full = self.scenario.participation_mask_device(k_part, offline_t)
        six = jax.lax.axis_index(CLIENT_AXIS)
        part_c = [jax.lax.dynamic_slice_in_dim(part_full, off + six * kc, kc)
                  for off, kc in zip(self.models.offsets, self.kloc_c)]
        part = self.models.concat(part_c)          # shard-local (kloc,)
        part_f = part.astype(jnp.float32)
        n_part = jnp.sum(part_full.astype(jnp.float32))  # global, replicated
        any_p = n_part > 0

        def gate(new, old):
            """Keep ``old`` wholesale on total-outage rounds."""
            return jax.tree_util.tree_map(
                lambda a, b: jnp.where(any_p, a, b), new, old)

        # --- clients (shard-local, per cohort): distill, then train ------
        cp = carry["client_params"]
        x_prev = consts["x_pub"][carry["prev_idx"]]
        upd = [distill_v(p, x_prev,
                         jnp.broadcast_to(carry["prev_teacher"],
                                          (kc,) + carry["prev_teacher"].shape),
                         c.lr_dist, c.distill_steps)
               for p, kc in zip(cp, self.kloc_c)]
        cp = _select_cohorts(upd, cp, [jnp.logical_and(pc, carry["have_prev"])
                                       for pc in part_c])
        upd = self._local_train_shard(cp, t, consts)
        cp = _select_cohorts(upd, cp, part_c)

        # --- request list (replicated cache) -----------------------------
        cache_prev = carry["cache"]
        if self.use_cache:
            key_exp = (jax.random.fold_in(jax.random.PRNGKey(c.seed), t)
                       if self.probabilistic_expiry else None)
            miss = cache_lib.miss_mask(cache_prev, idx, t, self.D,
                                       probabilistic=self.probabilistic_expiry,
                                       key=key_exp)
        else:
            miss = jnp.ones(c.public_per_round, bool)
        miss_f = miss.astype(jnp.float32)
        n_req = jnp.sum(miss_f)
        base, base_present = cache_lib.cached_at(cache_prev, idx)

        # --- uplink + two-phase aggregation ------------------------------
        # the cohort axis collapses here: soft-label shapes are
        # architecture-independent, so codec/strategy/ledger code below
        # is identical to the homogeneous path
        x_round = consts["x_pub"][idx]
        z_all = self._predict_all(cp, x_round)         # (kloc, m, N)
        # per-round transmit key, replicated across shards (same fold on
        # every shard; DCE'd when the strategy ignores it)
        z_all = s.transmit(z_all, jax.random.fold_in(kt, TRANSMIT_SALT))
        z_tx = z_all  # as transmitted: telemetry's codec-error reference
        if self._fused:
            # fused fast path: codec round trip + linear moments in one
            # round_kernel pass per shard; the psum + finalize
            # nonlinearity are unchanged from the per-op two-phase path
            um = s.upload_mask(z_all)
            fbase = (round_kernel.resolve_delta_base(
                         base, base_present, c.public_per_round, c.n_classes)
                     if self._fused_spec["mode"] == "delta" else None)
            partials = jax.lax.psum(
                s.partial_aggregate_fused(z_all, part_f, self._fused_spec,
                                          fbase, t), CLIENT_AXIS)
        else:
            if not self.codec_up.is_identity:
                z_all = self.codec_up.roundtrip(z_all, base=base,
                                                present=base_present)
            um = s.upload_mask(z_all)
            partials = jax.lax.psum(
                s.partial_aggregate(z_all, part_f, um, t), CLIENT_AXIS)
        fresh = s.finalize_aggregate(partials, t)      # replicated
        if not self.codec_down.is_identity:
            fresh = self.codec_down.roundtrip(fresh, base=base,
                                              present=base_present)

        # --- teacher + cache + server distill (replicated) ---------------
        cache = cache_prev
        if self.use_cache:
            teacher = cache_lib.assemble_teacher(cache_prev, idx, fresh, miss)
            new_cache, _ = cache_lib.update_global_cache(
                cache_prev, idx, teacher, miss, t)
            cache = gate(new_cache, cache_prev)
        else:
            teacher = fresh
        sp = distill(carry["server_params"], x_round, teacher,
                     c.lr_dist, c.distill_steps)
        server_params = gate(sp, carry["server_params"])

        zv = self._predict_all(cp, consts["x_pub_val"])  # (kloc, n_val, N)
        zv_sum = jax.lax.psum(jnp.sum(zv, axis=0), CLIENT_AXIS)
        teacher_val = jnp.where(any_p, zv_sum / K, carry["teacher_val"])
        have_tv = jnp.logical_or(carry["have_tv"], any_p)

        prev_teacher = jnp.where(any_p, teacher, carry["prev_teacher"])
        prev_idx = jnp.where(any_p, idx, carry["prev_idx"])
        have_prev = jnp.logical_or(carry["have_prev"], any_p)

        # --- byte accounting ---------------------------------------------
        # last_sync and the participation draw are both replicated, so
        # catch-up bytes are computed globally on every shard — the
        # *identical* expression the scanned engine evaluates, hence
        # byte-equal ledgers by construction (no psum needed)
        catch_up = 0.0
        if self.use_cache:
            catch_up = cache_lib.catch_up_bytes_device(
                cache_prev, carry["last_sync"], part_full, t)
        n_up = n_req
        if um is not None:  # Selective-FD: psum the uploaded-entry count
            uploaded_total = jax.lax.psum(jnp.sum(
                um.astype(jnp.float32) * part_f[:, None] * miss_f[None, :]),
                CLIENT_AXIS)
            n_up = uploaded_total / jnp.maximum(n_part, 1.0)
        uplink, downlink = comm_lib.distillation_round_cost_device(
            n_clients=jnp.sum(part_f),  # per-shard count; psum'd inside
            n_selected=float(c.public_per_round),
            n_up_samples=n_up,
            n_down_samples=n_req,
            n_classes=c.n_classes,
            uplink_bits=s.uplink_bits,
            downlink_bits=s.downlink_bits,
            with_cache_signals=self.use_cache,
            catch_up_down=catch_up,
            bytes_index=c.index_bytes,
            uplink_codec=self.codec_up,
            downlink_codec=self.codec_down,
            axis_name=CLIENT_AXIS,
        )
        uplink = jnp.where(any_p, uplink, 0.0)
        downlink = jnp.where(any_p, downlink, 0.0)
        last_sync = jnp.where(part_full, t, carry["last_sync"])

        # --- device-plane telemetry: counters from the replicated
        # full-width draw/last_sync, gauges from the shard-local stack
        # psum'd over the client axis inside _telemetry_row ----------------
        tel = None
        if self._telemetry:
            z_srv = z_all
            if self._fused and not self.codec_up.is_identity:
                z_srv = self.codec_up.roundtrip(z_tx, base=base,
                                                present=base_present)
            tel = obs_device.gate(self._telemetry_row(
                t=t, part_full=part_full, miss=miss,
                base_present=base_present, z_tx=z_tx, z_srv=z_srv,
                fresh=fresh, last_sync=carry["last_sync"], uplink=uplink,
                downlink=downlink, catch_up=catch_up,
                axis_name=CLIENT_AXIS, part_local=part_f), any_p)

        # --- eval: shard-local per-cohort partial sums under the cond,
        # psum outside (collectives stay unconditional; do_eval is
        # replicated) -----------------------------------------------------
        def _eval_local():
            sa = accuracy(server_params, consts["x_test"], consts["y_test"],
                          jnp.ones(consts["y_test"].shape[0]))
            acc_sums = jnp.stack([jnp.sum(accuracy_v(
                p, consts["xts"][i], consts["yts"][i],
                consts["tmask"][i].astype(jnp.float32)))
                for i, p in enumerate(cp)])            # (n_cohorts,)
            sv = val_loss_soft(server_params, consts["x_pub_val"], teacher_val)
            cv_part = sum(jnp.sum(val_loss_hard_v(
                p, consts["xs"][i], consts["ys"][i],
                consts["val_mask"][i].astype(jnp.float32)))
                for i, p in enumerate(cp))
            return sa, acc_sums, sv, cv_part

        sa, acc_sums, sv, cv_part = jax.lax.cond(
            do_eval, _eval_local,
            lambda: (jnp.float32(0),
                     jnp.zeros(self.models.n_cohorts, jnp.float32),
                     jnp.float32(0), jnp.float32(0)))
        acc_sums = jax.lax.psum(acc_sums, CLIENT_AXIS)  # global per cohort
        cacc = acc_sums / jnp.asarray(self.models.sizes, jnp.float32)
        ca = jnp.sum(acc_sums) / K
        cv = jax.lax.psum(cv_part, CLIENT_AXIS) / K

        new_carry = dict(
            client_params=cp,
            server_params=server_params,
            cache=cache,
            prev_teacher=prev_teacher,
            prev_idx=prev_idx,
            have_prev=have_prev,
            teacher_val=teacher_val,
            have_tv=have_tv,
            last_sync=last_sync,
        )
        ys = dict(uplink=uplink, downlink=downlink,
                  server_acc=sa, client_acc=ca, server_val=sv, client_val=cv,
                  cohort_acc=cacc, have_tv=have_tv)
        if tel is not None:
            new_carry["telemetry"] = obs_device.accumulate(
                carry["telemetry"], tel)
            ys["telemetry"] = tel
        return new_carry, ys

    # ------------------------------------------------------------------
    def _program(self):
        if self._shard_fn is None:
            carry_specs, xs_specs, consts_specs = self._specs()
            in_specs = (carry_specs, xs_specs, consts_specs)

            def scan_all(carry, xs, consts):
                return jax.lax.scan(
                    lambda cr, x: self._round_device_sharded(cr, x, consts),
                    carry, xs)

            # pin input shardings so chained run() calls hit one compile:
            # the first call feeds host/single-device arrays, later calls
            # feed the previous run's already-sharded outputs
            shardings = jax.tree_util.tree_map(
                lambda s: NamedSharding(self.mesh, s), in_specs,
                is_leaf=lambda x: isinstance(x, P))
            self._shard_fn = jax.jit(
                _shard_map_fn(scan_all, mesh=self.mesh, in_specs=in_specs,
                              out_specs=(carry_specs, P()),
                              check_rep=False),
                in_shardings=shardings)
        return self._shard_fn

    def _aot_args(self, ts, offline, do_eval):
        return (self._initial_carry(), (ts, offline, do_eval),
                self._consts())

    # ------------------------------------------------------------------
    def carry_update_fn(self):
        """The one-round carry update under the engine's real shard_map,
        plus matching abstract arguments — the entry point for the
        static replication checker
        (:mod:`repro.analysis.replication_checks`).

        The round program runs with ``check_rep=False`` (the scan body
        defeats the partitioner's replication inference), so nothing at
        compile time verifies that the carry leaves ``_specs()``
        declares replicated (``P()``) really stay bit-identical across
        client shards — the exact invariant the PR 5 ``last_sync`` bug
        violated.  The checker traces ``jax.make_jaxpr(fn)(*abstract)``
        (one shard_map equation) and proves it by ``axis_index`` taint
        analysis instead.
        """
        carry_specs, xs_specs, consts_specs = self._specs()
        fn = _shard_map_fn(
            lambda carry, xs, consts: self._round_device_sharded(
                carry, xs, consts),
            mesh=self.mesh, in_specs=(carry_specs, xs_specs, consts_specs),
            out_specs=(carry_specs, P()), check_rep=False)
        abstract = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(jnp.shape(x), jnp.result_type(x)),
            (self._initial_carry(),
             (jnp.int32(0), jnp.zeros(self.cfg.n_clients, bool),
              jnp.asarray(False)),
             self._consts()))
        return fn, abstract
