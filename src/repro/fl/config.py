"""Run configuration for the federated-distillation engine."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

from repro.fl.cohorts import CohortSpec


@dataclass(frozen=True)
class FLConfig:
    n_clients: int = 20
    n_classes: int = 10
    dim: int = 32
    rounds: int = 100
    local_steps: int = 5          # E
    distill_steps: int = 5        # E_dist
    lr: float = 0.1               # eta
    lr_dist: float = 0.1          # eta_dist
    public_size: int = 1000       # |P|
    public_per_round: int = 100   # |P^t|
    private_size: int = 2000
    alpha: float = 0.05           # Dirichlet
    participation: float = 1.0    # p
    hidden: int = 64
    mlp_depth: int = 2
    cluster_scale: float = 3.0   # class-center spread (task difficulty)
    noise: float = 1.0           # within-class noise (task difficulty)
    seed: int = 0
    eval_every: int = 10
    # wire codecs (repro.compress specs, e.g. "quant8", "cache_delta+quant8");
    # "identity" keeps the legacy dense-fp32 payloads and ledger values
    uplink_codec: str = "identity"
    downlink_codec: str = "identity"
    # request-list/index entry width in bytes (comm.index_bytes_for picks
    # 2 for public datasets <= 65k samples; 4 is the legacy conservative
    # default that all pinned ledger values assume)
    index_bytes: float = 4.0
    # heterogeneous client-model cohorts (repro.fl.cohorts): a tuple of
    # CohortSpec whose sizes sum to n_clients, assigned to contiguous
    # cohort-major client blocks.  None = one homogeneous cohort built
    # from (hidden, mlp_depth) — bit-identical to the pre-cohort path.
    # Soft-label shapes are architecture-independent, so strategies,
    # codecs, and the comm ledger are unaffected by the mix.
    cohorts: Optional[Tuple[CohortSpec, ...]] = None
    # client-sharded engine (engine="shard"): mesh to partition the
    # client axis over — "auto" (the widest local device count that
    # divides every cohort block; n_clients when homogeneous),
    # "DATA"/"DATAxMODEL" (e.g. "8", "2x4"), or
    # "production[_multipod]"; see repro.fl.shard_engine.resolve_mesh.
    # Explicit specs require n_clients divisible by the data-axis size.
    mesh_spec: str = "auto"
    # opt-in fast path: run the round hot path (uplink codec round trip
    # + participation-weighted reduction + ERA sharpening) as one fused
    # Pallas kernel (repro.kernels.round_kernel) instead of the per-op
    # chain.  Scan/shard engines only; requires a fused-capable strategy
    # and a kernel-expressible uplink codec (identity / quantN /
    # cache_delta[+quantN]).  The host engine ignores the flag — it is
    # the per-op reference the fused path is validated against.
    fused_round: bool = False
    # private/test shard assignment: "dirichlet" (the paper's non-IID
    # label partition — the default every pinned ledger/metric assumes)
    # or "uniform" (vectorized round-robin split, O(n) with no Python
    # loop over clients — the only partition that is tractable at the
    # active-set engine's K = 10^6 benchmark scale).
    partition: str = "dirichlet"
    # opt-in device-plane telemetry (repro.obs): accumulate a
    # RoundTelemetry pytree (cache hit/miss census, participation and
    # staleness counters, payload bytes, teacher-entropy/beta gauges)
    # inside the round body of every engine.  Rides the lax.scan carry
    # on the device engines, so the run stays one XLA program with no
    # host callbacks.  Off (the default) leaves every engine's program
    # and golden ledger byte-identical to a build without the feature.
    telemetry: bool = False
