"""Traffic models: arrival processes, report latency, and churn.

:mod:`repro.fl.scenarios` answers *who is willing* each round —
participation draws, static outage windows, schedule heterogeneity.
This module answers *when work actually happens* in a production
deployment: whether a client is reachable inside a given aggregation
window (arrival process + membership churn), and how many windows later
its soft-label report lands (report latency).  It is the input layer of
the async/buffered engine (:mod:`repro.fl.async_engine`): a client
dispatched in round ``t_d`` trains against the cache as of ``t_d`` and
its report arrives — and is aggregated — at ``t_d + delay``.

Everything is precomputed on the host into fixed-shape ``(T, K)`` numpy
arrays (:meth:`TrafficModel.compile`), exactly like
``Scenario.offline_masks``: the scanned engine consumes one ``(K,)``
availability row and one ``(K,)`` delay row per round as scan inputs,
so the whole run stays a single XLA program with no host round trips.

Time model: one *round* is one aggregation window of ``window_ticks``
abstract ticks.  Arrival intensities are per tick; latencies are drawn
in ticks and floored to whole windows (``delay = ticks //
window_ticks``).  Widening the window is therefore the knob that trades
staleness for round progress: once ``window_ticks`` exceeds every
possible latency, all delays collapse to zero ("full windows") and the
async engine is **byte-identical** to the synchronous scan engine
(``tests/test_engine_conformance.py``).

Determinism: all draws for round ``t`` come from
``np.random.default_rng([seed, TRAFFIC_SALT, t])``, keyed by the
*absolute* round number — chained ``run()`` legs and checkpoint-resumed
runs see the identical traffic a single uninterrupted run would, which
is what makes split-vs-unsplit async runs bit-comparable
(``tests/test_traffic.py``).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import NamedTuple, Optional, Tuple

import numpy as np

__all__ = [
    "ArrivalProcess",
    "LatencyModel",
    "ChurnEvent",
    "TrafficModel",
    "CompiledTraffic",
    "TRAFFIC_SALT",
]

# rng stream namespace: keeps traffic draws disjoint from the engine's
# [seed, 17]/[seed, 29] numpy streams for any seed
TRAFFIC_SALT = 911


@dataclass(frozen=True)
class ArrivalProcess:
    """Per-window client-availability process.

    kind:
      ``always``   every client is reachable every window (no RNG).
      ``poisson``  each client contacts the server as a Poisson process
                   of intensity ``rate`` per tick; it is available in a
                   window iff at least one contact lands inside it,
                   i.e. with probability ``1 - exp(-rate * window)``.
      ``diurnal``  Poisson with sinusoidally modulated intensity
                   ``rate * (1 + amplitude * sin(2*pi*t / period))`` —
                   day/night load, ``period`` in windows.
    """

    kind: str = "always"
    rate: float = 1.0
    period: int = 24
    amplitude: float = 0.5

    def window_probability(self, t: int, window_ticks: int) -> float:
        """P(client available in window ``t``)."""
        if self.kind == "always":
            return 1.0
        lam = self.rate
        if self.kind == "diurnal":
            lam *= 1.0 + self.amplitude * math.sin(2.0 * math.pi * t / self.period)
            lam = max(lam, 0.0)
        elif self.kind != "poisson":
            raise ValueError(f"unknown arrival kind: {self.kind!r}")
        return 1.0 - math.exp(-lam * window_ticks)

    def sample(self, t: int, n_clients: int, window_ticks: int,
               rng: np.random.Generator) -> np.ndarray:
        p = self.window_probability(t, window_ticks)
        if p >= 1.0:
            return np.ones(n_clients, bool)
        return rng.random(n_clients) < p


@dataclass(frozen=True)
class LatencyModel:
    """Dispatch-to-arrival report latency, in ticks.

    kind:
      ``zero``       every report lands inside its dispatch window.
      ``fixed``      exactly ``ticks`` every time.
      ``uniform``    integer ticks uniform on ``[lo, hi]``.
      ``geometric``  ``P(ticks = n) = p * (1-p)**n`` for ``n >= 0`` —
                     a heavy straggler tail (unbounded support).
    """

    kind: str = "zero"
    ticks: int = 0
    lo: int = 0
    hi: int = 0
    p: float = 0.5

    def sample_ticks(self, n_clients: int,
                     rng: np.random.Generator) -> np.ndarray:
        if self.kind == "zero":
            return np.zeros(n_clients, np.int64)
        if self.kind == "fixed":
            if self.ticks < 0:
                raise ValueError(f"latency must be >= 0, got {self.ticks}")
            return np.full(n_clients, int(self.ticks), np.int64)
        if self.kind == "uniform":
            if not 0 <= self.lo <= self.hi:
                raise ValueError(
                    f"need 0 <= lo <= hi, got [{self.lo}, {self.hi}]")
            return rng.integers(self.lo, self.hi + 1, n_clients)
        if self.kind == "geometric":
            # numpy's geometric counts trials (support >= 1); shift to
            # the "number of failures" convention with support >= 0
            return rng.geometric(self.p, n_clients).astype(np.int64) - 1
        raise ValueError(f"unknown latency kind: {self.kind!r}")

    @property
    def max_ticks(self) -> Optional[int]:
        """Largest possible latency, or ``None`` when unbounded."""
        return {"zero": 0, "fixed": int(self.ticks),
                "uniform": int(self.hi)}.get(self.kind)


@dataclass(frozen=True)
class ChurnEvent:
    """Client ``client`` is a population member for rounds
    ``join..leave`` (1-based, inclusive; ``leave=None`` means forever).

    A client with at least one event exists only inside its windows —
    join/leave churn, the complement of :class:`repro.fl.scenarios.Outage`
    (which subtracts windows from an always-present client).  Clients
    with no events at all are members throughout.
    """

    client: int
    join: int = 1
    leave: Optional[int] = None

    def covers(self, t: int) -> bool:
        return self.join <= t and (self.leave is None or t <= self.leave)


class CompiledTraffic(NamedTuple):
    """Fixed-shape scan inputs for one batch of rounds.

    available: (T, K) bool  — client reachable in that window.
    delay:     (T, K) int32 — whole-window report delay if dispatched
                              in that window (drawn for every client;
                              the dispatch mask selects which are used).
    """

    available: np.ndarray
    delay: np.ndarray


@dataclass(frozen=True)
class TrafficModel:
    """Arrival process x latency x churn, compiled to scan inputs.

    The default model (always available, zero latency, no churn,
    unit window) is the synchronous regime: the async engine under it
    is byte-identical to ``engine="scan"``.
    """

    arrivals: ArrivalProcess = field(default_factory=ArrivalProcess)
    latency: LatencyModel = field(default_factory=LatencyModel)
    churn: Tuple[ChurnEvent, ...] = ()
    window_ticks: int = 1
    seed: int = 0

    def __post_init__(self):
        if int(self.window_ticks) < 1:
            raise ValueError(
                f"window_ticks must be >= 1, got {self.window_ticks}")

    @property
    def is_synchronous(self) -> bool:
        """True when every report provably lands in its dispatch window
        (max latency fits the aggregation window) — the regime where the
        async ledger is proven byte-identical to ``engine="scan"``."""
        mt = self.latency.max_ticks
        return mt is not None and mt // int(self.window_ticks) == 0

    def member_mask(self, t: int, n_clients: int) -> np.ndarray:
        """(K,) population membership at round ``t`` under churn."""
        has_event = np.zeros(n_clients, bool)
        member = np.zeros(n_clients, bool)
        for e in self.churn:
            has_event[e.client] = True
            if e.covers(t):
                member[e.client] = True
        return member | ~has_event

    def compile(self, n_rounds: int, n_clients: int,
                start: int = 1) -> CompiledTraffic:
        """``(T, K)`` availability + delay arrays for rounds
        ``start..start+n_rounds-1`` (``start > 1`` for chained or
        checkpoint-resumed runs — absolute-round keying makes the
        result a row slice of the full-run compile)."""
        available = np.zeros((n_rounds, n_clients), bool)
        delay = np.zeros((n_rounds, n_clients), np.int32)
        w = int(self.window_ticks)
        for i, t in enumerate(range(start, start + n_rounds)):
            rng = np.random.default_rng([int(self.seed), TRAFFIC_SALT, int(t)])
            arr = self.arrivals.sample(t, n_clients, w, rng)
            available[i] = arr & self.member_mask(t, n_clients)
            ticks = self.latency.sample_ticks(n_clients, rng)
            delay[i] = (ticks // w).astype(np.int32)
        return CompiledTraffic(available=available, delay=delay)
