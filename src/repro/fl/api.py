"""Front door: run one FL method end-to-end."""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from repro.fl.active_engine import ActiveSetFederatedDistillation
from repro.fl.async_engine import AsyncFederatedDistillation
from repro.fl.baselines import FedAvg, Individual
from repro.fl.cohorts import CohortSpec
from repro.fl.config import FLConfig
from repro.fl.rounds import FederatedDistillation, History
from repro.fl.scan_engine import ScannedFederatedDistillation
from repro.fl.scenarios import Scenario
from repro.fl.shard_engine import ShardedFederatedDistillation
from repro.fl.strategies import STRATEGIES
from repro.fl.traffic import TrafficModel

_ENGINES = {
    "host": FederatedDistillation,
    "scan": ScannedFederatedDistillation,
    "shard": ShardedFederatedDistillation,
    "active": ActiveSetFederatedDistillation,
    "async": AsyncFederatedDistillation,
}

__all__ = ["run_method"]


def run_method(
    method: str,
    cfg: FLConfig,
    *,
    cache_duration: int = 0,
    use_cache: Optional[bool] = None,
    rounds: Optional[int] = None,
    probabilistic_expiry: bool = False,
    scenario: Optional[Scenario] = None,
    track_local_caches: bool = False,
    engine: str = "host",
    rng_backend: Optional[str] = None,
    codec: Optional[str] = None,
    downlink_codec: Optional[str] = None,
    cohorts: Optional[Sequence[CohortSpec]] = None,
    fused_round: Optional[bool] = None,
    telemetry: Optional[bool] = None,
    traffic: Optional[TrafficModel] = None,
    **strategy_kw,
) -> History:
    """Run one FL method end-to-end and return its History.

    method in {scarlet, dsfl, cfd, comet, selective_fd, mean, fedavg,
    individual}.  ``cache_duration``>0 with ``use_cache=True`` plugs the
    soft-label cache into any distillation method (paper Fig. 11).
    ``scenario`` composes participation sampling, client outages, and
    heterogeneous schedules onto any distillation strategy (scenarios
    are ignored by the fedavg/individual baselines).

    ``engine="scan"`` runs the device-resident fused multi-round engine
    (one ``lax.scan`` program, zero per-round host round-trips; see
    :mod:`repro.fl.scan_engine`); ``engine="shard"`` additionally
    partitions the client axis over the ``cfg.mesh_spec`` device mesh
    (:mod:`repro.fl.shard_engine` — client counts beyond one chip's
    memory); ``engine="active"`` keeps client state in a host-side
    (optionally memory-mapped) store and runs only each round's active
    participants on device (:mod:`repro.fl.active_engine` — million-
    client populations at O(m) device cost, same byte-exact ledger);
    ``engine="async"`` runs buffered aggregation under a traffic model
    (:mod:`repro.fl.async_engine` — clients dispatch, train against
    possibly-stale caches, and report late; the server aggregates
    whatever arrived each window with optional staleness decay via the
    ``staleness_decay`` strategy option); ``engine="host"`` is the
    reference Python round loop.  ``rng_backend="jax"`` makes the host
    loop draw subsets/participation from the scanned engines' key
    stream so all engines are directly comparable.

    ``traffic`` (a :class:`repro.fl.traffic.TrafficModel`) supplies the
    async engine's arrival/latency/churn processes; it applies to
    ``engine="async"`` only.  Omitted, the async engine runs the
    synchronous default model (byte-identical to ``engine="scan"``).

    ``codec`` (uplink) / ``downlink_codec`` select soft-label wire
    codecs (:mod:`repro.compress` specs, e.g. ``"quant8"``,
    ``"cache_delta+quant8"``) — shorthand for setting the corresponding
    ``FLConfig`` fields; the ledger switches to the codec's analytic
    payload accounting on that direction.

    ``cohorts`` (a sequence of :class:`repro.fl.CohortSpec`, shorthand
    for ``FLConfig.cohorts``) gives clients heterogeneous model
    architectures — the distillation methods exchange only soft-labels,
    so any strategy/codec/engine combination runs unchanged over a
    cohort mix.  Parameter-sharing baselines (fedavg) and the
    no-collaboration baseline reject cohorts: they assume the single
    homogeneous ``(hidden, mlp_depth)`` model.

    ``fused_round`` (shorthand for ``FLConfig.fused_round``) opts the
    scan/shard engines into the fused round hot path
    (:mod:`repro.kernels.round_kernel`): uplink codec round trip +
    masked aggregation + sharpening in one Pallas kernel.  The host
    engine ignores it — it is the per-op reference the fused path is
    validated against.

    ``telemetry`` (shorthand for ``FLConfig.telemetry``) opts the run
    into device-plane telemetry (:mod:`repro.obs`): the returned
    ``History.telemetry`` holds one
    :class:`~repro.obs.device.RoundTelemetry` row per round (cache
    hit/miss census, staleness, payload bytes, entropy/beta gauges),
    accumulated inside the compiled round body on every engine.  The
    baselines reject it — there is no distillation round to instrument.
    """
    if engine not in _ENGINES:
        raise ValueError(f"unknown engine: {engine!r} "
                         f"(want one of {sorted(_ENGINES)})")
    if traffic is not None and engine != "async":
        raise ValueError("traffic models apply to engine='async' only "
                         "(the synchronous engines have no dispatch/"
                         "arrival split)")
    if codec is not None:
        cfg = dataclasses.replace(cfg, uplink_codec=codec)
    if downlink_codec is not None:
        cfg = dataclasses.replace(cfg, downlink_codec=downlink_codec)
    if cohorts is not None:
        cfg = dataclasses.replace(cfg, cohorts=tuple(cohorts))
    if fused_round is not None:
        cfg = dataclasses.replace(cfg, fused_round=fused_round)
    if telemetry is not None:
        cfg = dataclasses.replace(cfg, telemetry=telemetry)
    if method in ("fedavg", "individual"):
        if cfg.cohorts:
            raise ValueError(
                f"{method} assumes the homogeneous (hidden, mlp_depth) "
                "model; client-model cohorts only apply to "
                "distillation-based methods")
        if engine != "host":
            raise ValueError(f"{method} is a baseline with no scanned/sharded "
                             "path; use engine='host'")
        if rng_backend is not None:
            raise ValueError(f"{method} has no rng_backend knob (baselines "
                             "draw nothing from the round key stream)")
        if cfg.uplink_codec != "identity" or cfg.downlink_codec != "identity":
            raise ValueError(f"{method} exchanges parameters, not "
                             "soft-labels; codecs do not apply")
        if cfg.telemetry:
            raise ValueError(f"{method} has no distillation round to "
                             "instrument; telemetry applies to "
                             "distillation-based methods only")
        cls = FedAvg if method == "fedavg" else Individual
        return cls(cfg).run(rounds)
    strat = STRATEGIES[method](**strategy_kw)
    cls = _ENGINES[engine]
    kw = dict(cache_duration=cache_duration,
              use_cache=use_cache,
              probabilistic_expiry=probabilistic_expiry,
              scenario=scenario,
              track_local_caches=track_local_caches)
    if rng_backend is not None:
        kw["rng_backend"] = rng_backend
    if traffic is not None:
        kw["traffic"] = traffic
    return cls(cfg, strat, **kw).run(rounds)
