"""Front door: run one FL method end-to-end."""
from __future__ import annotations

from typing import Optional

from repro.fl.baselines import FedAvg, Individual
from repro.fl.config import FLConfig
from repro.fl.rounds import FederatedDistillation, History
from repro.fl.scenarios import Scenario
from repro.fl.strategies import STRATEGIES

__all__ = ["run_method"]


def run_method(
    method: str,
    cfg: FLConfig,
    *,
    cache_duration: int = 0,
    use_cache: Optional[bool] = None,
    rounds: Optional[int] = None,
    probabilistic_expiry: bool = False,
    scenario: Optional[Scenario] = None,
    track_local_caches: bool = False,
    **strategy_kw,
) -> History:
    """Run one FL method end-to-end and return its History.

    method in {scarlet, dsfl, cfd, comet, selective_fd, mean, fedavg,
    individual}.  ``cache_duration``>0 with ``use_cache=True`` plugs the
    soft-label cache into any distillation method (paper Fig. 11).
    ``scenario`` composes participation sampling, client outages, and
    heterogeneous schedules onto any distillation strategy (scenarios
    are ignored by the fedavg/individual baselines).
    """
    if method == "fedavg":
        return FedAvg(cfg).run(rounds)
    if method == "individual":
        return Individual(cfg).run(rounds)
    strat = STRATEGIES[method](**strategy_kw)
    return FederatedDistillation(cfg, strat, cache_duration=cache_duration,
                                 use_cache=use_cache,
                                 probabilistic_expiry=probabilistic_expiry,
                                 scenario=scenario,
                                 track_local_caches=track_local_caches).run(rounds)
