"""Active-set engine: million-client populations, O(m) device compute.

SCARLET's evaluation (like DS-FL's) samples a small fraction of clients
per round, yet every dense engine materializes a K-stacked parameter
pytree on device, so the population is bounded by accelerator memory —
exactly the gap between simulation scale and production-scale federated
distillation, where per-round cost is driven by the m participants, not
the population (Sattler et al. 2020).  This engine removes that bound:

- **client state lives on the host** in a
  :class:`repro.checkpoint.ClientParamStore` (plain numpy or
  memory-mapped files; optionally persisted in row-sharded npz files) —
  per-client data shards, masks, and schedules stay host-side numpy via
  the ``rounds.py`` placement hooks;
- **each round draws participation over the full K** from the *exact*
  device key stream the dense engines fold
  (``fold_in(key_rounds, t)`` -> ``split`` -> subset choice /
  ``scenarios.participation_mask_device``), so the participation and
  request-list draws — and therefore the comm ledger — match the dense
  engines byte-for-byte;
- **only the m active clients are gathered** into a device stack
  (padded to the next power of two so jit signatures stay few), the
  scan-engine round body runs on that stack, and updated rows scatter
  back to the store;
- **O(K)-but-tiny bookkeeping stays on device**: ``last_sync``,
  participation counters, and catch-up byte accounting run as one small
  jitted step over ``(K,)`` integer arrays
  (``cache.catch_up_bytes_device(method="sorted")`` — the O(K + |P|)
  counting kernel that never materializes the dense engines' (K, |P|)
  comparison matrix), which is what keeps the ledger exact at K = 10^6.

Parity contract (``tests/test_engine_conformance.py``): every ledger
input is an exact small-integer count (participants, misses, catch-up
entry counts), evaluated by the same
``comm.distillation_round_cost_device`` expression the scan engine
traces — so active ledgers are **byte-identical** to scan/shard and
float32-exact against the host loop.  Metrics and cache values agree to
float reduction order (the gathered stack sums m rows where the dense
engines sum K mostly-masked rows).  One documented exception:
Selective-FD's fractional per-client upload average is a float
reduction over the stack, so its ledger is allclose, not byte-equal —
the same caveat the scan engine's ``um`` path already carries.

Restore-then-continue is bit-identical (``tests/test_checkpoint.py``):
``state_dict()`` reassembles the dense ``client_params`` structure from
the store, rounds are numbered absolutely, and the key stream is keyed
by absolute round.

``repro.analysis.active_checks`` proves the split at trace time: the
gathered client step's jaxpr must contain **no K-sized array** (the
O(K) bookkeeping may never leak into the O(m) compute), and both jitted
steps must be scan-safe (no host callbacks / host RNG).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.store import ClientParamStore
from repro.core import cache as cache_lib
from repro.core import comm as comm_lib
from repro.kernels import round_kernel
from repro.obs import device as obs_device
from repro.fl.scan_engine import ScannedFederatedDistillation
from repro.fl.rounds import (
    FederatedDistillation,
    History,
    accuracy,
    accuracy_v,
    distill,
    distill_v,
    local_train_masked_v,
    local_train_v,
    predict_v,
    val_loss_hard_v,
    val_loss_soft,
)
from repro.fl.strategies.base import TRANSMIT_SALT

__all__ = ["ActiveSetFederatedDistillation"]


def _next_pow2(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length() if n > 1 else 1


class ActiveSetFederatedDistillation(ScannedFederatedDistillation):
    """Active-set twin of the scan engine: host-resident client store,
    O(m) gathered device compute, byte-exact O(K) ledger bookkeeping.

    Same constructor as the dense engines plus the store knobs:
    ``store_backing`` (``"ram"`` | ``"memmap"``), ``store_dir`` (backing
    directory, required for memmap), ``init_chunk`` (clients initialised
    per device call), ``eval_chunk`` (clients evaluated per device call
    on eval rounds — eval is the one remaining O(K) *compute* pass, run
    chunked on the ``eval_every`` schedule only).
    """

    def __init__(self, *args, store_backing: str = "ram",
                 store_dir: Optional[str] = None, init_chunk: int = 65536,
                 eval_chunk: int = 4096, **kwargs):
        self._store_backing = store_backing
        self._store_dir = store_dir
        self._init_chunk = init_chunk
        self._eval_chunk = eval_chunk
        self._last_sync_dev = None
        super().__init__(*args, **kwargs)
        self._client_step_jit = jax.jit(self._client_step)
        self._bookkeeping_jit = jax.jit(self._bookkeeping_step)

    # ------------------------------------------------------------------
    # Placement hooks (rounds.py): per-client state stays host numpy.
    # ------------------------------------------------------------------
    def _client_array(self, x):
        return np.asarray(x)

    def _eval_array(self, x):
        return np.asarray(x)

    def _init_client_params(self, keys) -> None:
        self._store = ClientParamStore(
            self.models, keys, backing=self._store_backing,
            directory=self._store_dir, init_chunk=self._init_chunk)

    # client_params stays the dense engines' list-of-stacked-pytrees
    # view (numpy leaves), reassembled from / ingested into the store —
    # the shared state_dict()/load_state_dict() plumbing works unchanged.
    @property
    def client_params(self) -> List[Any]:
        return self._store.as_param_list()

    @client_params.setter
    def client_params(self, value) -> None:
        self._store.ingest_param_list(value)

    @property
    def store(self) -> ClientParamStore:
        return self._store

    # ------------------------------------------------------------------
    def run(self, rounds: Optional[int] = None) -> History:
        # host round loop (the store gather/scatter is inherently
        # host-paced); each round launches the two jitted steps below
        return FederatedDistillation.run(self, rounds)

    # ------------------------------------------------------------------
    def _get_last_sync_dev(self) -> jnp.ndarray:
        if self._last_sync_dev is None:
            self._last_sync_dev = jnp.asarray(self.last_sync, jnp.int32)
        return self._last_sync_dev

    # ------------------------------------------------------------------
    # O(K) bookkeeping step: tiny integer arrays, one jitted program.
    # ------------------------------------------------------------------
    def _bookkeeping_step(self, cache_prev, last_sync, part, t) -> Dict:
        catch_up = jnp.float32(0.0)
        if self.use_cache:
            # sorted counting kernel: same integer counts (and therefore
            # the same f32 total) as the dense engines' (K, |P|) matrix,
            # in O(K + |P| log |P|) memory
            catch_up = cache_lib.catch_up_bytes_device(
                cache_prev, last_sync, part, t, method="sorted")
        out = dict(catch_up=catch_up,
                   last_sync=jnp.where(part, t, last_sync))
        if self._telemetry:
            out["participants"] = obs_device.participants_per_cohort(
                part, self.models.offsets, self.models.sizes)
            out["catch_up_clients"] = obs_device.returning_client_count(
                part, last_sync, t)
            out["staleness_hist"] = obs_device.staleness_histogram(
                part, last_sync, t)
        return out

    # ------------------------------------------------------------------
    # Gather plan: per cohort, the active row indices (ascending, so the
    # concatenated cohort-major stack is in global client order — the
    # same participant order the dense engines' z_all[part] would see),
    # padded to the next power of two with duplicates of the first
    # active row.  Padding rows carry validity False and therefore
    # exactly-zero aggregation weight; they train redundantly and are
    # dropped at scatter.
    # ------------------------------------------------------------------
    def _gather_plan(self, part: np.ndarray) -> List[Tuple[int, np.ndarray,
                                                           np.ndarray]]:
        plan = []
        for ci, sl in enumerate(self.models.slices):
            rows = np.nonzero(part[sl])[0]
            if len(rows) == 0:
                continue
            cap = _next_pow2(len(rows))
            pad = np.concatenate(
                [rows, np.full(cap - len(rows), rows[0], rows.dtype)])
            plan.append((ci, rows, pad))
        return plan

    def _build_step_args(self, t: int, idx: np.ndarray, plan,
                         catch_up) -> Dict:
        c = self.cfg
        args: Dict[str, Any] = dict(
            t=jnp.asarray(t, jnp.int32),
            idx=jnp.asarray(idx),
            catch_up=jnp.asarray(catch_up, jnp.float32),
            server_params=self.server_params,
            cache=self.cache_g,
            params=[], xs=[], ys=[], train_mask=[], pv=[],
        )
        het = self.scenario.heterogeneity is not None
        if het:
            args["lr_k"], args["steps_k"] = [], []
        for ci, rows, pad in plan:
            args["params"].append(self._store.gather(ci, pad))
            args["xs"].append(jnp.asarray(self.xs_c[ci][pad]))
            args["ys"].append(jnp.asarray(self.ys_c[ci][pad]))
            args["train_mask"].append(
                jnp.asarray(self.train_mask_c[ci][pad]))
            pv = np.zeros(len(pad), bool)
            pv[: len(rows)] = True
            args["pv"].append(jnp.asarray(pv))
            if het:
                args["lr_k"].append(jnp.asarray(self._lr_k_c[ci][pad]))
                args["steps_k"].append(jnp.asarray(self._steps_k_c[ci][pad]))
        if self.prev_teacher is not None:
            pidx, pteach = self.prev_teacher
            args["prev_idx"] = jnp.asarray(pidx)
            args["prev_teacher"] = jnp.asarray(pteach)
        return args

    # ------------------------------------------------------------------
    # O(m) client step: the scan-engine round body on the gathered
    # stack.  Every row with pv=True is a participant, so there is no
    # participation select — padding rows compute redundantly (weight
    # exactly 0.0 in every reduction) and never scatter back.
    # ------------------------------------------------------------------
    def _client_step(self, args: Dict) -> Dict:
        c, s = self.cfg, self.strategy
        t, idx = args["t"], args["idx"]
        kt = jax.random.fold_in(self._key_rounds, t)
        params = args["params"]

        # --- clients: distill on previous teacher, then local training
        if "prev_teacher" in args:
            x_prev = self.x_pub[args["prev_idx"]]
            pteach = args["prev_teacher"]
            params = [
                distill_v(p, x_prev,
                          jnp.broadcast_to(pteach,
                                           (pv.shape[0],) + pteach.shape),
                          c.lr_dist, c.distill_steps)
                for p, pv in zip(params, args["pv"])]
        if self.scenario.heterogeneity is None:
            params = [
                local_train_v(p, x, y, m.astype(jnp.float32),
                              c.lr, c.local_steps)
                for p, x, y, m in zip(params, args["xs"], args["ys"],
                                      args["train_mask"])]
        else:
            decay = jnp.asarray(self._lr_decay, jnp.float32) ** (
                jnp.asarray(t, jnp.float32) - 1.0)
            params = [
                local_train_masked_v(p, x, y, m.astype(jnp.float32),
                                     lr * decay, st, self._max_steps)
                for p, x, y, m, lr, st in zip(
                    params, args["xs"], args["ys"], args["train_mask"],
                    args["lr_k"], args["steps_k"])]

        # --- request list (cache) -------------------------------------
        cache_prev = cache_lib.CacheState(*args["cache"])
        if self.use_cache:
            key_exp = (jax.random.fold_in(jax.random.PRNGKey(c.seed), t)
                       if self.probabilistic_expiry else None)
            miss = cache_lib.miss_mask(cache_prev, idx, t, self.D,
                                       probabilistic=self.probabilistic_expiry,
                                       key=key_exp)
        else:
            miss = jnp.ones(c.public_per_round, bool)
        miss_f = miss.astype(jnp.float32)
        n_req = jnp.sum(miss_f)
        base, base_present = cache_lib.cached_at(cache_prev, idx)

        # --- uplink + aggregation over the gathered stack -------------
        x_round = self.x_pub[idx]
        zs = [predict_v(p, x_round) for p in params]
        z_all = zs[0] if len(zs) == 1 else jnp.concatenate(zs, axis=0)
        z_all = s.transmit(z_all, jax.random.fold_in(kt, TRANSMIT_SALT))
        z_tx = z_all
        pv_all = (args["pv"][0] if len(args["pv"]) == 1
                  else jnp.concatenate(args["pv"]))
        pv_f = pv_all.astype(jnp.float32)
        n_part = jnp.sum(pv_f)
        if self._fused:
            um = s.upload_mask(z_all)
            fbase = (round_kernel.resolve_delta_base(
                         base, base_present, c.public_per_round, c.n_classes)
                     if self._fused_spec["mode"] == "delta" else None)
            fresh = s.aggregate_masked_fused(z_all, pv_f,
                                             self._fused_spec, fbase, t)
        else:
            if not self.codec_up.is_identity:
                z_all = self.codec_up.roundtrip(z_all, base=base,
                                                present=base_present)
            um = s.upload_mask(z_all)
            fresh = s.aggregate_masked(z_all, pv_f, um, t)
        if not self.codec_down.is_identity:
            fresh = self.codec_down.roundtrip(fresh, base=base,
                                              present=base_present)

        # --- assemble teacher + cache update --------------------------
        cache = cache_prev
        if self.use_cache:
            teacher = cache_lib.assemble_teacher(cache_prev, idx, fresh, miss)
            cache, _ = cache_lib.update_global_cache(
                cache_prev, idx, teacher, miss, t)
        else:
            teacher = fresh

        # --- server distillation --------------------------------------
        server_params = distill(args["server_params"], x_round, teacher,
                                c.lr_dist, c.distill_steps)

        # --- communication accounting: the scan engine's expression,
        # evaluated on the identical integer-derived inputs ------------
        n_up = n_req
        if um is not None:  # Selective-FD (float average; allclose only)
            uploaded_total = jnp.sum(
                um.astype(jnp.float32) * pv_f[:, None] * miss_f[None, :])
            n_up = uploaded_total / jnp.maximum(n_part, 1.0)
        uplink, downlink = comm_lib.distillation_round_cost_device(
            n_clients=n_part,
            n_selected=float(c.public_per_round),
            n_up_samples=n_up,
            n_down_samples=n_req,
            n_classes=c.n_classes,
            uplink_bits=s.uplink_bits,
            downlink_bits=s.downlink_bits,
            with_cache_signals=self.use_cache,
            catch_up_down=args["catch_up"],
            bytes_index=c.index_bytes,
            uplink_codec=self.codec_up,
            downlink_codec=self.codec_down,
        )

        out = dict(client_params=params, server_params=server_params,
                   cache=cache, teacher=teacher,
                   uplink=uplink, downlink=downlink)
        if self._telemetry:
            hits, new, expired = obs_device.cache_signal_counts(
                base_present, miss)
            z_srv = z_all
            if self._fused and not self.codec_up.is_identity:
                z_srv = self.codec_up.roundtrip(z_tx, base=base,
                                                present=base_present)
            if self.codec_up.is_identity:
                cerr = jnp.float32(0.0)
            else:
                cerr = obs_device.codec_error_mean(z_srv, z_tx, pv_f, n_part)
            zbar = obs_device.participant_mean(z_srv, pv_f, n_part)
            out.update(
                cache_hits=hits, cache_miss_new=new, cache_expired=expired,
                teacher_entropy_pre=obs_device.mean_entropy(zbar),
                teacher_entropy_post=obs_device.mean_entropy(fresh),
                beta=jnp.asarray(s.sharpen_gauge(zbar, t), jnp.float32),
                codec_quant_error=cerr)
        return out

    # ------------------------------------------------------------------
    def _round(self, t: int, hist: History) -> None:
        part, idx = self._draw_round(t)
        n_part = int(part.sum())
        if n_part == 0:  # total outage: nothing moves, the cache ages
            hist.ledger.record(comm_lib.RoundCost(0.0, 0.0))
            if self._telemetry:
                hist.telemetry.append(obs_device.zeros(self.models.n_cohorts))
            return

        book = self._bookkeeping_jit(self.cache_g, self._get_last_sync_dev(),
                                     jnp.asarray(part),
                                     jnp.asarray(t, jnp.int32))
        plan = self._gather_plan(part)
        args = self._build_step_args(t, idx, plan, book["catch_up"])
        out = self._client_step_jit(args)

        # scatter the valid (non-padding) rows back into the store
        for (ci, rows, _pad), new_p in zip(plan, out["client_params"]):
            n = len(rows)
            self._store.scatter(
                ci, rows,
                jax.tree_util.tree_map(lambda a: a[:n], new_p))
        self.server_params = out["server_params"]
        if self.use_cache:
            self.cache_g = cache_lib.CacheState(*out["cache"])
        self.prev_teacher = (idx, out["teacher"])

        hist.ledger.record(comm_lib.RoundCost(float(out["uplink"]),
                                              float(out["downlink"])))
        if self._telemetry:
            tel = obs_device.RoundTelemetry(
                participants=book["participants"],
                cache_hits=out["cache_hits"],
                cache_miss_new=out["cache_miss_new"],
                cache_expired=out["cache_expired"],
                catch_up_clients=book["catch_up_clients"],
                staleness_hist=book["staleness_hist"],
                uplink_bytes=jnp.asarray(out["uplink"], jnp.float32),
                downlink_bytes=jnp.asarray(out["downlink"], jnp.float32),
                catch_up_bytes=jnp.asarray(book["catch_up"], jnp.float32),
                teacher_entropy_pre=out["teacher_entropy_pre"],
                teacher_entropy_post=out["teacher_entropy_post"],
                beta=out["beta"],
                codec_quant_error=out["codec_quant_error"])
            if self.telemetry_hook is not None:
                tel = self.telemetry_hook(tel, t)
            hist.telemetry.append(tel)
        self._last_sync_dev = book["last_sync"]
        self.last_sync[part] = t

    # ------------------------------------------------------------------
    # Eval + the App.-D proxy teacher: the remaining O(K) compute,
    # chunked through the store on the eval schedule only.
    # ------------------------------------------------------------------
    def _iter_chunks(self):
        for ci in range(self.models.n_cohorts):
            size = self.models.sizes[ci]
            for lo in range(0, size, self._eval_chunk):
                hi = min(lo + self._eval_chunk, size)
                rows = np.arange(lo, hi)
                yield ci, rows, self._store.gather(ci, rows)

    def _teacher_val_full(self) -> jnp.ndarray:
        """Population-mean soft labels on the public validation split —
        the dense engines' ``last_teacher_val``, recomputed lazily from
        current params (it is a pure function of them) instead of every
        round: one chunked O(K) pass at eval/checkpoint time."""
        x_val = self.x_pub[self.pub_val_idx]
        total = jnp.zeros((len(self.pub_val_idx), self.cfg.n_classes),
                          jnp.float32)
        for _ci, _rows, p in self._iter_chunks():
            total = total + jnp.sum(predict_v(p, x_val), axis=0)
        return total / self.cfg.n_clients

    def _eval(self, t: int, hist: History) -> None:
        sa = float(accuracy(self.server_params, jnp.asarray(self.x_test),
                            jnp.asarray(self.y_test),
                            jnp.ones(len(self.y_test))))
        accs = [[] for _ in range(self.models.n_cohorts)]
        vls = []
        for ci, rows, p in self._iter_chunks():
            accs[ci].append(np.asarray(accuracy_v(
                p, jnp.asarray(self.xts_c[ci][rows]),
                jnp.asarray(self.yts_c[ci][rows]),
                jnp.asarray(self.tmask_c[ci][rows], jnp.float32))))
            vls.append(np.asarray(val_loss_hard_v(
                p, jnp.asarray(self.xs_c[ci][rows]),
                jnp.asarray(self.ys_c[ci][rows]),
                jnp.asarray(self.val_mask_c[ci][rows], jnp.float32))))
        accs = [np.concatenate(a) for a in accs]
        hist.rounds.append(t)
        hist.server_acc.append(sa)
        hist.client_acc.append(float(np.mean(np.concatenate(accs))))
        hist.cohort_client_acc.append([float(np.mean(a)) for a in accs])
        hist.cumulative_mb.append(hist.ledger.cumulative_total / 1e6)
        if self.prev_teacher is not None:
            self.last_teacher_val = self._teacher_val_full()
            hist.server_val_loss.append(float(val_loss_soft(
                self.server_params, self.x_pub[self.pub_val_idx],
                self.last_teacher_val)))
        hist.client_val_loss.append(float(np.mean(np.concatenate(vls))))

    # ------------------------------------------------------------------
    # Checkpointing: the shared plumbing works on the store-backed
    # client_params property; teacher_val is recomputed at save time.
    # ------------------------------------------------------------------
    def state_dict(self) -> Dict[str, Any]:
        self.last_teacher_val = (self._teacher_val_full()
                                 if self.prev_teacher is not None else None)
        return super().state_dict()

    def load_state_dict(self, state: Dict[str, Any]) -> None:
        super().load_state_dict(state)
        self._last_sync_dev = jnp.asarray(self.last_sync, jnp.int32)

    # ------------------------------------------------------------------
    # Analyzer entry (repro.analysis.active_checks): the two jitted
    # round-body functions with concrete example arguments, for
    # trace-time scan-safety + K-separation proofs.
    # ------------------------------------------------------------------
    def active_round_fns(self):
        """``[(label, fn, example_args), ...]`` for the bookkeeping and
        gathered client steps (args are concrete; the analyzer traces on
        their shapes)."""
        c = self.cfg
        part, idx = self._draw_round(1)
        if part.sum() == 0:
            part = part.copy()
            part[: min(2, len(part))] = True
        book_args = (self.cache_g, self._get_last_sync_dev(),
                     jnp.asarray(part), jnp.asarray(1, jnp.int32))
        plan = self._gather_plan(part)
        # force the distillation branch so the traced graph covers the
        # full round body (round 1 has no previous teacher)
        saved = self.prev_teacher
        self.prev_teacher = (np.zeros(c.public_per_round, np.int32),
                             jnp.zeros((c.public_per_round, c.n_classes),
                                       jnp.float32))
        try:
            step_args = self._build_step_args(1, idx, plan,
                                              jnp.float32(0.0))
        finally:
            self.prev_teacher = saved
        return [("bookkeeping", self._bookkeeping_step, book_args),
                ("client-step", self._client_step, (step_args,))]
