"""Client scenarios: orthogonal behaviors composable onto any strategy.

A :class:`Scenario` answers, for each round ``t``: which clients
participate, and with what local-training schedule.  It is deliberately
orthogonal to the :class:`~repro.fl.strategies.Strategy` axis (how
soft-labels are aggregated): any scenario runs against any strategy, so
a participation/straggler sweep over all six methods is a plain product
of the two registries.

Three orthogonal knobs:

- **Participation** — per-round client sampling: ``full`` (everyone),
  ``fraction`` (exactly ``max(round(rate*K), 1)`` clients, the paper's
  partial-participation model), or ``bernoulli`` (each client joins
  independently with probability ``rate``, so the per-round cohort size
  itself is random).
- **Outages** — deterministic offline windows per client (dropouts /
  stragglers).  A client inside an outage window never participates;
  when the window ends and it is sampled again, the engine sends it a
  cache catch-up package (Section III-D), which is exactly the path
  these masks exist to exercise.
- **Heterogeneity** — per-client local-step counts and learning-rate
  scales (plus an optional global per-round lr decay).  The engine
  keeps the client axis fully vmapped: heterogeneous schedules run as
  one jitted program over stacked params with per-client step masks,
  not as a Python loop over clients.

Sampling uses a dedicated numpy Generator owned by the engine (separate
from the public-subset stream), so two runs that differ only in their
scenario still select identical public subsets ``P^t`` — that is what
makes communication ledgers comparable across scenarios, and what the
"partial never exceeds full uplink" property test relies on.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "Participation",
    "Outage",
    "Heterogeneity",
    "Scenario",
    "full_participation",
    "fixed_fraction",
    "bernoulli_participation",
]


@dataclass(frozen=True)
class Participation:
    """Per-round client-sampling policy.

    kind:
      ``full``       every client, every round (no RNG consumed).
      ``fraction``   exactly ``max(round(rate*K), 1)`` clients, sampled
                     uniformly without replacement (paper Alg. 1).
      ``bernoulli``  each client independently with probability ``rate``.
    """

    kind: str = "full"
    rate: float = 1.0

    def sample(self, n_clients: int, rng: np.random.Generator) -> np.ndarray:
        if self.kind == "full":
            return np.ones(n_clients, bool)
        if self.kind == "fraction":
            n = min(max(int(round(self.rate * n_clients)), 1), n_clients)
            mask = np.zeros(n_clients, bool)
            mask[rng.choice(n_clients, n, replace=False)] = True
            return mask
        if self.kind == "bernoulli":
            return rng.random(n_clients) < self.rate
        raise ValueError(f"unknown participation kind: {self.kind!r}")

    def sample_device(self, key: jnp.ndarray, n_clients: int) -> jnp.ndarray:
        """jit/scan-safe twin of :meth:`sample` on a jax PRNG key.

        Same policy semantics, different (jax) RNG stream: runs with
        ``rng_backend="jax"`` draw from this stream both in the host
        loop and inside the scanned engine, which is what makes the two
        engines bit-comparable.
        """
        if self.kind == "full":
            return jnp.ones(n_clients, bool)
        if self.kind == "fraction":
            n = min(max(int(round(self.rate * n_clients)), 1), n_clients)
            sel = jax.random.choice(key, n_clients, (n,), replace=False)
            return jnp.zeros(n_clients, bool).at[sel].set(True)
        if self.kind == "bernoulli":
            return jax.random.uniform(key, (n_clients,)) < self.rate
        raise ValueError(f"unknown participation kind: {self.kind!r}")


def full_participation() -> "Participation":
    return Participation("full")


def fixed_fraction(rate: float) -> "Participation":
    return Participation("fraction", rate)


def bernoulli_participation(rate: float) -> "Participation":
    return Participation("bernoulli", rate)


@dataclass(frozen=True)
class Outage:
    """Client ``client`` is offline for rounds ``start..end`` (1-based,
    inclusive).  Overrides any participation draw for those rounds."""

    client: int
    start: int
    end: int

    def covers(self, t: int) -> bool:
        return self.start <= t <= self.end


@dataclass(frozen=True)
class Heterogeneity:
    """Per-client local-training schedules.

    ``local_steps[k]``: client k's local epoch count E_k (defaults to the
    config's homogeneous ``local_steps``).  ``lr_scale[k]`` multiplies
    the config lr for client k.  ``lr_decay`` applies a global
    ``decay**(t-1)`` factor each round.  Any field left ``None`` falls
    back to the homogeneous config value.
    """

    local_steps: Optional[Tuple[int, ...]] = None
    lr_scale: Optional[Tuple[float, ...]] = None
    lr_decay: float = 1.0

    def resolve(self, n_clients: int, base_lr: float,
                base_steps: int) -> Tuple[np.ndarray, np.ndarray, int]:
        """-> (lr_k (K,), steps_k (K,), max_steps)."""
        steps = (np.full(n_clients, base_steps, np.int32)
                 if self.local_steps is None
                 else np.asarray(self.local_steps, np.int32))
        scale = (np.ones(n_clients, np.float32)
                 if self.lr_scale is None
                 else np.asarray(self.lr_scale, np.float32))
        if steps.shape != (n_clients,) or scale.shape != (n_clients,):
            raise ValueError("heterogeneity schedules must have one entry "
                             f"per client ({n_clients})")
        return base_lr * scale, steps, int(steps.max())


@dataclass(frozen=True)
class Scenario:
    """Composition of participation sampling, outage windows, and
    per-client schedule heterogeneity.

    ``min_participants`` guards aggregation: if a round's draw comes up
    empty while some client is *available* (not in an outage window),
    the lowest-indexed available clients are conscripted.  If every
    client is offline the round proceeds with zero participants — the
    engine skips client updates and uplink but the cache keeps aging.
    """

    participation: Participation = field(default_factory=Participation)
    outages: Tuple[Outage, ...] = ()
    heterogeneity: Optional[Heterogeneity] = None
    min_participants: int = 1

    @classmethod
    def from_participation_rate(cls, rate: float) -> "Scenario":
        """Legacy ``FLConfig.participation`` semantics (Alg. 1)."""
        if rate >= 1.0:
            return cls(participation=full_participation())
        return cls(participation=fixed_fraction(rate))

    def offline_mask(self, t: int, n_clients: int) -> np.ndarray:
        off = np.zeros(n_clients, bool)
        for o in self.outages:
            if o.covers(t):
                off[o.client] = True
        return off

    def offline_masks(self, n_rounds: int, n_clients: int,
                      start: int = 1) -> np.ndarray:
        """``(T, K)`` stacked offline masks for rounds
        ``start..start+n_rounds-1`` — outage windows are static config,
        so the scanned engines precompute them once and feed them as
        scan inputs (``start > 1`` for checkpoint-resumed runs)."""
        if n_rounds == 0:  # zero-round legs still need a (0, K) scan input
            return np.zeros((0, n_clients), bool)
        return np.stack([self.offline_mask(t, n_clients)
                         for t in range(start, start + n_rounds)])

    def participation_mask_device(self, key: jnp.ndarray,
                                  offline: jnp.ndarray) -> jnp.ndarray:
        """jit/scan-safe twin of :meth:`participation_mask`.

        ``offline`` is this round's ``(K,)`` offline mask (a row of
        :meth:`offline_masks`).  Conscription mirrors the host loop:
        when the draw comes up short, the lowest-indexed available
        clients are added until ``min_participants`` is met (or nobody
        is left).
        """
        n_clients = offline.shape[0]
        mask = self.participation.sample_device(key, n_clients)
        mask = jnp.logical_and(mask, jnp.logical_not(offline))
        deficit = self.min_participants - jnp.sum(mask)
        candidates = jnp.logical_and(jnp.logical_not(mask),
                                     jnp.logical_not(offline))
        rank = jnp.cumsum(candidates)  # 1-based rank among candidates
        conscript = jnp.logical_and(candidates, rank <= deficit)
        return jnp.logical_or(mask, conscript)

    def participation_mask(self, t: int, n_clients: int,
                           rng: np.random.Generator) -> np.ndarray:
        mask = self.participation.sample(n_clients, rng)
        off = self.offline_mask(t, n_clients)
        mask &= ~off
        if mask.sum() < self.min_participants:
            avail = np.nonzero(~off)[0]
            need = self.min_participants - int(mask.sum())
            for k in avail:
                if need <= 0:
                    break
                if not mask[k]:
                    mask[k] = True
                    need -= 1
        return mask
