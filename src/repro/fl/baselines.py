"""Parameter-sharing / no-collaboration baselines (FedAvg, Individual)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import comm as comm_lib
from repro.fl.config import FLConfig
from repro.fl.rounds import (FederatedDistillation, History, accuracy,
                             accuracy_v, local_train_v)
from repro.fl.strategies.mean import MeanStrategy

__all__ = ["FedAvg", "Individual"]


def _homogeneous_params(fd: FederatedDistillation):
    """The single stacked param pytree of a homogeneous run.  The
    parameter-sharing / no-collaboration baselines average or train one
    architecture across all clients, so client-model cohorts
    (``repro.fl.cohorts``) do not apply to them."""
    if len(fd.client_params) != 1:
        raise ValueError(
            "baselines assume the homogeneous (hidden, mlp_depth) model; "
            "client-model cohorts only apply to distillation-based methods")
    return fd.client_params[0]


class FedAvg:
    def __init__(self, cfg: FLConfig):
        self.cfg = cfg
        fd = FederatedDistillation(cfg, MeanStrategy())
        self.__dict__.update({k: fd.__dict__[k] for k in (
            "xs", "ys", "mask", "xts", "yts", "tmask", "x_test", "y_test",
            "server_params", "n_params")})
        self.client_params = _homogeneous_params(fd)
        self.rng = np.random.default_rng(cfg.seed)

    def run(self, rounds: Optional[int] = None) -> History:
        c = self.cfg
        hist = History()
        sizes = jnp.sum(self.mask, axis=1)
        w = (sizes / jnp.sum(sizes))
        T = c.rounds if rounds is None else rounds
        for t in range(1, T + 1):
            bcast = jax.tree_util.tree_map(
                lambda p: jnp.broadcast_to(p, (c.n_clients,) + p.shape),
                self.server_params)
            trained = local_train_v(bcast, self.xs, self.ys, self.mask, c.lr, c.local_steps)
            self.server_params = jax.tree_util.tree_map(
                lambda p: jnp.tensordot(w, p, axes=(0, 0)), trained)
            self.client_params = trained
            hist.ledger.record(comm_lib.fedavg_round_cost(
                n_clients=c.n_clients, n_params=self.n_params))
            if t % c.eval_every == 0 or t == T:
                sa = float(accuracy(self.server_params, self.x_test, self.y_test,
                                    jnp.ones(len(self.y_test))))
                ca = float(jnp.mean(accuracy_v(self.client_params, self.xts, self.yts,
                                               self.tmask.astype(jnp.float32))))
                hist.rounds.append(t)
                hist.server_acc.append(sa)
                hist.client_acc.append(ca)
                hist.cumulative_mb.append(hist.ledger.cumulative_total / 1e6)
        hist.final_server_acc = hist.server_acc[-1] if hist.server_acc else None
        hist.final_client_acc = hist.client_acc[-1] if hist.client_acc else None
        return hist


class Individual:
    """Isolated client training — the paper's no-collaboration baseline."""

    def __init__(self, cfg: FLConfig):
        self.cfg = cfg
        fd = FederatedDistillation(cfg, MeanStrategy())
        self.__dict__.update({k: fd.__dict__[k] for k in (
            "xs", "ys", "mask", "xts", "yts", "tmask", "x_test", "y_test",
            "server_params")})
        self.client_params = _homogeneous_params(fd)

    def run(self, rounds: Optional[int] = None) -> History:
        c = self.cfg
        hist = History()
        T = c.rounds if rounds is None else rounds
        for t in range(1, T + 1):
            self.client_params = local_train_v(
                self.client_params, self.xs, self.ys, self.mask, c.lr, c.local_steps)
            hist.ledger.record(comm_lib.RoundCost(0.0, 0.0))
            if t % c.eval_every == 0 or t == T:
                ca = float(jnp.mean(accuracy_v(self.client_params, self.xts, self.yts,
                                               self.tmask.astype(jnp.float32))))
                hist.rounds.append(t)
                hist.server_acc.append(0.0)
                hist.client_acc.append(ca)
                hist.cumulative_mb.append(0.0)
        # no server model exists in this baseline, so its accuracy was
        # never *measured* — None, not a phantom zero
        hist.final_server_acc = None
        hist.final_client_acc = hist.client_acc[-1] if hist.client_acc else None
        return hist
