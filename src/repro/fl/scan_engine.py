"""Device-resident multi-round engine: ``jax.lax.scan`` over rounds.

The host loop in :mod:`repro.fl.rounds` dispatches dozens of device
programs per round and forces a host sync every round (participation
counts, miss counts, numpy subset sampling, catch-up packaging).  This
engine compiles the *entire run* into one XLA program: participation
sampling, public-subset selection, client distillation + local
training, wire-codec round trips (``repro.compress``), strategy
aggregation, teacher assembly, global-cache update, catch-up and
uplink/downlink byte accounting all execute on-device inside the scan
body, and nothing crosses back to the host until the stacked per-round
metrics come out at the end.

Parity contract: with ``rng_backend="jax"`` the host loop folds the
identical per-round key stream (``fold_in(key_rounds, t)`` ->
``split`` -> subset choice / participation draw), so a scanned run and
a host-loop run of the same config produce the same ledger, cache
state, and eval metrics up to float reduction order — asserted by
``tests/test_scan_parity.py``.

What still requires the host loop:

- ``track_local_caches=True`` (mirrored per-client caches build
  dynamically-sized catch-up packages — a verification mode, not part
  of the simulation proper);
- strategies with host-side state or dynamic shapes
  (``Strategy.scan_safe = False``, currently COMET's numpy k-means);
- the numpy RNG streams of legacy runs (``rng_backend="numpy"``).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cache as cache_lib
from repro.core import comm as comm_lib
from repro.kernels import round_kernel
from repro.obs import device as obs_device
from repro.fl.strategies.base import TRANSMIT_SALT
from repro.fl.rounds import (
    FederatedDistillation,
    History,
    _select_cohorts,
    accuracy,
    accuracy_v,
    distill,
    val_loss_hard_v,
    val_loss_soft,
)

__all__ = ["ScannedFederatedDistillation"]


class ScannedFederatedDistillation(FederatedDistillation):
    """Scanned (fused multi-round) twin of :class:`FederatedDistillation`.

    Same constructor; ``rng_backend`` is forced to ``"jax"`` (the numpy
    Generators cannot run under ``lax.scan``).  ``run()`` returns the
    same :class:`History` the host loop builds, with one ledger entry
    per round and eval rows on the ``eval_every`` schedule.
    """

    def __init__(self, *args, **kwargs):
        kwargs.setdefault("rng_backend", "jax")
        super().__init__(*args, **kwargs)
        if self.rng_backend != "jax":
            raise ValueError("the scanned engine requires rng_backend='jax'")
        if self.track_local_caches:
            raise ValueError(
                "track_local_caches builds dynamically-sized catch-up "
                "packages — use the host-loop engine for that mode")
        if not self.strategy.scan_safe:
            raise ValueError(
                f"strategy {self.strategy.name!r} is not scan-safe "
                "(host-side state or dynamic shapes); use the host loop")
        for codec in (self.codec_up, self.codec_down):
            if not codec.scan_safe:
                raise ValueError(
                    f"codec {codec.name!r} is not scan-safe; use the "
                    "host loop")
        # fused round fast path (FLConfig.fused_round): validated here so
        # a bad combination fails at construction, not mid-scan
        self._fused = bool(self.cfg.fused_round)
        self._fused_spec = None
        if self._fused:
            if not self.strategy.supports_fused_round:
                raise ValueError(
                    f"fused_round: strategy {self.strategy.name!r} has no "
                    "fused round path (adaptive beta and host-side "
                    "strategies need the per-op chain)")
            self._fused_spec = round_kernel.codec_kernel_spec(self.codec_up)
            if self._fused_spec is None:
                raise ValueError(
                    f"fused_round: uplink codec {self.codec_up.name!r} is "
                    "not kernel-expressible (supported: identity, quantN, "
                    "cache_delta[+quantN])")
        self._scan_fn = None

    # ------------------------------------------------------------------
    def _round_device(self, carry, xs):
        c, s = self.cfg, self.strategy
        t, offline_t, do_eval = xs

        kt = jax.random.fold_in(self._key_rounds, t)
        k_idx, k_part = jax.random.split(kt)
        idx = jnp.sort(jax.random.choice(
            k_idx, c.public_size, (c.public_per_round,), replace=False))
        part = self.scenario.participation_mask_device(k_part, offline_t)
        part_f = part.astype(jnp.float32)
        n_part = jnp.sum(part_f)
        any_p = n_part > 0

        def gate(new, old):
            """Keep ``old`` wholesale on total-outage rounds."""
            return jax.tree_util.tree_map(
                lambda a, b: jnp.where(any_p, a, b), new, old)

        # --- clients: distill on previous teacher, then local training ----
        cp = carry["client_params"]
        part_c = self.models.split(part)
        x_prev = self.x_pub[carry["prev_idx"]]
        upd = self._distill_all(cp, x_prev, carry["prev_teacher"])
        cp = _select_cohorts(upd, cp, self.models.split(
            jnp.logical_and(part, carry["have_prev"])))
        upd = self._local_train_all(cp, t)
        cp = _select_cohorts(upd, cp, part_c)

        # --- request list (cache) ----------------------------------------
        cache_prev = carry["cache"]
        if self.use_cache:
            key_exp = (jax.random.fold_in(jax.random.PRNGKey(c.seed), t)
                       if self.probabilistic_expiry else None)
            miss = cache_lib.miss_mask(cache_prev, idx, t, self.D,
                                       probabilistic=self.probabilistic_expiry,
                                       key=key_exp)
        else:
            miss = jnp.ones(c.public_per_round, bool)
        miss_f = miss.astype(jnp.float32)
        n_req = jnp.sum(miss_f)
        # shared delta-coding base: the synchronized cache at P^t (pre-update)
        base, base_present = cache_lib.cached_at(cache_prev, idx)

        # --- uplink + aggregation (fixed shapes, participation-masked) ----
        x_round = self.x_pub[idx]
        z_all = self._predict_all(cp, x_round)             # (K, m, N)
        # per-round transmit key: an extra fold off kt (DCE'd when the
        # strategy ignores it, so the legacy key stream is untouched)
        z_all = s.transmit(z_all, jax.random.fold_in(kt, TRANSMIT_SALT))
        z_tx = z_all  # as transmitted: telemetry's codec-error reference
        if self._fused:
            # fused fast path: uplink codec round trip + masked
            # aggregation + sharpening in one round_kernel VMEM pass
            um = s.upload_mask(z_all)
            fbase = (round_kernel.resolve_delta_base(
                         base, base_present, c.public_per_round, c.n_classes)
                     if self._fused_spec["mode"] == "delta" else None)
            fresh = s.aggregate_masked_fused(z_all, part_f,
                                             self._fused_spec, fbase, t)
        else:
            if not self.codec_up.is_identity:  # lossy wire: server's view
                z_all = self.codec_up.roundtrip(z_all, base=base,
                                                present=base_present)
            um = s.upload_mask(z_all)
            fresh = s.aggregate_masked(z_all, part_f, um, t)
        if not self.codec_down.is_identity:  # decoded broadcast (see rounds.py)
            fresh = self.codec_down.roundtrip(fresh, base=base,
                                              present=base_present)

        # --- assemble teacher + cache update ------------------------------
        cache = cache_prev
        if self.use_cache:
            teacher = cache_lib.assemble_teacher(cache_prev, idx, fresh, miss)
            new_cache, _ = cache_lib.update_global_cache(
                cache_prev, idx, teacher, miss, t)
            cache = gate(new_cache, cache_prev)
        else:
            teacher = fresh

        # --- server distillation + App.-D proxy teacher -------------------
        sp = distill(carry["server_params"], x_round, teacher,
                     c.lr_dist, c.distill_steps)
        server_params = gate(sp, carry["server_params"])
        zv = self._predict_all(cp, self.x_pub[self.pub_val_idx])
        teacher_val = jnp.where(any_p, jnp.mean(zv, axis=0),
                                carry["teacher_val"])
        have_tv = jnp.logical_or(carry["have_tv"], any_p)

        prev_teacher = jnp.where(any_p, teacher, carry["prev_teacher"])
        prev_idx = jnp.where(any_p, idx, carry["prev_idx"])
        have_prev = jnp.logical_or(carry["have_prev"], any_p)

        # --- communication accounting (all on-device) ---------------------
        catch_up = 0.0
        if self.use_cache:
            catch_up = cache_lib.catch_up_bytes_device(
                cache_prev, carry["last_sync"], part, t)
        n_up = n_req
        if um is not None:  # Selective-FD: uplink-only confidence gating
            uploaded_total = jnp.sum(
                um.astype(jnp.float32) * part_f[:, None] * miss_f[None, :])
            n_up = uploaded_total / jnp.maximum(n_part, 1.0)
        uplink, downlink = comm_lib.distillation_round_cost_device(
            n_clients=n_part,
            n_selected=float(c.public_per_round),
            n_up_samples=n_up,
            n_down_samples=n_req,
            n_classes=c.n_classes,
            uplink_bits=s.uplink_bits,
            downlink_bits=s.downlink_bits,
            with_cache_signals=self.use_cache,
            catch_up_down=catch_up,
            bytes_index=c.index_bytes,
            uplink_codec=self.codec_up,
            downlink_codec=self.codec_down,
        )
        uplink = jnp.where(any_p, uplink, 0.0)
        downlink = jnp.where(any_p, downlink, 0.0)
        last_sync = jnp.where(part, t, carry["last_sync"])

        # --- device-plane telemetry (pre-update last_sync; whole row
        # gated so outage rounds match the host loop's zero row) -----------
        tel = None
        if self._telemetry:
            # the fused path never materializes the server's decoded
            # view, so telemetry round-trips the transmitted stack
            # itself (an opt-in observation cost, off the fused path's
            # critical per-op chain)
            z_srv = z_all
            if self._fused and not self.codec_up.is_identity:
                z_srv = self.codec_up.roundtrip(z_tx, base=base,
                                                present=base_present)
            tel = obs_device.gate(self._telemetry_row(
                t=t, part_full=part, miss=miss, base_present=base_present,
                z_tx=z_tx, z_srv=z_srv, fresh=fresh,
                last_sync=carry["last_sync"], uplink=uplink,
                downlink=downlink, catch_up=catch_up), any_p)

        # --- eval (only on scheduled rounds; lax.cond skips the rest) ------
        def _eval():
            sa = accuracy(server_params, self.x_test, self.y_test,
                          jnp.ones(len(self.y_test)))
            accs = [accuracy_v(p, self.xts_c[i], self.yts_c[i],
                               self.tmask_c[i].astype(jnp.float32))
                    for i, p in enumerate(cp)]
            ca = jnp.mean(self.models.concat(accs))
            cacc = jnp.stack([jnp.mean(a) for a in accs])
            sv = val_loss_soft(server_params, self.x_pub[self.pub_val_idx],
                               teacher_val)
            cv = jnp.mean(self.models.concat(
                [val_loss_hard_v(p, self.xs_c[i], self.ys_c[i],
                                 self.val_mask_c[i].astype(jnp.float32))
                 for i, p in enumerate(cp)]))
            return sa, ca, sv, cv, cacc

        sa, ca, sv, cv, cacc = jax.lax.cond(
            do_eval, _eval,
            lambda: (jnp.float32(0),) * 4
            + (jnp.zeros(self.models.n_cohorts, jnp.float32),))

        new_carry = dict(
            client_params=cp,
            server_params=server_params,
            cache=cache,
            prev_teacher=prev_teacher,
            prev_idx=prev_idx,
            have_prev=have_prev,
            teacher_val=teacher_val,
            have_tv=have_tv,
            last_sync=last_sync,
        )
        ys = dict(uplink=uplink, downlink=downlink,
                  server_acc=sa, client_acc=ca, server_val=sv, client_val=cv,
                  cohort_acc=cacc, have_tv=have_tv)
        if tel is not None:
            # per-round row out through ys, running totals in the carry
            new_carry["telemetry"] = obs_device.accumulate(
                carry["telemetry"], tel)
            ys["telemetry"] = tel
        return new_carry, ys

    # ------------------------------------------------------------------
    def _initial_carry(self):
        """The scan carry is exactly the checkpointable engine state
        (same placeholders, same ``have_*`` flags) minus the host-side
        round counter — one source of truth for both.  Telemetry-on
        runs additionally carry the running RoundTelemetry totals (not
        checkpointable state: telemetry is a per-run-leg observation,
        zeroed at every run())."""
        carry = self.state_dict()
        del carry["t_done"]
        if self._telemetry:
            carry["telemetry"] = obs_device.zeros(self.models.n_cohorts)
        return carry

    # ------------------------------------------------------------------
    def run(self, rounds: Optional[int] = None) -> History:
        c = self.cfg
        T = c.rounds if rounds is None else rounds
        t0 = self.t_done  # absolute round numbering (chained/restored runs)
        ts = jnp.arange(t0 + 1, t0 + T + 1, dtype=jnp.int32)
        offline = jnp.asarray(
            self.scenario.offline_masks(T, c.n_clients, start=t0 + 1))
        eval_np = np.array([(t % c.eval_every == 0) or (t == t0 + T)
                            for t in range(t0 + 1, t0 + T + 1)], dtype=bool)
        carry, ys = self._run_rounds(ts, offline, jnp.asarray(eval_np))
        self.t_done = t0 + T
        return self._finish_run(carry, ys, eval_np, t0)

    def _run_rounds(self, ts, offline, do_eval):
        """Launch the device program for the given round batch."""
        return self._program()(*self._aot_args(ts, offline, do_eval))

    def _program(self):
        """The jitted whole-run program (lazily built, cached); the
        client-sharded engine overrides this with its shard_map twin."""
        if self._scan_fn is None:
            self._scan_fn = jax.jit(
                lambda carry, xs: jax.lax.scan(self._round_device, carry, xs))
        return self._scan_fn

    def _aot_args(self, ts, offline, do_eval):
        """Concrete arguments matching ``_program()``'s signature."""
        return (self._initial_carry(), (ts, offline, do_eval))

    def aot_lower(self, rounds: int = 1):
        """AOT-lower the round program without running it: the
        ``jax.stages.Lowered`` for a ``rounds``-round batch (no eval
        rounds).  ``.compile()`` gives optimized HLO + XLA cost analysis
        — what :mod:`benchmarks.engine_roofline` feeds the
        :mod:`repro.launch.roofline` model."""
        c = self.cfg
        t0 = self.t_done
        ts = jnp.arange(t0 + 1, t0 + rounds + 1, dtype=jnp.int32)
        offline = jnp.asarray(
            self.scenario.offline_masks(rounds, c.n_clients, start=t0 + 1))
        do_eval = jnp.zeros(rounds, bool)
        return self._program().lower(*self._aot_args(ts, offline, do_eval))

    def _finish_run(self, carry, ys, eval_np, t0) -> History:
        # telemetry leaves first: they are observation outputs, not
        # engine state (the carry totals are redundant with the stack
        # and exist to prove the accumulate path; the stack is the record)
        carry, ys = dict(carry), dict(ys)
        carry.pop("telemetry", None)
        tel_stack = ys.pop("telemetry", None)

        # persist final device state (parity checks, chained run() calls)
        self.client_params = carry["client_params"]
        self.server_params = carry["server_params"]
        self.cache_g = cache_lib.CacheState(*carry["cache"])
        self.last_sync = np.asarray(carry["last_sync"]).astype(np.int64)
        if bool(carry["have_prev"]):
            self.prev_teacher = (np.asarray(carry["prev_idx"]),
                                 carry["prev_teacher"])
        if bool(carry["have_tv"]):
            self.last_teacher_val = carry["teacher_val"]

        # --- rebuild the host-visible History from the stacked metrics ----
        up = np.asarray(ys["uplink"], np.float64)
        down = np.asarray(ys["downlink"], np.float64)
        cum = np.cumsum(up + down)
        sa = np.asarray(ys["server_acc"])
        ca = np.asarray(ys["client_acc"])
        sv = np.asarray(ys["server_val"])
        cv = np.asarray(ys["client_val"])
        cacc = np.asarray(ys["cohort_acc"])               # (T, n_cohorts)
        have_tv = np.asarray(ys["have_tv"])

        hist = History()
        if tel_stack is not None:
            hist.telemetry = obs_device.TelemetryLog.from_stacked(tel_stack)
        for u, d in zip(up, down):
            hist.ledger.record(comm_lib.RoundCost(float(u), float(d)))
        for i in np.nonzero(eval_np)[0]:
            hist.rounds.append(t0 + int(i) + 1)
            hist.server_acc.append(float(sa[i]))
            hist.client_acc.append(float(ca[i]))
            hist.cohort_client_acc.append([float(x) for x in cacc[i]])
            hist.cumulative_mb.append(float(cum[i]) / 1e6)
            if have_tv[i]:
                hist.server_val_loss.append(float(sv[i]))
            hist.client_val_loss.append(float(cv[i]))
        hist.final_server_acc = hist.server_acc[-1] if hist.server_acc else None
        hist.final_client_acc = hist.client_acc[-1] if hist.client_acc else None
        return hist
