"""Host-side client parameter store for the active-set engine.

Client parameters for the dense engines are device-resident stacked
pytrees — one ``(size_c, ...)`` leaf stack per cohort — which bounds
the population K by device memory.  :class:`ClientParamStore` keeps the
same per-cohort stacks on the **host** instead (plain numpy, or
``np.memmap`` files under a directory for populations that exceed
RAM), and moves only the m active clients per round:

- :meth:`gather` pulls the selected rows of one cohort into a fresh
  ``(m_c, ...)`` device stack;
- :meth:`scatter` writes the updated rows back.

The store is **bit-compatible** with the dense engines: rows are
initialised by the same per-client ``ClientModels._init_one`` vmap
(chunked — ``jax.random`` is counter-based, so per-key results do not
depend on the batch split), and :meth:`as_param_list` reassembles the
exact ``client_params`` list-of-stacked-pytrees structure the shared
``state_dict()`` plumbing expects, so checkpoints interchange freely
with host/scan/shard.

Persistence rides :mod:`repro.checkpoint.io`: :meth:`save` writes one
npz; :meth:`save_sharded` splits the client axis into
``clients_per_shard`` row blocks (``clients_00000000_00000512.npz``
...), so a million-client store never materialises as one file.
"""
from __future__ import annotations

import os
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.io import CheckpointKeyError, load_pytree, save_pytree


def _leaf_paths(tree) -> List[str]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return ["/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in kp)
            for kp, _ in flat]


class ClientParamStore:
    """Per-cohort host-resident stacks of client parameters.

    Parameters
    ----------
    models:
        A ``repro.fl.cohorts.ClientModels`` (owns cohort sizes and the
        per-client initializer).
    keys:
        ``(K,)`` stacked PRNG keys, one per client (the same
        ``jax.random.split(...)[:-1]`` slice the dense engines use).
    backing:
        ``"ram"`` (default) for plain numpy arrays, ``"memmap"`` for
        ``np.lib.format.open_memmap`` files under ``directory``.
    directory:
        Required for ``backing="memmap"``; created if absent.
    init_chunk:
        Clients initialised per vmap call (bounds peak device memory
        during initialisation; results are independent of the split).
    """

    def __init__(self, models, keys, *, backing: str = "ram",
                 directory: Optional[str] = None, init_chunk: int = 4096):
        if backing not in ("ram", "memmap"):
            raise ValueError(f"unknown backing {backing!r}")
        if backing == "memmap" and directory is None:
            raise ValueError("backing='memmap' requires a directory")
        self.models = models
        self.backing = backing
        self.directory = directory
        self._cohorts: List[Dict[str, Any]] = []  # leaf-name -> (size_c, ...) array
        self._treedefs = []
        self._leaf_names: List[List[str]] = []
        if backing == "memmap":
            os.makedirs(directory, exist_ok=True)
        for c, spec in enumerate(models.cohorts):
            sl = models.slices[c]
            size = models.sizes[c]
            shapes = jax.eval_shape(lambda k, s=spec: models._init_one(s, k),
                                    jax.ShapeDtypeStruct(keys.shape[1:], keys.dtype))
            flat, treedef = jax.tree_util.tree_flatten(shapes)
            names = _leaf_paths(shapes)
            arrays = {}
            for name, leaf in zip(names, flat):
                shape = (size,) + tuple(leaf.shape)
                dtype = np.dtype(leaf.dtype)
                if backing == "ram":
                    arrays[name] = np.empty(shape, dtype)
                else:
                    fn = os.path.join(directory, f"cohort{c}_{name.replace('/', '_')}.npy")
                    arrays[name] = np.lib.format.open_memmap(
                        fn, mode="w+", dtype=dtype, shape=shape)
            self._cohorts.append(arrays)
            self._treedefs.append(treedef)
            self._leaf_names.append(names)
            # Chunked init: identical per-row bits to the dense
            # models.init_params(keys) vmap, any chunk size.  Eager
            # vmap like the dense path — jitting would let XLA fuse
            # (FMA) differently and shift init values by 1 ulp.
            init_v = jax.vmap(lambda k, s=spec: models._init_one(s, k))
            ck = keys[sl]
            for lo in range(0, size, init_chunk):
                hi = min(lo + init_chunk, size)
                chunk = init_v(ck[lo:hi])
                for name, leaf in zip(names, jax.tree_util.tree_leaves(chunk)):
                    arrays[name][lo:hi] = np.asarray(leaf)

    # -- shape/bookkeeping ------------------------------------------------
    @property
    def n_cohorts(self) -> int:
        return len(self._cohorts)

    @property
    def nbytes(self) -> int:
        return sum(a.nbytes for c in self._cohorts for a in c.values())

    def _unflatten(self, c: int, arrays: Sequence[Any]):
        return jax.tree_util.tree_unflatten(self._treedefs[c], list(arrays))

    # -- the data path ----------------------------------------------------
    def gather(self, c: int, rows: np.ndarray):
        """Device stack of cohort ``c``'s selected rows (``(len(rows), ...)``)."""
        arrs = self._cohorts[c]
        return self._unflatten(
            c, [jnp.asarray(arrs[n][rows]) for n in self._leaf_names[c]])

    def scatter(self, c: int, rows: np.ndarray, updated) -> None:
        """Write an updated ``(len(rows), ...)`` device stack back."""
        arrs = self._cohorts[c]
        for name, leaf in zip(self._leaf_names[c],
                              jax.tree_util.tree_leaves(updated)):
            arrs[name][rows] = np.asarray(leaf)

    # -- state_dict interchange -------------------------------------------
    def as_param_list(self) -> List[Any]:
        """The dense engines' ``client_params`` structure (numpy leaves)."""
        return [self._unflatten(c, [arrs[n] for n in self._leaf_names[c]])
                for c, arrs in enumerate(self._cohorts)]

    def ingest_param_list(self, params: List[Any]) -> None:
        """Overwrite the store from a dense ``client_params`` list."""
        if len(params) != self.n_cohorts:
            raise ValueError(
                f"expected {self.n_cohorts} cohort stacks, got {len(params)}")
        for c, stack in enumerate(params):
            arrs = self._cohorts[c]
            for name, leaf in zip(self._leaf_names[c],
                                  jax.tree_util.tree_leaves(stack)):
                if arrs[name].shape != np.shape(leaf):
                    raise ValueError(
                        f"cohort {c} leaf {name}: stack shape "
                        f"{np.shape(leaf)} != store shape {arrs[name].shape}")
                arrs[name][...] = np.asarray(leaf)

    # -- persistence ------------------------------------------------------
    def save(self, path: str) -> None:
        save_pytree(path, self.as_param_list())

    def load(self, path: str) -> None:
        self.ingest_param_list(load_pytree(path, self.as_param_list()))

    def save_sharded(self, directory: str, clients_per_shard: int) -> None:
        """One npz per ``clients_per_shard`` row block of every cohort."""
        os.makedirs(directory, exist_ok=True)
        for c, arrs in enumerate(self._cohorts):
            size = self.models.sizes[c]
            for lo in range(0, size, clients_per_shard):
                hi = min(lo + clients_per_shard, size)
                block = self._unflatten(
                    c, [arrs[n][lo:hi] for n in self._leaf_names[c]])
                save_pytree(os.path.join(
                    directory, f"cohort{c}_clients_{lo:08d}_{hi:08d}.npz"), block)

    def load_sharded(self, directory: str, clients_per_shard: int) -> None:
        for c, arrs in enumerate(self._cohorts):
            size = self.models.sizes[c]
            for lo in range(0, size, clients_per_shard):
                hi = min(lo + clients_per_shard, size)
                fn = os.path.join(
                    directory, f"cohort{c}_clients_{lo:08d}_{hi:08d}.npz")
                if not os.path.exists(fn):
                    raise CheckpointKeyError(f"missing store shard {fn}")
                like = self._unflatten(
                    c, [arrs[n][lo:hi] for n in self._leaf_names[c]])
                block = load_pytree(fn, like)
                for name, leaf in zip(self._leaf_names[c],
                                      jax.tree_util.tree_leaves(block)):
                    arrs[name][lo:hi] = np.asarray(leaf)
