from repro.checkpoint.io import (  # noqa: F401
    CheckpointDtypeError,
    CheckpointError,
    CheckpointKeyError,
    CheckpointShapeError,
    load_pytree,
    save_pytree,
)
from repro.checkpoint.store import ClientParamStore  # noqa: F401
