"""npz-based pytree checkpointing (keyed by tree paths, dtype-preserving)."""
from __future__ import annotations

import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def _key(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def save_pytree(path: str, tree: Any) -> None:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    arrays = {}
    for kp, leaf in flat:
        arr = np.asarray(leaf)
        if arr.dtype == jnp.bfloat16:
            arrays["BF16::" + _key(kp)] = arr.view(np.uint16)
        else:
            arrays[_key(kp)] = arr
    tmp = path + ".tmp"
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(tmp, "wb") as f:
        np.savez(f, **arrays)
    os.replace(tmp, path)


def load_pytree(path: str, like: Any) -> Any:
    with np.load(path) as data:
        flat, treedef = jax.tree_util.tree_flatten_with_path(like)
        leaves = []
        for kp, leaf in flat:
            k = _key(kp)
            if "BF16::" + k in data:
                arr = jnp.asarray(data["BF16::" + k].view(jnp.bfloat16))
            else:
                arr = jnp.asarray(data[k])
            assert arr.shape == leaf.shape, (k, arr.shape, leaf.shape)
            leaves.append(arr)
        return jax.tree_util.tree_unflatten(treedef, [l for (_, l) in zip(flat, leaves)])
