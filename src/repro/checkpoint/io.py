"""npz-based pytree checkpointing (keyed by tree paths, dtype-preserving).

npz keys are built from the jax key path with one component per path
entry, **type-tagged and percent-escaped**:

- ``d:<key>``  — dict key (``DictKey``), with ``%`` -> ``%25`` and
  ``/`` -> ``%2F`` escaped inside the key;
- ``i:<idx>``  — sequence index (``SequenceKey``);
- ``a:<name>`` — attribute / named-tuple field (``GetAttrKey``);
- ``f:<key>``  — flattened-index key (``FlattenedIndexKey``) or any
  other path type, escaped like dict keys.

This makes the mapping path -> key injective: a dict key containing
``"/"`` (``{"a/b": x}`` vs ``{"a": {"b": y}}``) and a dict key ``"0"``
vs a sequence index ``0`` no longer collide (both silently overwrote
one leaf on save before).  ``load_pytree`` still falls back to the
legacy untagged key for any leaf whose tagged key is absent, so
checkpoints written by older code keep loading.

Validation on load raises typed errors (never ``assert``, which
``python -O`` strips): :class:`CheckpointKeyError` for missing or
unconsumed npz keys, :class:`CheckpointShapeError` /
:class:`CheckpointDtypeError` for leaf mismatches — a float64-saved
leaf no longer silently casts into a float32 tree.
"""
from __future__ import annotations

import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


class CheckpointError(Exception):
    """Base class for checkpoint load/save validation failures."""


class CheckpointKeyError(CheckpointError):
    """A tree leaf has no stored array, or stored arrays went unused."""


class CheckpointShapeError(CheckpointError):
    """Stored array shape does not match the template leaf."""


class CheckpointDtypeError(CheckpointError):
    """Stored array dtype does not match the template leaf."""


_BF16 = "BF16::"


def _escape(s: str) -> str:
    return s.replace("%", "%25").replace("/", "%2F")


def _component(p) -> str:
    if isinstance(p, jax.tree_util.DictKey):
        return "d:" + _escape(str(p.key))
    if isinstance(p, jax.tree_util.SequenceKey):
        return "i:" + str(p.idx)
    if isinstance(p, jax.tree_util.GetAttrKey):
        return "a:" + _escape(str(p.name))
    # FlattenedIndexKey and anything exotic.
    return "f:" + _escape(str(getattr(p, "key", getattr(p, "idx", p))))


def _key(path) -> str:
    return "/".join(_component(p) for p in path)


def _legacy_key(path) -> str:
    # The pre-tagging scheme (collision-prone); used only as a load
    # fallback so old fixtures keep working.
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def save_pytree(path: str, tree: Any) -> None:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    arrays = {}
    for kp, leaf in flat:
        arr = np.asarray(leaf)
        k = _key(kp)
        k = (_BF16 + k) if arr.dtype == jnp.bfloat16 else k
        if k in arrays:
            raise CheckpointKeyError(
                f"duplicate npz key {k!r} — two tree paths flattened to the "
                "same key, which would silently drop a leaf")
        arrays[k] = arr.view(np.uint16) if arr.dtype == jnp.bfloat16 else arr
    tmp = path + ".tmp"
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(tmp, "wb") as f:
        np.savez(f, **arrays)
    os.replace(tmp, path)


def _lookup(data, kp):
    """Resolve one leaf path against the npz, tagged first then legacy.

    Returns the stored array as **numpy** so dtype validation sees the
    file's actual dtype — ``jnp.asarray`` here would silently downcast
    a float64 file to float32 before the check could fire."""
    for key in (_key(kp), _legacy_key(kp)):
        if _BF16 + key in data:
            return _BF16 + key, data[_BF16 + key].view(jnp.bfloat16)
        if key in data:
            return key, data[key]
    raise CheckpointKeyError(
        f"no stored array for leaf {_key(kp)!r} "
        f"(legacy key {_legacy_key(kp)!r} also absent) in checkpoint")


def load_pytree(path: str, like: Any) -> Any:
    with np.load(path) as data:
        flat, treedef = jax.tree_util.tree_flatten_with_path(like)
        leaves = []
        consumed = set()
        for kp, leaf in flat:
            key, arr = _lookup(data, kp)
            consumed.add(key)
            leaf_shape = tuple(np.shape(leaf))
            leaf_dtype = np.result_type(leaf)
            if arr.shape != leaf_shape:
                raise CheckpointShapeError(
                    f"leaf {_key(kp)!r}: stored shape {tuple(arr.shape)} != "
                    f"template shape {leaf_shape}")
            if arr.dtype != leaf_dtype:
                raise CheckpointDtypeError(
                    f"leaf {_key(kp)!r}: stored dtype {arr.dtype} != "
                    f"template dtype {leaf_dtype} (refusing to cast)")
            # numpy template leaves stay numpy (e.g. the active engine's
            # host-resident client store); everything else goes to device
            leaves.append(arr if isinstance(leaf, np.ndarray)
                          else jnp.asarray(arr))
        extra = sorted(set(data.files) - consumed)
        if extra:
            raise CheckpointKeyError(
                f"checkpoint holds {len(extra)} array(s) the template tree "
                f"never consumed: {extra[:5]}{'...' if len(extra) > 5 else ''}")
        return jax.tree_util.tree_unflatten(treedef, leaves)
