"""Lightweight cache hit-rate simulation (paper Appendix A, Alg. 3; Fig. 3).

Models only the random sampling of the public subset and the expiry
logic — no FL training — to predict the per-round cache hit ratio for a
given duration ``D``.  Used to pick ``D`` before running full FL.
Pure numpy; trivially fast.
"""
from __future__ import annotations

import numpy as np


def simulate_hit_rate(
    public_size: int,
    per_round: int,
    D: int,
    rounds: int,
    seed: int = 0,
) -> np.ndarray:
    """Returns array of per-round cache hit ratios, length ``rounds``.

    Alg. 3: an index hits when it is present and ``t - ts <= D``;
    otherwise it misses and is (re)cached at ``t``.
    """
    if per_round > public_size:
        raise ValueError("per_round must be <= public_size")
    rng = np.random.default_rng(seed)
    if D == 0:
        return np.zeros(rounds, dtype=np.float64)
    ts = np.full(public_size, -(2**30), dtype=np.int64)
    out = np.empty(rounds, dtype=np.float64)
    for t in range(1, rounds + 1):
        idx = rng.choice(public_size, size=per_round, replace=False)
        age = t - ts[idx]
        hit = age <= D
        ts[idx[~hit]] = t
        out[t - 1] = hit.mean()
    return out


def simulate_hit_rate_probabilistic(
    public_size: int,
    per_round: int,
    D: int,
    rounds: int,
    seed: int = 0,
) -> np.ndarray:
    """Per-sample stochastic expiry (hazard age/D) — the paper's §V
    'probabilistic or selective per-sample expiration' direction.  Same
    expected refresh budget as the hard cutoff, but no synchronized
    mass-refresh waves: the hit-ratio trace is smooth."""
    if per_round > public_size:
        raise ValueError("per_round must be <= public_size")
    rng = np.random.default_rng(seed)
    if D == 0:
        return np.zeros(rounds, dtype=np.float64)
    ts = np.full(public_size, -(2**30), dtype=np.int64)
    out = np.empty(rounds, dtype=np.float64)
    for t in range(1, rounds + 1):
        idx = rng.choice(public_size, size=per_round, replace=False)
        age = t - ts[idx]
        hazard = np.clip((age - 1.0) / D, 0.0, 1.0)
        miss = rng.random(per_round) < hazard
        ts[idx[miss]] = t
        out[t - 1] = 1.0 - miss.mean()
    return out


def expected_steady_state_hit_rate(public_size: int, per_round: int, D: int) -> float:
    """Analytic steady-state approximation of the hit rate.

    Each sample is selected per round with prob ``s = per_round/public_size``.
    A selected sample hits iff its last *refresh* (miss) is within D rounds
    and it was selected since... A cleaner renewal argument: consider a
    sample's timeline of selections (Bernoulli(s) per round).  After a
    refresh at time t0, every selection in (t0, t0+D] hits; the first
    selection after t0+D misses and renews.  Expected selections per
    renewal cycle: hits H = E[# selections in D rounds] = s*D; misses = 1.
    Steady-state hit rate ≈ sD / (sD + 1).
    """
    s = per_round / public_size
    return (s * D) / (s * D + 1.0)
