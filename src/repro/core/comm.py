"""Communication-cost accounting (paper §IV-A4, Table V, Figs. 8-11).

Counts every byte exchanged between server and clients: soft-labels,
request lists, cache signals, catch-up packages, quantized payloads
(CFD), cluster assignments (COMET), and — for parameter-sharing
baselines (FedAvg) — model parameters.  The one-time public-dataset
distribution is excluded, as in the paper.

All quantities are analytic functions of what the algorithms actually
transmit; the FL engine calls ``RoundCost`` hooks each round and the
ledger accumulates uplink/downlink separately (asymmetric-bandwidth
analysis, Table V).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

BYTES_F32 = 4.0
BYTES_INDEX = 4.0
BYTES_SIGNAL = 0.25  # 2 bits/sample, packed


def index_bytes_for(n_items: int) -> float:
    """Smallest standard unsigned width that can index ``n_items``
    distinct values (public-sample ids, top-k class positions, ...).

    Public datasets up to 65k samples — every dataset in the paper —
    only need uint16 request-list/index entries; callers pass the result
    as ``bytes_index`` instead of the conservative 4-byte default.
    """
    if n_items <= 2 ** 8:
        return 1.0
    if n_items <= 2 ** 16:
        return 2.0
    return 4.0


@dataclass
class RoundCost:
    uplink: float = 0.0    # client -> server, summed over clients, bytes
    downlink: float = 0.0  # server -> client, summed over clients, bytes

    def __add__(self, other: "RoundCost") -> "RoundCost":
        return RoundCost(self.uplink + other.uplink, self.downlink + other.downlink)

    @property
    def total(self) -> float:
        return self.uplink + self.downlink


@dataclass
class CommLedger:
    """Per-round uplink/downlink byte ledger."""

    rounds: List[RoundCost] = field(default_factory=list)

    def record(self, cost: RoundCost) -> None:
        self.rounds.append(cost)

    @property
    def cumulative_uplink(self) -> float:
        return sum(r.uplink for r in self.rounds)

    @property
    def cumulative_downlink(self) -> float:
        return sum(r.downlink for r in self.rounds)

    @property
    def cumulative_total(self) -> float:
        return self.cumulative_uplink + self.cumulative_downlink

    def summary(self) -> Dict[str, float]:
        """Per-direction stats over recorded rounds.

        An empty ledger reports explicit zeros for every field (and
        ``rounds: 0.0``) — it must never fabricate a phantom round to
        make the reductions well-defined, since ``run_record.json``
        exports these numbers as if they were measured.
        """
        up = np.array([r.uplink for r in self.rounds], dtype=np.float64)
        down = np.array([r.downlink for r in self.rounds], dtype=np.float64)
        empty = up.size == 0

        def _stat(arr: np.ndarray, red) -> float:
            return 0.0 if empty else float(red(arr))

        return {
            "rounds": float(len(self.rounds)),
            "uplink_mean": _stat(up, np.mean),
            "uplink_std": _stat(up, np.std),
            "uplink_max": _stat(up, np.max),
            "downlink_mean": _stat(down, np.mean),
            "downlink_std": _stat(down, np.std),
            "downlink_max": _stat(down, np.max),
            "cumulative_total": float(up.sum() + down.sum()),
        }


def soft_label_bytes(n_samples: int, n_classes: int, bits: float = 32.0) -> float:
    return n_samples * n_classes * bits / 8.0


def distillation_round_cost_device(
    *,
    n_clients,
    n_selected,
    n_up_samples,
    n_down_samples,
    n_classes: int,
    uplink_bits: float = 32.0,
    downlink_bits: float = 32.0,
    with_cache_signals: bool = False,
    with_request_list: bool = True,
    catch_up_down=0.0,
    bytes_index: float = BYTES_INDEX,
    uplink_codec=None,
    downlink_codec=None,
    axis_name: Optional[str] = None,
) -> Tuple[float, float]:
    """Pure-arithmetic ``(uplink, downlink)`` bytes for one round.

    Every non-static argument may be a python number *or* a traced jnp
    scalar — this is the cost function the scanned (``lax.scan``) engine
    evaluates on-device each round; ``distillation_round_cost`` wraps it
    for the host loop.

    ``axis_name`` makes the cost shard-aware for client-sharded
    (``shard_map``) engines: ``n_clients`` is then the *per-shard*
    participant count and is psum-reduced over that mesh axis before the
    (replicated) arithmetic.  Every other count — including
    ``catch_up_down`` — must already be a replicated global value (the
    shard engine reduces catch-up via
    ``cache.catch_up_bytes_device(..., axis_name=...)``).

    The uplink and downlink *sample counts are split*: confidence-gated
    methods (Selective-FD) upload fewer samples per client
    (``n_up_samples``, may be fractional — a per-client average), but the
    server still broadcasts aggregated labels for every requested sample
    (``n_down_samples``), so only the uplink shrinks.

    ``uplink_codec``/``downlink_codec`` (any :class:`repro.compress.Codec`
    with a non-identity wire format) replace the flat bits-per-value
    payload model with the codec's analytic ``payload_bytes`` on that
    direction; identity/None keeps the legacy ``*_bits`` accounting, so
    CFD's Table-V byte values are untouched.  Request-list and cache
    signal bytes are codec-independent (``bytes_index`` per index entry).
    """
    if axis_name is not None:
        n_clients = jax.lax.psum(n_clients, axis_name)
    if uplink_codec is not None and not uplink_codec.is_identity:
        up_per_client = uplink_codec.payload_bytes(n_up_samples, n_classes)
    else:
        up_per_client = soft_label_bytes(n_up_samples, n_classes, uplink_bits)
    if downlink_codec is not None and not downlink_codec.is_identity:
        down_per_client = downlink_codec.payload_bytes(n_down_samples, n_classes)
    else:
        down_per_client = soft_label_bytes(n_down_samples, n_classes, downlink_bits)
    if with_request_list:
        down_per_client += n_down_samples * bytes_index + n_selected * bytes_index
    if with_cache_signals:
        down_per_client += n_selected * BYTES_SIGNAL
    return n_clients * up_per_client, n_clients * down_per_client + catch_up_down


def distillation_round_cost(
    *,
    n_clients: int,
    n_selected: int,
    n_requested: Optional[float] = None,
    n_classes: int,
    uplink_bits: float = 32.0,
    downlink_bits: float = 32.0,
    with_cache_signals: bool = False,
    with_request_list: bool = True,
    catch_up_down: float = 0.0,
    n_up_samples: Optional[float] = None,
    n_down_samples: Optional[float] = None,
    bytes_index: float = BYTES_INDEX,
    uplink_codec=None,
    downlink_codec=None,
) -> RoundCost:
    """Generic per-round cost for distillation-based FL.

    - uplink: each client sends soft-labels for ``n_up_samples`` samples
      (``n_selected`` when no cache; possibly fewer under upload gating).
    - downlink: server broadcasts aggregated soft-labels for
      ``n_down_samples`` samples (+ signals over all ``n_selected`` when
      caching) + the request list, to each client.

    ``n_requested`` is the legacy single-count form (uplink == downlink
    samples, i.e. no upload gating); pass the split counts explicitly
    for methods where clients withhold part of the request list.
    """
    if n_up_samples is None:
        n_up_samples = n_requested
    if n_down_samples is None:
        n_down_samples = n_requested
    if n_up_samples is None or n_down_samples is None:
        raise TypeError("pass n_requested or both n_up_samples/n_down_samples")
    up, down = distillation_round_cost_device(
        n_clients=n_clients,
        n_selected=n_selected,
        n_up_samples=n_up_samples,
        n_down_samples=n_down_samples,
        n_classes=n_classes,
        uplink_bits=uplink_bits,
        downlink_bits=downlink_bits,
        with_cache_signals=with_cache_signals,
        with_request_list=with_request_list,
        catch_up_down=catch_up_down,
        bytes_index=bytes_index,
        uplink_codec=uplink_codec,
        downlink_codec=downlink_codec,
    )
    return RoundCost(uplink=float(up), downlink=float(down))


def fedavg_round_cost(*, n_clients: int, n_params: int, bits: float = 32.0) -> RoundCost:
    per = n_params * bits / 8.0
    return RoundCost(uplink=n_clients * per, downlink=n_clients * per)
