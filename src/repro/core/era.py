"""Aggregation mechanisms: ERA (DS-FL) and Enhanced ERA (SCARLET, Eq. 4).

Soft-labels are normalized probability vectors over ``N`` classes.  The
server averages the per-client soft-labels and then *sharpens* them:

- ERA (Itahara et al., DS-FL):      ``softmax(z_mean / T)``
- Enhanced ERA (this paper, Eq. 4): ``z_mean**beta / sum_j z_mean_j**beta``

``beta = 1`` is an exact identity (plain federated averaging of
soft-labels); ``beta > 1`` monotonically sharpens (majorization,
Appendix B); ``beta < 1`` smooths.

All functions are pure jnp and jit-safe.  ``enhanced_era`` can dispatch
to the fused Pallas TPU kernel via ``impl="pallas"`` (interpret mode on
CPU); the default pure-jnp path is the reference oracle.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

_EPS = 1e-12


def softmax_with_temperature(logits: jnp.ndarray, T: float, axis: int = -1) -> jnp.ndarray:
    """Temperature softmax; ``T -> 0`` approaches one-hot argmax."""
    return jax.nn.softmax(logits / T, axis=axis)


def era(z_mean: jnp.ndarray, T: float, axis: int = -1) -> jnp.ndarray:
    """Conventional Entropy Reduction Aggregation (DS-FL, Eq. 2).

    Applies a temperature softmax to *already-normalized* averaged
    soft-labels.  Note the well-known instability: the output log-ratio
    is ``(z_i - z_j)/T`` — scale (entropy) dependent, and the
    sensitivity w.r.t. T explodes as ``1/T^2`` (Appendix C).
    """
    return softmax_with_temperature(z_mean, T, axis=axis)


def enhanced_era(
    z_mean: jnp.ndarray,
    beta: float | jnp.ndarray,
    axis: int = -1,
    eps: float = _EPS,
    impl: str = "jnp",
) -> jnp.ndarray:
    """Enhanced ERA (SCARLET, Eq. 4): ``z^beta / sum z^beta``.

    Computed as ``exp(beta * log z)`` with clamping so zero entries stay
    (numerically) zero for ``beta > 0``.  The output log-ratio between
    two classes is ``beta * ln(z_i / z_j)`` — scale-invariant and linear
    in ``beta`` (Appendix C), which is the paper's stability argument.
    """
    if impl == "pallas":
        from repro.kernels import ops as _kops

        if axis not in (-1, z_mean.ndim - 1):
            raise ValueError("pallas impl requires last-axis classes")
        return _kops.enhanced_era(z_mean, beta)
    z = jnp.clip(z_mean, eps, None)
    # log-space for numerical stability with large beta / tiny probs.
    logits = beta * jnp.log(z)
    out = jax.nn.softmax(logits, axis=axis)
    return out


def aggregate_soft_labels(
    z_clients: jnp.ndarray,
    method: str = "enhanced_era",
    *,
    beta: float = 1.0,
    T: float = 0.1,
    weights: Optional[jnp.ndarray] = None,
    impl: str = "jnp",
) -> jnp.ndarray:
    """Aggregate per-client soft-labels ``(K, B, N) -> (B, N)``.

    ``weights`` optionally weights clients (e.g. by dataset size);
    defaults to a uniform mean as in the paper.
    """
    if z_clients.ndim < 2:
        raise ValueError("expected (K, ..., N)")
    if weights is None:
        z_mean = jnp.mean(z_clients, axis=0)
    else:
        w = weights / jnp.sum(weights)
        z_mean = jnp.tensordot(w, z_clients, axes=(0, 0))
    if method == "mean":
        return z_mean
    if method == "era":
        return era(z_mean, T)
    if method == "enhanced_era":
        return enhanced_era(z_mean, beta, impl=impl)
    raise ValueError(f"unknown aggregation method: {method}")


def entropy(p: jnp.ndarray, axis: int = -1, eps: float = _EPS) -> jnp.ndarray:
    """Shannon entropy (nats) of probability vectors."""
    p = jnp.clip(p, eps, 1.0)
    return -jnp.sum(p * jnp.log(p), axis=axis)


@functools.partial(jax.jit, static_argnames=("axis",))
def log_prob_ratio(p: jnp.ndarray, i: int, j: int, axis: int = -1) -> jnp.ndarray:
    """``ln(p_i / p_j)`` — the Appendix-C stability diagnostic."""
    pi = jnp.take(p, i, axis=axis)
    pj = jnp.take(p, j, axis=axis)
    return jnp.log(jnp.clip(pi, _EPS)) - jnp.log(jnp.clip(pj, _EPS))
