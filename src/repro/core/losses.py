"""Losses: hard-label CE and soft-target distillation (KL / soft CE).

The distillation loss is the per-step hot spot of distillation-based FL
(client + server distill every round over |P^t| x N).  ``impl="pallas"``
dispatches to the fused flash-softmax Pallas kernel for large class
counts (LM vocabs); the jnp path is the oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_EPS = 1e-12


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray, axis: int = -1) -> jnp.ndarray:
    """Mean CE over integer labels; ignores entries where label < 0."""
    logp = jax.nn.log_softmax(logits, axis=axis)
    mask = labels >= 0
    safe = jnp.where(mask, labels, 0)
    nll = -jnp.take_along_axis(logp, safe[..., None], axis=axis)[..., 0]
    nll = jnp.where(mask, nll, 0.0)
    return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1)


def soft_cross_entropy(
    logits: jnp.ndarray, teacher: jnp.ndarray, impl: str = "jnp"
) -> jnp.ndarray:
    """Mean ``-sum_j teacher_j * log_softmax(logits)_j`` (soft-target CE).

    Equal to ``KL(teacher || student) + H(teacher)`` — same gradients as
    the KL distillation loss used in the paper (phi_dist).
    """
    if impl == "pallas":
        from repro.kernels import ops as _kops

        return _kops.distill_loss(logits, teacher)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.sum(teacher * logp, axis=-1))


def kl_divergence(teacher: jnp.ndarray, logits: jnp.ndarray) -> jnp.ndarray:
    """Mean ``KL(teacher || softmax(logits))`` (paper's phi_dist)."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    t = jnp.clip(teacher, _EPS, 1.0)
    return jnp.mean(jnp.sum(t * (jnp.log(t) - logp), axis=-1))
