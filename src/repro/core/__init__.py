"""Core SCARLET library: aggregation (ERA / Enhanced ERA), synchronized
soft-label caching, the cache-hit-rate simulator, distillation losses and
communication accounting."""
from repro.core import cache, cache_sim, comm, era, losses  # noqa: F401
from repro.core.cache import (  # noqa: F401
    CacheState,
    CatchUpPackage,
    init_cache,
    miss_mask,
    update_global_cache,
    update_local_cache,
)
from repro.core.era import aggregate_soft_labels, enhanced_era, entropy  # noqa: F401
from repro.core.losses import cross_entropy, kl_divergence, soft_cross_entropy  # noqa: F401
