"""Synchronized soft-label caching (SCARLET Alg. 1 + Alg. 2).

Server keeps a *global cache* ``C_g[i] -> (z, t)`` over the public
dataset; clients keep mirrored *local caches* ``C_k`` driven purely by
per-round cache signals.  Implementation is functional and jit-safe:
caches are dense arrays indexed by public-sample id.

Semantics note (documented deviation): the paper's Alg. 1 computes
``I_req = {i : C_g(i) does not exist}`` and expires entries only inside
``UpdateGlobalCache``, which lets an expired entry be served stale once
and makes the client FIFO queue under/overdraw (EXPIRED pops a queue that
only holds labels for requested indices).  Appendix A's simulator
(Alg. 3) instead checks expiry at *request* time: an index misses when it
is absent **or** older than ``D``, and a miss refreshes the entry.  We
adopt the Alg.-3 semantics everywhere — it is self-consistent between
server and clients, matches the published cache-hit-rate simulation
(Fig. 3), and preserves the communication model (only missed labels are
transmitted, plus O(|P^t|) signals).

Signals (2 bits/sample):
  NEWLY_CACHED: index was absent; fresh label appended to the FIFO queue.
  CACHED:       valid entry reused; no label transmitted.
  EXPIRED:      entry was present but stale; fresh label in the queue
                replaces it (client deletes then re-caches).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

NEWLY_CACHED = jnp.int32(0)
CACHED = jnp.int32(1)
EXPIRED = jnp.int32(2)

_NEVER = jnp.int32(-(2**30))


class CacheState(NamedTuple):
    """Dense soft-label cache over the public dataset.

    values:  (|P|, N) float32 — cached soft-labels.
    ts:      (|P|,)   int32   — round at which the entry was cached.
    present: (|P|,)   bool    — whether the entry exists.
    """

    values: jnp.ndarray
    ts: jnp.ndarray
    present: jnp.ndarray

    @property
    def size(self) -> int:
        return self.values.shape[0]

    @property
    def num_classes(self) -> int:
        return self.values.shape[1]


def init_cache(public_size: int, num_classes: int, dtype=jnp.float32) -> CacheState:
    return CacheState(
        values=jnp.zeros((public_size, num_classes), dtype=dtype),
        ts=jnp.full((public_size,), _NEVER, dtype=jnp.int32),
        present=jnp.zeros((public_size,), dtype=bool),
    )


def normalize_cache_duration(D) -> int:
    """Validate a cache duration at the config boundary.

    Accepts python/numpy integers and integral floats, returns a plain
    non-negative python ``int``.  Engines call this in their
    constructors so ``miss_mask``'s static ``D == 0`` disable-caching
    branch actually fires for every spelling of zero (``np.int64(0)``,
    ``0.0``) instead of silently falling through to the expiry
    comparison, and so a negative duration fails loudly up front rather
    than expiring everything forever.
    """
    if isinstance(D, bool):
        raise TypeError("cache duration must be an integer, not a bool")
    if isinstance(D, (int, np.integer)):
        val = int(D)
    elif isinstance(D, float) and float(D).is_integer():
        val = int(D)
    else:
        raise TypeError(f"cache duration must be an integer, got {D!r}")
    if val < 0:
        raise ValueError(f"cache duration must be >= 0, got {val}")
    return val


def miss_mask(cache: CacheState, idx: jnp.ndarray, t: int | jnp.ndarray, D: int,
              *, probabilistic: bool = False,
              key: jnp.ndarray | None = None) -> jnp.ndarray:
    """True where a request must be issued (absent or expired); Alg. 3 test.

    ``D == 0`` disables caching entirely (every sample misses), matching
    the paper's D=0 baseline — whether ``D`` is a static python integer
    or a traced array.  The traced path used to fall through to the
    ``age <= D`` comparison, where ``D = 0`` lets same-round entries
    (``age == 0``) hit instead of forcing all-miss; traced zero
    durations now mask every entry stale, matching the static branch.
    Static negative durations are rejected (see
    :func:`normalize_cache_duration` for the config-boundary check).

    ``probabilistic=True`` implements the paper's §V future direction —
    per-sample stochastic expiry with hazard ``age/D`` clipped to [0,1]
    (expected lifetime comparable to the hard cutoff, but refreshes
    de-synchronize across samples, eliminating the mass-refresh waves
    that destabilize training at large D; see benchmarks/ext_prob_expiry).
    """
    present = cache.present[idx]
    age = t - cache.ts[idx]
    static_D = isinstance(D, (int, np.integer)) and not isinstance(D, bool)
    if static_D:
        if D < 0:
            raise ValueError(f"cache duration must be >= 0, got {int(D)}")
        if D == 0:
            return jnp.ones(idx.shape, dtype=bool)
    if probabilistic:
        if key is None:
            raise ValueError("probabilistic expiry needs a PRNG key")
        # traced durations guard the hazard denominator; the D == 0 case
        # is handled by the all-miss mask below, so clamping to 1 never
        # changes an observable value for valid (>= 1) durations
        denom = D if static_D else jnp.maximum(jnp.asarray(D, jnp.float32), 1.0)
        hazard = jnp.clip((age.astype(jnp.float32) - 1.0) / denom, 0.0, 1.0)
        expire = jax.random.uniform(key, idx.shape) < hazard
        fresh = jnp.logical_and(present, jnp.logical_not(expire))
    else:
        fresh = jnp.logical_and(present, age <= D)
    if not static_D:
        fresh = jnp.logical_and(fresh, jnp.asarray(D) != 0)
    return jnp.logical_not(fresh)


def request_list(cache: CacheState, idx: jnp.ndarray, t, D: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(miss_mask, I_req) for round t.  ``I_req`` is idx[miss] (dynamic
    size — only used outside jit; jitted paths consume the mask)."""
    m = miss_mask(cache, idx, t, D)
    return m, idx[m]


def cached_at(cache: CacheState, idx: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(values, present) at request positions — the shared prediction
    base both ends use for cache-delta uplink coding.

    Under Alg.-3 semantics the global cache state fully determines every
    synchronized local cache, so server and clients agree on these values
    bit-for-bit (including the *stale* value of an EXPIRED entry, which
    stays in ``values`` until the refresh overwrites it) — which is what
    lets clients transmit quantized residuals against them
    (:class:`repro.compress.CacheDeltaCodec`) instead of full labels.
    """
    return cache.values[idx], cache.present[idx]


def signals_for_round(cache: CacheState, idx: jnp.ndarray, miss: jnp.ndarray) -> jnp.ndarray:
    """Per-sample signal gamma^t for the selected indices."""
    present = cache.present[idx]
    return jnp.where(
        miss,
        jnp.where(present, EXPIRED, NEWLY_CACHED),
        CACHED,
    )


def assemble_teacher(
    cache: CacheState,
    idx: jnp.ndarray,
    fresh: jnp.ndarray,
    miss: jnp.ndarray,
) -> jnp.ndarray:
    """Assemble the full teacher set z-hat^t for idx.

    ``fresh`` is (len(idx), N): the freshly aggregated soft-labels laid
    out at the *positions of idx* (entries at non-miss positions are
    ignored).  This dense layout keeps everything jittable; the FIFO
    queue of the paper corresponds to ``fresh[miss]`` in idx order.
    """
    cached_vals = cache.values[idx]
    return jnp.where(miss[:, None], fresh, cached_vals)


def update_global_cache(
    cache: CacheState,
    idx: jnp.ndarray,
    teacher: jnp.ndarray,
    miss: jnp.ndarray,
    t,
) -> Tuple[CacheState, jnp.ndarray]:
    """UpdateGlobalCache (Alg. 2, with Alg.-3 expiry): store fresh
    entries for missed indices, return signals."""
    sig = signals_for_round(cache, idx, miss)
    values = cache.values.at[idx].set(
        jnp.where(miss[:, None], teacher, cache.values[idx])
    )
    ts = cache.ts.at[idx].set(jnp.where(miss, jnp.int32(t), cache.ts[idx]))
    present = cache.present.at[idx].set(jnp.logical_or(miss, cache.present[idx]))
    return CacheState(values, ts, present), sig


def update_local_cache(
    cache_k: CacheState,
    idx: jnp.ndarray,
    signals: jnp.ndarray,
    z_req_dense: jnp.ndarray,
    t,
) -> Tuple[CacheState, jnp.ndarray]:
    """UpdateLocalCache (Alg. 2): reconstruct teacher from signals +
    local cache + the broadcast queue, and sync the local cache.

    ``z_req_dense`` is (len(idx), N) with fresh labels at miss positions
    (the dense form of the FIFO queue; see ``pack_queue``/``unpack_queue``
    for the wire format used by comm accounting).
    Returns (new_cache, teacher).
    """
    is_miss = signals != CACHED
    teacher = jnp.where(is_miss[:, None], z_req_dense, cache_k.values[idx])
    values = cache_k.values.at[idx].set(teacher)
    ts = cache_k.ts.at[idx].set(jnp.where(is_miss, jnp.int32(t), cache_k.ts[idx]))
    present = cache_k.present.at[idx].set(True)
    return CacheState(values, ts, present), teacher


def pack_queue(z_dense: jnp.ndarray, miss: jnp.ndarray) -> jnp.ndarray:
    """Wire format: the FIFO queue actually transmitted = fresh labels at
    miss positions, in idx order (dynamic size; host-side only)."""
    return z_dense[miss]


def unpack_queue(queue: jnp.ndarray, miss: jnp.ndarray, num_classes: int) -> jnp.ndarray:
    """Inverse of ``pack_queue``: scatter queue entries back to a dense
    (len(idx), N) array (zeros at cached positions)."""
    n = miss.shape[0]
    out = jnp.zeros((n, num_classes), dtype=queue.dtype)
    pos = jnp.cumsum(miss) - 1  # queue position for each miss
    safe_pos = jnp.clip(pos, 0, max(queue.shape[0] - 1, 0))
    gathered = queue[safe_pos] if queue.shape[0] > 0 else jnp.zeros((n, num_classes), queue.dtype)
    return jnp.where(miss[:, None], gathered, out)


# ---------------------------------------------------------------------------
# Partial participation: catch-up packages (Section III-D).
# ---------------------------------------------------------------------------

class CatchUpPackage(NamedTuple):
    """Differential cache sync for a client that skipped rounds.

    The server sends, for every public index whose global-cache entry is
    newer than the client's last-synced round, the cached value and its
    timestamp.  After applying it the client is bit-identical to a client
    that participated every round (given Alg.-3 semantics, the global
    cache state fully determines local caches).
    """

    idx: jnp.ndarray     # (M,) indices to overwrite
    values: jnp.ndarray  # (M, N)
    ts: jnp.ndarray      # (M,)


def make_catch_up(cache_g: CacheState, last_sync: int) -> CatchUpPackage:
    """Entries cached strictly after ``last_sync`` (host-side, dynamic)."""
    newer = jnp.logical_and(cache_g.present, cache_g.ts > last_sync)
    idx = jnp.nonzero(newer)[0]
    return CatchUpPackage(idx=idx, values=cache_g.values[idx], ts=cache_g.ts[idx])


def apply_catch_up(cache_k: CacheState, pkg: CatchUpPackage) -> CacheState:
    values = cache_k.values.at[pkg.idx].set(pkg.values)
    ts = cache_k.ts.at[pkg.idx].set(pkg.ts)
    present = cache_k.present.at[pkg.idx].set(True)
    return CacheState(values, ts, present)


def catch_up_bytes(pkg: CatchUpPackage, bytes_per_value: float = 4.0) -> float:
    """Downlink cost of a catch-up package (values + indices + ts)."""
    m, n = pkg.values.shape
    return m * n * bytes_per_value + m * 4 + m * 4


def catch_up_bytes_device(
    cache_g: CacheState,
    last_sync: jnp.ndarray,
    part: jnp.ndarray,
    t,
    bytes_per_value: float = 4.0,
    *,
    axis_name: str | None = None,
    method: str = "dense",
) -> jnp.ndarray:
    """Total catch-up downlink bytes for this round.

    jit/scan-safe equivalent of ``make_catch_up`` + ``catch_up_bytes``
    summed over returning stragglers: for each participating client
    whose ``last_sync`` predates round ``t - 1``, count the global-cache
    entries newer than its sync point and charge values + index + ts per
    entry.  ``last_sync``/``part`` are ``(K,)``; ``t`` may be traced.

    ``method`` selects the counting kernel; both produce **bit-identical
    totals** (the per-client term is an exact small-integer count times
    the same constant, summed in client order):

    - ``"dense"`` (default, the scan/shard engines' path) materializes
      the ``(K, |P|)`` comparison matrix — fine at simulation scale;
    - ``"sorted"`` sorts the present entries' timestamps once
      (non-present entries map to a sentinel below every possible
      ``last_sync``) and counts via ``searchsorted``, using O(K + |P|)
      memory — the active-set engine's path, where K may be 10^6 and a
      K x |P| bool matrix must never materialize.

    Under a client-sharded (``shard_map``) engine, ``last_sync``/``part``
    are the shard-local ``(K_loc,)`` slices; pass ``axis_name`` to
    psum the per-shard total into the replicated global value (the cache
    itself is replicated, so per-client terms need no communication).
    """
    n_classes = cache_g.num_classes
    returning = jnp.logical_and(part, last_sync < t - 1)              # (K,)
    if method == "dense":
        newer = jnp.logical_and(cache_g.present[None, :],
                                cache_g.ts[None, :] > last_sync[:, None])  # (K, |P|)
        counts = jnp.sum(newer, axis=1).astype(jnp.float32)
    elif method == "sorted":
        # count_k = |{p : present_p and ts_p > last_sync_k}|, via one
        # sort of the |P| timestamps.  Non-present entries sink to
        # _NEVER - 1, strictly below any reachable last_sync (>= _NEVER),
        # so they can never land on the "newer" side of the split.
        ts_eff = jnp.where(cache_g.present, cache_g.ts, _NEVER - 1)
        ts_sorted = jnp.sort(ts_eff)                                   # (|P|,)
        pos = jnp.searchsorted(ts_sorted, last_sync, side="right")     # (K,)
        counts = (ts_sorted.shape[0] - pos).astype(jnp.float32)
    else:
        raise ValueError(f"unknown catch-up method {method!r}")
    per_client = counts * (n_classes * bytes_per_value + 8.0)
    total = jnp.sum(jnp.where(returning, per_client, 0.0))
    if axis_name is not None:
        total = jax.lax.psum(total, axis_name)
    return total


def catch_up_bytes_async(
    cache_g: CacheState,
    last_sync: jnp.ndarray,
    dispatch: jnp.ndarray,
    arrive: jnp.ndarray,
    t,
    bytes_per_value: float = 4.0,
    *,
    axis_name: str | None = None,
    method: str = "dense",
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Delay-aware catch-up accounting for async/buffered rounds.

    An async round syncs a client's mirrored cache twice, and each side
    is charged against the cache state *at the time the bytes actually
    flow*:

    - **dispatch side**: a dispatched client must train against the
      current cache, so any dispatched client whose ``last_sync``
      predates ``t - 1`` receives the standard catch-up package —
      literally :func:`catch_up_bytes_device` over the dispatch mask.
      The dispatch handshake then marks the client synced through the
      pre-round cache (``last_sync = t - 1``).
    - **arrival side**: a report landing at ``t`` after ``d`` rounds in
      flight returns to a cache that moved while it was away; the
      entries cached since its dispatch (``ts > t_d - 1``) are charged
      against the cache at arrival, using the dispatch-updated sync
      points.  A zero-delay arrival has ``last_sync == t - 1`` after
      the dispatch-side update, so its arrival charge is exactly 0.0.

    Returns ``(total, dispatch_bytes)`` — the engine needs the dispatch
    side alone for rounds where work was dispatched but nothing arrived.

    **Byte identity with the sync path**: when every delay is zero the
    arrival mask equals the dispatch mask, every arrival-side term is
    exactly ``0.0`` (the ``ts > t - 1`` comparison is against entries
    the pre-round cache cannot contain), and IEEE addition of an exact
    zero is the identity, so ``total`` is bit-for-bit the synchronous
    ``catch_up_bytes_device(cache_g, last_sync, part, t)``.  Pinned by
    tests/test_cache.py and the async↔scan conformance cells.
    """
    disp_bytes = catch_up_bytes_device(
        cache_g, last_sync, dispatch, t, bytes_per_value,
        axis_name=axis_name, method=method)
    t_arr = jnp.asarray(t, last_sync.dtype)
    ls_mid = jnp.where(dispatch, t_arr - 1, last_sync)
    arr_bytes = catch_up_bytes_device(
        cache_g, ls_mid, arrive, t, bytes_per_value,
        axis_name=axis_name, method=method)
    return disp_bytes + arr_bytes, disp_bytes
