"""Render a run record (``repro.obs.export.run_record``) as a report.

``fmt="markdown"`` emits GitHub-flavored tables; ``fmt="text"`` emits
aligned plain text for terminals without markdown rendering.  Both
share the same row builders so they cannot drift apart.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

__all__ = ["render"]


def _fmt_num(v: Any) -> str:
    if isinstance(v, bool):
        return str(v)
    if isinstance(v, int):
        return f"{v:,}"
    if isinstance(v, float):
        if v == 0:
            return "0"
        if abs(v) >= 1e6 or abs(v) < 1e-3:
            return f"{v:.3e}"
        return f"{v:.4g}"
    return str(v)


def _fmt_bytes(b: float) -> str:
    for unit in ("B", "KB", "MB", "GB"):
        if abs(b) < 1000.0:
            return f"{b:.2f} {unit}"
        b /= 1000.0
    return f"{b:.2f} TB"


def _table(headers: Sequence[str], rows: List[Sequence[str]],
           markdown: bool) -> List[str]:
    if markdown:
        out = ["| " + " | ".join(headers) + " |",
               "|" + "|".join("---" for _ in headers) + "|"]
        out += ["| " + " | ".join(r) + " |" for r in rows]
        return out
    widths = [max(len(h), *(len(r[i]) for r in rows)) if rows else len(h)
              for i, h in enumerate(headers)]
    out = ["  ".join(h.ljust(w) for h, w in zip(headers, widths)),
           "  ".join("-" * w for w in widths)]
    out += ["  ".join(c.ljust(w) for c, w in zip(r, widths)) for r in rows]
    return out


def _section(title: str, markdown: bool) -> List[str]:
    return [f"## {title}", ""] if markdown else [title, "-" * len(title), ""]


def _span_rows(spans: List[Dict[str, Any]]) -> List[Sequence[str]]:
    rows = []
    for s in sorted(spans, key=lambda s: s.get("start_s", 0.0)):
        indent = "  " * int(s.get("depth", 0))
        meta = s.get("meta") or {}
        rows.append((indent + s.get("name", "?"),
                     f"{s.get('start_s', 0.0):.3f}",
                     f"{s.get('dur_s', 0.0):.3f}",
                     ", ".join(f"{k}={v}" for k, v in meta.items())))
    return rows


def render(record: Dict[str, Any], fmt: str = "markdown") -> str:
    """Render a run-record dict to a markdown or plain-text report."""
    if fmt not in ("markdown", "text"):
        raise ValueError(f"unknown format {fmt!r} (want markdown|text)")
    md = fmt == "markdown"
    name = record.get("name", "run")
    lines: List[str] = []
    lines += [f"# Run report: {name}", ""] if md else \
        [f"Run report: {name}", "=" * (12 + len(str(name))), ""]

    # --- host-plane spans ---------------------------------------------
    spans = record.get("spans") or []
    if spans:
        lines += _section("Spans (host plane)", md)
        lines += _table(("span", "start [s]", "dur [s]", "meta"),
                        _span_rows(spans), md)
        lines.append("")

    hist = record.get("history") or {}

    # --- accuracy / run outcome ---------------------------------------
    if hist:
        rows: List[Sequence[str]] = []
        for key in ("final_server_acc", "final_client_acc"):
            if key in hist:
                v = hist[key]
                # None = that model was never evaluated in this run leg
                # (distinct from a measured 0.0 accuracy)
                rows.append((key, "n/a" if v is None else _fmt_num(v)))
        comm = hist.get("comm") or {}
        if comm:
            rows.append(("rounds", _fmt_num(comm.get("rounds", 0))))
            rows.append(("cumulative comm",
                         _fmt_bytes(float(comm.get("cumulative_total", 0.0)))))
            rows.append(("uplink mean/round",
                         _fmt_bytes(float(comm.get("uplink_mean", 0.0)))))
            rows.append(("downlink mean/round",
                         _fmt_bytes(float(comm.get("downlink_mean", 0.0)))))
        if rows:
            lines += _section("Run outcome", md)
            lines += _table(("metric", "value"), rows, md)
            lines.append("")

    # --- device-plane telemetry ---------------------------------------
    tel = record.get("telemetry") or {}
    summ = tel.get("summary") or {}
    if summ:
        lines += _section("Telemetry (device plane)", md)
        rows = []
        for key in ("rounds", "active_rounds", "participants_total",
                    "cache_hits", "cache_miss_new", "cache_expired",
                    "cache_hit_rate", "catch_up_clients",
                    "teacher_entropy_pre_mean", "teacher_entropy_post_mean",
                    "beta_mean", "beta_last", "codec_quant_error_mean"):
            if key in summ:
                rows.append((key, _fmt_num(summ[key])))
        for key in ("uplink_bytes", "downlink_bytes", "catch_up_bytes"):
            if key in summ:
                rows.append((key, _fmt_bytes(float(summ[key]))))
        lines += _table(("counter", "value"), rows, md)
        lines.append("")
        hist_row = summ.get("staleness_hist")
        if hist_row:
            lines += _section("Participant staleness histogram", md)
            lines += _table(
                tuple(f"{i}" if i < len(hist_row) - 1 else f">={i}"
                      for i in range(len(hist_row))),
                [tuple(_fmt_num(int(x)) for x in hist_row)], md)
            lines += ["", "(rounds since previous participation, over all "
                          "participating client-rounds)", ""]

    if len(lines) <= 3:
        lines += ["(empty record: no spans, history, or telemetry)", ""]
    return "\n".join(lines).rstrip() + "\n"
