"""Host-plane tracing: monotonic clocks and a span tracer.

Pure stdlib — importing this module never imports jax, so the launch
scripts can route their timing through :func:`now` before they set
``XLA_FLAGS`` and initialize the backend.  The opt-in
:func:`profiler_trace` hook imports jax lazily, and only when given a
log directory.

Spans are recorded as a well-nested B/E event sequence *by
construction*: ``span()`` pushes the begin event on entry and the end
event on exit, so the exported Chrome trace (Perfetto's legacy JSON
format) is always valid regardless of clock granularity — the
``python -m repro.obs validate`` check replays exactly this stack
discipline.
"""
from __future__ import annotations

import contextlib
import time
from dataclasses import dataclass, field
from typing import Any, Dict, Iterator, List, Optional

__all__ = ["now", "Span", "SpanTracer", "profiler_trace"]


def now() -> float:
    """Monotonic seconds for duration measurement.

    ``time.perf_counter()`` — unlike ``time.time()`` it never jumps on
    NTP adjustment or DST, so durations cannot go negative.  The epoch
    is arbitrary: only differences are meaningful.
    """
    return time.perf_counter()


@dataclass
class Span:
    """One completed (or still-open) span, relative to the tracer t0."""
    name: str
    start_s: float
    dur_s: float
    depth: int
    meta: Dict[str, Any] = field(default_factory=dict)

    def as_dict(self) -> Dict[str, Any]:
        return {"name": self.name, "start_s": self.start_s,
                "dur_s": self.dur_s, "depth": self.depth,
                "meta": self.meta}


class SpanTracer:
    """Nestable wall-clock spans with Chrome-trace / JSONL export.

    >>> tr = SpanTracer("demo")
    >>> with tr.span("compile", engine="scan"):
    ...     with tr.span("lower"):
    ...         pass
    >>> trace = tr.chrome_trace()   # load in ui.perfetto.dev

    All clocks are :func:`now` (monotonic); timestamps in the exported
    trace are microseconds relative to tracer construction.
    """

    def __init__(self, name: str = "run",
                 meta: Optional[Dict[str, Any]] = None):
        self.name = name
        self.meta = dict(meta or {})
        self.t0 = now()
        self.spans: List[Span] = []
        self._events: List[Dict[str, Any]] = [
            {"name": "process_name", "ph": "M", "pid": 0, "tid": 0,
             "args": {"name": f"repro.obs:{name}"}},
        ]
        self._depth = 0

    # -- recording ------------------------------------------------------
    @contextlib.contextmanager
    def span(self, name: str, **meta: Any) -> Iterator[Span]:
        """Context manager: times the enclosed block as one span."""
        start = now()
        rel = start - self.t0
        self._events.append(self._event(name, "B", rel, meta))
        self._depth += 1
        sp = Span(name, rel, 0.0, self._depth - 1, dict(meta))
        try:
            yield sp
        finally:
            self._depth -= 1
            sp.dur_s = now() - start
            self._events.append(self._event(name, "E", rel + sp.dur_s, {}))
            self.spans.append(sp)

    def record(self, name: str, start_s: float, dur_s: float,
               **meta: Any) -> Span:
        """Record an already-measured interval (``start_s`` in the
        :func:`now` clock) as a top-level span."""
        rel = start_s - self.t0
        self._events.append(self._event(name, "B", rel, meta))
        self._events.append(self._event(name, "E", rel + dur_s, {}))
        sp = Span(name, rel, dur_s, 0, dict(meta))
        self.spans.append(sp)
        return sp

    def _event(self, name: str, ph: str, rel_s: float,
               meta: Dict[str, Any]) -> Dict[str, Any]:
        ev = {"name": name, "ph": ph, "ts": rel_s * 1e6, "pid": 0, "tid": 0}
        if meta:
            ev["args"] = {k: _jsonable(v) for k, v in meta.items()}
        return ev

    # -- export views ---------------------------------------------------
    def chrome_trace(self) -> Dict[str, Any]:
        """Trace-event JSON (Chrome ``about:tracing`` / Perfetto)."""
        return {"traceEvents": list(self._events),
                "displayTimeUnit": "ms",
                "otherData": {"tracer": self.name,
                              **{k: _jsonable(v)
                                 for k, v in self.meta.items()}}}

    def jsonl_lines(self) -> List[Dict[str, Any]]:
        """One dict per completed span (newline-delimited export)."""
        return [s.as_dict() for s in self.spans]

    def total_s(self) -> float:
        return now() - self.t0


def _jsonable(v: Any) -> Any:
    return v if isinstance(v, (int, float, bool, str, type(None))) else str(v)


@contextlib.contextmanager
def profiler_trace(logdir: Optional[str] = None) -> Iterator[None]:
    """Opt-in ``jax.profiler.trace`` wrapper.

    A falsy ``logdir`` is a no-op (and keeps jax out of the import
    graph entirely); otherwise the enclosed block is profiled into
    TensorBoard/XPlane format under ``logdir``.
    """
    if not logdir:
        yield
        return
    import jax

    with jax.profiler.trace(str(logdir)):
        yield
