"""Device-plane telemetry: the ``RoundTelemetry`` pytree and its math.

The scan/shard engines compile the entire run into ONE XLA program —
nothing crosses back to the host until the stacked per-round outputs
come out of the final ``lax.scan``.  Telemetry therefore cannot be a
Python-side logger: every counter and gauge here is a fixed-shape jnp
value computed *inside* the round body, stacked by the scan like any
other ``ys`` leaf, and accumulated in the carry for running totals.
No callbacks, no dynamic shapes, no host round-trips — the static
analyzer (``repro.analysis``) proves the instrumented round body is
free of host-callback primitives.

Parity contract: every integer counter is computed from REPLICATED
full-width inputs (the global participation draw, the pre-update cache
presence/miss masks, ``last_sync``) with the identical expression in
all three engines, so host x scan x shard counter stacks are
byte-equal.  Float gauges that average over participants reduce with a
``psum`` over the client mesh axis in the sharded engine (the same
two-phase contract strategy aggregation uses) and are asserted
allclose, not byte-equal.

Everything in this module is also safe to call from host-loop numpy
code: the helpers take anything ``jnp.asarray`` accepts.
"""
from __future__ import annotations

from typing import Any, Dict, Iterable, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import era as era_lib

__all__ = [
    "STALENESS_BUCKETS",
    "RoundTelemetry",
    "TelemetryLog",
    "zeros",
    "gate",
    "accumulate",
    "participants_per_cohort",
    "cache_signal_counts",
    "returning_client_count",
    "staleness_histogram",
    "participant_mean",
    "mean_entropy",
    "codec_error_mean",
]

# staleness histogram width: bucket b counts participants whose last
# participation was b rounds before the previous round (b = t-1 -
# last_sync, clipped into the top bucket).  Fixed so the pytree shape
# is static under scan.
STALENESS_BUCKETS = 8


class RoundTelemetry(NamedTuple):
    """One round's device-resident metrics (a scan-stackable pytree).

    Integer counters (byte-equal across engines):

    - ``participants``: (n_cohorts,) participating clients per cohort;
    - ``cache_hits`` / ``cache_miss_new`` / ``cache_expired``: the
      Alg. 3 signal census over the round's public subset P^t —
      CACHED / NEWLY_CACHED / EXPIRED counts (hits + new + expired
      == |P^t| on active rounds; cache-off runs count every request
      as new);
    - ``catch_up_clients``: returning stragglers (participating with
      ``last_sync < t-1``) served a catch-up package this round;
    - ``staleness_hist``: (STALENESS_BUCKETS,) histogram of
      ``t - 1 - last_sync`` over participants (bucket 0 = was present
      last round; top bucket clips).

    Byte counters (f32, still byte-equal — every input is an exact
    small integer so f32 and f64 arithmetic agree):

    - ``uplink_bytes`` / ``downlink_bytes``: the ledger's per-round
      payloads; ``catch_up_bytes``: the catch-up share of downlink.

    Float gauges (allclose across engines — reduction order differs):

    - ``teacher_entropy_pre``: mean Shannon entropy (nats) of the
      participant-mean soft labels as the server sees them (post
      uplink codec), BEFORE strategy sharpening/aggregation;
    - ``teacher_entropy_post``: mean entropy of the aggregated teacher
      after sharpening and the downlink codec — the pre/post gap is
      the ERA/Enhanced-ERA sharpening effect the paper studies;
    - ``beta``: the resolved sharpening knob
      (:meth:`repro.fl.strategies.base.Strategy.sharpen_gauge` —
      Enhanced ERA's static or adaptive beta, ERA's temperature, 0
      where the strategy has none);
    - ``codec_quant_error``: mean |decode(encode(z)) - z| over
      participating clients' uplink entries (0 for identity codecs).
    """

    participants: jnp.ndarray
    cache_hits: jnp.ndarray
    cache_miss_new: jnp.ndarray
    cache_expired: jnp.ndarray
    catch_up_clients: jnp.ndarray
    staleness_hist: jnp.ndarray
    uplink_bytes: jnp.ndarray
    downlink_bytes: jnp.ndarray
    catch_up_bytes: jnp.ndarray
    teacher_entropy_pre: jnp.ndarray
    teacher_entropy_post: jnp.ndarray
    beta: jnp.ndarray
    codec_quant_error: jnp.ndarray


# field partition used by the conformance suite: EXACT fields must be
# byte-equal across host/scan/shard; GAUGE fields are allclose only.
EXACT_FIELDS = ("participants", "cache_hits", "cache_miss_new",
                "cache_expired", "catch_up_clients", "staleness_hist",
                "uplink_bytes", "downlink_bytes", "catch_up_bytes")
GAUGE_FIELDS = ("teacher_entropy_pre", "teacher_entropy_post", "beta",
                "codec_quant_error")


def zeros(n_cohorts: int) -> RoundTelemetry:
    """The all-zero telemetry row (outage rounds, initial carry)."""
    i0 = jnp.zeros((), jnp.int32)
    f0 = jnp.zeros((), jnp.float32)
    return RoundTelemetry(
        participants=jnp.zeros((n_cohorts,), jnp.int32),
        cache_hits=i0, cache_miss_new=i0, cache_expired=i0,
        catch_up_clients=i0,
        staleness_hist=jnp.zeros((STALENESS_BUCKETS,), jnp.int32),
        uplink_bytes=f0, downlink_bytes=f0, catch_up_bytes=f0,
        teacher_entropy_pre=f0, teacher_entropy_post=f0, beta=f0,
        codec_quant_error=f0)


def gate(tel: RoundTelemetry, keep) -> RoundTelemetry:
    """Zero the whole row unless ``keep`` (total-outage rounds must
    match the host loop's early return, which records nothing)."""
    z = zeros(tel.participants.shape[0])
    return jax.tree_util.tree_map(
        lambda a, b: jnp.where(keep, a, b), tel, z)


def accumulate(total: RoundTelemetry, tel: RoundTelemetry) -> RoundTelemetry:
    """Running totals for the scan carry (element-wise sum)."""
    return jax.tree_util.tree_map(lambda a, b: a + b, total, tel)


# ---------------------------------------------------------------------------
# counter math (replicated inputs -> byte-equal everywhere)
# ---------------------------------------------------------------------------

def participants_per_cohort(part, offsets: Sequence[int],
                            sizes: Sequence[int]) -> jnp.ndarray:
    """(n_cohorts,) participant counts from the FULL-width mask.

    ``offsets``/``sizes`` are the static cohort blocks
    (:class:`repro.fl.cohorts.ClientModels`), so plain slicing keeps
    the expression scan- and shard-safe (the sharded engine passes the
    replicated global draw, never the shard-local slice).
    """
    p = jnp.asarray(part).astype(jnp.int32)
    return jnp.stack([jnp.sum(p[off:off + n])
                      for off, n in zip(offsets, sizes)])


def cache_signal_counts(present, miss) -> Tuple[jnp.ndarray, jnp.ndarray,
                                                jnp.ndarray]:
    """(hits, newly_cached, expired) over the round's request list.

    Mirrors :func:`repro.core.cache.signals_for_round`: a non-miss is a
    CACHED hit; a miss splits into EXPIRED (was present) vs
    NEWLY_CACHED (never cached).  ``present``/``miss`` are the
    *pre-update* masks every engine already computes (``cached_at`` /
    ``miss_mask``), so the census is byte-equal by construction.
    Cache-off runs (all-miss, none present) count every request as
    newly cached.
    """
    p = jnp.asarray(present).astype(jnp.int32)
    m = jnp.asarray(miss).astype(jnp.int32)
    hits = jnp.sum(1 - m)
    expired = jnp.sum(m * p)
    new = jnp.sum(m * (1 - p))
    return hits.astype(jnp.int32), new.astype(jnp.int32), \
        expired.astype(jnp.int32)


def returning_client_count(part, last_sync, t) -> jnp.ndarray:
    """Participants whose last participation predates round ``t - 1``
    — exactly the clients :func:`repro.core.cache.catch_up_bytes_device`
    bills a catch-up package for.  Must see the PRE-update
    ``last_sync``."""
    ls = jnp.asarray(last_sync, jnp.int32)
    tt = jnp.asarray(t, jnp.int32)
    back = jnp.logical_and(jnp.asarray(part, bool), ls < tt - 1)
    return jnp.sum(back.astype(jnp.int32))


def staleness_histogram(part, last_sync, t,
                        n_buckets: int = STALENESS_BUCKETS) -> jnp.ndarray:
    """(n_buckets,) histogram of ``t - 1 - last_sync`` over this
    round's participants (pre-update ``last_sync``; top bucket clips).
    Bucket 0 therefore counts clients that were present last round."""
    ls = jnp.asarray(last_sync, jnp.int32)
    tt = jnp.asarray(t, jnp.int32)
    stale = jnp.clip(tt - 1 - ls, 0, n_buckets - 1)
    one_hot = jax.nn.one_hot(stale, n_buckets, dtype=jnp.int32)
    p = jnp.asarray(part, bool)
    return jnp.sum(jnp.where(p[:, None], one_hot, 0), axis=0)


# ---------------------------------------------------------------------------
# gauge math (participant reductions; psum on the sharded engine)
# ---------------------------------------------------------------------------

def participant_mean(z, part_f, n_part,
                     axis_name: Optional[str] = None) -> jnp.ndarray:
    """Mean of ``z`` (clients, ...) over participating clients.

    ``part_f``/``z`` may be shard-local; pass ``axis_name`` to psum the
    weighted sum over the client mesh axis (``n_part`` is already the
    replicated global count in both device engines).
    """
    zs = jnp.tensordot(jnp.asarray(part_f, jnp.float32),
                       jnp.asarray(z, jnp.float32), axes=1)
    if axis_name is not None:
        zs = jax.lax.psum(zs, axis_name)
    return zs / jnp.maximum(jnp.asarray(n_part, jnp.float32), 1.0)


def mean_entropy(p) -> jnp.ndarray:
    """Mean Shannon entropy (nats) over a (..., n_classes) batch of
    soft labels — the ERA pre/post sharpening gauge."""
    return jnp.mean(era_lib.entropy(jnp.asarray(p, jnp.float32)))


def codec_error_mean(z_post, z_pre, part_f, n_part,
                     axis_name: Optional[str] = None) -> jnp.ndarray:
    """Mean absolute uplink quantization error |decoded - transmitted|
    over participating clients' entries (0 for identity codecs)."""
    z_post = jnp.asarray(z_post, jnp.float32)
    z_pre = jnp.asarray(z_pre, jnp.float32)
    w = jnp.asarray(part_f, jnp.float32)
    err = jnp.sum(jnp.abs(z_post - z_pre)
                  * w.reshape((-1,) + (1,) * (z_post.ndim - 1)))
    if axis_name is not None:
        err = jax.lax.psum(err, axis_name)
    m = float(np.prod(z_post.shape[1:]))
    denom = jnp.maximum(jnp.asarray(n_part, jnp.float32) * m, 1.0)
    return err / denom


# ---------------------------------------------------------------------------
# host-side container
# ---------------------------------------------------------------------------

class TelemetryLog:
    """Host-side per-round telemetry record (numpy, never traced).

    The host loop ``append``s one :class:`RoundTelemetry` per round;
    the device engines build one from the scan's stacked ``ys`` via
    :meth:`from_stacked`.  Either way the log exposes the same
    ``stacks()`` / ``summary()`` / ``as_dict()`` views, so the
    conformance suite and the exporters are engine-agnostic.
    """

    def __init__(self, rounds: Optional[Iterable[RoundTelemetry]] = None):
        self._rounds: List[RoundTelemetry] = []
        for r in (rounds or []):
            self.append(r)

    def append(self, tel: RoundTelemetry) -> None:
        self._rounds.append(RoundTelemetry(
            *[np.asarray(leaf) for leaf in tel]))

    @classmethod
    def from_stacked(cls, stacked: RoundTelemetry) -> "TelemetryLog":
        """Rebuild from scan-stacked leaves (leading round axis)."""
        leaves = [np.asarray(leaf) for leaf in stacked]
        n = leaves[0].shape[0]
        return cls(RoundTelemetry(*[leaf[i] for leaf in leaves])
                   for i in range(n))

    def __len__(self) -> int:
        return len(self._rounds)

    def stacks(self) -> Dict[str, np.ndarray]:
        """field -> (T, ...) numpy stack, one row per round."""
        return {f: np.stack([np.asarray(getattr(r, f))
                             for r in self._rounds])
                for f in RoundTelemetry._fields}

    def totals(self) -> RoundTelemetry:
        acc = [np.zeros_like(np.asarray(leaf)) for leaf in self._rounds[0]]
        for r in self._rounds:
            acc = [a + np.asarray(leaf) for a, leaf in zip(acc, r)]
        return RoundTelemetry(*acc)

    def summary(self) -> Dict[str, Any]:
        """Scalar digest for reports / ``BENCH_*.json`` embedding."""
        if not self._rounds:
            return {"rounds": 0}
        s = self.stacks()
        active = s["participants"].sum(axis=1) > 0
        n_active = int(active.sum())
        requests = int(s["cache_hits"].sum() + s["cache_miss_new"].sum()
                       + s["cache_expired"].sum())

        def _mean_active(field):
            return float(s[field][active].mean()) if n_active else 0.0

        return {
            "rounds": len(self._rounds),
            "active_rounds": n_active,
            "participants_total": int(s["participants"].sum()),
            "cache_hits": int(s["cache_hits"].sum()),
            "cache_miss_new": int(s["cache_miss_new"].sum()),
            "cache_expired": int(s["cache_expired"].sum()),
            "cache_hit_rate": (float(s["cache_hits"].sum()) / requests
                               if requests else 0.0),
            "catch_up_clients": int(s["catch_up_clients"].sum()),
            "catch_up_bytes": float(s["catch_up_bytes"].sum()),
            "uplink_bytes": float(s["uplink_bytes"].sum()),
            "downlink_bytes": float(s["downlink_bytes"].sum()),
            "staleness_hist": [int(x) for x in
                               s["staleness_hist"].sum(axis=0)],
            "teacher_entropy_pre_mean": _mean_active("teacher_entropy_pre"),
            "teacher_entropy_post_mean": _mean_active("teacher_entropy_post"),
            "beta_mean": _mean_active("beta"),
            "beta_last": (float(s["beta"][active][-1]) if n_active else 0.0),
            "codec_quant_error_mean": _mean_active("codec_quant_error"),
        }

    def as_dict(self) -> Dict[str, Any]:
        """JSON-ready record (run records, ``fl_train`` dumps)."""
        return {
            "schema": 1,
            "rounds": len(self._rounds),
            "summary": self.summary(),
            "per_round": {f: np.asarray(v).tolist()
                          for f, v in self.stacks().items()},
        }
