"""CLI: ``python -m repro.obs <render|validate> ...``.

``render RECORD.json [--format markdown|text] [--out PATH]``
    Render a run record (written by ``repro.obs.export``) into a
    human-readable report.

``validate TRACE.json``
    Check an exported Chrome trace is loadable trace-event JSON with
    paired, well-nested B/E events — the CI smoke that keeps the
    exporter honest.  Exit code is nonzero on any violation.

stdlib only: neither subcommand imports jax.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, List, Tuple

from repro.obs import report as report_mod


def _cmd_render(args) -> int:
    with open(args.record) as f:
        record = json.load(f)
    if record.get("record") != "repro.obs/run":
        print(f"warning: {args.record} has no "
              f"record='repro.obs/run' marker; rendering anyway",
              file=sys.stderr)
    text = report_mod.render(record, fmt=args.format)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text)
        print(f"wrote {args.out}")
    else:
        print(text, end="")
    return 0


def validate_trace(trace: Dict[str, Any]) -> List[str]:
    """Violation messages for a parsed Chrome trace (empty = valid)."""
    problems: List[str] = []
    if not isinstance(trace, dict):
        return ["top-level document is not a trace object"]
    events = trace.get("traceEvents")
    if not isinstance(events, list):
        return ["top-level 'traceEvents' missing or not a list"]
    stacks: Dict[Tuple[Any, Any], List[str]] = {}
    n_spans = 0
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            problems.append(f"event {i}: not an object")
            continue
        ph = ev.get("ph")
        name = ev.get("name")
        if ph is None or name is None:
            problems.append(f"event {i}: missing 'ph' or 'name'")
            continue
        if ph in ("M", "C", "i", "I"):  # metadata / counters / instants
            continue
        if ph == "X":
            if "dur" not in ev or "ts" not in ev:
                problems.append(f"event {i} ({name}): X event without "
                                "ts/dur")
            continue
        if ph not in ("B", "E"):
            problems.append(f"event {i} ({name}): unsupported phase {ph!r}")
            continue
        if not isinstance(ev.get("ts"), (int, float)):
            problems.append(f"event {i} ({name}): missing numeric 'ts'")
            continue
        key = (ev.get("pid", 0), ev.get("tid", 0))
        stack = stacks.setdefault(key, [])
        if ph == "B":
            stack.append(name)
            n_spans += 1
        else:
            if not stack:
                problems.append(f"event {i}: E({name}) with empty stack "
                                f"on pid/tid {key}")
            elif stack[-1] != name:
                problems.append(f"event {i}: E({name}) does not close "
                                f"open span {stack[-1]!r} on pid/tid {key}")
                stack.pop()
            else:
                stack.pop()
    for key, stack in stacks.items():
        if stack:
            problems.append(f"unclosed span(s) on pid/tid {key}: {stack}")
    if not problems and n_spans == 0:
        problems.append("no B/E span events found")
    return problems


def _cmd_validate(args) -> int:
    try:
        with open(args.trace) as f:
            trace = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"INVALID {args.trace}: {e}")
        return 1
    problems = validate_trace(trace)
    if problems:
        print(f"INVALID {args.trace}:")
        for p in problems:
            print(f"  - {p}")
        return 1
    n = len(trace["traceEvents"])
    print(f"ok: {args.trace} ({n} events, paired B/E spans well-nested)")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="telemetry run-record renderer and trace validator")
    sub = ap.add_subparsers(dest="cmd", required=True)

    r = sub.add_parser("render", help="render a run record as a report")
    r.add_argument("record", help="run-record JSON path")
    r.add_argument("--format", choices=("markdown", "text"),
                   default="markdown")
    r.add_argument("--out", default=None, help="write instead of print")
    r.set_defaults(fn=_cmd_render)

    v = sub.add_parser("validate",
                       help="check a Chrome trace for paired B/E events")
    v.add_argument("trace", help="trace-event JSON path")
    v.set_defaults(fn=_cmd_validate)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
