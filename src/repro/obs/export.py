"""Exporters: Chrome traces, span JSONL, and self-contained run records.

A *run record* is the single JSON artifact ``python -m repro.obs
render`` consumes: run metadata + the :class:`~repro.fl.rounds.History`
dict + the telemetry log + the host-plane spans.  Everything here is
stdlib-only; inputs are plain dicts or the obs-layer objects
(duck-typed via ``as_dict`` / ``summary``), never engine types.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Dict, Optional

__all__ = ["write_chrome_trace", "write_spans_jsonl", "run_record",
           "write_run_record", "telemetry_summary"]

RUN_RECORD_KIND = "repro.obs/run"


def _ensure_dir(path: str) -> None:
    d = os.path.dirname(os.path.abspath(path))
    if d:
        os.makedirs(d, exist_ok=True)


def _as_dict(obj: Any) -> Optional[Dict[str, Any]]:
    if obj is None or isinstance(obj, dict):
        return obj
    return obj.as_dict()


def write_chrome_trace(path: str, tracer) -> str:
    """Write the tracer's trace-event JSON (Perfetto-loadable)."""
    _ensure_dir(path)
    with open(path, "w") as f:
        json.dump(tracer.chrome_trace(), f)
        f.write("\n")
    return path


def write_spans_jsonl(path: str, tracer) -> str:
    """One JSON object per completed span, newline-delimited."""
    _ensure_dir(path)
    with open(path, "w") as f:
        for line in tracer.jsonl_lines():
            f.write(json.dumps(line) + "\n")
    return path


def run_record(*, name: str, config: Any = None,
               history: Any = None, telemetry: Any = None,
               tracer=None, extra: Optional[Dict[str, Any]] = None
               ) -> Dict[str, Any]:
    """Assemble the run-record dict (see module docstring)."""
    if dataclasses.is_dataclass(config) and not isinstance(config, type):
        config = dataclasses.asdict(config)
    if telemetry is None:  # default to the history's own telemetry log
        telemetry = (history.get("telemetry") if isinstance(history, dict)
                     else getattr(history, "telemetry", None))
    rec: Dict[str, Any] = {
        "record": RUN_RECORD_KIND,
        "schema": 1,
        "name": name,
        "config": config,
        "history": _as_dict(history),
        "telemetry": _as_dict(telemetry),
        "spans": tracer.jsonl_lines() if tracer is not None else [],
    }
    if extra:
        rec.update(extra)
    return rec


def write_run_record(path: str, **kwargs: Any) -> Dict[str, Any]:
    """Build with :func:`run_record` and write it; returns the record."""
    rec = run_record(**kwargs)
    _ensure_dir(path)
    with open(path, "w") as f:
        json.dump(rec, f, indent=2, sort_keys=True)
        f.write("\n")
    return rec


def telemetry_summary(history) -> Optional[Dict[str, Any]]:
    """The telemetry summary dict off a History (or None) — the shape
    ``benchmarks._common.write_bench`` embeds in ``BENCH_*.json``."""
    tel = getattr(history, "telemetry", None)
    if tel is None:
        return None
    return tel.summary()
