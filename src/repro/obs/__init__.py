"""Run telemetry: device-resident round metrics + host-plane tracing.

Two planes, deliberately separate:

- **Device plane** (:mod:`repro.obs.device`): the ``RoundTelemetry``
  pytree the FL engines accumulate *inside* the compiled round body —
  cache hit/miss census, participation and staleness counters, payload
  bytes, teacher-entropy and sharpening gauges.  Opt-in via
  ``FLConfig.telemetry`` / ``run_method(telemetry=...)``; rides the
  ``lax.scan`` carry, so the whole run stays one XLA program with no
  host callbacks.
- **Host plane** (:mod:`repro.obs.trace` / ``export`` / ``report``):
  monotonic span tracing around compile/run/eval blocks, Chrome-trace
  (Perfetto) + JSONL exporters, run records, and the
  ``python -m repro.obs`` renderer/validator.

Importing ``repro.obs`` (or ``repro.obs.trace``) never imports jax:
launch scripts route their clocks through :func:`now` before they set
``XLA_FLAGS``.  Device-plane names are re-exported lazily.
"""
from __future__ import annotations

from typing import Any

from repro.obs.trace import Span, SpanTracer, now, profiler_trace

__all__ = [
    "now", "Span", "SpanTracer", "profiler_trace",
    # lazy (jax-importing) device-plane names
    "RoundTelemetry", "TelemetryLog",
]

_DEVICE_NAMES = ("RoundTelemetry", "TelemetryLog")


def __getattr__(name: str) -> Any:
    if name in _DEVICE_NAMES:
        from repro.obs import device

        return getattr(device, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
