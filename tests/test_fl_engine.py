"""Integration tests: the FL engine end-to-end (system behaviour)."""
import numpy as np
import pytest

from repro.fl.engine import FLConfig, STRATEGIES, run_method

CFG = FLConfig(
    n_clients=6, n_classes=6, dim=12, rounds=20, local_steps=3,
    distill_steps=3, public_size=300, public_per_round=60,
    private_size=600, alpha=0.05, cluster_scale=2.0, noise=2.0,
    eval_every=10, seed=0, hidden=32,
)

TINY = FLConfig(
    n_clients=4, n_classes=4, dim=8, rounds=2, local_steps=2,
    distill_steps=2, public_size=60, public_per_round=12,
    private_size=80, alpha=0.5, eval_every=1, seed=0, hidden=16,
)


@pytest.mark.parametrize("name", sorted(STRATEGIES))
def test_strategy_registry_smoke(name):
    """Every registered strategy runs 2 rounds and yields finite metrics."""
    h = run_method(name, TINY, rounds=2, cache_duration=3)
    d = h.as_dict()
    assert len(h.rounds) == 2
    for key in ("server_acc", "client_acc", "cumulative_mb",
                "server_val_loss", "client_val_loss"):
        vals = d[key]
        assert len(vals) > 0, (name, key)
        assert np.isfinite(vals).all(), (name, key)
    assert np.isfinite(list(d["comm"].values())).all(), name
    assert h.ledger.cumulative_total > 0, name


def test_scarlet_learns_and_saves_comm():
    h_sc = run_method("scarlet", CFG, cache_duration=10, beta=1.5)
    h_ds = run_method("dsfl", CFG, T=0.1)
    # collaboration learns something
    assert h_sc.final_server_acc > 1.5 / CFG.n_classes
    # cache cuts uplink vs DS-FL substantially
    up_sc = h_sc.ledger.summary()["uplink_mean"]
    up_ds = h_ds.ledger.summary()["uplink_mean"]
    assert up_sc < 0.75 * up_ds
    # downlink also lower
    assert h_sc.ledger.summary()["downlink_mean"] < 1.05 * h_ds.ledger.summary()["downlink_mean"]


def test_collaboration_beats_isolation():
    h_ind = run_method("individual", CFG)
    h_sc = run_method("scarlet", CFG, cache_duration=10, beta=1.5)
    assert h_sc.final_client_acc > h_ind.final_client_acc


def test_d0_equals_no_cache_comm():
    h0 = run_method("scarlet", CFG, cache_duration=0, beta=1.5)
    h_ds = run_method("dsfl", CFG, T=0.1)
    # without cache, scarlet transmits the full subset like DS-FL (same
    # soft-label payload; scarlet never sends signals when cache is off)
    assert h0.ledger.summary()["uplink_mean"] == h_ds.ledger.summary()["uplink_mean"]


def test_fedavg_comm_dominates():
    h_fa = run_method("fedavg", CFG)
    h_sc = run_method("scarlet", CFG, cache_duration=10, beta=1.5)
    assert (h_fa.ledger.summary()["cumulative_total"]
            > 3 * h_sc.ledger.summary()["cumulative_total"])


def test_caching_plugs_into_baselines():
    for method in ("cfd", "selective_fd"):
        h0 = run_method(method, CFG)
        h1 = run_method(method, CFG, use_cache=True, cache_duration=10)
        c0 = h0.ledger.summary()["cumulative_total"]
        c1 = h1.ledger.summary()["cumulative_total"]
        assert c1 < 0.85 * c0, method


def test_partial_participation_runs_with_catch_up():
    cfg = FLConfig(**{**CFG.__dict__, "participation": 0.5})
    h = run_method("scarlet", cfg, cache_duration=10, beta=1.5)
    assert h.final_server_acc >= 0.0
    # catch-up packages inflate downlink relative to full participation
    assert h.ledger.summary()["downlink_mean"] > 0


def test_quantized_uplink_is_cheap():
    h_cfd = run_method("cfd", CFG)
    h_ds = run_method("dsfl", CFG, T=0.1)
    assert (h_cfd.ledger.summary()["uplink_mean"]
            < 0.05 * h_ds.ledger.summary()["uplink_mean"])


def test_determinism_same_seed():
    h1 = run_method("scarlet", CFG, cache_duration=10, beta=1.5)
    h2 = run_method("scarlet", CFG, cache_duration=10, beta=1.5)
    assert h1.final_server_acc == pytest.approx(h2.final_server_acc, abs=1e-6)
    assert h1.ledger.summary() == h2.ledger.summary()


def test_adaptive_beta_and_probabilistic_expiry_run():
    h = run_method("scarlet", CFG, cache_duration=8, beta="adaptive", beta_max=2.0)
    assert 0.0 <= h.final_server_acc <= 1.0
    h = run_method("scarlet", CFG, cache_duration=8, beta=1.5,
                   probabilistic_expiry=True)
    assert 0.0 <= h.final_server_acc <= 1.0


def test_appendix_d_proxy_metrics_track_accuracy():
    """App. D: deployable validation proxies converge with accuracy."""
    import numpy as np

    cfg = FLConfig(**{**CFG.__dict__, "rounds": 30, "eval_every": 5})
    h = run_method("scarlet", cfg, cache_duration=8, beta=1.5)
    assert len(h.server_val_loss) == len(h.server_acc)
    assert len(h.client_val_loss) == len(h.client_acc)
    assert all(np.isfinite(h.server_val_loss)) and all(np.isfinite(h.client_val_loss))
    # client proxy decreases as training proceeds (coarse check)
    assert h.client_val_loss[-1] < h.client_val_loss[0] * 1.5


def test_zero_round_leg_reports_none_not_phantom_zero():
    """A leg that never evaluates must report final accuracies as None
    — 'not measured' — rather than a fabricated 0.0 (or, worse,
    silently running the full config because ``rounds=0`` was falsy).
    Covers all engines that accept rounds=0."""
    for engine in ("host", "scan", "async"):
        h = run_method("scarlet", TINY, cache_duration=3, rounds=0,
                       engine=engine)
        assert h.rounds == [], engine
        assert h.final_server_acc is None, engine
        assert h.final_client_acc is None, engine
    for method in ("fedavg", "individual"):
        h = run_method(method, TINY, rounds=0)
        assert h.final_server_acc is None, method
        assert h.final_client_acc is None, method


def test_individual_baseline_server_acc_is_none():
    """The no-collaboration baseline has no server model: its final
    server accuracy is None (never measured), not a phantom 0.0 that
    comparison plots would render as a real data point."""
    h = run_method("individual", TINY, rounds=2)
    assert h.final_server_acc is None
    assert h.final_client_acc is not None and h.final_client_acc > 0.0


def test_short_leg_still_measures_finals():
    """rounds < eval_every: every engine force-evaluates the final
    round of a leg, so a 1-round run yields measured floats (the
    None-vs-0.0 distinction must not eat real measurements)."""
    h = run_method("scarlet", CFG, cache_duration=3, rounds=1)  # eval_every=10
    assert isinstance(h.final_server_acc, float)
    assert isinstance(h.final_client_acc, float)
