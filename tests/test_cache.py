"""Soft-label cache invariants (paper Alg. 1/2, §III-C/D)."""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import cache as cl


def _rand_probs(rng, n, N):
    p = rng.random((n, N)) + 1e-6
    return jnp.asarray(p / p.sum(-1, keepdims=True), jnp.float32)


def test_signal_lifecycle():
    rng = np.random.default_rng(0)
    c = cl.init_cache(50, 4)
    idx = jnp.arange(10)
    D = 3
    # round 1: everything missing
    m = cl.miss_mask(c, idx, 1, D)
    assert m.all()
    z1 = _rand_probs(rng, 10, 4)
    c, sig = cl.update_global_cache(c, idx, z1, m, 1)
    assert (np.asarray(sig) == int(cl.NEWLY_CACHED)).all()
    # round 2..4: cached
    for t in (2, 3, 4):
        m = cl.miss_mask(c, idx, t, D)
        assert not m.any()
        sig = cl.signals_for_round(c, idx, m)
        assert (np.asarray(sig) == int(cl.CACHED)).all()
    # round 5: age 4 > D=3 -> expired
    m = cl.miss_mask(c, idx, 5, D)
    assert m.all()
    sig = cl.signals_for_round(c, idx, m)
    assert (np.asarray(sig) == int(cl.EXPIRED)).all()
    z2 = _rand_probs(rng, 10, 4)
    c, _ = cl.update_global_cache(c, idx, z2, m, 5)
    np.testing.assert_allclose(np.asarray(c.values[idx]), np.asarray(z2))


def test_d_zero_disables_cache():
    c = cl.init_cache(10, 3)
    idx = jnp.arange(5)
    z = _rand_probs(np.random.default_rng(1), 5, 3)
    c, _ = cl.update_global_cache(c, idx, z, cl.miss_mask(c, idx, 1, 0), 1)
    assert cl.miss_mask(c, idx, 2, 0).all()


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 12), st.integers(2, 30))
def test_local_cache_reconstructs_server_teacher(seed, D, rounds):
    """Bit-exact sync invariant: a client applying signals + queue each
    round reconstructs exactly the server's assembled teacher, and local
    cache state equals global cache state."""
    rng = np.random.default_rng(seed)
    P, N, m = 40, 5, 12
    cg = cl.init_cache(P, N)
    ck = cl.init_cache(P, N)
    for t in range(1, rounds + 1):
        idx = jnp.asarray(np.sort(rng.choice(P, m, replace=False)))
        miss = cl.miss_mask(cg, idx, t, D)
        fresh = _rand_probs(rng, m, N)
        teacher_srv = cl.assemble_teacher(cg, idx, fresh, miss)
        cg, sig = cl.update_global_cache(cg, idx, teacher_srv, miss, t)
        # wire format: queue of missed labels only
        queue = cl.pack_queue(teacher_srv, np.asarray(miss))
        dense = cl.unpack_queue(queue, miss, N)
        ck, teacher_cli = cl.update_local_cache(ck, idx, sig, dense, t)
        np.testing.assert_allclose(np.asarray(teacher_cli), np.asarray(teacher_srv),
                                   rtol=0, atol=0)
    np.testing.assert_array_equal(np.asarray(cg.values), np.asarray(ck.values))
    np.testing.assert_array_equal(np.asarray(cg.present), np.asarray(ck.present))


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 8))
def test_catch_up_resyncs_stale_client(seed, skip):
    """Section III-D: a client offline for ``skip`` rounds, after applying
    the catch-up package, matches the global cache exactly."""
    rng = np.random.default_rng(seed)
    P, N, m, D = 30, 4, 10, 6
    cg = cl.init_cache(P, N)
    ck = cl.init_cache(P, N)
    last_sync = 0
    for t in range(1, 4):  # synced rounds
        idx = jnp.asarray(np.sort(rng.choice(P, m, replace=False)))
        miss = cl.miss_mask(cg, idx, t, D)
        fresh = _rand_probs(rng, m, N)
        teacher = cl.assemble_teacher(cg, idx, fresh, miss)
        cg, sig = cl.update_global_cache(cg, idx, teacher, miss, t)
        dense = cl.unpack_queue(cl.pack_queue(teacher, np.asarray(miss)), miss, N)
        ck, _ = cl.update_local_cache(ck, idx, sig, dense, t)
        last_sync = t
    for t in range(4, 4 + skip):  # client offline
        idx = jnp.asarray(np.sort(rng.choice(P, m, replace=False)))
        miss = cl.miss_mask(cg, idx, t, D)
        fresh = _rand_probs(rng, m, N)
        teacher = cl.assemble_teacher(cg, idx, fresh, miss)
        cg, _ = cl.update_global_cache(cg, idx, teacher, miss, t)
    pkg = cl.make_catch_up(cg, last_sync)
    ck = cl.apply_catch_up(ck, pkg)
    live = np.asarray(cg.present)
    np.testing.assert_array_equal(np.asarray(cg.values)[live],
                                  np.asarray(ck.values)[live])
    assert cl.catch_up_bytes(pkg) >= 0


def test_assemble_prefers_cache_for_hits():
    rng = np.random.default_rng(3)
    c = cl.init_cache(20, 3)
    idx = jnp.arange(6)
    z1 = _rand_probs(rng, 6, 3)
    c, _ = cl.update_global_cache(c, idx, z1, cl.miss_mask(c, idx, 1, 5), 1)
    z2 = _rand_probs(rng, 6, 3)
    miss = cl.miss_mask(c, idx, 2, 5)  # all hits
    teacher = cl.assemble_teacher(c, idx, z2, miss)
    np.testing.assert_allclose(np.asarray(teacher), np.asarray(z1))


def test_probabilistic_expiry_never_expires_fresh_and_always_expires_old():
    import jax

    rng = np.random.default_rng(5)
    c = cl.init_cache(50, 4)
    idx = jnp.arange(20)
    z = _rand_probs(rng, 20, 4)
    c, _ = cl.update_global_cache(c, idx, z, cl.miss_mask(c, idx, 1, 10), 1)
    key = jax.random.PRNGKey(0)
    # age 1 -> hazard 0: never expires
    m = cl.miss_mask(c, idx, 2, 10, probabilistic=True, key=key)
    assert not np.asarray(m).any()
    # age >> D -> hazard 1: always expires
    m = cl.miss_mask(c, idx, 100, 10, probabilistic=True, key=key)
    assert np.asarray(m).all()
    # intermediate age: some expire, deterministically under the same key
    m1 = cl.miss_mask(c, idx, 6, 10, probabilistic=True, key=key)
    m2 = cl.miss_mask(c, idx, 6, 10, probabilistic=True, key=key)
    np.testing.assert_array_equal(np.asarray(m1), np.asarray(m2))


# ---------------------------------------------------------------------------
# Traced-D miss_mask (the D=0 expiry bug) + config-boundary validation
# ---------------------------------------------------------------------------

def test_miss_mask_traced_d_zero_disables_cache():
    """D=0 must disable caching even when D arrives as a traced array
    (a jitted caller passing jnp.int32(0)).  The traced path used to
    fall through to the ``age <= D`` comparison, where same-round
    entries (age 0) counted as fresh hits."""
    import jax

    c = cl.init_cache(10, 3)
    idx = jnp.arange(5)
    z = _rand_probs(np.random.default_rng(1), 5, 3)
    c, _ = cl.update_global_cache(c, idx, z, jnp.ones(5, bool), 2)

    miss = jax.jit(lambda cg, D: cl.miss_mask(cg, idx, 2, D))(c, jnp.int32(0))
    assert np.asarray(miss).all()
    # nonzero traced D still honors the expiry window
    miss = jax.jit(lambda cg, D: cl.miss_mask(cg, idx, 2, D))(c, jnp.int32(3))
    assert not np.asarray(miss).any()


def test_miss_mask_static_negative_d_rejected():
    import pytest

    c = cl.init_cache(10, 3)
    with pytest.raises(ValueError, match="cache duration"):
        cl.miss_mask(c, jnp.arange(5), 1, -2)


def test_normalize_cache_duration():
    import pytest

    assert cl.normalize_cache_duration(3) == 3
    assert cl.normalize_cache_duration(np.int64(7)) == 7
    assert cl.normalize_cache_duration(5.0) == 5  # integral float ok
    assert cl.normalize_cache_duration(0) == 0
    with pytest.raises(ValueError):
        cl.normalize_cache_duration(-1)
    with pytest.raises(TypeError):
        cl.normalize_cache_duration(2.5)
    with pytest.raises(TypeError):
        cl.normalize_cache_duration(True)  # bool is not a duration
    with pytest.raises(TypeError):
        cl.normalize_cache_duration("3")


# ---------------------------------------------------------------------------
# Delay-aware catch-up accounting (async engine's ledger primitive)
# ---------------------------------------------------------------------------

def _cache_with_entries(ts_by_slot):
    """A 3-class cache whose slot i holds an entry stamped ts_by_slot[i]
    (0 = absent)."""
    rng = np.random.default_rng(9)
    c = cl.init_cache(len(ts_by_slot), 3)
    for slot, ts in enumerate(ts_by_slot):
        if ts:
            z = _rand_probs(rng, 1, 3)
            c, _ = cl.update_global_cache(
                c, jnp.asarray([slot]), z, jnp.asarray([True]), ts)
    return c


def test_catch_up_bytes_async_zero_delay_is_bitwise_sync():
    """dispatch == arrive (every report lands in its own window): the
    async total must be BIT-IDENTICAL to the synchronous charge — the
    arrival side is exactly 0.0 because the dispatch handshake already
    synced everyone through t-1 and the pre-round cache holds nothing
    newer."""
    c = _cache_with_entries([1, 3, 4, 0, 2])
    last_sync = jnp.asarray([0, 2, 4, 1], jnp.int32)
    part = jnp.asarray([True, True, False, True])
    t = 5
    sync = cl.catch_up_bytes_device(c, last_sync, part, t)
    total, disp = cl.catch_up_bytes_async(c, last_sync, part, part, t)
    assert float(total) == float(sync)
    assert float(disp) == float(sync)


def test_catch_up_bytes_async_charges_flight_window_entries():
    """A client dispatched at t_d whose report lands at t > t_d owes an
    arrival-side charge for exactly the entries cached in (t_d - 1, t],
    valued at per-entry cost = n_classes * 4 + 8 bytes."""
    # entries stamped 1..4 in slots 0..3; slot 4 empty
    c = _cache_with_entries([1, 2, 3, 4, 0])
    per_entry = 3 * 4.0 + 8.0
    # client 0 dispatched at t_d=3 (last_sync already moved to 2 by its
    # dispatch round), report arrives at t=5: entries with ts > 2 are
    # the ts=3 and ts=4 ones -> 2 * per_entry, charged at arrival only
    last_sync = jnp.asarray([2], jnp.int32)
    dispatch = jnp.asarray([False])  # in flight: not re-dispatched
    arrive = jnp.asarray([True])
    total, disp = cl.catch_up_bytes_async(c, last_sync, dispatch, arrive, 5)
    assert float(disp) == 0.0
    assert float(total) == 2 * per_entry
    # same round, the client ALSO re-dispatched after arrival windows
    # don't overlap -- dispatch side charges ts > last_sync for a
    # returning straggler, arrival side then sees ls_mid = t-1 (nothing
    # newer) and charges zero
    total2, disp2 = cl.catch_up_bytes_async(
        c, last_sync, jnp.asarray([True]), jnp.asarray([True]), 5)
    assert float(disp2) == 2 * per_entry
    assert float(total2) == float(disp2)


def test_catch_up_bytes_async_methods_agree():
    c = _cache_with_entries([1, 0, 3, 4, 2, 0, 5])
    last_sync = jnp.asarray([0, 3, 1, 5], jnp.int32)
    dispatch = jnp.asarray([True, False, True, False])
    arrive = jnp.asarray([False, True, True, True])
    dense = cl.catch_up_bytes_async(c, last_sync, dispatch, arrive, 6,
                                    method="dense")
    srt = cl.catch_up_bytes_async(c, last_sync, dispatch, arrive, 6,
                                  method="sorted")
    assert float(dense[0]) == float(srt[0])
    assert float(dense[1]) == float(srt[1])
