"""Soft-label cache invariants (paper Alg. 1/2, §III-C/D)."""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import cache as cl


def _rand_probs(rng, n, N):
    p = rng.random((n, N)) + 1e-6
    return jnp.asarray(p / p.sum(-1, keepdims=True), jnp.float32)


def test_signal_lifecycle():
    rng = np.random.default_rng(0)
    c = cl.init_cache(50, 4)
    idx = jnp.arange(10)
    D = 3
    # round 1: everything missing
    m = cl.miss_mask(c, idx, 1, D)
    assert m.all()
    z1 = _rand_probs(rng, 10, 4)
    c, sig = cl.update_global_cache(c, idx, z1, m, 1)
    assert (np.asarray(sig) == int(cl.NEWLY_CACHED)).all()
    # round 2..4: cached
    for t in (2, 3, 4):
        m = cl.miss_mask(c, idx, t, D)
        assert not m.any()
        sig = cl.signals_for_round(c, idx, m)
        assert (np.asarray(sig) == int(cl.CACHED)).all()
    # round 5: age 4 > D=3 -> expired
    m = cl.miss_mask(c, idx, 5, D)
    assert m.all()
    sig = cl.signals_for_round(c, idx, m)
    assert (np.asarray(sig) == int(cl.EXPIRED)).all()
    z2 = _rand_probs(rng, 10, 4)
    c, _ = cl.update_global_cache(c, idx, z2, m, 5)
    np.testing.assert_allclose(np.asarray(c.values[idx]), np.asarray(z2))


def test_d_zero_disables_cache():
    c = cl.init_cache(10, 3)
    idx = jnp.arange(5)
    z = _rand_probs(np.random.default_rng(1), 5, 3)
    c, _ = cl.update_global_cache(c, idx, z, cl.miss_mask(c, idx, 1, 0), 1)
    assert cl.miss_mask(c, idx, 2, 0).all()


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 12), st.integers(2, 30))
def test_local_cache_reconstructs_server_teacher(seed, D, rounds):
    """Bit-exact sync invariant: a client applying signals + queue each
    round reconstructs exactly the server's assembled teacher, and local
    cache state equals global cache state."""
    rng = np.random.default_rng(seed)
    P, N, m = 40, 5, 12
    cg = cl.init_cache(P, N)
    ck = cl.init_cache(P, N)
    for t in range(1, rounds + 1):
        idx = jnp.asarray(np.sort(rng.choice(P, m, replace=False)))
        miss = cl.miss_mask(cg, idx, t, D)
        fresh = _rand_probs(rng, m, N)
        teacher_srv = cl.assemble_teacher(cg, idx, fresh, miss)
        cg, sig = cl.update_global_cache(cg, idx, teacher_srv, miss, t)
        # wire format: queue of missed labels only
        queue = cl.pack_queue(teacher_srv, np.asarray(miss))
        dense = cl.unpack_queue(queue, miss, N)
        ck, teacher_cli = cl.update_local_cache(ck, idx, sig, dense, t)
        np.testing.assert_allclose(np.asarray(teacher_cli), np.asarray(teacher_srv),
                                   rtol=0, atol=0)
    np.testing.assert_array_equal(np.asarray(cg.values), np.asarray(ck.values))
    np.testing.assert_array_equal(np.asarray(cg.present), np.asarray(ck.present))


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 8))
def test_catch_up_resyncs_stale_client(seed, skip):
    """Section III-D: a client offline for ``skip`` rounds, after applying
    the catch-up package, matches the global cache exactly."""
    rng = np.random.default_rng(seed)
    P, N, m, D = 30, 4, 10, 6
    cg = cl.init_cache(P, N)
    ck = cl.init_cache(P, N)
    last_sync = 0
    for t in range(1, 4):  # synced rounds
        idx = jnp.asarray(np.sort(rng.choice(P, m, replace=False)))
        miss = cl.miss_mask(cg, idx, t, D)
        fresh = _rand_probs(rng, m, N)
        teacher = cl.assemble_teacher(cg, idx, fresh, miss)
        cg, sig = cl.update_global_cache(cg, idx, teacher, miss, t)
        dense = cl.unpack_queue(cl.pack_queue(teacher, np.asarray(miss)), miss, N)
        ck, _ = cl.update_local_cache(ck, idx, sig, dense, t)
        last_sync = t
    for t in range(4, 4 + skip):  # client offline
        idx = jnp.asarray(np.sort(rng.choice(P, m, replace=False)))
        miss = cl.miss_mask(cg, idx, t, D)
        fresh = _rand_probs(rng, m, N)
        teacher = cl.assemble_teacher(cg, idx, fresh, miss)
        cg, _ = cl.update_global_cache(cg, idx, teacher, miss, t)
    pkg = cl.make_catch_up(cg, last_sync)
    ck = cl.apply_catch_up(ck, pkg)
    live = np.asarray(cg.present)
    np.testing.assert_array_equal(np.asarray(cg.values)[live],
                                  np.asarray(ck.values)[live])
    assert cl.catch_up_bytes(pkg) >= 0


def test_assemble_prefers_cache_for_hits():
    rng = np.random.default_rng(3)
    c = cl.init_cache(20, 3)
    idx = jnp.arange(6)
    z1 = _rand_probs(rng, 6, 3)
    c, _ = cl.update_global_cache(c, idx, z1, cl.miss_mask(c, idx, 1, 5), 1)
    z2 = _rand_probs(rng, 6, 3)
    miss = cl.miss_mask(c, idx, 2, 5)  # all hits
    teacher = cl.assemble_teacher(c, idx, z2, miss)
    np.testing.assert_allclose(np.asarray(teacher), np.asarray(z1))


def test_probabilistic_expiry_never_expires_fresh_and_always_expires_old():
    import jax

    rng = np.random.default_rng(5)
    c = cl.init_cache(50, 4)
    idx = jnp.arange(20)
    z = _rand_probs(rng, 20, 4)
    c, _ = cl.update_global_cache(c, idx, z, cl.miss_mask(c, idx, 1, 10), 1)
    key = jax.random.PRNGKey(0)
    # age 1 -> hazard 0: never expires
    m = cl.miss_mask(c, idx, 2, 10, probabilistic=True, key=key)
    assert not np.asarray(m).any()
    # age >> D -> hazard 1: always expires
    m = cl.miss_mask(c, idx, 100, 10, probabilistic=True, key=key)
    assert np.asarray(m).all()
    # intermediate age: some expire, deterministically under the same key
    m1 = cl.miss_mask(c, idx, 6, 10, probabilistic=True, key=key)
    m2 = cl.miss_mask(c, idx, 6, 10, probabilistic=True, key=key)
    np.testing.assert_array_equal(np.asarray(m1), np.asarray(m2))
