"""Unit tests for the roofline hardware model and the HLO collective
byte parser (``repro.launch.roofline``)."""
import pytest

from repro.launch import roofline as rl
from repro.launch.hlo_analysis import HloSummary


# ---------------------------------------------------------------------------
# collective_bytes HLO line parsing
# ---------------------------------------------------------------------------

def test_collective_bytes_async_start_counted_once():
    """Async collectives appear as ``-start`` / ``-done`` pairs; only the
    ``-start`` line carries the opcode match — the ``-done`` wrapper must
    not double-count the transfer."""
    hlo = """
  ar-start = f32[8,32]{1,0} all-reduce-start(f32[8,32]{1,0} p0), to_apply=add
  ar-done = f32[8,32]{1,0} all-reduce-done(f32[8,32]{1,0} ar-start)
"""
    total, by_kind, counts = rl.collective_bytes(hlo)
    assert counts["all-reduce"] == 1
    assert total == pytest.approx(8 * 32 * 4)


def test_collective_bytes_fusion_names_not_miscounted():
    """Instruction *names* containing a collective substring (fusion
    names, computation labels) must not match — only the opcode on the
    right-hand side does."""
    hlo = """
  fused_all-reduce.1 = f32[64]{0} fusion(f32[64]{0} p0), kind=kLoop, calls=c1
  all-gather.clone = f32[16,4]{1,0} add(f32[16,4]{1,0} a, f32[16,4]{1,0} b)
  real = f32[16]{0} all-gather(f32[4]{0} p1), dimensions={0}
"""
    total, by_kind, counts = rl.collective_bytes(hlo)
    assert counts["all-reduce"] == 0
    assert counts["all-gather"] == 1
    assert total == pytest.approx(16 * 4)  # max shape on the real line


def test_collective_bytes_scalar_shape():
    """Scalar ``f32[]`` shapes (e.g. a psum'd scalar count) parse as one
    element, not zero."""
    hlo = "  r = f32[] all-reduce(f32[] p0), to_apply=add\n"
    total, by_kind, counts = rl.collective_bytes(hlo)
    assert counts["all-reduce"] == 1
    assert total == pytest.approx(4)


def test_collective_bytes_ignores_non_collectives():
    hlo = """
  d = f32[128,128]{1,0} dot(f32[128,64]{1,0} a, f32[64,128]{1,0} b)
  e = f32[128]{0} add(f32[128]{0} x, f32[128]{0} y)
"""
    total, _, counts = rl.collective_bytes(hlo)
    assert total == 0 and sum(counts.values()) == 0


# ---------------------------------------------------------------------------
# HardwareSpec presets + threading
# ---------------------------------------------------------------------------

def test_presets_and_resolve():
    assert rl.resolve_hw(None) is rl.DEFAULT_HW
    assert rl.resolve_hw("tpu_v4").peak_flops == pytest.approx(275e12)
    spec = rl.HardwareSpec("custom", 1e12, 1e11, 1e10)
    assert rl.resolve_hw(spec) is spec
    with pytest.raises(ValueError, match="unknown hardware preset"):
        rl.resolve_hw("gpu_h100")


def test_legacy_constants_alias_default_hw():
    """Pre-HardwareSpec callers read module constants; they must stay
    the v5e defaults."""
    assert rl.PEAK_FLOPS == rl.HW_PRESETS["tpu_v5e"].peak_flops
    assert rl.HBM_BW == rl.HW_PRESETS["tpu_v5e"].hbm_bw
    assert rl.LINK_BW == rl.HW_PRESETS["tpu_v5e"].link_bw


def _summary(**kw):
    base = dict(dot_flops=0.0, transcendental_elems=0, collective_bytes=0.0,
                collective_by_kind={}, collective_counts={},
                residual_while_loops=0)
    base.update(kw)
    return HloSummary(**base)


def test_hw_threads_through_roofline_terms():
    """The same program must produce hardware-dependent rate terms (the
    hard-coded v5e peaks were the bug)."""
    s = _summary(dot_flops=275e12, collective_bytes=100e9)
    common = dict(arch="x", shape="s", mesh_name="m", scheme="tp", chips=1,
                  summary=s, bytes_accessed=819e9, xla_flops=0.0,
                  model_flops=0.0, bytes_per_device=0.0)
    v5e = rl.compute_roofline_from_summary(**common)  # default hw
    v4 = rl.compute_roofline_from_summary(**common, hw="tpu_v4")
    assert v5e.hw == "tpu_v5e" and v4.hw == "tpu_v4"
    assert v4.compute_s == pytest.approx(1.0)                      # 275/275
    assert v5e.compute_s == pytest.approx(275.0 / 197.0, rel=1e-6)
    assert v5e.memory_s == pytest.approx(1.0)                      # 819/819
    assert v4.collective_s == pytest.approx(1.0)                   # 100/100
    assert v5e.collective_s == pytest.approx(2.0)                  # 100/50


def test_hw_changes_bottleneck_verdict():
    """A memory-vs-collective tie on one chip flips on another — the
    whole point of parameterizing the peaks."""
    # v5e (819 GB/s HBM, 50 GB/s link): memory term wins;
    # v5p (2765 GB/s HBM, 100 GB/s link): HBM got 3.4x faster but the
    # link only 2x, so the same program becomes collective-bound
    s = _summary(collective_bytes=50e9)
    common = dict(arch="x", shape="s", mesh_name="m", scheme="tp", chips=1,
                  summary=s, bytes_accessed=1000e9, xla_flops=0.0,
                  model_flops=0.0, bytes_per_device=0.0)
    assert rl.compute_roofline_from_summary(**common).bottleneck == "memory"
    assert rl.compute_roofline_from_summary(
        **common, hw="tpu_v5p").bottleneck == "collective"
