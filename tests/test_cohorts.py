"""Client-model cohort subsystem (``repro.fl.cohorts``).

Two layers:

- deterministic unit tests of :class:`CohortSpec` validation,
  :class:`ClientModels` index maps / split-concat plumbing, and the
  heterogeneous data path (per-cohort param shapes, per-cohort History
  metrics, api shorthand, baseline rejection);
- a hypothesis property pinning the **legacy-equivalence invariant**:
  for random widths/depths/seeds, a run configured with an explicit
  single-cohort ``CohortSpec`` is *bit-identical* — ledger bytes, final
  cache state, sync bookkeeping, and every History metric — to the same
  config expressed through the legacy homogeneous ``(hidden,
  mlp_depth)`` fields, on all three engines.  ``ClientModels.split`` /
  ``concat`` are the identity for one cohort, so the traced programs
  must be the same; any slice/concat sneaking into the homogeneous path
  breaks this test before it breaks the golden fixtures.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.fl import (
    ClientModels,
    CohortSpec,
    FederatedDistillation,
    FLConfig,
    ScannedFederatedDistillation,
    Scenario,
    ShardedFederatedDistillation,
    bernoulli_participation,
    resolve_cohorts,
    run_method,
)
from repro.fl.strategies import STRATEGIES

CFG = FLConfig(n_clients=4, n_classes=4, dim=8, rounds=3, local_steps=2,
               distill_steps=2, public_size=48, public_per_round=10,
               private_size=64, alpha=0.5, eval_every=2, seed=0, hidden=12,
               mesh_spec="2x4")


# ---------------------------------------------------------------------------
# CohortSpec / resolve_cohorts validation
# ---------------------------------------------------------------------------

def test_resolve_default_is_single_legacy_cohort():
    assert resolve_cohorts(CFG) == (CohortSpec(4, 12, 2),)


def test_resolve_rejects_size_mismatch():
    cfg = dataclasses.replace(
        CFG, cohorts=(CohortSpec(3, 12, 2), CohortSpec(3, 8, 1)))
    with pytest.raises(ValueError, match="sum to 6"):
        resolve_cohorts(cfg)


@pytest.mark.parametrize("bad", [
    CohortSpec(0, 12, 2),
    CohortSpec(4, 0, 2),
    CohortSpec(4, 12, -1),
    CohortSpec(4, 12, 2, family="resnet50"),
])
def test_spec_validation_rejects(bad):
    with pytest.raises(ValueError):
        bad.validate()


def test_index_maps():
    m = ClientModels((CohortSpec(3, 16, 2), CohortSpec(2, 8, 1),
                      CohortSpec(4, 24, 3)), dim=8, n_classes=4)
    assert m.n_clients == 9
    assert m.offsets == (0, 3, 5)
    assert m.slices == (slice(0, 3), slice(3, 5), slice(5, 9))
    np.testing.assert_array_equal(m.cohort_of(),
                                  [0, 0, 0, 1, 1, 2, 2, 2, 2])
    arr = jnp.arange(9)
    parts = m.split(arr)
    assert [p.tolist() for p in parts] == [[0, 1, 2], [3, 4], [5, 6, 7, 8]]
    np.testing.assert_array_equal(m.concat(parts), arr)
    assert m.shard_sizes(1) == (3, 2, 4)
    with pytest.raises(ValueError, match="not divisible over"):
        m.shard_sizes(2)


def test_split_concat_are_identity_for_single_cohort():
    """The homogeneous path must not grow slice/concat ops — identity on
    the SAME array object keeps the traced program bit-identical to the
    pre-cohort engines."""
    m = ClientModels((CohortSpec(4, 12, 2),), dim=8, n_classes=4)
    arr = jnp.arange(4.0)
    assert m.split(arr)[0] is arr
    assert m.concat([arr]) is arr


def test_init_params_shapes_and_key_stream():
    """Per-cohort stacked params: right widths per cohort, and each
    client consumes the same global key it would in a homogeneous run."""
    m = ClientModels((CohortSpec(2, 16, 2), CohortSpec(2, 12, 2)),
                     dim=8, n_classes=4)
    keys = jax.random.split(jax.random.PRNGKey(0), 4)
    params = m.init_params(keys)
    assert params[0]["w1"].shape == (2, 16, 16)
    assert params[1]["w1"].shape == (2, 12, 12)
    # cohort 1's client 0 is global client 2: same key -> same leading
    # row as a width-12 cohort starting at that key
    m2 = ClientModels((CohortSpec(2, 12, 2),), dim=8, n_classes=4)
    ref = m2.init_params(keys[2:])
    np.testing.assert_array_equal(params[1]["w0"], ref[0]["w0"])


def test_param_counts():
    m = ClientModels((CohortSpec(1, 16, 2), CohortSpec(1, 8, 0)),
                     dim=8, n_classes=4)
    # 8*16+16 + 16*16+16 + 16*4+4 = 484 ; depth 0 -> linear: 8*4+4 = 36
    assert m.param_counts() == (484, 36)


# ---------------------------------------------------------------------------
# Heterogeneous runs: data path + api plumbing
# ---------------------------------------------------------------------------

def test_heterogeneous_run_per_cohort_metrics():
    cohorts = (CohortSpec(2, 16, 2), CohortSpec(2, 8, 1))
    h = run_method("scarlet", CFG, cache_duration=3, beta=1.5,
                   engine="scan", cohorts=cohorts)
    assert all(len(row) == 2 for row in h.cohort_client_acc)
    assert len(h.cohort_client_acc) == len(h.rounds)
    # the weighted cohort means recompose the global client accuracy
    for row, ca in zip(h.cohort_client_acc, h.client_acc):
        assert abs(np.average(row, weights=[2, 2]) - ca) < 1e-5


def test_engine_params_are_per_cohort():
    cohorts = (CohortSpec(2, 16, 2), CohortSpec(2, 8, 1))
    cfg = dataclasses.replace(CFG, cohorts=cohorts)
    eng = FederatedDistillation(cfg, STRATEGIES["scarlet"](beta=1.5),
                                cache_duration=3)
    assert len(eng.client_params) == 2
    assert eng.client_params[0]["w1"].shape == (2, 16, 16)
    assert eng.client_params[1]["w0"].shape == (2, 8, 8)
    assert eng.models.describe() == "2xmlp(h=16,d=2) + 2xmlp(h=8,d=1)"


def test_shard_auto_mesh_respects_cohort_blocks():
    """``mesh_spec="auto"`` must never reject a cohort mix: it sizes the
    data axis from the gcd of the cohort sizes (2 here, even with 8
    local devices and K=4 divisible by 4)."""
    cfg = dataclasses.replace(
        CFG, mesh_spec="auto",
        cohorts=(CohortSpec(2, 24, 3), CohortSpec(2, 8, 1)))
    eng = ShardedFederatedDistillation(
        cfg, STRATEGIES["scarlet"](beta=1.5), cache_duration=3)
    assert eng.n_shards == 2
    eng.run(1)


def test_baselines_reject_cohorts():
    cohorts = (CohortSpec(2, 16, 2), CohortSpec(2, 8, 1))
    for method in ("fedavg", "individual"):
        with pytest.raises(ValueError, match="homogeneous"):
            run_method(method, CFG, cohorts=cohorts)


# ---------------------------------------------------------------------------
# Legacy-equivalence property: single cohort == pre-cohort path, bitwise
# ---------------------------------------------------------------------------

def _run_pair(cfg_legacy, engine):
    """(legacy-config run, explicit-single-cohort run) on one engine."""
    cohort_cfg = dataclasses.replace(
        cfg_legacy,
        cohorts=(CohortSpec(cfg_legacy.n_clients, cfg_legacy.hidden,
                            cfg_legacy.mlp_depth),))
    out = []
    for cfg in (cfg_legacy, cohort_cfg):
        kw = dict(cache_duration=3,
                  scenario=Scenario(participation=bernoulli_participation(0.5)))
        if engine is FederatedDistillation:
            kw["rng_backend"] = "jax"
        eng = engine(cfg, STRATEGIES["scarlet"](beta=1.5), **kw)
        out.append((eng, eng.run()))
    return out


def _assert_bit_identical(a, b):
    (eng_a, hist_a), (eng_b, hist_b) = a, b
    np.testing.assert_array_equal([r.uplink for r in hist_a.ledger.rounds],
                                  [r.uplink for r in hist_b.ledger.rounds])
    np.testing.assert_array_equal([r.downlink for r in hist_a.ledger.rounds],
                                  [r.downlink for r in hist_b.ledger.rounds])
    assert hist_a.rounds == hist_b.rounds
    assert hist_a.server_acc == hist_b.server_acc
    assert hist_a.client_acc == hist_b.client_acc
    assert hist_a.cohort_client_acc == hist_b.cohort_client_acc
    assert hist_a.server_val_loss == hist_b.server_val_loss
    assert hist_a.client_val_loss == hist_b.client_val_loss
    for f in ("present", "ts", "values"):
        np.testing.assert_array_equal(
            np.asarray(getattr(eng_a.cache_g, f)),
            np.asarray(getattr(eng_b.cache_g, f)))
    np.testing.assert_array_equal(eng_a.last_sync, eng_b.last_sync)
    for x, y in zip(jax.tree_util.tree_leaves(eng_a.client_params),
                    jax.tree_util.tree_leaves(eng_b.client_params)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@settings(max_examples=4, deadline=None)
@given(hidden=st.integers(4, 24), depth=st.integers(0, 3),
       seed=st.integers(0, 2 ** 16))
def test_single_cohort_bit_identical_host(hidden, depth, seed):
    cfg = dataclasses.replace(CFG, hidden=hidden, mlp_depth=depth, seed=seed)
    _assert_bit_identical(*_run_pair(cfg, FederatedDistillation))


@settings(max_examples=4, deadline=None)
@given(hidden=st.integers(4, 24), depth=st.integers(0, 3),
       seed=st.integers(0, 2 ** 16))
def test_single_cohort_bit_identical_scan(hidden, depth, seed):
    cfg = dataclasses.replace(CFG, hidden=hidden, mlp_depth=depth, seed=seed)
    _assert_bit_identical(*_run_pair(cfg, ScannedFederatedDistillation))


@settings(max_examples=4, deadline=None)
@given(hidden=st.integers(4, 24), depth=st.integers(0, 3),
       seed=st.integers(0, 2 ** 16))
def test_single_cohort_bit_identical_shard(hidden, depth, seed):
    cfg = dataclasses.replace(CFG, hidden=hidden, mlp_depth=depth, seed=seed)
    _assert_bit_identical(*_run_pair(cfg, ShardedFederatedDistillation))
