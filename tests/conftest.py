"""Shared test configuration.

Forces a multi-device host platform: ``XLA_FLAGS`` gets
``--xla_force_host_platform_device_count=8`` (unless the flag is
already set) *before* anything imports jax, so the client-sharded
``shard_map`` engine runs against a real 8-device mesh in every test
environment — the conformance matrix must never silently degenerate to
a single shard.  Override by exporting the flag yourself (e.g.
``XLA_FLAGS=--xla_force_host_platform_device_count=1`` to reproduce a
single-device failure).

Guards hypothesis-based modules: when `hypothesis` is not installed,
a minimal stub is injected into ``sys.modules`` so that

    from hypothesis import given, settings
    from hypothesis import strategies as st
    from hypothesis.extra import numpy as hnp

still import at collection time, and every ``@given``-decorated test
skips when it runs (the stub plays the role ``pytest.importorskip``
would, which can't be used directly since it would find the stub) — the
suite degrades to *skips* instead of collection errors.  Plain
(non-property) tests in the same modules keep running.  With hypothesis
installed the stub is never created and everything runs for real.

Environments that *promise* hypothesis (CI exports
``REPRO_REQUIRE_HYPOTHESIS=1``) fail collection instead of stubbing, so
the property tests can never silently skip where they are supposed to
run.
"""
from __future__ import annotations

import os
import sys
import types

# Must precede any jax import (the device count is locked at first init).
_XLA_DEV_FLAG = "xla_force_host_platform_device_count"
if _XLA_DEV_FLAG not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + f" --{_XLA_DEV_FLAG}=8").strip()

import pytest

try:
    import hypothesis  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

def pytest_addoption(parser):
    parser.addoption(
        "--update-golden", action="store_true", default=False,
        help="regenerate tests/golden/*.json ledger fixtures in place "
             "(tests/test_golden_ledgers.py) instead of asserting "
             "byte-equality; commit the resulting diff")


if not HAVE_HYPOTHESIS and os.environ.get("REPRO_REQUIRE_HYPOTHESIS"):
    raise RuntimeError(
        "REPRO_REQUIRE_HYPOTHESIS is set but hypothesis is not importable — "
        "property tests would silently degrade to skips. Install the dev "
        "extra: pip install -e '.[dev]'")


class _StubStrategy:
    """Stands in for any hypothesis SearchStrategy at collection time."""

    def __call__(self, *a, **k):
        return self

    def map(self, f):
        return self

    def filter(self, f):
        return self

    def flatmap(self, f):
        return self

    def example(self):
        pytest.skip("hypothesis is not installed")


def _stub_strategy_factory(*a, **k):
    return _StubStrategy()


def _stub_given(*_a, **_k):
    def deco(fn):
        # *args-only signature: pytest must not treat the hypothesis
        # arguments of the wrapped function as fixtures.  (Can't use
        # pytest.importorskip here: it would find our own stub.)
        def shim(*args, **kwargs):
            pytest.skip("hypothesis is not installed")

        shim.__name__ = getattr(fn, "__name__", "hypothesis_test")
        shim.__doc__ = getattr(fn, "__doc__", None)
        shim.__module__ = getattr(fn, "__module__", __name__)
        shim.pytestmark = list(getattr(fn, "pytestmark", []))
        return shim

    return deco


def _stub_settings(*a, **_k):
    if a and callable(a[0]):  # bare @settings
        return a[0]

    def deco(fn):
        return fn

    return deco


def _install_hypothesis_stub() -> None:
    root = types.ModuleType("hypothesis")
    root.given = _stub_given
    root.settings = _stub_settings
    root.assume = lambda *a, **k: True
    root.note = lambda *a, **k: None
    root.HealthCheck = types.SimpleNamespace(all=lambda: [])
    root.__getattr__ = lambda name: _stub_strategy_factory

    strategies = types.ModuleType("hypothesis.strategies")
    strategies.__getattr__ = lambda name: _stub_strategy_factory

    extra = types.ModuleType("hypothesis.extra")
    extra_numpy = types.ModuleType("hypothesis.extra.numpy")
    extra_numpy.__getattr__ = lambda name: _stub_strategy_factory

    root.strategies = strategies
    root.extra = extra
    extra.numpy = extra_numpy

    sys.modules["hypothesis"] = root
    sys.modules["hypothesis.strategies"] = strategies
    sys.modules["hypothesis.extra"] = extra
    sys.modules["hypothesis.extra.numpy"] = extra_numpy


if not HAVE_HYPOTHESIS:
    _install_hypothesis_stub()
