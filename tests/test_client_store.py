"""ClientParamStore (repro.checkpoint.store) + the sorted catch-up
counting kernel (repro.core.cache.catch_up_bytes_device method="sorted")
— the two host/device substrates of the active-set engine.

The store contract: bit-compatible with the dense engines' client
parameter stacks (same per-key init, same ``client_params`` structure),
gather/scatter round-trips rows exactly, persistence (whole-file and
row-sharded) rides the checkpoint io layer.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointKeyError, ClientParamStore
from repro.core import cache as cache_lib
from repro.fl import FLConfig
from repro.fl.cohorts import ClientModels, CohortSpec, resolve_cohorts

CFG = FLConfig(n_clients=6, n_classes=4, dim=8, hidden=12, mlp_depth=1)


def _models(cfg=CFG):
    return ClientModels(resolve_cohorts(cfg), cfg.dim, cfg.n_classes)


def _keys(cfg=CFG):
    return jax.random.split(jax.random.PRNGKey(cfg.seed), cfg.n_clients + 1)[:-1]


def _assert_trees_equal(a, b):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# init parity + gather/scatter
# ---------------------------------------------------------------------------

def test_store_init_matches_dense_init_bitwise():
    """Chunked store init must produce the exact rows of the dense
    ``models.init_params(keys)`` vmap (jax.random is counter-based, so
    the batch split cannot change per-key results)."""
    models, keys = _models(), _keys()
    store = ClientParamStore(models, keys, init_chunk=2)
    _assert_trees_equal(store.as_param_list(), models.init_params(keys))


def test_store_init_parity_with_cohorts():
    cfg = dataclasses.replace(
        CFG, n_clients=7, cohorts=(CohortSpec(4, 16, 2), CohortSpec(3, 8, 1)))
    models, keys = _models(cfg), _keys(cfg)
    store = ClientParamStore(models, keys, init_chunk=3)
    assert store.n_cohorts == 2
    _assert_trees_equal(store.as_param_list(), models.init_params(keys))


def test_store_gather_scatter_roundtrip():
    models, keys = _models(), _keys()
    store = ClientParamStore(models, keys)
    rows = np.asarray([1, 3, 4])
    stack = store.gather(0, rows)
    bumped = jax.tree_util.tree_map(lambda a: a + 1.0, stack)
    store.scatter(0, rows, bumped)
    _assert_trees_equal(store.gather(0, rows), bumped)
    # untouched rows keep their original bits
    _assert_trees_equal(store.gather(0, np.asarray([0])),
                        jax.tree_util.tree_map(
                            lambda a: a[0:1], models.init_params(keys)[0]))


def test_store_memmap_backing_matches_ram(tmp_path):
    models, keys = _models(), _keys()
    ram = ClientParamStore(models, keys)
    mm = ClientParamStore(models, keys, backing="memmap",
                          directory=str(tmp_path))
    _assert_trees_equal(ram.as_param_list(), mm.as_param_list())
    assert mm.nbytes == ram.nbytes


def test_store_rejects_bad_backing(tmp_path):
    models, keys = _models(), _keys()
    with pytest.raises(ValueError, match="backing"):
        ClientParamStore(models, keys, backing="tape")
    with pytest.raises(ValueError, match="directory"):
        ClientParamStore(models, keys, backing="memmap")


def test_store_ingest_validates_structure():
    models, keys = _models(), _keys()
    store = ClientParamStore(models, keys)
    with pytest.raises(ValueError, match="cohort stacks"):
        store.ingest_param_list([])
    bad = [jax.tree_util.tree_map(lambda a: a[:2], store.as_param_list()[0])]
    with pytest.raises(ValueError, match="shape"):
        store.ingest_param_list(bad)


# ---------------------------------------------------------------------------
# persistence
# ---------------------------------------------------------------------------

def test_store_save_load_roundtrip(tmp_path):
    models, keys = _models(), _keys()
    store = ClientParamStore(models, keys)
    store.scatter(0, np.asarray([2]), jax.tree_util.tree_map(
        lambda a: a * 2.0, store.gather(0, np.asarray([2]))))
    path = str(tmp_path / "store.npz")
    store.save(path)
    other = ClientParamStore(models, keys)
    other.load(path)
    _assert_trees_equal(store.as_param_list(), other.as_param_list())


def test_store_sharded_save_load_roundtrip(tmp_path):
    cfg = dataclasses.replace(
        CFG, n_clients=7, cohorts=(CohortSpec(4, 16, 2), CohortSpec(3, 8, 1)))
    models, keys = _models(cfg), _keys(cfg)
    store = ClientParamStore(models, keys)
    store.save_sharded(str(tmp_path), clients_per_shard=3)
    # 4-client cohort -> 2 shards, 3-client cohort -> 1 shard
    names = sorted(p.name for p in tmp_path.iterdir())
    assert names == ["cohort0_clients_00000000_00000003.npz",
                     "cohort0_clients_00000003_00000004.npz",
                     "cohort1_clients_00000000_00000003.npz"]
    other = ClientParamStore(models, keys)
    other.scatter(0, np.arange(4), jax.tree_util.tree_map(
        lambda a: a * 0.0, other.gather(0, np.arange(4))))
    other.load_sharded(str(tmp_path), clients_per_shard=3)
    _assert_trees_equal(store.as_param_list(), other.as_param_list())


def test_store_load_sharded_missing_shard(tmp_path):
    models, keys = _models(), _keys()
    store = ClientParamStore(models, keys)
    store.save_sharded(str(tmp_path), clients_per_shard=4)
    with pytest.raises(CheckpointKeyError, match="missing store shard"):
        store.load_sharded(str(tmp_path), clients_per_shard=3)


# ---------------------------------------------------------------------------
# sorted catch-up counting kernel: bit-identical totals to the dense
# (K, |P|) comparison matrix, without ever materialising it
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(4))
def test_catch_up_bytes_sorted_matches_dense_bitwise(seed):
    rng = np.random.default_rng(seed)
    P, K, n_classes, t = 32, 50, 6, 9
    cache = cache_lib.CacheState(
        values=jnp.asarray(rng.random((P, n_classes), np.float32)),
        ts=jnp.asarray(rng.integers(0, t, P), jnp.int32),
        present=jnp.asarray(rng.random(P) < 0.7),
    )
    last_sync = jnp.asarray(rng.integers(0, t, K), jnp.int32)
    part = jnp.asarray(rng.random(K) < 0.4)
    dense = cache_lib.catch_up_bytes_device(cache, last_sync, part, t,
                                            method="dense")
    srt = cache_lib.catch_up_bytes_device(cache, last_sync, part, t,
                                          method="sorted")
    np.testing.assert_array_equal(np.asarray(dense), np.asarray(srt))


def test_catch_up_bytes_rejects_unknown_method():
    cache = cache_lib.init_cache(8, 4)
    with pytest.raises(ValueError, match="method"):
        cache_lib.catch_up_bytes_device(cache, jnp.zeros(4, jnp.int32),
                                        jnp.ones(4, bool), 3, method="hash")
