"""Property tests: the two-phase partial/finalize aggregation contract.

The client-sharded engine never materializes the full (K, m, N) stack:
each shard computes ``Strategy.partial_aggregate`` on its local clients,
the engine psums the moment dicts entrywise, and
``Strategy.finalize_aggregate`` applies the nonlinearity once on the
reduction.  The contract that makes this correct — for *any* split of
the client axis into shards,

    finalize(sum over shards of partial(shard)) ==
    aggregate_masked(unsplit stack)            (allclose)

— is asserted here for every scan-safe strategy over random stacks,
random participation masks (including all-masked shards and fully
masked rounds), and random shard splits.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.fl.strategies import STRATEGIES

# every scan-safe strategy (COMET is host-only by design), plus the
# adaptive-beta SCARLET variant whose finalize derives beta from the
# reduced mean itself
SCAN_SAFE = {
    name: (lambda cls=cls: cls())
    for name, cls in STRATEGIES.items() if cls().scan_safe
}
SCAN_SAFE["scarlet_adaptive"] = lambda: STRATEGIES["scarlet"](beta="adaptive")


def _tree_sum(dicts):
    """Entrywise sum of the per-shard moment dicts — the psum stand-in."""
    out = {}
    for d in dicts:
        for k, v in d.items():
            out[k] = v if k not in out else out[k] + v
    return out


def _stack(seed, K, m, N):
    key = jax.random.PRNGKey(seed)
    z = jax.random.dirichlet(key, jnp.ones(N), (K, m))
    part = (jax.random.uniform(jax.random.fold_in(key, 1), (K,)) < 0.6)
    um = (jax.random.uniform(jax.random.fold_in(key, 2), (K, m)) < 0.5)
    return z, part.astype(jnp.float32), um


def _split_points(cuts, K):
    """Sorted interior cut points -> contiguous shard slices of 0..K."""
    pts = sorted({min(c, K - 1) for c in cuts} - {0})
    return [0] + pts + [K]


def _check_contract(strat, z, part, um, bounds, rtol=1e-4, atol=1e-5):
    whole = strat.aggregate_masked(z, part, um, 0)
    partials = _tree_sum([
        strat.partial_aggregate(z[a:b], part[a:b],
                                None if um is None else um[a:b], 0)
        for a, b in zip(bounds[:-1], bounds[1:])
    ])
    sharded = strat.finalize_aggregate(partials, 0)
    np.testing.assert_allclose(np.asarray(sharded), np.asarray(whole),
                               rtol=rtol, atol=atol)


@settings(max_examples=120, deadline=None)
@given(name=st.sampled_from(sorted(SCAN_SAFE)),
       seed=st.integers(0, 2**31 - 1),
       K=st.integers(2, 10),
       m=st.integers(1, 5),
       N=st.integers(2, 8),
       cuts=st.sets(st.integers(1, 9), min_size=0, max_size=4))
def test_partial_finalize_matches_aggregate_masked(name, seed, K, m, N, cuts):
    strat = SCAN_SAFE[name]()
    z, part, um = _stack(seed, K, m, N)
    _check_contract(strat, z, part, um if strat.upload_mask(z) is not None
                    else None, _split_points(cuts, K))


@pytest.mark.parametrize("name", sorted(SCAN_SAFE))
def test_contract_with_all_masked_shard(name):
    """A shard whose clients all sat the round out contributes zero
    moments — the reduction must be unaffected by how zeros group."""
    strat = SCAN_SAFE[name]()
    z, _, um = _stack(7, 6, 3, 4)
    part = jnp.asarray([0.0, 0.0, 0.0, 1.0, 1.0, 0.0])  # shard [0:3] empty
    um = um if strat.upload_mask(z) is not None else None
    _check_contract(strat, z, part, um, [0, 3, 6])


@pytest.mark.parametrize("name", sorted(SCAN_SAFE))
def test_contract_with_no_participants_at_all(name):
    """Total outage: every guard (max(wsum, 1), upload fallbacks) must
    behave identically split and unsplit — no NaNs, no mismatches."""
    strat = SCAN_SAFE[name]()
    z, _, um = _stack(11, 4, 2, 5)
    part = jnp.zeros(4, jnp.float32)
    um = um if strat.upload_mask(z) is not None else None
    _check_contract(strat, z, part, um, [0, 1, 4])
    whole = strat.aggregate_masked(z, part, um, 0)
    assert np.isfinite(np.asarray(whole)).all()


@pytest.mark.parametrize("name", sorted(SCAN_SAFE))
def test_single_shard_split_is_trivially_exact(name):
    """One shard = the scanned engine's layout: the composition must
    reproduce aggregate_masked (bitwise for pure-jnp defaults is not
    required — allclose covers kernel fast paths too)."""
    strat = SCAN_SAFE[name]()
    z, part, um = _stack(3, 5, 4, 3)
    um = um if strat.upload_mask(z) is not None else None
    _check_contract(strat, z, part, um, [0, 5])
