"""Pallas kernel sweeps: shapes x dtypes, assert_allclose vs ref.py
oracles (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import attn_kernel, distill_kernel, era_kernel, ops, quant_kernel, ref

KEY = jax.random.PRNGKey(42)


def _probs(key, shape):
    return jax.random.dirichlet(key, jnp.ones(shape[-1]), shape[:-1])


# ---------------------------------------------------------------------------
# Enhanced ERA
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,N", [(8, 10), (100, 100), (257, 33), (1000, 200)])
@pytest.mark.parametrize("beta", [0.5, 1.0, 1.5, 3.0])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_era_kernel_sweep(B, N, beta, dtype):
    z = _probs(KEY, (B, N)).astype(dtype)
    out = era_kernel.enhanced_era(z, beta, block_b=64)
    exp = ref.enhanced_era(z, beta)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), rtol=tol, atol=tol)


@pytest.mark.parametrize("K,B,N", [(4, 50, 10), (16, 100, 64), (3, 33, 100)])
def test_era_fused_kernel(K, B, N):
    z = _probs(KEY, (K, B, N))
    out = era_kernel.enhanced_era_fused(z, 1.5)
    exp = ref.enhanced_era_fused(z, 1.5)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), rtol=1e-5, atol=1e-6)


def test_era_kernel_matches_core_impl():
    from repro.core import era as core_era

    z = _probs(KEY, (64, 10))
    a = np.asarray(core_era.enhanced_era(z, 2.0, impl="jnp"))
    b = np.asarray(core_era.enhanced_era(z, 2.0, impl="pallas"))
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# Row-block alignment (f32 sublane tiling)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("block_b,n_rows,want", [
    (256, 10, 16),   # the regression shape: min() alone would give 10
    (256, 8, 8),
    (256, 1, 8),     # floor at one sublane group
    (256, 17, 24),
    (64, 1000, 64),  # block already legal and smaller than the input
    (256, 256, 256),
])
def test_align_block_rows(block_b, n_rows, want):
    from repro.kernels.runtime import align_block_rows

    got = align_block_rows(block_b, n_rows)
    assert got == want
    assert got % 8 == 0


@pytest.mark.parametrize("B", [1, 3, 10, 17, 250, 1001])
def test_ops_era_passes_aligned_block_to_kernel(B, monkeypatch):
    """Regression: ``ops.enhanced_era`` shrank block_b with a bare
    ``min(block_b, rows)``, handing the kernel row blocks like 10 that
    mis-tile on native TPU (f32 sublane = 8).  Interpret mode executes
    them anyway, so assert on the block size actually passed down —
    this test FAILS on the pre-fix wrapper for any B not a multiple
    of 8."""
    seen = {}
    real = era_kernel.enhanced_era

    def spy(z, beta, block_b=256, interpret=None):
        seen["block_b"] = block_b
        return real(z, beta, block_b=block_b, interpret=interpret)

    monkeypatch.setattr(ops.era_kernel, "enhanced_era", spy)
    z = _probs(KEY, (B, 10))
    out = ops.enhanced_era(z, 1.5)
    assert seen["block_b"] % 8 == 0, (
        f"ops.enhanced_era passed an unaligned row block "
        f"{seen['block_b']} for B={B}")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref.enhanced_era(z, 1.5)),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("B", [1, 3, 10, 100])
def test_era_fused_default_block_vs_small_B(B):
    """The fused kernel's default block_b=128 must legally shrink to
    small row counts (teacher batches are often << 128)."""
    z = _probs(KEY, (5, B, 10))
    out = era_kernel.enhanced_era_fused(z, 1.5)  # default block_b=128
    exp = ref.enhanced_era_fused(z, 1.5)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("B", [9, 10, 33, 1001])
def test_era_kernel_odd_row_counts(B):
    """Odd row counts through the wrapper directly (the shapes whose
    shrunk blocks were illegal pre-fix)."""
    z = _probs(KEY, (B, 10))
    out = era_kernel.enhanced_era(z, 2.0)  # default block_b=256 > B
    exp = ref.enhanced_era(z, 2.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# Quantize-dequantize (soft-label codec round trip)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,N", [(8, 10), (100, 100), (257, 33), (5, 200)])
@pytest.mark.parametrize("bits", [1, 4, 8])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quant_kernel_sweep(B, N, bits, dtype):
    z = _probs(KEY, (B, N)).astype(dtype)
    out = quant_kernel.quantize_dequantize(z, bits, block_b=64)
    exp = ref.quantize_dequantize(z, bits)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), rtol=tol, atol=tol)


def test_quant_kernel_lane_padding_does_not_corrupt_minmax():
    """N < 128 forces lane padding; the masked reduction must ignore the
    pad (an unmasked min would see the zero pad and stretch the range)."""
    z = 0.5 + 0.4 * _probs(KEY, (16, 7))  # all entries well above 0
    out = np.asarray(quant_kernel.quantize_dequantize(z, 8))
    assert out.min() >= float(z.min()) - 1e-5


def _assert_roundtrip_in_row_range(z, bits):
    """The level clamp's invariant: every dequantized value stays inside
    its row's [min, max] — degenerate rows (eps scale) included."""
    out = np.asarray(quant_kernel.quantize_dequantize(jnp.asarray(z), bits),
                     np.float64)
    zn = np.asarray(z, np.float64)
    lo = zn.min(axis=-1, keepdims=True)
    hi = zn.max(axis=-1, keepdims=True)
    assert np.isfinite(out).all()
    assert (out >= lo - 1e-6).all() and (out <= hi + 1e-6).all()


def test_quant_kernel_all_equal_rows():
    """Constant rows collapse the range to the eps floor; the round trip
    must return the constant, not a value scaled off the eps."""
    z = jnp.full((12, 10), 0.1, jnp.float32)
    out = np.asarray(quant_kernel.quantize_dequantize(z, 8))
    np.testing.assert_allclose(out, np.asarray(z), atol=1e-7)
    _assert_roundtrip_in_row_range(z, 8)


def test_quant_kernel_one_bit():
    """bits=1 is the coarsest wire (two levels: row min and row max)."""
    z = _probs(KEY, (32, 10))
    out = np.asarray(quant_kernel.quantize_dequantize(z, 1))
    exp = np.asarray(ref.quantize_dequantize(z, 1))
    np.testing.assert_allclose(out, exp, rtol=1e-6, atol=1e-6)
    _assert_roundtrip_in_row_range(z, 1)


def test_quant_kernel_single_class():
    """N=1: zero range per row; the round trip must be the identity."""
    z = jnp.linspace(0.1, 0.9, 16).reshape(16, 1).astype(jnp.float32)
    out = np.asarray(quant_kernel.quantize_dequantize(z, 8))
    np.testing.assert_allclose(out, np.asarray(z), atol=1e-7)


@pytest.mark.parametrize("B", [5, 13, 100])
def test_quant_kernel_rows_not_multiple_of_block(B):
    """Row counts that don't divide the block exercise both the row
    padding and the (aligned) shrunk block."""
    z = _probs(KEY, (B, 10))
    out = np.asarray(quant_kernel.quantize_dequantize(z, 8, block_b=64))
    exp = np.asarray(ref.quantize_dequantize(z, 8))
    np.testing.assert_allclose(out, exp, rtol=1e-6, atol=1e-6)
    _assert_roundtrip_in_row_range(z, 8)


# ---------------------------------------------------------------------------
# Distillation loss
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,V", [(8, 100), (64, 5000), (3, 131), (16, 16384)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_distill_kernel_sweep(B, V, dtype):
    logits = (jax.random.normal(KEY, (B, V)) * 4).astype(dtype)
    teacher = _probs(jax.random.fold_in(KEY, 1), (B, V)).astype(dtype)
    out = distill_kernel.distill_loss(logits, teacher, block_b=8, block_v=512)
    exp = ref.distill_loss(logits, teacher)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp, np.float32),
                               rtol=tol, atol=tol)


def test_distill_matches_core_loss():
    from repro.core import losses

    logits = jax.random.normal(KEY, (32, 777)) * 3
    teacher = _probs(KEY, (32, 777))
    a = float(losses.soft_cross_entropy(logits, teacher, impl="jnp"))
    b = float(losses.soft_cross_entropy(logits, teacher, impl="pallas"))
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,Hkv,d", [
    (2, 128, 4, 2, 64),
    (1, 256, 8, 8, 32),
    (2, 128, 8, 2, 128),
])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 64), (False, 0)])
def test_flash_attention_sweep(B, S, H, Hkv, d, causal, window):
    q = jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, H, d), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, Hkv, d), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(KEY, 3), (B, S, Hkv, d), jnp.float32)
    out = attn_kernel.flash_attention(q, k, v, causal=causal, window=window,
                                      block_q=64, block_k=64)
    exp = ref.flash_attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), rtol=2e-3, atol=2e-3)


def test_flash_attention_bf16():
    q = jax.random.normal(KEY, (1, 128, 4, 64), jnp.bfloat16)
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (1, 128, 2, 64), jnp.bfloat16)
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (1, 128, 2, 64), jnp.bfloat16)
    out = attn_kernel.flash_attention(q, k, v, block_q=64, block_k=64)
    exp = ref.flash_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), rtol=5e-2, atol=5e-2)


def test_flash_matches_model_attention():
    """Kernel agrees with the model-zoo attention (the jnp execution path)."""
    from repro.models import common as cm

    q = jax.random.normal(KEY, (2, 128, 4, 64), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (2, 128, 2, 64), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (2, 128, 2, 64), jnp.float32)
    a = cm.attention(q, k, v, causal=True)
    b = ops.flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3, atol=2e-3)


def test_flash_vjp_matches_reference_grads():
    q = jax.random.normal(KEY, (2, 128, 4, 32))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (2, 128, 2, 32))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (2, 128, 2, 32))

    def loss_flash(q, k, v):
        return jnp.sum(attn_kernel.flash_attention_diff(
            q, k, v, True, 0, 64, 64, True) ** 2)

    def loss_ref(q, k, v):
        return jnp.sum(ref.flash_attention(q, k, v, causal=True) ** 2)

    g1 = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-3)


def test_model_attention_pallas_path_parity():
    """ATTN_IMPL='pallas' routes model attention through the flash kernel
    with identical results (the TPU runtime path)."""
    from repro.configs.base import ModelConfig
    from repro.models import common as cm
    from repro.models import transformer as tfm

    cfg = ModelConfig(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
                      head_dim=32, d_ff=256, vocab_size=300,
                      param_dtype="float32", compute_dtype="float32")
    params, _ = tfm.init(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 128), 0, 300)
    l_xla, _ = tfm.forward(cfg, params, toks)
    try:
        cm.ATTN_IMPL = "pallas"
        l_pl, _ = tfm.forward(cfg, params, toks)
    finally:
        cm.ATTN_IMPL = "xla"
    np.testing.assert_allclose(np.asarray(l_xla), np.asarray(l_pl),
                               rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# Block-alignment regressions: pre-fix, flash_attention shrank blocks with
# a bare min() (misaligned sublane blocks for small/odd S, and a hard
# assert for S not a multiple of the block); distill_loss forwarded
# caller block sizes unaligned.  These shapes fail on the pre-fix code.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("Sq,Sk,causal,window", [
    (4, 4, True, 0),        # pre-fix: block_q=4, misaligned sublane block
    (100, 100, True, 7),    # pre-fix: block 100 (odd), misaligned
    (130, 130, True, 0),    # pre-fix: 130 % 128 != 0 -> AssertionError
    (8, 20, False, 0),      # ragged KV: padded tail must be masked
])
def test_flash_attention_ragged_and_small_seq(Sq, Sk, causal, window):
    B, H, Hkv, d = 1, 2, 1, 64
    q = jax.random.normal(jax.random.fold_in(KEY, 4), (B, Sq, H, d))
    k = jax.random.normal(jax.random.fold_in(KEY, 5), (B, Sk, Hkv, d))
    v = jax.random.normal(jax.random.fold_in(KEY, 6), (B, Sk, Hkv, d))
    out = attn_kernel.flash_attention(q, k, v, causal=causal, window=window)
    exp = ref.flash_attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-3, atol=2e-3)


def test_flash_attention_blocks_stay_sublane_aligned():
    """The native-path BlockSpecs are lint-clean even for awkward shapes
    (traced with interpret=False; nothing executes)."""
    from repro.analysis import pallas_checks

    for label, fn, args in attn_kernel.analysis_cases():
        findings = pallas_checks.check_case(label, fn, args)
        errs = [f for f in findings if f.level == "error"]
        assert not errs, f"{label}: {[str(f) for f in errs]}"


def test_distill_odd_caller_blocks_are_aligned():
    """Caller-supplied odd block sizes are snapped to the tile grid and
    still produce exact results."""
    B, V = 13, 260
    l = jax.random.normal(jax.random.fold_in(KEY, 7), (B, V))
    t = _probs(jax.random.fold_in(KEY, 8), (B, V))
    out = distill_kernel.distill_loss(l, t, block_b=10, block_v=100)
    exp = ref.distill_loss(l, t)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-4, atol=1e-4)

    from repro.analysis import pallas_checks

    for label, fn, args in distill_kernel.analysis_cases():
        findings = pallas_checks.check_case(label, fn, args)
        errs = [f for f in findings if f.level == "error"]
        assert not errs, f"{label}: {[str(f) for f in errs]}"


@pytest.mark.parametrize("K", [7, 50])
def test_fused_round_unaligned_client_counts(K):
    """The (K, 1) weights operand makes K a sublane dim: unaligned client
    counts (not multiples of 8) must be padded, not mis-tiled — and the
    padding must not perturb the weighted reduction."""
    from repro.kernels import round_kernel

    rng = np.random.default_rng(3)
    z = jnp.asarray(rng.dirichlet(np.ones(10), size=(K, 24)), jnp.float32)
    w = jnp.asarray(rng.random(K), jnp.float32)
    out = round_kernel.fused_round(z, w, 1.5, mode="identity", sharpen=True)
    exp = ref.fused_round(z, w, 1.5, mode="identity", sharpen=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-5, atol=1e-6)
