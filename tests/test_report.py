"""Unit tests for roofline math + report generation."""
import json
import os

import pytest

from repro.configs.base import SHAPES_BY_NAME
from repro.configs.registry import ARCHS
from repro.launch import report, roofline as rl


def test_model_flops_modes():
    cfg = ARCHS["granite-3-2b"]
    n = cfg.active_param_count()
    tr = rl.model_flops_for(cfg, SHAPES_BY_NAME["train_4k"])
    pf = rl.model_flops_for(cfg, SHAPES_BY_NAME["prefill_32k"])
    de = rl.model_flops_for(cfg, SHAPES_BY_NAME["decode_32k"])
    assert tr == pytest.approx(6.0 * n * 256 * 4096)
    assert pf == pytest.approx(2.0 * n * 32 * 32768)
    assert de == pytest.approx(2.0 * n * 128)


def test_moe_uses_active_params():
    kimi = ARCHS["kimi-k2-1t-a32b"]
    tr = rl.model_flops_for(kimi, SHAPES_BY_NAME["train_4k"])
    assert tr < 6.0 * kimi.param_count() * 256 * 4096 * 0.1  # far below total


def test_report_tables(tmp_path):
    rows = [
        {"arch": "a", "shape": "train_4k", "mesh": "16x16", "scheme": "tp",
         "status": "ok", "compile_s": 10.0, "bytes_per_device": 1e9,
         "hlo_gflops_per_device": 100.0, "hlo_gbytes_per_device": 10.0,
         "collective_gbytes_per_device": 1.0, "collective_counts": {"all-reduce": 3},
         "compute_s": 0.1, "memory_s": 0.2, "collective_s": 0.02,
         "bottleneck": "memory", "model_gflops": 90.0, "hlo_gflops": 25600.0,
         "useful_flops_ratio": 0.9},
        {"arch": "a", "shape": "long_500k", "mesh": "16x16", "scheme": "tp",
         "status": "skipped", "reason": "pure full-attention arch"},
        {"arch": "b", "shape": "train_4k", "mesh": "16x16", "scheme": "tp",
         "status": "error", "error": "boom"},
    ]
    d = tmp_path / "arts"
    d.mkdir()
    for i, r in enumerate(rows):
        (d / f"{i}.json").write_text(json.dumps(r))
    loaded = report.load(str(d))
    assert len(loaded) == 3
    summary = report.summarize(loaded)
    assert "| 16x16 | tp | 1 | 1 | 1 |" in summary
    table = report.dryrun_table(loaded, "16x16", "tp")
    assert "SKIP" in table and "**FAIL**" in table and "all-reducex3" in table
    roof = report.roofline_table(loaded, "16x16", "tp")
    assert "**memory**" in roof and "100.00ms" in roof


def test_bottleneck_selection():
    from repro.launch.hlo_analysis import HloSummary

    s = HloSummary(dot_flops=197e12, transcendental_elems=0,
                   collective_bytes=0, collective_by_kind={},
                   collective_counts={}, residual_while_loops=0)
    r = rl.compute_roofline_from_summary(
        arch="x", shape="train_4k", mesh_name="16x16", scheme="tp",
        chips=256, summary=s, bytes_accessed=1.0, xla_flops=0.0,
        model_flops=1.0, bytes_per_device=0.0)
    assert r.bottleneck == "compute" and r.compute_s == pytest.approx(1.0)
