"""Model-zoo correctness: decode-vs-forward parity per family, SSD
chunk-size invariance, Gemma2 feature behavior, MoE dispatch invariants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import common as cm
from repro.models import jamba, mamba2, transformer, whisper

KEY = jax.random.PRNGKey(0)
F32 = dict(param_dtype="float32", compute_dtype="float32")


def _decode_all(mod, cfg, params, toks, **extra):
    cache = mod.init_decode_cache(cfg, toks.shape[0], toks.shape[1])
    cache.update(extra)
    outs = []
    for pos in range(toks.shape[1]):
        lg, cache = mod.decode_step(cfg, params, cache, toks[:, pos:pos + 1],
                                    jnp.int32(pos))
        outs.append(lg)
    return np.stack(outs, 1)


def test_dense_decode_matches_forward():
    cfg = ModelConfig(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
                      head_dim=32, d_ff=256, vocab_size=300, **F32)
    params, _ = transformer.init(cfg, KEY)
    toks = jax.random.randint(KEY, (2, 16), 0, 300)
    logits, _ = transformer.forward(cfg, params, toks)
    dec = _decode_all(transformer, cfg, params, toks)
    np.testing.assert_allclose(dec, np.asarray(logits), rtol=2e-3, atol=2e-3)


def test_gemma2_softcap_bounds_logits():
    cfg = ModelConfig(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
                      head_dim=32, d_ff=256, vocab_size=300,
                      attn_softcap=50.0, final_softcap=30.0,
                      sliding_window=8, local_global_alternating=True, **F32)
    params, _ = transformer.init(cfg, KEY)
    toks = jax.random.randint(KEY, (2, 16), 0, 300)
    logits, _ = transformer.forward(cfg, params, toks)
    assert float(jnp.max(jnp.abs(logits))) <= 30.0 + 1e-4


def test_gemma2_sliding_window_masks_context():
    """With window=4, token 10's local-layer attention cannot see token 2:
    perturbing token 2 must not change a 1-layer local-only model's output
    at position 10."""
    cfg = ModelConfig(n_layers=2, d_model=64, n_heads=2, n_kv_heads=2,
                      head_dim=32, d_ff=128, vocab_size=100,
                      sliding_window=4, **F32)
    params, _ = transformer.init(cfg, KEY)
    toks = jax.random.randint(KEY, (1, 16), 0, 100)
    toks2 = toks.at[0, 2].set((toks[0, 2] + 1) % 100)
    l1, _ = transformer.forward(cfg, params, toks)
    l2, _ = transformer.forward(cfg, params, toks2)
    # window-3 reach per layer, 2 layers: positions >= 2 + 2*(window-1) + 1
    np.testing.assert_allclose(np.asarray(l1[0, 9:]), np.asarray(l2[0, 9:]),
                               rtol=1e-5, atol=1e-5)
    # position 3 (within window) IS affected
    assert not np.allclose(np.asarray(l1[0, 3]), np.asarray(l2[0, 3]), atol=1e-5)


def test_moe_dispatch_matches_dense_computation():
    """With top_k == n_experts and ample capacity, token-choice MoE equals
    the dense mixture sum_e gate_e * expert_e(x)."""
    D, F, E, T = 32, 64, 4, 24
    k = jax.random.PRNGKey(1)
    x = jax.random.normal(k, (2, T // 2, D))
    router = jax.random.normal(jax.random.fold_in(k, 1), (D, E)) * 0.3
    w1 = jax.random.normal(jax.random.fold_in(k, 2), (E, D, F)) * 0.1
    w3 = jax.random.normal(jax.random.fold_in(k, 3), (E, D, F)) * 0.1
    w2 = jax.random.normal(jax.random.fold_in(k, 4), (E, F, D)) * 0.1
    out, aux = cm.moe_ffn(x, router, w1, w3, w2, top_k=E, capacity_factor=4.0)
    probs = jax.nn.softmax(
        jnp.einsum("btd,de->bte", x, router).astype(jnp.float32), -1)
    dense = jnp.zeros_like(x)
    for e in range(E):
        h = jnp.einsum("btd,df->btf", x, w1[e])
        g = jnp.einsum("btd,df->btf", x, w3[e])
        y = jnp.einsum("btf,fd->btd", jax.nn.silu(h) * g, w2[e])
        dense += probs[..., e:e + 1] * y
    np.testing.assert_allclose(np.asarray(out), np.asarray(dense),
                               rtol=2e-3, atol=2e-3)
    assert float(aux) > 0


def test_moe_capacity_drops_dont_nan():
    D, F, E = 16, 32, 4
    k = jax.random.PRNGKey(2)
    x = jax.random.normal(k, (1, 64, D))
    router = jax.random.normal(jax.random.fold_in(k, 1), (D, E)) * 5  # skewed
    w1 = jax.random.normal(jax.random.fold_in(k, 2), (E, D, F)) * 0.1
    w3 = jax.random.normal(jax.random.fold_in(k, 3), (E, D, F)) * 0.1
    w2 = jax.random.normal(jax.random.fold_in(k, 4), (E, F, D)) * 0.1
    out, _ = cm.moe_ffn(x, router, w1, w3, w2, top_k=2, capacity_factor=0.5)
    assert np.isfinite(np.asarray(out)).all()


def test_ssd_chunk_invariance_and_decode_parity():
    cfg = ModelConfig(name="m", family="ssm", n_layers=2, d_model=64,
                      vocab_size=200, ssm_state=32, ssm_head_dim=32,
                      ssm_chunk=8, **F32)
    params, _ = mamba2.init(cfg, KEY)
    toks = jax.random.randint(KEY, (2, 32), 0, 200)
    l8, _ = mamba2.forward(cfg, params, toks)
    l16, _ = mamba2.forward(dataclasses.replace(cfg, ssm_chunk=16), params, toks)
    np.testing.assert_allclose(np.asarray(l8), np.asarray(l16), rtol=2e-4, atol=2e-4)
    dec = _decode_all(mamba2, cfg, params, toks)
    np.testing.assert_allclose(dec, np.asarray(l8), rtol=5e-3, atol=5e-3)


def test_jamba_decode_parity():
    cfg = ModelConfig(name="j", family="hybrid", n_layers=4, d_model=64,
                      n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
                      vocab_size=200, n_experts=4, top_k=2, moe_d_ff=64,
                      moe_every=2, ssm_state=16, ssm_head_dim=16, ssm_chunk=8,
                      attn_layer_period=4, **F32)
    params, _ = jamba.init(cfg, KEY)
    toks = jax.random.randint(KEY, (2, 16), 0, 200)
    logits, _ = jamba.forward(cfg, params, toks)
    dec = _decode_all(jamba, cfg, params, toks)
    np.testing.assert_allclose(dec, np.asarray(logits), rtol=5e-3, atol=5e-3)


def test_whisper_decode_parity_with_cross_kv():
    cfg = ModelConfig(name="w", family="encdec", n_layers=2,
                      n_encoder_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                      head_dim=16, d_ff=128, vocab_size=200, encoder_len=12,
                      **F32)
    params, _ = whisper.init(cfg, KEY)
    toks = jax.random.randint(KEY, (2, 16), 0, 200)
    audio = jax.random.normal(KEY, (2, 12, 64))
    logits, _ = whisper.forward(cfg, params, toks, audio)
    enc = whisper.encode(cfg, params, audio)
    xk, xv = whisper.precompute_cross_kv(cfg, params, enc)
    dec = _decode_all(whisper, cfg, params, toks, xk=xk, xv=xv)
    np.testing.assert_allclose(dec, np.asarray(logits), rtol=5e-3, atol=5e-3)


def test_chunked_attention_equals_unchunked():
    k = jax.random.PRNGKey(3)
    q = jax.random.normal(k, (2, 64, 4, 32))
    kk = jax.random.normal(jax.random.fold_in(k, 1), (2, 64, 2, 32))
    v = jax.random.normal(jax.random.fold_in(k, 2), (2, 64, 2, 32))
    a = cm.attention(q, kk, v, causal=True)
    b = cm.attention(q, kk, v, causal=True, chunk_q=16)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)


def test_scan_unroll_equivalence():
    """cm.scan(unroll) must be numerically identical to the loop form."""
    cfg = ModelConfig(n_layers=3, d_model=32, n_heads=2, n_kv_heads=2,
                      head_dim=16, d_ff=64, vocab_size=100, **F32)
    params, _ = transformer.init(cfg, KEY)
    toks = jax.random.randint(KEY, (1, 8), 0, 100)
    l1, _ = transformer.forward(cfg, params, toks)
    try:
        cm.SCAN_UNROLL = True
        l2, _ = transformer.forward(cfg, params, toks)
    finally:
        cm.SCAN_UNROLL = False
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-5, atol=1e-5)


def test_resnet20_shapes_and_grads():
    from repro.models import resnet

    p, _ = resnet.init(KEY, depth=20, n_classes=10)
    img = jax.random.normal(KEY, (2, 32, 32, 3))

    def loss(p):
        return jnp.mean(resnet.apply(p, img, depth=20) ** 2)

    g = jax.grad(loss)(p)
    for leaf in jax.tree_util.tree_leaves(g):
        assert np.isfinite(np.asarray(leaf)).all()
