"""Checkpoint round-trips: ``repro.checkpoint.io`` + engine state.

Two layers:

- ``save_pytree``/``load_pytree`` preserve arbitrary pytrees (nested
  dicts/tuples, int/bool/bf16 leaves) bit-for-bit through the npz file;
- an engine snapshot (``state_dict`` — params, cache, sync bookkeeping,
  round counter) restored into a *fresh* engine continues the run
  bit-identically to the uninterrupted original, for the host loop, the
  scanned engine, and the client-sharded engine (the jax key stream is
  keyed by absolute round, so split runs replay the same rounds).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    CheckpointDtypeError,
    CheckpointKeyError,
    CheckpointShapeError,
    load_pytree,
    save_pytree,
)
from repro.core import comm
from repro.fl import (
    ActiveSetFederatedDistillation,
    FederatedDistillation,
    FLConfig,
    ScannedFederatedDistillation,
    ShardedFederatedDistillation,
    Scenario,
    bernoulli_participation,
)
from repro.fl.strategies import STRATEGIES

CFG = FLConfig(
    n_clients=4, n_classes=4, dim=8, rounds=6, local_steps=2,
    distill_steps=2, public_size=48, public_per_round=10,
    private_size=64, alpha=0.5, eval_every=3, seed=0, hidden=12,
    mesh_spec="2x4",
)

ENGINES = {
    "host": FederatedDistillation,
    "scan": ScannedFederatedDistillation,
    "shard": ShardedFederatedDistillation,
    "active": ActiveSetFederatedDistillation,
}


def _make(engine):
    kw = dict(cache_duration=3,
              scenario=Scenario(participation=bernoulli_participation(0.5)))
    if engine == "host":
        kw["rng_backend"] = "jax"
    return ENGINES[engine](CFG, STRATEGIES["scarlet"](beta=1.5), **kw)


# ---------------------------------------------------------------------------
# io-level round trips
# ---------------------------------------------------------------------------

def test_pytree_roundtrip_preserves_values_and_dtypes(tmp_path):
    tree = {
        "w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4) / 7.0,
        "nested": {"ts": jnp.asarray([-5, 0, 9], jnp.int32),
                   "flag": jnp.asarray([True, False])},
        "tup": (jnp.float32(3.25), jnp.asarray([1.5, -2.5], jnp.bfloat16)),
    }
    path = str(tmp_path / "tree.npz")
    save_pytree(path, tree)
    out = load_pytree(path, tree)
    flat_in = jax.tree_util.tree_leaves(tree)
    flat_out = jax.tree_util.tree_leaves(out)
    for a, b in zip(flat_in, flat_out):
        assert np.asarray(a).dtype == np.asarray(b).dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_pytree_roundtrip_rejects_shape_mismatch(tmp_path):
    path = str(tmp_path / "tree.npz")
    save_pytree(path, {"w": jnp.zeros((2, 3))})
    with pytest.raises(CheckpointShapeError, match=r"\(2, 3\)"):
        load_pytree(path, {"w": jnp.zeros((3, 2))})


def test_pytree_roundtrip_rejects_dtype_mismatch(tmp_path):
    """Regression: the old loader checked only shapes, so an f64 file
    silently loaded into an f32 template (or int into float) and the
    cast surfaced later as drift.  The typed error must fire instead."""
    path = str(tmp_path / "tree.npz")
    save_pytree(path, {"w": np.zeros((2, 3), np.float64)})
    with pytest.raises(CheckpointDtypeError, match="refusing to cast"):
        load_pytree(path, {"w": jnp.zeros((2, 3), jnp.float32)})


def test_pytree_load_reports_missing_and_extra_keys(tmp_path):
    path = str(tmp_path / "tree.npz")
    save_pytree(path, {"a": jnp.zeros(2), "b": jnp.ones(2)})
    # missing: the like-tree wants a leaf the file never stored
    with pytest.raises(CheckpointKeyError, match="no stored array"):
        load_pytree(path, {"a": jnp.zeros(2), "c": jnp.zeros(2)})
    # extra: the file holds leaves the like-tree never consumed
    with pytest.raises(CheckpointKeyError, match="never consumed"):
        load_pytree(path, {"a": jnp.zeros(2)})


def test_pytree_key_escaping_disambiguates_paths(tmp_path):
    """Regression for the ``_key`` collisions: a dict key containing a
    literal "/" used to collide with genuine nesting, and a dict key
    "0" with sequence index 0 — the later leaf silently overwrote the
    earlier one in the npz and both loaded the same array.  With tagged,
    escaped components every leaf round-trips distinctly."""
    tree = {
        "a/b": jnp.asarray([1.0, 2.0]),
        "a": {"b": jnp.asarray([3.0, 4.0])},
        "s": {"0": jnp.asarray([5.0])},
        "t": (jnp.asarray([6.0]),),
    }
    path = str(tmp_path / "tree.npz")
    save_pytree(path, tree)
    out = load_pytree(path, tree)
    np.testing.assert_array_equal(np.asarray(out["a/b"]), [1.0, 2.0])
    np.testing.assert_array_equal(np.asarray(out["a"]["b"]), [3.0, 4.0])
    np.testing.assert_array_equal(np.asarray(out["s"]["0"]), [5.0])
    np.testing.assert_array_equal(np.asarray(out["t"][0]), [6.0])


def test_pytree_save_rejects_colliding_keys(tmp_path):
    """If two leaves ever mapped to the same npz entry the writer must
    fail loudly instead of silently dropping one (belt and braces on
    top of the escaping)."""
    from repro.checkpoint import io as ckpt_io

    tree = {"x": jnp.zeros(2), "y": jnp.ones(2)}
    orig = ckpt_io._key
    ckpt_io._key = lambda path: "same"
    try:
        with pytest.raises(CheckpointKeyError, match="duplicate npz key"):
            save_pytree(str(tmp_path / "t.npz"), tree)
    finally:
        ckpt_io._key = orig


def test_pytree_load_accepts_legacy_untagged_keys(tmp_path):
    """Checkpoints written by the old joiner (plain "/"-joined, untagged
    components) must still load when their keys were unambiguous."""
    tree = {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nested": {"ts": jnp.asarray([1, 2], jnp.int32)},
            "tup": (jnp.asarray([1.5], jnp.float32),)}
    path = str(tmp_path / "legacy.npz")
    np.savez(path, **{"w": np.asarray(tree["w"]),
                      "nested/ts": np.asarray(tree["nested"]["ts"]),
                      "tup/0": np.asarray(tree["tup"][0])})
    out = load_pytree(path, tree)
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# engine-state round trips: save at round 3, restore into a fresh
# engine, and the continued run must be bit-identical to the original
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", sorted(ENGINES))
def test_restored_engine_continues_bit_identically(engine, tmp_path):
    full = _make(engine)
    h_full = full.run(6)  # the uninterrupted reference

    first = _make(engine)
    h_first = first.run(3)
    path = str(tmp_path / "engine.npz")
    save_pytree(path, first.state_dict())

    restored = _make(engine)  # fresh engine: params/cache re-initialized
    restored.load_state_dict(load_pytree(path, restored.state_dict()))
    assert restored.t_done == 3
    h_rest = restored.run(3)

    # ledger: rounds 1-3 from the first leg, 4-6 from the restored leg,
    # together byte-identical to the uninterrupted run's ledger
    split = [r for h in (h_first, h_rest) for r in h.ledger.rounds]
    np.testing.assert_array_equal([r.uplink for r in h_full.ledger.rounds],
                                  [r.uplink for r in split])
    np.testing.assert_array_equal([r.downlink for r in h_full.ledger.rounds],
                                  [r.downlink for r in split])
    # eval metrics: the restored leg evals at absolute rounds 6 (t==t_end
    # catches 3 on the first leg); all shared rounds must agree exactly
    for t, sa, ca in zip(h_rest.rounds, h_rest.server_acc, h_rest.client_acc):
        if t in h_full.rounds:
            i = h_full.rounds.index(t)
            assert sa == h_full.server_acc[i]
            assert ca == h_full.client_acc[i]
    # final device state agrees bitwise with the uninterrupted run
    np.testing.assert_array_equal(np.asarray(full.cache_g.values),
                                  np.asarray(restored.cache_g.values))
    np.testing.assert_array_equal(np.asarray(full.cache_g.ts),
                                  np.asarray(restored.cache_g.ts))
    np.testing.assert_array_equal(full.last_sync, restored.last_sync)
    for a, b in zip(jax.tree_util.tree_leaves(full.server_params),
                    jax.tree_util.tree_leaves(restored.server_params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree_util.tree_leaves(full.client_params),
                    jax.tree_util.tree_leaves(restored.client_params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restore_rejects_stateful_numpy_backend():
    """The numpy Generators are not captured by state_dict: restoring a
    numpy-backend host engine would silently replay virgin RNG streams,
    so it must be rejected outright."""
    donor = _make("host")
    donor.run(2)
    legacy = FederatedDistillation(CFG, STRATEGIES["scarlet"](beta=1.5),
                                   cache_duration=3)  # rng_backend="numpy"
    with pytest.raises(ValueError, match="rng_backend='jax'"):
        legacy.load_state_dict(donor.state_dict())


def test_state_dict_rejects_per_client_teacher_stacks():
    """COMET carries per-client (K, m, N) teachers that don't fit the
    fixed (m, N) prev_teacher slot of the checkpoint structure — saving
    must fail loudly rather than produce an unrestorable npz."""
    eng = FederatedDistillation(CFG, STRATEGIES["comet"](),
                                rng_backend="jax")
    eng.run(2)
    with pytest.raises(ValueError, match="per-client prev_teacher"):
        eng.state_dict()


def test_restore_rejects_uncaptured_local_cache_mirrors():
    """track_local_caches mirrors are not checkpointed: restoring into
    that mode would verify cold mirrors against a warm global cache."""
    donor = _make("host")
    donor.run(2)
    verifier = FederatedDistillation(
        CFG, STRATEGIES["scarlet"](beta=1.5), cache_duration=3,
        rng_backend="jax", track_local_caches=True)
    with pytest.raises(ValueError, match="track_local_caches"):
        verifier.load_state_dict(donor.state_dict())


def test_ledger_roundtrip_through_checkpoint(tmp_path):
    """A History ledger serialized alongside the engine state restores
    to identical per-round byte values."""
    eng = _make("scan")
    hist = eng.run(4)
    path = str(tmp_path / "run.npz")
    blob = dict(
        engine=eng.state_dict(),
        ledger_up=jnp.asarray([r.uplink for r in hist.ledger.rounds]),
        ledger_down=jnp.asarray([r.downlink for r in hist.ledger.rounds]),
    )
    save_pytree(path, blob)
    out = load_pytree(path, blob)
    ledger = comm.CommLedger()
    for u, d in zip(np.asarray(out["ledger_up"]),
                    np.asarray(out["ledger_down"])):
        ledger.record(comm.RoundCost(float(u), float(d)))
    assert ledger.cumulative_total == hist.ledger.cumulative_total
    assert [r.uplink for r in ledger.rounds] == \
        [r.uplink for r in hist.ledger.rounds]
