"""Unit + property tests for ERA / Enhanced ERA (paper §III-E, App. B/C)."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st
from hypothesis.extra import numpy as hnp

from repro.core import era

jax.config.update("jax_enable_x64", False)


def _rand_probs(draw_arr):
    p = np.abs(draw_arr) + 1e-6
    return p / p.sum(axis=-1, keepdims=True)


probs_strategy = hnp.arrays(
    np.float64, st.tuples(st.integers(1, 5), st.integers(2, 12)),
    elements=st.floats(0.01, 10.0),
).map(_rand_probs)


def test_beta_one_is_identity():
    z = jnp.asarray(np.random.default_rng(0).dirichlet(np.ones(10), size=50))
    out = era.enhanced_era(z, 1.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(z), atol=1e-6)


@settings(max_examples=50, deadline=None)
@given(probs_strategy, st.floats(0.3, 5.0))
def test_output_is_distribution(p, beta):
    out = np.asarray(era.enhanced_era(jnp.asarray(p, jnp.float32), beta))
    assert np.all(out >= 0)
    np.testing.assert_allclose(out.sum(-1), 1.0, atol=1e-4)


@settings(max_examples=50, deadline=None)
@given(probs_strategy, st.floats(0.5, 3.0), st.floats(0.05, 1.5))
def test_entropy_monotone_in_beta(p, b1, delta):
    """Appendix B majorization corollary: H(beta2) <= H(beta1) for beta2>beta1."""
    b2 = b1 + delta
    z = jnp.asarray(p, jnp.float32)
    h1 = np.asarray(era.entropy(era.enhanced_era(z, b1)))
    h2 = np.asarray(era.entropy(era.enhanced_era(z, b2)))
    assert np.all(h2 <= h1 + 1e-4)


@settings(max_examples=50, deadline=None)
@given(probs_strategy, st.floats(1.01, 4.0))
def test_majorization_prefix_sums(p, beta):
    """Appendix B Theorem 1: sorted prefix sums of beta-sharpened dominate."""
    z = np.sort(np.asarray(p, np.float64), axis=-1)[..., ::-1]  # descending
    out1 = z / z.sum(-1, keepdims=True)
    out2 = z**beta / (z**beta).sum(-1, keepdims=True)
    cs1 = np.cumsum(out1, -1)
    cs2 = np.cumsum(out2, -1)
    assert np.all(cs2 >= cs1 - 1e-9)  # sharper distribution majorizes


@settings(max_examples=30, deadline=None)
@given(st.floats(0.05, 0.45), st.floats(1.2, 9.0), st.floats(0.5, 3.0))
def test_scale_invariance_of_log_ratio(zj, ratio, beta):
    """Appendix C: Enhanced-ERA output log-ratio depends only on the input
    ratio R and beta (ln Ratio = beta ln R), not on the absolute scale."""
    zi = zj * ratio
    rest = 1.0 - zi - zj
    if rest <= 0.01:
        return
    # two inputs with identical ratio R but different scales
    a = np.array([zi, zj, rest])
    b = np.array([zi / 2, zj / 2, 1.0 - (zi + zj) / 2])
    for N, vec in (("a", a), ("b", b)):
        out = np.asarray(era.enhanced_era(jnp.asarray(vec, jnp.float32), beta), np.float64)
        lr = np.log(out[0]) - np.log(out[1])
        np.testing.assert_allclose(lr, beta * np.log(ratio), rtol=1e-3, atol=1e-3)


def test_era_is_scale_dependent_counterexample():
    """Appendix C: conventional ERA maps identical-ratio inputs to
    DIFFERENT log-ratios — the instability Enhanced ERA removes."""
    T = 0.1
    a = jnp.asarray([0.15, 0.10, 0.75])
    b = jnp.asarray([0.30, 0.20, 0.50])  # same ratio z_i/z_j = 1.5
    oa = np.asarray(era.era(a, T), np.float64)
    ob = np.asarray(era.era(b, T), np.float64)
    lra = np.log(oa[0] / oa[1])
    lrb = np.log(ob[0] / ob[1])
    np.testing.assert_allclose(lra, 0.05 / T, rtol=1e-3)
    np.testing.assert_allclose(lrb, 0.10 / T, rtol=1e-3)
    assert abs(lrb - 2 * lra) < 1e-3  # doubled sharpening for same knowledge


def test_era_limits_agree():
    """T->0 and beta->inf both approach one-hot argmax."""
    z = jnp.asarray([0.5, 0.3, 0.2])
    e1 = np.asarray(era.era(z, 0.001))
    e2 = np.asarray(era.enhanced_era(z, 200.0))
    np.testing.assert_allclose(e1, [1, 0, 0], atol=1e-6)
    np.testing.assert_allclose(e2, [1, 0, 0], atol=1e-6)


def test_aggregate_weights_and_methods():
    rng = np.random.default_rng(1)
    zc = jnp.asarray(rng.dirichlet(np.ones(6), size=(4, 10)))
    m = era.aggregate_soft_labels(zc, "mean")
    np.testing.assert_allclose(np.asarray(m), np.asarray(zc.mean(0)), atol=1e-6)
    w = jnp.asarray([1.0, 1.0, 2.0, 0.0])
    mw = era.aggregate_soft_labels(zc, "mean", weights=w)
    expect = (zc[0] + zc[1] + 2 * zc[2]) / 4
    np.testing.assert_allclose(np.asarray(mw), np.asarray(expect), atol=1e-6)
    for method, kw in [("era", {"T": 0.1}), ("enhanced_era", {"beta": 1.5})]:
        out = np.asarray(era.aggregate_soft_labels(zc, method, **kw))
        np.testing.assert_allclose(out.sum(-1), 1.0, atol=1e-5)


def test_enhanced_era_handles_zeros_and_onehot():
    z = jnp.asarray([[0.0, 0.0, 1.0], [0.5, 0.5, 0.0]])
    out = np.asarray(era.enhanced_era(z, 2.0))
    np.testing.assert_allclose(out[0], [0, 0, 1], atol=1e-5)
    np.testing.assert_allclose(out[1], [0.5, 0.5, 0], atol=1e-5)
    assert np.isfinite(out).all()
