"""Unit tests for ``repro.launch.perf.variant_plan`` — the perf-sweep
variant table that maps a variant name to (sharding scheme, config
overrides, MoE dispatch spec, MoE all-to-all flag).

``repro.launch.perf`` mutates ``XLA_FLAGS`` at import time (it forces
512 host devices for the sweep); the import is wrapped so the rest of
the suite keeps its own flags.
"""
import os

import pytest


def _variant_plan():
    saved = os.environ.get("XLA_FLAGS")
    try:
        from repro.launch.perf import variant_plan
    finally:
        if saved is None:
            os.environ.pop("XLA_FLAGS", None)
        else:
            os.environ["XLA_FLAGS"] = saved
    return variant_plan


EP_SPEC = ("data", None, "model")

# name -> (scheme, overrides, moe_spec(is_moe), moe_spec(dense), a2a)
TABLE = {
    "ep-a2a": ("ep", {}, None, None, True),
    "baseline-tp": ("tp", {}, None, None, False),
    "tp-ep": ("tp", {}, EP_SPEC, EP_SPEC, False),
    "tp-dots-remat": ("tp", {"remat_policy": "dots_saveable"},
                      None, None, False),
    "tp-lse-ce": ("tp", {"ce_impl": "lse"}, None, None, False),
    "tp-bf16logits": ("tp", {"fp32_logits": False, "ce_impl": "lse"},
                      None, None, False),
    "tp-bf16attn": ("tp", {"attn_f32": False}, None, None, False),
    "tp-all": ("tp", {"remat_policy": "dots_saveable", "ce_impl": "lse",
                      "attn_f32": False}, EP_SPEC, None, False),
    "fsdp": ("fsdp", {}, None, None, False),
    "fsdp-bf16logits": ("fsdp", {"fp32_logits": False}, None, None, False),
    "fsdp-dots-remat": ("fsdp", {"remat_policy": "dots_saveable"},
                        None, None, False),
    "fsdp-ep": ("fsdp", {}, EP_SPEC, EP_SPEC, False),
    "fsdp-all": ("fsdp", {"fp32_logits": False,
                          "remat_policy": "dots_saveable"},
                 EP_SPEC, None, False),
}


@pytest.mark.parametrize("name", sorted(TABLE))
@pytest.mark.parametrize("is_moe", (True, False), ids=("moe", "dense"))
def test_variant_plan_table(name, is_moe):
    variant_plan = _variant_plan()
    scheme, overrides, moe_wanted, dense_wanted, a2a = TABLE[name]
    got = variant_plan(name, is_moe)
    assert got == (scheme, overrides,
                   moe_wanted if is_moe else dense_wanted, a2a)


def test_variant_plan_overrides_are_fresh_objects():
    """Mutating one call's overrides must not leak into the next (the
    sweep loop feeds them into dryrun.run_combo as-is)."""
    variant_plan = _variant_plan()
    a = variant_plan("fsdp-all", True)[1]
    a["remat_policy"] = "mutated"
    assert variant_plan("fsdp-all", True)[1]["remat_policy"] == \
        "dots_saveable"


def test_variant_plan_unknown_name_raises():
    variant_plan = _variant_plan()
    with pytest.raises(ValueError, match="no-such-variant"):
        variant_plan("no-such-variant", False)


def test_variant_plan_ep_only_gated_on_all_variants():
    """The *-all variants attach the expert-parallel dispatch spec only
    for MoE archs; the explicit *-ep variants always attach it."""
    variant_plan = _variant_plan()
    for name in ("tp-all", "fsdp-all"):
        assert variant_plan(name, True)[2] == EP_SPEC
        assert variant_plan(name, False)[2] is None
    for name in ("tp-ep", "fsdp-ep"):
        assert variant_plan(name, False)[2] == EP_SPEC
