"""Unit + property tests for the soft-label codec subsystem
(`repro.compress`): simplex preservation, quantization-error
monotonicity, cache-delta exactness, analytic payload hand-counts, and
the CFD-refactor regression (Table-V bytes + aggregation output)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.compress import (
    CODECS,
    CacheDeltaCodec,
    IdentityCodec,
    QuantCodec,
    TopKCodec,
    get_codec,
)
from repro.core import comm
from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(7)

ALL_SPECS = ("identity", "quant8", "quant4", "quant1", "topk2", "topk4",
             "cache_delta", "cache_delta+quant8", "cache_delta+quant4",
             "cache_delta+topk4")


def _probs(key, shape):
    return jax.random.dirichlet(key, jnp.ones(shape[-1]), shape[:-1])


def _ctx(key, m, n):
    base = _probs(key, (m, n))
    present = jax.random.bernoulli(jax.random.fold_in(key, 1), 0.6, (m,))
    return base, present


# ---------------------------------------------------------------------------
# Protocol invariants
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec", ALL_SPECS)
def test_roundtrip_equals_decode_of_encode(spec):
    """The fused roundtrip (kernel path) must match decode(encode(z))."""
    c = get_codec(spec)
    z = _probs(KEY, (3, 17, 10))
    base, present = _ctx(jax.random.fold_in(KEY, 2), 17, 10)
    rt = c.roundtrip(z, base=base, present=present)
    dd = c.decode(c.encode(z, base, present), base, present)
    np.testing.assert_allclose(np.asarray(rt), np.asarray(dd),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("spec", ALL_SPECS)
def test_decoded_outputs_stay_on_simplex(spec):
    c = get_codec(spec)
    z = _probs(KEY, (4, 23, 6))
    base, present = _ctx(jax.random.fold_in(KEY, 3), 23, 6)
    out = np.asarray(c.roundtrip(z, base=base, present=present))
    assert out.shape == z.shape
    assert (out >= -1e-7).all(), spec
    np.testing.assert_allclose(out.sum(-1), 1.0, atol=1e-5)


@pytest.mark.parametrize("spec", ALL_SPECS)
def test_codecs_are_scan_safe_and_jittable(spec):
    c = get_codec(spec)
    assert c.scan_safe
    z = _probs(KEY, (2, 9, 5))
    base, present = _ctx(jax.random.fold_in(KEY, 4), 9, 5)
    jitted = jax.jit(lambda z: c.roundtrip(z, base=base, present=present))
    np.testing.assert_allclose(
        np.asarray(jitted(z)),
        np.asarray(c.roundtrip(z, base=base, present=present)),
        rtol=1e-6, atol=1e-7)


# ---------------------------------------------------------------------------
# Quantization
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.integers(1, 40), st.integers(2, 32), st.integers(0, 10_000))
def test_quant_error_monotone_non_increasing_in_bits(rows, n_classes, seed):
    """More bits never hurts — pointwise, because the min-max grids nest
    (levels 1 | 15 | 255 all divide the next) and share endpoints."""
    z = jnp.asarray(np.random.default_rng(seed).dirichlet(
        np.ones(n_classes), rows), jnp.float32)
    errs = [jnp.abs(z - ops.quantize_dequantize(z, bits))
            for bits in (1, 4, 8)]
    assert (errs[1] <= errs[0] + 1e-6).all()
    assert (errs[2] <= errs[1] + 1e-6).all()


def test_quant_kernel_matches_ref_oracle():
    z = jax.random.normal(KEY, (37, 21))  # arbitrary reals, not just probs
    for bits in (1, 2, 4, 8):
        np.testing.assert_allclose(
            np.asarray(ops.quantize_dequantize(z, bits)),
            np.asarray(ref.quantize_dequantize(z, bits)),
            rtol=1e-6, atol=1e-6)


def test_quant1_collapses_to_row_extremes():
    z = _probs(KEY, (5, 8))
    out = np.asarray(ops.quantize_dequantize(z, 1))
    zmin = np.asarray(z.min(-1, keepdims=True))
    zmax = np.asarray(z.max(-1, keepdims=True))
    assert np.all(np.isclose(out, zmin, atol=1e-6)
                  | np.isclose(out, zmax, atol=1e-6))


# ---------------------------------------------------------------------------
# Cache-delta
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.integers(2, 30), st.integers(2, 16), st.integers(0, 10_000))
def test_cache_delta_exact_when_prediction_equals_cache(m, n, seed):
    """Zero residual survives any inner quantizer: min-max of an
    all-zero row quantizes to exactly zero."""
    rng = np.random.default_rng(seed)
    base = jnp.asarray(rng.dirichlet(np.ones(n), m), jnp.float32)
    z = jnp.broadcast_to(base, (3, m, n))
    for spec in ("cache_delta", "cache_delta+quant8", "cache_delta+quant1"):
        c = get_codec(spec)
        out = c.roundtrip(z, base=base, present=jnp.ones(m, bool))
        np.testing.assert_allclose(np.asarray(out), np.asarray(z),
                                   atol=1e-5, err_msg=spec)


def test_cache_delta_uses_uniform_base_where_absent():
    """Absent cache entries delta against the uniform prior — decoding
    with identity inner is lossless either way."""
    m, n = 11, 7
    z = _probs(KEY, (2, m, n))
    base = _probs(jax.random.fold_in(KEY, 5), (m, n))
    c = get_codec("cache_delta")
    for present in (jnp.zeros(m, bool), jnp.ones(m, bool)):
        out = c.roundtrip(z, base=base, present=present)
        np.testing.assert_allclose(np.asarray(out), np.asarray(z), atol=1e-5)
    # and with no cache context at all
    np.testing.assert_allclose(np.asarray(c.roundtrip(z)), np.asarray(z),
                               atol=1e-5)


def test_cache_delta_residuals_smaller_than_raw_quant_error():
    """The point of delta coding: near-cache predictions survive coarse
    quantization far better than raw labels do."""
    m, n = 64, 10
    base = _probs(KEY, (m, n))
    noise = 0.02 * jax.random.normal(jax.random.fold_in(KEY, 6), (m, n))
    z = jnp.maximum(base + noise, 0.0)
    z = z / z.sum(-1, keepdims=True)
    present = jnp.ones(m, bool)
    err_delta = jnp.abs(z - get_codec("cache_delta+quant4").roundtrip(
        z, base=base, present=present)).mean()
    err_raw = jnp.abs(z - get_codec("quant4").roundtrip(z)).mean()
    assert float(err_delta) < float(err_raw)


# ---------------------------------------------------------------------------
# Analytic payload accounting
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(st.integers(0, 500), st.integers(2, 100))
def test_payload_bytes_hand_counts(n, N):
    assert IdentityCodec().payload_bytes(n, N) == n * N * 4.0
    assert QuantCodec(8).payload_bytes(n, N) == n * N
    assert QuantCodec(4).payload_bytes(n, N) == n * N * 0.5
    assert QuantCodec(1).payload_bytes(n, N) == n * N / 8.0
    # topk: k fp32 values + k indices per row
    assert TopKCodec(2).payload_bytes(n, N) == n * 2 * (4.0 + 4.0)
    assert TopKCodec(2, index_bytes=2.0).payload_bytes(n, N) == n * 2 * 6.0
    # cache_delta: inner pays for N-1 classes (sum-zero drop)
    assert get_codec("cache_delta+quant8").payload_bytes(n, N) == n * (N - 1)
    assert CacheDeltaCodec().payload_bytes(n, N) == n * (N - 1) * 4.0


def test_payload_bytes_small_case_exact():
    """The hand-count from the docstring: 3 samples, 10 classes."""
    assert IdentityCodec().payload_bytes(3, 10) == 120.0
    assert QuantCodec(8).payload_bytes(3, 10) == 30.0
    assert get_codec("cache_delta+quant8").payload_bytes(3, 10) == 27.0
    assert TopKCodec(2).payload_bytes(3, 10) == 48.0


def test_round_cost_uses_codec_payloads():
    plain = comm.distillation_round_cost(
        n_clients=10, n_selected=100, n_requested=40, n_classes=10)
    coded = comm.distillation_round_cost(
        n_clients=10, n_selected=100, n_requested=40, n_classes=10,
        uplink_codec=get_codec("quant8"),
        downlink_codec=get_codec("cache_delta+quant8"))
    assert coded.uplink == plain.uplink / 4
    # downlink payload shrinks; request-list bytes unchanged
    req_list = 40 * 4.0 + 100 * 4.0
    assert coded.downlink == pytest.approx(
        10 * (get_codec("cache_delta+quant8").payload_bytes(40, 10) + req_list))
    # identity codecs leave the legacy bits path untouched
    ident = comm.distillation_round_cost(
        n_clients=10, n_selected=100, n_requested=40, n_classes=10,
        uplink_codec=get_codec("identity"),
        downlink_codec=get_codec("identity"))
    assert (ident.uplink, ident.downlink) == (plain.uplink, plain.downlink)


def test_index_bytes_configurable():
    assert comm.index_bytes_for(200) == 1.0
    assert comm.index_bytes_for(1000) == 2.0
    assert comm.index_bytes_for(65536) == 2.0
    assert comm.index_bytes_for(100_000) == 4.0
    wide = comm.distillation_round_cost(
        n_clients=10, n_selected=100, n_requested=40, n_classes=10)
    narrow = comm.distillation_round_cost(
        n_clients=10, n_selected=100, n_requested=40, n_classes=10,
        bytes_index=2.0)
    assert wide.downlink - narrow.downlink == 10 * (40 + 100) * 2.0
    assert wide.uplink == narrow.uplink


# ---------------------------------------------------------------------------
# Registry / spec parsing
# ---------------------------------------------------------------------------

def test_registry_and_spec_parsing():
    assert set(CODECS) >= {"identity", "quant8", "quant4", "quant1",
                           "topk", "cache_delta"}
    assert get_codec(None).is_identity
    assert get_codec("quant6").bits == 6
    assert get_codec("topk4").k == 4
    assert get_codec("topk").k == 2
    c = get_codec("cache_delta+quant8")
    assert c.name == "cache_delta+quant8" and c.inner.bits == 8
    assert not c.inner.renormalize  # residual mode
    inst = QuantCodec(3)
    assert get_codec(inst) is inst
    with pytest.raises(ValueError):
        get_codec("nope")
    with pytest.raises(ValueError):
        get_codec("cache_delta+nope")


def test_registry_is_the_extension_point():
    """A codec registered in CODECS resolves by name through get_codec
    (and hence through the FLConfig codec fields)."""
    CODECS["_test_custom"] = lambda: QuantCodec(5)
    try:
        assert get_codec("_test_custom").bits == 5
    finally:
        del CODECS["_test_custom"]


def test_index_bytes_threads_into_topk():
    assert get_codec("topk2", index_bytes=2.0).payload_bytes(10, 8) \
        == 10 * 2 * (4.0 + 2.0)
    assert get_codec("cache_delta+topk2",
                     index_bytes=2.0).inner.index_bytes == 2.0
    # and from FLConfig through the engine constructor
    from repro.fl import FederatedDistillation, FLConfig
    from repro.fl.strategies import STRATEGIES

    cfg = FLConfig(n_clients=4, n_classes=4, dim=8, rounds=2, local_steps=1,
                   distill_steps=1, public_size=60, public_per_round=12,
                   private_size=80, hidden=16, alpha=0.5,
                   uplink_codec="topk2", index_bytes=2.0)
    fd = FederatedDistillation(cfg, STRATEGIES["mean"]())
    assert fd.codec_up.index_bytes == 2.0


# ---------------------------------------------------------------------------
# CFD refactor regression: the strategy now delegates to QuantCodec
# ---------------------------------------------------------------------------

def _legacy_cfd_transmit(z, b_up):
    """The inline quantizer CFDStrategy shipped before the codec
    subsystem existed — pinned verbatim as the regression oracle."""
    levels = 2 ** b_up - 1
    zmin = z.min(axis=-1, keepdims=True)
    zmax = z.max(axis=-1, keepdims=True)
    scale = jnp.maximum(zmax - zmin, 1e-9)
    q = jnp.round((z - zmin) / scale * levels) / levels
    deq = q * scale + zmin
    return deq / jnp.maximum(deq.sum(-1, keepdims=True), 1e-9)


@pytest.mark.parametrize("b_up", [1, 2, 8])
def test_cfd_transmit_matches_legacy_inline_quantizer(b_up):
    from repro.fl.strategies import STRATEGIES

    s = STRATEGIES["cfd"](b_up=b_up)
    z = _probs(KEY, (6, 40, 10))
    got = s.transmit(z, None)
    want = _legacy_cfd_transmit(z, b_up)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)
    # aggregation output (the value the server actually consumes)
    np.testing.assert_allclose(np.asarray(s.aggregate(got, None, 1)[0]),
                               np.asarray(jnp.mean(want, axis=0)),
                               rtol=1e-5, atol=1e-6)


def test_cfd_table5_byte_values_pinned():
    """Table V setting (K=100, |P^t|=1000, N=10, b_up=1): the refactor
    must not move a single byte of the pinned analytic costs."""
    c = comm.distillation_round_cost(
        n_clients=100, n_selected=1000, n_requested=1000, n_classes=10,
        uplink_bits=1.0)
    assert c.uplink == 100 * 1000 * 10 * 1 / 8  # 125_000.0, byte-exact
    assert c.downlink == 100 * (1000 * 10 * 4.0 + 1000 * 4.0 + 1000 * 4.0)
