"""Fused round kernel (``repro.kernels.round_kernel``): oracle parity,
bit-level parity with the per-op codec + aggregation chain, and the
engine-level validation of ``FLConfig.fused_round``."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compress.codecs import get_codec
from repro.fl.config import FLConfig
from repro.fl.scan_engine import ScannedFederatedDistillation
from repro.fl.strategies import STRATEGIES
from repro.fl.strategies.scarlet import EnhancedERAStrategy
from repro.kernels import ops, ref, round_kernel

KEY = jax.random.PRNGKey(7)


def _probs(key, shape):
    return jax.random.dirichlet(key, jnp.ones(shape[-1]), shape[:-1])


def _mask(key, k):
    return (jax.random.uniform(key, (k,)) < 0.6).astype(jnp.float32)


MODES = [("identity", None), ("quant", 8), ("quant", 4),
         ("delta", None), ("delta", 8)]


# ---------------------------------------------------------------------------
# Kernel vs oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("K,M,N", [(4, 8, 10), (7, 10, 10), (16, 33, 21),
                                   (3, 100, 100)])
@pytest.mark.parametrize("mode,bits", MODES)
@pytest.mark.parametrize("sharpen", [True, False])
def test_fused_round_matches_oracle(K, M, N, mode, bits, sharpen):
    z = _probs(KEY, (K, M, N))
    w = _mask(jax.random.fold_in(KEY, 1), K) * 1.7
    base = (_probs(jax.random.fold_in(KEY, 2), (M, N))
            if mode == "delta" else None)
    beta = 1.5 if sharpen else None
    out = round_kernel.fused_round(z, w, beta, base, mode=mode, bits=bits,
                                   sharpen=sharpen)
    exp = ref.fused_round(z, w, beta, base, mode=mode, bits=bits,
                          sharpen=sharpen)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-5, atol=1e-6)


def test_fused_round_block_sizing_auto_shrinks():
    """Large K must shrink the row block against the VMEM budget while
    staying 8-aligned — and still match the oracle."""
    K, M, N = 1000, 24, 10
    z = _probs(KEY, (K, M, N))
    w = jnp.ones(K)
    bm = round_kernel._auto_block_m(M, K, 128, True)
    assert bm % 8 == 0 and bm >= 8
    out = round_kernel.fused_round(z, w, 1.5, mode="identity")
    exp = ref.fused_round(z, w, 1.5, mode="identity")
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-5, atol=1e-6)


def test_fused_round_validation_errors():
    z = _probs(KEY, (4, 8, 10))
    w = jnp.ones(4)
    with pytest.raises(ValueError, match="unknown mode"):
        round_kernel.fused_round(z, w, 1.5, mode="nope")
    with pytest.raises(ValueError, match="requires bits"):
        round_kernel.fused_round(z, w, 1.5, mode="quant")
    with pytest.raises(ValueError, match="requires beta"):
        round_kernel.fused_round(z, w, None, mode="identity", sharpen=True)
    with pytest.raises(ValueError, match="resolved base"):
        round_kernel.fused_round(z, w, 1.5, mode="delta")


# ---------------------------------------------------------------------------
# Bit-level parity with the per-op chain (what the engines replace)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec", ["identity", "quant8", "cache_delta",
                                  "cache_delta+quant8"])
def test_strategy_fused_matches_perop_chain(spec):
    """``aggregate_masked_fused`` == codec.roundtrip + ``aggregate_masked``
    exactly in interpret mode (same f32 expression sequence); the
    acceptance tolerance of one quantization step (~scale/levels) is the
    native-TPU bound, so assert the much tighter interpret-mode band."""
    K, M, N = 6, 10, 10
    s = EnhancedERAStrategy(beta=1.5)
    codec = get_codec(spec)
    kspec = round_kernel.codec_kernel_spec(codec)
    assert kspec is not None
    z = _probs(KEY, (K, M, N))
    part = _mask(jax.random.fold_in(KEY, 3), K)
    base = _probs(jax.random.fold_in(KEY, 4), (M, N))
    present = jax.random.uniform(jax.random.fold_in(KEY, 5), (M,)) < 0.5

    if codec.is_identity:
        z_rt = z
    else:
        z_rt = codec.roundtrip(z, base=base, present=present)
    perop = s.aggregate_masked(z_rt, part, None, 1)
    fbase = (round_kernel.resolve_delta_base(base, present, M, N)
             if kspec["mode"] == "delta" else None)
    fused = s.aggregate_masked_fused(z, part, kspec, fbase, 1)
    # one-quant-step acceptance bound; interpret mode is in fact exact
    step = (1.0 / (2 ** (kspec["bits"] or 32) - 1)
            if kspec["bits"] else 1e-6)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(perop),
                               atol=step, rtol=1e-5)


def test_strategy_fused_interpret_mode_is_exact():
    """On this (CPU) backend the kernel runs the interpreter, which
    executes the identical f32 expression sequence — byte-equal output."""
    K, M, N = 6, 10, 10
    s = EnhancedERAStrategy(beta=1.5)
    codec = get_codec("cache_delta+quant8")
    z = _probs(KEY, (K, M, N))
    part = _mask(jax.random.fold_in(KEY, 3), K)
    base = _probs(jax.random.fold_in(KEY, 4), (M, N))
    present = jax.random.uniform(jax.random.fold_in(KEY, 5), (M,)) < 0.5
    perop = s.aggregate_masked(codec.roundtrip(z, base=base, present=present),
                               part, None, 1)
    fused = s.aggregate_masked_fused(
        z, part, {"mode": "delta", "bits": 8},
        round_kernel.resolve_delta_base(base, present, M, N), 1)
    assert np.asarray(perop).tobytes() == np.asarray(fused).tobytes()


def test_fused_total_outage_uniform_teacher():
    """All clients out: the fused path must reproduce
    ``aggregate_masked``'s ``jnp.where`` uniform-teacher guard."""
    K, M, N = 5, 8, 10
    s = EnhancedERAStrategy(beta=1.5)
    z = _probs(KEY, (K, M, N))
    part = jnp.zeros(K)
    fused = s.aggregate_masked_fused(z, part, {"mode": "identity",
                                               "bits": None}, None, 1)
    perop = s.aggregate_masked(z, part, None, 1)
    np.testing.assert_allclose(np.asarray(fused), np.full((M, N), 1.0 / N),
                               atol=1e-7)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(perop), atol=1e-7)


def test_partial_aggregate_fused_matches_two_phase():
    """The linear fused phase composes with finalize_aggregate to the
    same teacher as the per-op two-phase path (the shard contract)."""
    K, M, N = 8, 12, 10
    s = EnhancedERAStrategy(beta=1.5)
    codec = get_codec("quant8")
    z = _probs(KEY, (K, M, N))
    part = _mask(jax.random.fold_in(KEY, 6), K)
    z_rt = codec.roundtrip(z)
    perop = s.finalize_aggregate(s.partial_aggregate(z_rt, part, None, 1), 1)
    partials = s.partial_aggregate_fused(z, part, {"mode": "quant", "bits": 8},
                                         None, 1)
    fused = s.finalize_aggregate(partials, 1)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(perop),
                               rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# codec_kernel_spec / resolve_delta_base
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec,want", [
    ("identity", {"mode": "identity", "bits": None}),
    ("quant8", {"mode": "quant", "bits": 8}),
    ("quant4", {"mode": "quant", "bits": 4}),
    ("cache_delta", {"mode": "delta", "bits": None}),
    ("cache_delta+quant8", {"mode": "delta", "bits": 8}),
    ("topk2", None),  # no fused equivalent -> per-op path
])
def test_codec_kernel_spec(spec, want):
    assert round_kernel.codec_kernel_spec(get_codec(spec)) == want


def test_resolve_delta_base_matches_codec_base():
    codec = get_codec("cache_delta")
    M, N = 6, 10
    base = _probs(KEY, (M, N))
    present = jnp.asarray([True, False, True, True, False, False])
    a = np.asarray(codec._base(jnp.zeros((4, M, N)), base, present))
    b = np.asarray(round_kernel.resolve_delta_base(base, present, M, N))
    np.testing.assert_allclose(np.broadcast_to(b, a.shape), a, atol=0)
    # no cache at all -> uniform prior
    u = np.asarray(round_kernel.resolve_delta_base(None, None, M, N))
    np.testing.assert_allclose(u, 1.0 / N, atol=0)


# ---------------------------------------------------------------------------
# Engine construction validation
# ---------------------------------------------------------------------------

CFG = FLConfig(n_clients=4, n_classes=4, dim=6, rounds=2, local_steps=1,
               distill_steps=1, public_size=32, public_per_round=8,
               private_size=40, hidden=8, eval_every=10**6, fused_round=True)


def test_engine_rejects_unfusable_codec():
    with pytest.raises(ValueError, match="not kernel-expressible"):
        ScannedFederatedDistillation(
            dataclasses.replace(CFG, uplink_codec="topk2"),
            STRATEGIES["scarlet"](beta=1.5), cache_duration=4)


def test_engine_rejects_unfused_strategy():
    with pytest.raises(ValueError, match="no fused round path"):
        ScannedFederatedDistillation(
            CFG, STRATEGIES["dsfl"](T=0.1))


def test_engine_rejects_adaptive_beta():
    with pytest.raises(ValueError, match="no fused round path"):
        ScannedFederatedDistillation(
            CFG, STRATEGIES["scarlet"](beta="adaptive"), cache_duration=4)


def test_ops_entry_point():
    """The jit'd public wrapper dispatches with backend-detected
    interpret mode."""
    z = _probs(KEY, (4, 8, 10))
    out = ops.fused_round(z, jnp.ones(4), 1.5, mode="quant", bits=8)
    exp = ref.fused_round(z, jnp.ones(4), 1.5, mode="quant", bits=8)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-5, atol=1e-6)
