"""Telemetry conformance: host x scan x shard emit the SAME telemetry.

The round counters are computed from the replicated full-width
participation draw with one shared expression
(``FederatedDistillation._telemetry_row``), so across engines they are
not merely close — the integer counters and exact byte tallies must be
**byte-equal stacks**.  The float gauges (teacher entropy, beta, codec
quantization error) reduce over clients in different orders (host
einsum vs scan tensordot vs shard psum), so they get allclose.

Also pinned here:

- the cache-signal partition invariant: every distilled row is exactly
  one of hit / new miss / expired miss, so the three counters sum to
  ``active_rounds * public_per_round``;
- telemetry **on** does not move the ledger: a telemetry-on scan run at
  the golden config must reproduce the committed golden-ledger bytes
  (no new golden fixtures — the existing files are the contract);
- telemetry **off** leaves ``History.telemetry`` None (and the golden
  tests in ``test_golden_ledgers.py`` keep pinning the off-path bytes).
"""
import json

import numpy as np
import pytest

from repro.fl import (
    FLConfig,
    Outage,
    Scenario,
    bernoulli_participation,
    fixed_fraction,
    run_method,
)
from repro.obs.device import EXACT_FIELDS, GAUGE_FIELDS
from test_golden_ledgers import CFG as GOLDEN_CFG
from test_golden_ledgers import GOLDEN_DIR, METHOD_KW

CFG = FLConfig(
    n_clients=4, n_classes=4, dim=8, rounds=4, local_steps=2,
    distill_steps=2, public_size=48, public_per_round=10,
    private_size=64, alpha=0.5, eval_every=2, seed=0, hidden=12,
    mesh_spec="2x4", telemetry=True,
)

STRATEGY_KW = {
    "scarlet": dict(cache_duration=3, beta=1.5),
    # dsfl with the cache plugged in so its cells exercise hit/expiry
    # counters too (dsfl alone never populates the cache)
    "dsfl": dict(T=0.1, use_cache=True, cache_duration=3),
}

PARTICIPATIONS = {
    "bernoulli": Scenario(participation=bernoulli_participation(0.5)),
    # outage windows: zero-participant rounds (gated telemetry rows) and
    # returning stragglers (catch-up counters + staleness tail)
    "outage": Scenario(participation=fixed_fraction(0.5),
                       outages=(Outage(1, 2, 3),)),
}

CODECS = ("identity", "cache_delta+quant8")

MATRIX = [(s, p, c) for s in sorted(STRATEGY_KW)
          for p in sorted(PARTICIPATIONS) for c in CODECS]


def _run(engine, strategy, scenario, codec, **extra):
    kw = dict(STRATEGY_KW[strategy])
    kw.update(extra)
    return run_method(strategy, CFG, engine=engine, codec=codec,
                      scenario=scenario, **kw)


@pytest.mark.parametrize("strategy,part,codec", MATRIX,
                         ids=[f"{s}-{p}-{c}" for s, p, c in MATRIX])
def test_three_engine_telemetry_parity(strategy, part, codec):
    scenario = PARTICIPATIONS[part]
    host = _run("host", strategy, scenario, codec, rng_backend="jax")
    scan = _run("scan", strategy, scenario, codec)
    shard = _run("shard", strategy, scenario, codec)

    stacks = {n: h.telemetry.stacks()
              for n, h in (("host", host), ("scan", scan), ("shard", shard))}
    for field in EXACT_FIELDS:
        ref = stacks["host"][field]
        for other in ("scan", "shard"):
            assert np.array_equal(ref, stacks[other][field]), (
                f"{field}: host vs {other} counter stacks diverged\n"
                f"host={ref}\n{other}={stacks[other][field]}")
    for field in GAUGE_FIELDS:
        ref = stacks["host"][field]
        for other in ("scan", "shard"):
            np.testing.assert_allclose(
                stacks[other][field], ref, atol=1e-5, rtol=1e-5,
                err_msg=f"{field}: host vs {other} gauge stacks diverged")

    # partition invariant: each distilled row is exactly one cache signal
    s = scan.telemetry.summary()
    assert (s["cache_hits"] + s["cache_miss_new"] + s["cache_expired"]
            == s["active_rounds"] * CFG.public_per_round)
    # and the byte counters must reproduce the ledger's totals exactly
    led = scan.ledger.summary()
    assert s["uplink_bytes"] == pytest.approx(
        led["uplink_mean"] * led["rounds"], rel=1e-6)


@pytest.mark.parametrize("method,codec",
                         [(m, c) for m in ("scarlet", "dsfl")
                          for c in ("identity", "quant8")],
                         ids=lambda v: str(v))
def test_telemetry_on_ledger_matches_golden(method, codec):
    """Turning telemetry ON may not move a single ledger byte: the scan
    run at the golden config must still reproduce the committed fixture
    (the structural half of this guarantee is proven statically by
    ``repro.analysis`` pass 4)."""
    h = run_method(
        method, GOLDEN_CFG, engine="scan", codec=codec, telemetry=True,
        scenario=Scenario(participation=bernoulli_participation(0.5)),
        **METHOD_KW[method])
    text = json.dumps(h.ledger.summary(), sort_keys=True, indent=2) + "\n"
    golden = (GOLDEN_DIR / f"{method}-{codec}.json").read_text()
    assert golden == text, (
        f"telemetry=True perturbed the {method}-{codec} golden ledger")
    assert h.telemetry is not None and h.telemetry.summary()["rounds"] == 4


def test_telemetry_off_history_has_no_log():
    h = run_method("scarlet", GOLDEN_CFG, engine="scan",
                   **METHOD_KW["scarlet"])
    assert h.telemetry is None
    assert "telemetry" not in h.as_dict()


def test_baseline_methods_reject_telemetry():
    with pytest.raises(ValueError, match="telemetry"):
        run_method("fedavg", GOLDEN_CFG, telemetry=True)
