"""Per-architecture smoke tests (deliverable f): REDUCED variant of each
assigned family — one forward + one train step on CPU, asserting output
shapes and absence of NaNs; plus a decode step for serve-mode shapes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS, ASSIGNED
from repro.launch.specs import make_batch
from repro.models import registry
from repro.optim import get as get_opt

SMOKE_SEQ = 32
SMOKE_BATCH = 2


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ASSIGNED)
def test_forward_and_train_step(arch, key):
    cfg = ARCHS[arch].reduced()
    params, axes = registry.init(cfg, key)
    # axes pytree structurally matches params
    jax.tree_util.tree_map(lambda p, a: None, params, axes)
    batch = make_batch(cfg, SMOKE_BATCH, SMOKE_SEQ)

    logits = registry.prefill(cfg, params, batch)
    expected_s = SMOKE_SEQ
    if cfg.family == "vlm":
        expected_s += cfg.n_patches
    assert logits.shape == (SMOKE_BATCH, expected_s, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    # one optimizer step reduces nothing catastrophic and stays finite
    opt = get_opt("adamw")
    state = opt.init(params)
    loss, grads = jax.value_and_grad(
        lambda p: registry.loss_fn(cfg, p, batch))(params)
    assert np.isfinite(float(loss))
    new_params, state = opt.update(grads, state, params, 1e-3)
    for leaf in jax.tree_util.tree_leaves(new_params):
        assert np.isfinite(np.asarray(leaf, np.float32)).all()


@pytest.mark.parametrize("arch", ASSIGNED)
def test_decode_step(arch, key):
    cfg = ARCHS[arch].reduced()
    params, _ = registry.init(cfg, key)
    cache = registry.init_decode_cache(cfg, SMOKE_BATCH, SMOKE_SEQ)
    tok = jnp.zeros((SMOKE_BATCH, 1), jnp.int32)
    logits, cache2 = registry.decode_step(cfg, params, cache, tok, jnp.int32(3))
    assert logits.shape == (SMOKE_BATCH, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    jax.tree_util.tree_map(lambda a, b: (a.shape, a.dtype) == (b.shape, b.dtype)
                           or pytest.fail("cache shape drift"), cache, cache2)


def test_full_configs_match_assignment():
    """The FULL configs carry the exact assigned hyperparameters."""
    c = ARCHS["kimi-k2-1t-a32b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads) == (61, 7168, 64, 8)
    assert (c.n_experts, c.top_k, c.vocab_size) == (384, 8, 163840)
    c = ARCHS["gemma2-27b"]
    assert c.local_global_alternating and c.sliding_window == 4096
    assert c.attn_softcap == 50.0 and c.vocab_size == 256000
    c = ARCHS["jamba-v0.1-52b"]
    assert c.attn_layer_period == 8 and c.n_experts == 16 and c.top_k == 2
    c = ARCHS["mamba2-1.3b"]
    assert c.ssm_state == 128 and c.n_layers == 48 and c.family == "ssm"
    c = ARCHS["whisper-large-v3"]
    assert c.family == "encdec" and c.d_model == 1280 and c.n_heads == 20
    c = ARCHS["internvl2-26b"]
    assert c.family == "vlm" and c.vocab_size == 92553
    c = ARCHS["grok-1-314b"]
    assert c.n_experts == 8 and c.d_ff == 32768
    assert ARCHS["phi4-mini-3.8b"].vocab_size == 200064
    assert ARCHS["granite-3-2b"].d_model == 2048
    assert ARCHS["granite-3-8b"].d_model == 4096
    assert len([a for a in ASSIGNED]) == 10


def test_param_counts_in_band():
    """Analytic param counts should land near the advertised sizes."""
    expect = {
        "kimi-k2-1t-a32b": (900e9, 1150e9),
        "grok-1-314b": (280e9, 350e9),
        "jamba-v0.1-52b": (45e9, 60e9),
        "gemma2-27b": (24e9, 32e9),
        "granite-3-8b": (7e9, 10e9),
        "granite-3-2b": (2e9, 3.5e9),
        "phi4-mini-3.8b": (3.3e9, 5e9),
        "mamba2-1.3b": (1.1e9, 1.7e9),
    }
    for name, (lo, hi) in expect.items():
        n = ARCHS[name].param_count()
        assert lo <= n <= hi, (name, n)
    # active params for the MoEs
    assert 30e9 <= ARCHS["kimi-k2-1t-a32b"].active_param_count() <= 40e9
    assert 10e9 <= ARCHS["jamba-v0.1-52b"].active_param_count() <= 14e9
