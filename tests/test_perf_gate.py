"""Unit tests for the CI perf-regression gate (``benchmarks.perf_gate``):
the gate must fail on a simulated regression and stay quiet inside the
tolerance band."""
import copy
import json
import os

import pytest

from benchmarks import perf_gate

ENV = {"backend": "cpu", "device_kind": "cpu", "device_count": 8,
       "jax": "x", "python": "x", "machine": "x"}


def _doc(rows):
    return {"bench": "engine", "schema": 1, "quick": True, "env": dict(ENV),
            "rows": rows}


BASE = _doc([
    {"name": "engine_scan_perop_K200", "us_per_call": 10_000.0,
     "rounds_per_sec": 100.0, "derived": ""},
    {"name": "engine_scan_fused_K200", "us_per_call": 5_000.0,
     "rounds_per_sec": 200.0, "speedup": 2.0, "derived": ""},
])


def test_identical_docs_pass():
    assert perf_gate.gate_docs(BASE, copy.deepcopy(BASE)) == []


def test_within_band_passes():
    cur = copy.deepcopy(BASE)
    cur["rows"][0]["us_per_call"] = 10_000.0 * 1.5  # inside 1+ratio_tol
    cur["rows"][1]["speedup"] = 2.0 * 0.6           # above 1-ratio_tol floor
    assert perf_gate.gate_docs(BASE, cur, ratio_tol=0.75,
                               abs_tol_us=0.0) == []


def test_simulated_time_regression_fails():
    cur = copy.deepcopy(BASE)
    cur["rows"][1]["us_per_call"] = 50_000.0  # 10x slower
    fails = perf_gate.gate_docs(BASE, cur)
    assert any("us_per_call regressed" in f and "fused" in f for f in fails)


def test_simulated_speedup_loss_fails():
    """The fused path silently losing its advantage (speedup 2.0 -> 0.3)
    must trip the gate even if absolute times stay within the band."""
    cur = copy.deepcopy(BASE)
    cur["rows"][1]["speedup"] = 0.3
    cur["rows"][1]["rounds_per_sec"] = 30.0
    fails = perf_gate.gate_docs(BASE, cur)
    assert any("speedup regressed" in f for f in fails)
    assert any("rounds_per_sec regressed" in f for f in fails)


def test_missing_row_fails():
    cur = copy.deepcopy(BASE)
    cur["rows"] = cur["rows"][:1]
    fails = perf_gate.gate_docs(BASE, cur)
    assert any("missing from current run" in f for f in fails)


def test_new_rows_allowed():
    cur = copy.deepcopy(BASE)
    cur["rows"].append({"name": "engine_new_case", "us_per_call": 1e9})
    assert perf_gate.gate_docs(BASE, cur) == []


def test_env_mismatch_fails():
    cur = copy.deepcopy(BASE)
    cur["env"]["backend"] = "tpu"
    fails = perf_gate.gate_docs(BASE, cur)
    assert any("env mismatch" in f for f in fails)


def test_abs_floor_absorbs_micro_noise():
    """Microsecond-scale rows: a 3x blip on a 20us row is scheduler
    noise, absorbed by the additive floor."""
    base = _doc([{"name": "tiny", "us_per_call": 20.0}])
    cur = _doc([{"name": "tiny", "us_per_call": 60.0}])
    assert perf_gate.gate_docs(base, cur, ratio_tol=0.5, abs_tol_us=500.0) == []
    fails = perf_gate.gate_docs(base, cur, ratio_tol=0.5, abs_tol_us=0.0)
    assert fails  # without the floor it would (correctly) trip


def test_gate_dirs_roundtrip(tmp_path):
    bdir, cdir = tmp_path / "base", tmp_path / "cur"
    bdir.mkdir(), cdir.mkdir()
    (bdir / "BENCH_engine.json").write_text(json.dumps(BASE))
    # missing current file fails
    fails = perf_gate.gate_dirs(str(bdir), str(cdir))
    assert any("missing from current dir" in f for f in fails)
    (cdir / "BENCH_engine.json").write_text(json.dumps(BASE))
    assert perf_gate.gate_dirs(str(bdir), str(cdir)) == []
    # regression through the file path too
    bad = copy.deepcopy(BASE)
    bad["rows"][0]["us_per_call"] = 1e9
    (cdir / "BENCH_engine.json").write_text(json.dumps(bad))
    assert perf_gate.gate_dirs(str(bdir), str(cdir))


def test_empty_baseline_dir_fails(tmp_path):
    fails = perf_gate.gate_dirs(str(tmp_path), str(tmp_path))
    assert any("no BENCH" in f for f in fails)
