"""Engine-parity regressions outside the conformance matrix.

The strategy x participation x codec matrix itself (host x scan x shard
pairwise parity from one shared fixture) lives in
``tests/test_engine_conformance.py``; this module keeps the cases the
matrix does not span: lossy-downlink cache identity, analytic
ledger-ratio pinning, unsupported-mode rejection, and the Selective-FD
accounting regression.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import comm
from repro.fl import (
    FederatedDistillation,
    FLConfig,
    ScannedFederatedDistillation,
)
from repro.fl.strategies import STRATEGIES
from test_engine_conformance import assert_parity

CFG = FLConfig(
    n_clients=4, n_classes=4, dim=8, rounds=4, local_steps=2,
    distill_steps=2, public_size=60, public_per_round=12,
    private_size=80, alpha=0.5, eval_every=2, seed=0, hidden=16,
)


@pytest.mark.parametrize("codec", ("quant4", "topk", "cache_delta"))
def test_scanned_engine_matches_host_loop_with_codec(codec):
    """Codec families outside the conformance matrix (quant4, top-k
    index costing, pure delta coding) keep host/scan parity coverage —
    under bernoulli participation so per-round cohort sizes vary."""
    from repro.fl import Scenario, bernoulli_participation

    sc = Scenario(participation=bernoulli_participation(0.5))
    cfg = dataclasses.replace(CFG, uplink_codec=codec)
    host = FederatedDistillation(
        cfg, STRATEGIES["scarlet"](beta=1.5), cache_duration=3,
        scenario=sc, rng_backend="jax")
    scan = ScannedFederatedDistillation(
        cfg, STRATEGIES["scarlet"](beta=1.5), cache_duration=3, scenario=sc)
    assert_parity(host, host.run(), scan, scan.run())


def test_scanned_engine_matches_host_loop_with_downlink_codec():
    """Lossy downlink feeds the decoded teacher into the global cache —
    cache values must still agree bit-for-bit between the engines."""
    cfg = dataclasses.replace(CFG, uplink_codec="cache_delta+quant8",
                              downlink_codec="quant8")
    host = FederatedDistillation(
        cfg, STRATEGIES["scarlet"](beta=1.5), cache_duration=3,
        rng_backend="jax")
    scan = ScannedFederatedDistillation(
        cfg, STRATEGIES["scarlet"](beta=1.5), cache_duration=3)
    assert_parity(host, host.run(), scan, scan.run())


def test_codec_shrinks_ledger_by_analytic_ratio():
    """Same run, quant8 uplink vs identity: every round's uplink is
    exactly 4x smaller; downlink is untouched."""
    base = FederatedDistillation(
        CFG, STRATEGIES["scarlet"](beta=1.5), cache_duration=3,
        rng_backend="jax")
    h0 = base.run()
    coded = FederatedDistillation(
        dataclasses.replace(CFG, uplink_codec="quant8"),
        STRATEGIES["scarlet"](beta=1.5), cache_duration=3, rng_backend="jax")
    h1 = coded.run()
    for r0, r1 in zip(h0.ledger.rounds, h1.ledger.rounds):
        assert r1.uplink == pytest.approx(r0.uplink / 4)


def test_scanned_engine_rejects_unsupported_modes():
    with pytest.raises(ValueError):
        ScannedFederatedDistillation(CFG, STRATEGIES["comet"]())
    with pytest.raises(ValueError):
        ScannedFederatedDistillation(
            CFG, STRATEGIES["scarlet"](beta=1.5), cache_duration=3,
            track_local_caches=True)
    with pytest.raises(ValueError):
        ScannedFederatedDistillation(
            CFG, STRATEGIES["scarlet"](beta=1.5), rng_backend="numpy")


# ---------------------------------------------------------------------------
# Selective-FD accounting regression (the downlink-undercount bugfix)
# ---------------------------------------------------------------------------

def test_selective_fd_downlink_matches_analytic_value():
    """The confidence gate masks only the uplink: the server still
    broadcasts aggregated labels for every requested sample, so with no
    cache every round's downlink is exactly
    ``n_clients * (m*N*4 + m*4 + m*4)`` bytes — independent of how many
    labels passed the selector.  (The pre-fix code scaled downlink by
    the upload fraction too, undercounting it.)
    """
    fd = FederatedDistillation(CFG, STRATEGIES["selective_fd"]())
    hist = fd.run(3)
    K, m, N = CFG.n_clients, CFG.public_per_round, CFG.n_classes
    expected_down = K * (m * N * 4.0 + m * 4.0 + m * 4.0)
    full_up = K * m * N * 4.0
    for r in hist.ledger.rounds:
        assert r.downlink == pytest.approx(expected_down)
        assert r.uplink <= full_up + 1e-9
    # near-uniform early predictions fail the confidence gate, so some
    # uplink must actually have been withheld
    assert hist.ledger.rounds[0].uplink < full_up


def test_split_cost_counts_match_legacy_when_equal():
    legacy = comm.distillation_round_cost(
        n_clients=10, n_selected=100, n_requested=40, n_classes=10)
    split = comm.distillation_round_cost(
        n_clients=10, n_selected=100, n_up_samples=40, n_down_samples=40,
        n_classes=10)
    assert legacy.uplink == split.uplink
    assert legacy.downlink == split.downlink
    # gated uplink shrinks only the uplink
    gated = comm.distillation_round_cost(
        n_clients=10, n_selected=100, n_up_samples=25.5, n_down_samples=40,
        n_classes=10)
    assert gated.uplink < split.uplink
    assert gated.downlink == split.downlink
