"""Parity suite: the scanned (lax.scan) engine vs the host reference loop.

Both engines draw subsets/participation from the identical jax key
stream (``rng_backend="jax"``), so every round sees the same P^t and
the same cohort; the remaining differences are float reduction order.
The ledger is integer-derived (sample counts, byte constants), so it
must match to float exactness; eval metrics and cache values to
allclose.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import comm
from repro.fl import (
    FederatedDistillation,
    FLConfig,
    Outage,
    Scenario,
    ScannedFederatedDistillation,
    bernoulli_participation,
    fixed_fraction,
    full_participation,
)
from repro.fl.strategies import STRATEGIES

CFG = FLConfig(
    n_clients=4, n_classes=4, dim=8, rounds=4, local_steps=2,
    distill_steps=2, public_size=60, public_per_round=12,
    private_size=80, alpha=0.5, eval_every=2, seed=0, hidden=16,
)

STRATEGY_KW = {
    "scarlet": dict(beta=1.5),
    "dsfl": dict(T=0.1),
    "mean": dict(),
}
CACHE_D = {"scarlet": 3, "dsfl": 0, "mean": 0}

PARTICIPATIONS = {
    "full": Scenario(participation=full_participation()),
    "bernoulli": Scenario(participation=bernoulli_participation(0.5)),
}


def _pair(name, scenario, **kw):
    strat_kw = STRATEGY_KW[name]
    host = FederatedDistillation(
        CFG, STRATEGIES[name](**strat_kw), cache_duration=CACHE_D[name],
        scenario=scenario, rng_backend="jax", **kw)
    scan = ScannedFederatedDistillation(
        CFG, STRATEGIES[name](**strat_kw), cache_duration=CACHE_D[name],
        scenario=scenario, **kw)
    return host, host.run(), scan, scan.run()


def _assert_parity(host, h_host, scan, h_scan):
    # --- per-round ledger: integer-derived, must match exactly ---------
    assert len(h_host.ledger.rounds) == len(h_scan.ledger.rounds)
    np.testing.assert_allclose(
        [r.uplink for r in h_host.ledger.rounds],
        [r.uplink for r in h_scan.ledger.rounds], rtol=1e-7)
    np.testing.assert_allclose(
        [r.downlink for r in h_host.ledger.rounds],
        [r.downlink for r in h_scan.ledger.rounds], rtol=1e-7)
    # --- History metrics ----------------------------------------------
    assert h_host.rounds == h_scan.rounds
    np.testing.assert_allclose(h_host.server_acc, h_scan.server_acc, atol=1e-5)
    np.testing.assert_allclose(h_host.client_acc, h_scan.client_acc, atol=1e-5)
    np.testing.assert_allclose(h_host.cumulative_mb, h_scan.cumulative_mb,
                               rtol=1e-7)
    np.testing.assert_allclose(h_host.server_val_loss, h_scan.server_val_loss,
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(h_host.client_val_loss, h_scan.client_val_loss,
                               rtol=1e-4, atol=1e-5)
    # --- cache state + sync bookkeeping -------------------------------
    np.testing.assert_array_equal(np.asarray(host.cache_g.present),
                                  np.asarray(scan.cache_g.present))
    np.testing.assert_array_equal(np.asarray(host.cache_g.ts),
                                  np.asarray(scan.cache_g.ts))
    np.testing.assert_allclose(np.asarray(host.cache_g.values),
                               np.asarray(scan.cache_g.values), atol=1e-5)
    np.testing.assert_array_equal(host.last_sync, scan.last_sync)


@pytest.mark.parametrize("participation", sorted(PARTICIPATIONS))
@pytest.mark.parametrize("name", sorted(STRATEGY_KW))
def test_scanned_engine_matches_host_loop(name, participation):
    _assert_parity(*_pair(name, PARTICIPATIONS[participation]))


def test_scanned_engine_matches_host_loop_with_catch_up():
    """Outage + partial participation exercises the dense catch-up byte
    accounting against the host loop's per-package packaging."""
    sc = Scenario(participation=fixed_fraction(0.5), outages=(Outage(0, 2, 3),))
    _assert_parity(*_pair("scarlet", sc))


# ---------------------------------------------------------------------------
# Wire codecs: both engines must apply the identical encode->decode round
# trip AND charge the identical analytic payload bytes
# ---------------------------------------------------------------------------

CODEC_SPECS = ("quant8", "quant4", "topk", "cache_delta", "cache_delta+quant8")


@pytest.mark.parametrize("codec", CODEC_SPECS)
def test_scanned_engine_matches_host_loop_with_codec(codec):
    strat_kw = STRATEGY_KW["scarlet"]
    cfg = dataclasses.replace(CFG, uplink_codec=codec)
    host = FederatedDistillation(
        cfg, STRATEGIES["scarlet"](**strat_kw), cache_duration=3,
        scenario=PARTICIPATIONS["bernoulli"], rng_backend="jax")
    scan = ScannedFederatedDistillation(
        cfg, STRATEGIES["scarlet"](**strat_kw), cache_duration=3,
        scenario=PARTICIPATIONS["bernoulli"])
    _assert_parity(host, host.run(), scan, scan.run())


def test_scanned_engine_matches_host_loop_with_downlink_codec():
    """Lossy downlink feeds the decoded teacher into the global cache —
    cache values must still agree bit-for-bit between the engines."""
    cfg = dataclasses.replace(CFG, uplink_codec="cache_delta+quant8",
                              downlink_codec="quant8")
    host = FederatedDistillation(
        cfg, STRATEGIES["scarlet"](beta=1.5), cache_duration=3,
        rng_backend="jax")
    scan = ScannedFederatedDistillation(
        cfg, STRATEGIES["scarlet"](beta=1.5), cache_duration=3)
    _assert_parity(host, host.run(), scan, scan.run())


def test_codec_shrinks_ledger_by_analytic_ratio():
    """Same run, quant8 uplink vs identity: every round's uplink is
    exactly 4x smaller; downlink is untouched."""
    base = FederatedDistillation(
        CFG, STRATEGIES["scarlet"](beta=1.5), cache_duration=3,
        rng_backend="jax")
    h0 = base.run()
    coded = FederatedDistillation(
        dataclasses.replace(CFG, uplink_codec="quant8"),
        STRATEGIES["scarlet"](beta=1.5), cache_duration=3, rng_backend="jax")
    h1 = coded.run()
    for r0, r1 in zip(h0.ledger.rounds, h1.ledger.rounds):
        assert r1.uplink == pytest.approx(r0.uplink / 4)


def test_scanned_engine_rejects_unsupported_modes():
    with pytest.raises(ValueError):
        ScannedFederatedDistillation(CFG, STRATEGIES["comet"]())
    with pytest.raises(ValueError):
        ScannedFederatedDistillation(
            CFG, STRATEGIES["scarlet"](beta=1.5), cache_duration=3,
            track_local_caches=True)
    with pytest.raises(ValueError):
        ScannedFederatedDistillation(
            CFG, STRATEGIES["scarlet"](beta=1.5), rng_backend="numpy")


# ---------------------------------------------------------------------------
# Selective-FD accounting regression (the downlink-undercount bugfix)
# ---------------------------------------------------------------------------

def test_selective_fd_downlink_matches_analytic_value():
    """The confidence gate masks only the uplink: the server still
    broadcasts aggregated labels for every requested sample, so with no
    cache every round's downlink is exactly
    ``n_clients * (m*N*4 + m*4 + m*4)`` bytes — independent of how many
    labels passed the selector.  (The pre-fix code scaled downlink by
    the upload fraction too, undercounting it.)
    """
    fd = FederatedDistillation(CFG, STRATEGIES["selective_fd"]())
    hist = fd.run(3)
    K, m, N = CFG.n_clients, CFG.public_per_round, CFG.n_classes
    expected_down = K * (m * N * 4.0 + m * 4.0 + m * 4.0)
    full_up = K * m * N * 4.0
    for r in hist.ledger.rounds:
        assert r.downlink == pytest.approx(expected_down)
        assert r.uplink <= full_up + 1e-9
    # near-uniform early predictions fail the confidence gate, so some
    # uplink must actually have been withheld
    assert hist.ledger.rounds[0].uplink < full_up


def test_split_cost_counts_match_legacy_when_equal():
    legacy = comm.distillation_round_cost(
        n_clients=10, n_selected=100, n_requested=40, n_classes=10)
    split = comm.distillation_round_cost(
        n_clients=10, n_selected=100, n_up_samples=40, n_down_samples=40,
        n_classes=10)
    assert legacy.uplink == split.uplink
    assert legacy.downlink == split.downlink
    # gated uplink shrinks only the uplink
    gated = comm.distillation_round_cost(
        n_clients=10, n_selected=100, n_up_samples=25.5, n_down_samples=40,
        n_classes=10)
    assert gated.uplink < split.uplink
    assert gated.downlink == split.downlink
