"""Tests for the static contract analyzer (``repro.analysis``).

Two halves:

- the real repo must come back clean from all three passes (the same
  property CI's ``python -m repro.analysis --strict`` enforces);
- every deliberately broken fixture must be flagged at its expected
  level, and the repaired replication twin must NOT be flagged (the
  false-positive check).

Everything here is trace-only: no kernel executes, no training runs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import fixtures, jaxpr_checks, pallas_checks
from repro.analysis.report import Finding, Report
from repro.analysis.traceutil import record_host_rng, trace


# ---------------------------------------------------------------------------
# Report plumbing
# ---------------------------------------------------------------------------

def test_report_exit_codes():
    r = Report()
    r.add("ok", "p", "s", "fine")
    r.add("info", "p", "s", "fyi")
    assert r.exit_code(strict=False) == 0
    assert r.exit_code(strict=True) == 0  # info never fails

    r.add("warn", "p", "s", "hmm")
    assert r.exit_code(strict=False) == 0
    assert r.exit_code(strict=True) == 1

    r.add("error", "p", "s", "bad")
    assert r.exit_code(strict=False) == 1
    assert len(r.errors) == 1 and len(r.warnings) == 1


def test_report_render_and_json():
    r = Report()
    r.add("error", "pallas", "case", "boom")
    text = r.render(verbose=True)
    assert "boom" in text and "ERROR" in text.upper()
    d = r.to_dict()
    assert d["findings"][0]["level"] == "error"
    assert "boom" in r.to_json()


def test_finding_str():
    f = Finding("warn", "jaxpr", "subj", "msg")
    assert "warn" in str(f).lower() and "subj" in str(f)


# ---------------------------------------------------------------------------
# traceutil
# ---------------------------------------------------------------------------

def test_trace_detects_callbacks():
    def f(x):
        out = jax.ShapeDtypeStruct(x.shape, x.dtype)
        return jax.pure_callback(lambda a: a, out, x)

    tr = trace(f, jax.ShapeDtypeStruct((4,), jnp.float32))
    assert tr.ok and tr.callbacks
    assert any("callback" in v for v in tr.scan_safety_violations())


def test_record_host_rng_spy():
    seen = []
    with record_host_rng(seen):
        np.random.default_rng(0)
    assert seen  # constructor call recorded
    # and restored afterwards
    assert np.random.default_rng(0).integers(10) >= 0


# ---------------------------------------------------------------------------
# The repo itself is clean
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_repo_jaxpr_pass_clean():
    findings = jaxpr_checks.run()
    errs = [f for f in findings if f.level in ("error", "warn")]
    assert not errs, "\n".join(str(f) for f in errs)
    assert any(f.level == "ok" for f in findings)


def test_repo_pallas_pass_clean():
    findings = pallas_checks.run()
    errs = [f for f in findings if f.level in ("error", "warn")]
    assert not errs, "\n".join(str(f) for f in errs)
    # every kernel module contributed at least one linted case
    subjects = {f.subject.split("/")[0] for f in findings}
    for mod in ("era_fused", "quant", "round", "distill", "attn"):
        assert any(s.startswith(mod.split("_")[0]) for s in subjects), mod


@pytest.mark.slow
def test_repo_replication_pass_clean():
    from repro.analysis import replication_checks

    findings = replication_checks.run()
    errs = [f for f in findings if f.level == "error"]
    assert not errs, "\n".join(str(f) for f in errs)
    assert any(f.level == "ok" for f in findings)


# ---------------------------------------------------------------------------
# Broken fixtures are flagged
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(fixtures.BROKEN_STRATEGIES))
def test_broken_strategy_flagged(name):
    want = fixtures.EXPECTED_STRATEGY_LEVEL[name]
    got = jaxpr_checks.check_strategy(name, fixtures.BROKEN_STRATEGIES[name])
    assert any(f.level == want for f in got), (
        f"{name}: expected a {want!r} finding, got "
        + "\n".join(str(f) for f in got))


@pytest.mark.parametrize(
    "label,fn,args,want",
    fixtures.broken_kernel_cases(),
    ids=[c[0] for c in fixtures.broken_kernel_cases()])
def test_broken_kernel_flagged(label, fn, args, want):
    got = pallas_checks.check_case(label, fn, args)
    assert any(f.level == want for f in got), (
        f"{label}: expected {want!r}, got "
        + "\n".join(str(f) for f in got))


@pytest.mark.slow
def test_repo_obs_pass_clean():
    from repro.analysis import obs_checks

    findings = obs_checks.run()
    errs = [f for f in findings if f.level == "error"]
    assert not errs, "\n".join(str(f) for f in errs)
    assert any("structurally additive" in f.message for f in findings)


def test_telemetry_callback_hook_flagged():
    from repro.analysis import obs_checks

    got = obs_checks.check_round_body(
        "fixture/telemetry-callback", fixtures.telemetry_callback_engine())
    errs = [f for f in got if f.level == "error"]
    assert errs, "debug_callback-smuggling telemetry hook not flagged"
    assert any("callback" in f.message for f in errs)


@pytest.mark.slow
def test_repo_active_pass_clean():
    from repro.analysis import active_checks

    findings = active_checks.run()
    errs = [f for f in findings if f.level == "error"]
    assert not errs, "\n".join(str(f) for f in errs)
    # one ok per analysis variant, each certifying the K-separation
    oks = [f for f in findings if f.level == "ok"]
    assert len(oks) == len(active_checks.ANALYSIS_VARIANTS)
    assert all(f"K={active_checks.K_ANALYSIS}" in f.message for f in oks)


def test_leaky_active_engine_flagged():
    from repro.analysis import active_checks

    got = active_checks.check_engine(
        "fixture/active-k-leak", fixtures.leaky_active_engine())
    errs = [f for f in got if f.level == "error"]
    assert errs, "O(K) leak into the gathered client step not flagged"
    assert any("client step" in f.message for f in errs)
    # the leak is in the client step, not the (legitimately O(K))
    # bookkeeping step
    assert all("client-step" in f.subject for f in errs)


def test_active_pass_traces_the_right_functions():
    """The K-presence sanity check: hand the checker an engine whose
    bookkeeping never touches K-sized state and it must refuse to
    certify (a vacuous K-separation proof is worse than none)."""
    from repro.analysis import active_checks

    eng = active_checks.build_engine("scarlet", {}, {"cache_duration": 2},
                                     "identity")
    orig = eng.active_round_fns

    def swapped():
        entries = orig()
        # keep only the client step but mislabel it as bookkeeping
        (_, fn, args) = [e for e in entries if e[0] == "client-step"][0]
        return [("bookkeeping", fn, args)]

    eng.active_round_fns = swapped
    got = active_checks.check_engine("fixture/mislabeled", eng)
    errs = [f for f in got if f.level == "error"]
    assert errs and any("proves nothing" in f.message for f in errs)


def test_broken_carry_flagged_fixed_carry_clean():
    from repro.analysis import replication_checks

    broken = replication_checks.check_shard_map_fn(
        *fixtures.broken_carry_fn(), subject_prefix="fixture-broken:")
    errs = [f for f in broken if f.level == "error"]
    assert errs, "axis_index-tainted replicated carry not flagged"
    assert any("data" in f.message for f in errs)

    fixed = replication_checks.check_shard_map_fn(
        *fixtures.fixed_carry_fn(), subject_prefix="fixture-fixed:")
    assert not [f for f in fixed if f.level == "error"], (
        "psum-cleaned twin falsely flagged:\n"
        + "\n".join(str(f) for f in fixed))


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def test_cli_selftest_fast(capsys):
    from repro.analysis.__main__ import main

    assert main(["--selftest", "--fast"]) == 0
    out = capsys.readouterr().out
    assert "flagged as expected" in out


def test_cli_fast_strict_on_repo(capsys, tmp_path):
    from repro.analysis.__main__ import main

    json_path = tmp_path / "report.json"
    assert main(["--fast", "--strict", "--json", str(json_path)]) == 0
    assert json_path.exists() and "findings" in json_path.read_text()
