"""Edge-case tests for ``repro.launch.hlo_analysis.analyze`` on
hand-written HLO text: empty modules, fusion-only modules, modules with
no collectives, entry-computation fallback, and residual while loops.
The dry-run roofline feeds real XLA dumps through this parser; these
pin its conventions on minimal inputs.
"""
from repro.launch.hlo_analysis import HloSummary, analyze, parse_hlo

FUSION_ONLY = """\
%fused_dot (p0: f32[8,4], p1: f32[4,16]) -> f32[8,16] {
  %p0 = f32[8,4] parameter(0)
  %p1 = f32[4,16] parameter(1)
  ROOT %dot.1 = f32[8,16] dot(%p0, %p1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}

ENTRY %main.1 (a: f32[8,4], b: f32[4,16]) -> f32[8,16] {
  %a = f32[8,4] parameter(0)
  %b = f32[4,16] parameter(1)
  ROOT %fusion = f32[8,16] fusion(%a, %b), kind=kOutput, calls=%fused_dot
}
"""

NO_COLLECTIVES = """\
ENTRY %main.2 (x: f32[32]) -> f32[32] {
  %x = f32[32] parameter(0)
  %e = f32[32] exponential(%x)
  ROOT %t = f32[32] tanh(%e)
}
"""

ALL_REDUCE = """\
%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main.3 (x: f32[128]) -> f32[128] {
  %x = f32[128] parameter(0)
  ROOT %ar = f32[128] all-reduce(%x), replica_groups={}, to_apply=%add
}
"""

NO_ENTRY_MARKER = """\
%helper (p: f32[4]) -> f32[4] {
  %p = f32[4] parameter(0)
  ROOT %n = f32[4] negate(%p)
}

%top.0 (x: f32[4]) -> f32[4] {
  %x = f32[4] parameter(0)
  ROOT %c = f32[4] call(%x), to_apply=%helper
}
"""

WITH_WHILE = """\
%body (s: s32[]) -> s32[] {
  %s = s32[] parameter(0)
  %one = s32[] constant(1)
  ROOT %n = s32[] add(%s, %one)
}

%cond (s: s32[]) -> pred[] {
  %s = s32[] parameter(0)
  %lim = s32[] constant(10)
  ROOT %lt = pred[] compare(%s, %lim), direction=LT
}

ENTRY %main.4 (x: s32[]) -> s32[] {
  %x = s32[] parameter(0)
  ROOT %w = s32[] while(%x), condition=%cond, body=%body
}
"""


def test_empty_module():
    s = analyze("")
    assert isinstance(s, HloSummary)
    assert s.dot_flops == 0.0
    assert s.collective_bytes == 0.0
    assert s.residual_while_loops == 0


def test_comment_only_module():
    s = analyze("# HloModule foo\n# no computations here\n")
    assert s.dot_flops == 0.0 and s.collective_bytes == 0.0


def test_fusion_only_dot_flops():
    s = analyze(FUSION_ONLY)
    # dot: out 8*16=128 elems, contracted dim 4 -> 2*128*4 = 1024 FLOPs,
    # weighted by one fusion call from the entry
    assert s.dot_flops == 2.0 * 8 * 16 * 4
    assert s.collective_bytes == 0.0
    assert s.residual_while_loops == 0


def test_no_collectives_counts_transcendentals():
    s = analyze(NO_COLLECTIVES)
    assert s.collective_bytes == 0.0
    assert s.collective_by_kind == {}
    assert s.transcendental_elems == 64  # exp(32) + tanh(32)


def test_all_reduce_bytes_convention():
    s = analyze(ALL_REDUCE)
    # all-reduce convention: 2 x max(in, out) = 2 * 128 * 4B = 1024
    assert s.collective_by_kind == {"all-reduce": 1024.0}
    assert s.collective_bytes == 1024.0
    assert s.collective_counts == {"all-reduce": 1}
    # the scalar %add reduction computation contributes no dot flops
    assert s.dot_flops == 0.0


def test_entry_fallback_without_main_marker():
    # no ENTRY/"main" name: the computation never called by others wins
    s = analyze(NO_ENTRY_MARKER)
    comps = parse_hlo(NO_ENTRY_MARKER)
    assert set(comps) == {"%helper", "%top.0"}
    assert comps["%top.0"].called == ["%helper"]
    # both reachable from the fallback entry; nothing crashes, no flops
    assert s.dot_flops == 0.0


def test_residual_while_loop_flagged():
    s = analyze(WITH_WHILE)
    assert s.residual_while_loops == 1


def test_parse_hlo_shapes_and_operands():
    comps = parse_hlo(FUSION_ONLY)
    dot = comps["%fused_dot"].instrs["%dot.1"]
    assert dot.opcode == "dot"
    assert dot.operands == ["%p0", "%p1"]
    assert dot.out_elems == 128
    assert dot.out_bytes == 128 * 4
