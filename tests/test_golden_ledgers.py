"""Golden-ledger regression fixtures (``tests/golden/*.json``).

Each fixture is the exact ``CommLedger.summary()`` of a tiny scanned
run, serialized canonically (sorted keys, 2-space indent, trailing
newline) and compared **byte-for-byte** against the committed file.
Ledger values are analytic functions of exact integer counts, so any
drift — a changed payload model, an extra charged byte, a reordered
round — fails here even when cross-engine conformance still holds
(conformance compares engines to each other; the goldens pin the
absolute values the paper's tables are computed from).

The committed fixtures were generated from the pre-cohort engines, so
they simultaneously pin the cohort refactor's homogeneous-path
byte-compatibility.

Intentional changes: regenerate with

    PYTHONPATH=src python -m pytest tests/test_golden_ledgers.py \
        --update-golden

and commit the diff (the run skips with an "updated" note).
"""
import json
from pathlib import Path

import pytest

from repro.fl import FLConfig, Scenario, bernoulli_participation, run_method

GOLDEN_DIR = Path(__file__).parent / "golden"

CFG = FLConfig(n_clients=4, n_classes=4, dim=8, rounds=4, local_steps=2,
               distill_steps=2, public_size=48, public_per_round=10,
               private_size=64, alpha=0.5, eval_every=2, seed=0, hidden=12)

METHOD_KW = {
    "scarlet": dict(cache_duration=3, beta=1.5),
    "dsfl": dict(T=0.1),
    "cfd": dict(),
}
CODECS = ("identity", "quant8")
CASES = [(m, c) for m in sorted(METHOD_KW) for c in CODECS]


def _summary_text(method: str, codec: str) -> str:
    h = run_method(
        method, CFG, engine="scan", codec=codec,
        scenario=Scenario(participation=bernoulli_participation(0.5)),
        **METHOD_KW[method])
    return json.dumps(h.ledger.summary(), sort_keys=True, indent=2) + "\n"


@pytest.mark.parametrize("method,codec", CASES,
                         ids=[f"{m}-{c}" for m, c in CASES])
def test_golden_ledger(method, codec, request):
    path = GOLDEN_DIR / f"{method}-{codec}.json"
    text = _summary_text(method, codec)
    if request.config.getoption("--update-golden"):
        path.write_text(text)
        pytest.skip(f"updated {path.name}")
    assert path.exists(), (
        f"missing golden fixture {path}; generate it with "
        "--update-golden and commit the file")
    golden = path.read_text()
    assert golden == text, (
        f"{path.name} drifted from the committed bytes.\n"
        f"committed:\n{golden}\ncomputed:\n{text}\n"
        "If the change is intentional, regenerate with --update-golden "
        "and commit the diff.")


@pytest.mark.parametrize("codec", CODECS)
def test_golden_ledger_fused_round_byte_identical(codec):
    """The fused fast path must reproduce the committed per-op goldens
    byte-for-byte: comm accounting is analytic in integer counts, so
    fusing the compute hot path may not move a single byte.  No separate
    fused fixtures exist on purpose — the per-op files are the contract."""
    path = GOLDEN_DIR / f"scarlet-{codec}.json"
    h = run_method(
        "scarlet", CFG, engine="scan", codec=codec, fused_round=True,
        scenario=Scenario(participation=bernoulli_participation(0.5)),
        **METHOD_KW["scarlet"])
    text = json.dumps(h.ledger.summary(), sort_keys=True, indent=2) + "\n"
    assert path.read_text() == text


def test_no_stale_golden_fixtures():
    """Every committed fixture corresponds to a live matrix cell, so a
    renamed case cannot leave an unchecked golden behind."""
    expected = {f"{m}-{c}.json" for m, c in CASES}
    actual = {p.name for p in GOLDEN_DIR.glob("*.json")}
    assert actual == expected
