"""Example smoke tests: every ``examples/*.py`` runs end to end.

Each example is executed as a real subprocess (``PYTHONPATH=src``, the
same way its docstring tells users to run it) with
``REPRO_EXAMPLES_QUICK=1``, which every example honors by shrinking its
workload to CI-smoke size while keeping the code path identical — so an
example can never silently rot against an API change.

The parametrization globs ``examples/`` at collection time: a new
example is covered automatically, and removing one removes its test.
A guard test pins the glob against accidentally going empty.
"""
import os
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).parent.parent
EXAMPLES = sorted((REPO / "examples").glob("*.py"))

TIMEOUT_S = 600


def _run_example(path: Path) -> subprocess.CompletedProcess:
    env = dict(
        os.environ,
        REPRO_EXAMPLES_QUICK="1",
        PYTHONPATH=str(REPO / "src") + os.pathsep + os.environ.get(
            "PYTHONPATH", ""),
    )
    return subprocess.run(
        [sys.executable, str(path)], env=env, cwd=str(REPO),
        capture_output=True, text=True, timeout=TIMEOUT_S)


@pytest.mark.parametrize("path", EXAMPLES, ids=[p.stem for p in EXAMPLES])
def test_example_runs(path):
    proc = _run_example(path)
    assert proc.returncode == 0, (
        f"{path.name} exited {proc.returncode}\n"
        f"--- stdout ---\n{proc.stdout}\n--- stderr ---\n{proc.stderr}")
    assert proc.stdout.strip(), f"{path.name} printed nothing"


def test_examples_glob_is_nonempty():
    """If the examples directory moves, fail loudly instead of silently
    collecting zero example tests."""
    assert len(EXAMPLES) >= 5, [p.name for p in EXAMPLES]
