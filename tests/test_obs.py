"""Unit tests for the ``repro.obs`` subsystem against hand-computed
values: device-plane counter/gauge math, the ``TelemetryLog``
container, the host-plane span tracer, the exporters, the report
renderer, and the ``python -m repro.obs`` CLI."""
import json
import math

import jax.numpy as jnp
import numpy as np
import pytest

from repro.obs import SpanTracer, device as obs_device
from repro.obs.__main__ import main as obs_main
from repro.obs.__main__ import validate_trace
from repro.obs.device import RoundTelemetry, TelemetryLog
from repro.obs.export import (
    run_record,
    telemetry_summary,
    write_chrome_trace,
    write_run_record,
    write_spans_jsonl,
)
from repro.obs.report import render


# ---------------------------------------------------------------------------
# device-plane counter math (hand-computed)
# ---------------------------------------------------------------------------

def test_cache_signal_counts():
    present = jnp.asarray([True, False, True, False])
    miss = jnp.asarray([True, True, False, False])
    hits, new, expired = obs_device.cache_signal_counts(present, miss)
    # non-miss rows 2,3 -> 2 hits; miss & never-present row 1 -> 1 new;
    # miss & was-present row 0 -> 1 expired
    assert (int(hits), int(new), int(expired)) == (2, 1, 1)


def test_cache_signal_counts_cache_off_all_new():
    present = jnp.zeros(5, bool)
    miss = jnp.ones(5, bool)
    hits, new, expired = obs_device.cache_signal_counts(present, miss)
    assert (int(hits), int(new), int(expired)) == (0, 5, 0)


def test_staleness_histogram_and_returning():
    # t=5: participant last_sync 4 -> bucket 0 (present last round),
    # 0 -> bucket 4, 2 -> bucket 2; client 3 absent -> not counted
    part = jnp.asarray([True, True, True, False])
    last_sync = jnp.asarray([4, 0, 2, 4])
    hist = np.asarray(obs_device.staleness_histogram(part, last_sync, 5))
    want = np.zeros(obs_device.STALENESS_BUCKETS, np.int32)
    want[0], want[4], want[2] = 1, 1, 1
    assert np.array_equal(hist, want)
    # returning = participating with last_sync < t-1: clients 1 and 2
    assert int(obs_device.returning_client_count(part, last_sync, 5)) == 2


def test_staleness_histogram_clips_top_bucket():
    part = jnp.asarray([True])
    last_sync = jnp.asarray([-1])  # never synced, t=100 -> clipped
    hist = np.asarray(obs_device.staleness_histogram(part, last_sync, 100))
    assert hist[obs_device.STALENESS_BUCKETS - 1] == 1 and hist.sum() == 1


def test_participants_per_cohort():
    part = jnp.asarray([1, 0, 1, 1, 0, 1], bool)
    counts = obs_device.participants_per_cohort(part, (0, 2, 5), (2, 3, 1))
    assert np.array_equal(np.asarray(counts), [1, 2, 1])


def test_participant_mean_and_entropy():
    z = jnp.asarray([[[1.0, 0.0]], [[0.0, 1.0]], [[0.5, 0.5]]])
    part_f = jnp.asarray([1.0, 0.0, 1.0])
    zbar = np.asarray(obs_device.participant_mean(z, part_f, 2))
    assert np.allclose(zbar, [[0.75, 0.25]])
    # uniform over 4 classes -> ln 4 nats
    u = jnp.full((3, 4), 0.25)
    assert float(obs_device.mean_entropy(u)) == pytest.approx(
        math.log(4.0), abs=1e-6)
    # n_part=0 guards the denominator
    assert np.allclose(obs_device.participant_mean(z, jnp.zeros(3), 0), 0.0)


def test_codec_error_mean():
    z_pre = jnp.asarray([[[0.5, 0.5]], [[1.0, 0.0]]])
    z_post = jnp.asarray([[[0.25, 0.75]], [[9.0, 9.0]]])  # client 1 masked
    err = obs_device.codec_error_mean(z_post, z_pre,
                                      jnp.asarray([1.0, 0.0]), 1)
    assert float(err) == pytest.approx(0.25, abs=1e-6)


def test_gate_and_accumulate():
    row = obs_device.zeros(2)._replace(
        cache_hits=jnp.asarray(3, jnp.int32),
        uplink_bytes=jnp.asarray(10.0, jnp.float32))
    gated = obs_device.gate(row, jnp.asarray(False))
    assert int(gated.cache_hits) == 0 and float(gated.uplink_bytes) == 0.0
    kept = obs_device.gate(row, jnp.asarray(True))
    assert int(kept.cache_hits) == 3
    total = obs_device.accumulate(obs_device.accumulate(
        obs_device.zeros(2), row), row)
    assert int(total.cache_hits) == 6 and float(total.uplink_bytes) == 20.0


def test_field_partition_covers_all_fields():
    assert (set(obs_device.EXACT_FIELDS) | set(obs_device.GAUGE_FIELDS)
            == set(RoundTelemetry._fields))
    assert not set(obs_device.EXACT_FIELDS) & set(obs_device.GAUGE_FIELDS)


# ---------------------------------------------------------------------------
# TelemetryLog
# ---------------------------------------------------------------------------

def _row(n_cohorts=1, **kw):
    row = obs_device.zeros(n_cohorts)
    return row._replace(**{k: jnp.asarray(v) for k, v in kw.items()})


def test_telemetry_log_roundtrip_and_summary():
    log = TelemetryLog()
    log.append(_row(participants=jnp.asarray([2], jnp.int32),
                    cache_hits=jnp.asarray(3, jnp.int32),
                    cache_miss_new=jnp.asarray(7, jnp.int32),
                    uplink_bytes=jnp.asarray(100.0, jnp.float32),
                    beta=jnp.asarray(1.5, jnp.float32)))
    log.append(_row())  # outage round: all zeros, inactive
    assert len(log) == 2
    s = log.summary()
    assert s["rounds"] == 2 and s["active_rounds"] == 1
    assert s["cache_hits"] == 3 and s["cache_miss_new"] == 7
    assert s["cache_hit_rate"] == pytest.approx(0.3)
    assert s["uplink_bytes"] == 100.0
    # gauge means average over ACTIVE rounds only
    assert s["beta_mean"] == 1.5 and s["beta_last"] == 1.5

    # from_stacked must reproduce an appended log exactly
    stacked = RoundTelemetry(*[np.stack([np.asarray(getattr(r, f))
                                         for r in log._rounds])
                               for f in RoundTelemetry._fields])
    log2 = TelemetryLog.from_stacked(stacked)
    for f in RoundTelemetry._fields:
        assert np.array_equal(log.stacks()[f], log2.stacks()[f])
    assert json.dumps(log.as_dict(), sort_keys=True)  # JSON-ready


def test_telemetry_log_empty_summary():
    assert TelemetryLog().summary() == {"rounds": 0}


def test_telemetry_log_totals():
    log = TelemetryLog([_row(cache_hits=jnp.asarray(2, jnp.int32)),
                        _row(cache_hits=jnp.asarray(5, jnp.int32))])
    assert int(log.totals().cache_hits) == 7


# ---------------------------------------------------------------------------
# host plane: tracer + validator + exporters + report + CLI
# ---------------------------------------------------------------------------

def test_span_tracer_nesting_and_chrome_trace():
    tr = SpanTracer("t", meta={"k": "v"})
    with tr.span("outer", engine="scan"):
        with tr.span("inner"):
            pass
    assert [s.name for s in tr.spans] == ["inner", "outer"]  # exit order
    assert tr.spans[0].depth == 1 and tr.spans[1].depth == 0
    assert tr.spans[1].dur_s >= tr.spans[0].dur_s >= 0.0
    trace = tr.chrome_trace()
    assert validate_trace(trace) == []
    assert trace["otherData"]["k"] == "v"
    # B/E pairs are well-nested in event order
    phs = [e["ph"] for e in trace["traceEvents"] if e["ph"] in "BE"]
    assert phs == ["B", "B", "E", "E"]


def test_span_tracer_record():
    tr = SpanTracer()
    t0 = tr.t0
    tr.record("precompile", t0 + 1.0, 2.5, stage="warmup")
    (line,) = tr.jsonl_lines()
    assert line["name"] == "precompile"
    assert line["start_s"] == pytest.approx(1.0)
    assert line["dur_s"] == pytest.approx(2.5)
    assert validate_trace(tr.chrome_trace()) == []


def test_validate_trace_catches_malformed():
    assert validate_trace({}) == ["top-level 'traceEvents' missing or "
                                  "not a list"]
    bad = {"traceEvents": [
        {"name": "a", "ph": "B", "ts": 0.0},
        {"name": "MISMATCH", "ph": "E", "ts": 1.0},
    ]}
    assert any("does not close" in p for p in validate_trace(bad))
    unclosed = {"traceEvents": [{"name": "a", "ph": "B", "ts": 0.0}]}
    assert any("unclosed" in p for p in validate_trace(unclosed))
    empty = {"traceEvents": []}
    assert validate_trace(empty) == ["no B/E span events found"]


def test_exporters_and_run_record(tmp_path):
    tr = SpanTracer("exp")
    with tr.span("run"):
        pass
    trace_path = write_chrome_trace(str(tmp_path / "trace.json"), tr)
    assert validate_trace(json.load(open(trace_path))) == []
    jsonl_path = write_spans_jsonl(str(tmp_path / "spans.jsonl"), tr)
    lines = [json.loads(li) for li in open(jsonl_path)]
    assert len(lines) == 1 and lines[0]["name"] == "run"

    log = TelemetryLog([_row(cache_hits=jnp.asarray(4, jnp.int32),
                             participants=jnp.asarray([2], jnp.int32))])
    rec = write_run_record(
        str(tmp_path / "rec.json"), name="unit", telemetry=log, tracer=tr,
        history={"final_server_acc": 0.5,
                 "comm": {"rounds": 1, "cumulative_total": 2048.0,
                          "uplink_mean": 1024.0, "downlink_mean": 1024.0}})
    on_disk = json.load(open(tmp_path / "rec.json"))
    assert on_disk == rec and rec["record"] == "repro.obs/run"
    assert rec["telemetry"]["summary"]["cache_hits"] == 4

    # telemetry defaults from the history when not passed explicitly
    rec2 = run_record(name="u2", history={"telemetry": log.as_dict()})
    assert rec2["telemetry"]["summary"]["cache_hits"] == 4
    assert telemetry_summary(object()) is None


def test_render_markdown_and_text(tmp_path):
    tr = SpanTracer("r")
    with tr.span("run", engine="scan"):
        pass
    log = TelemetryLog([_row(participants=jnp.asarray([3], jnp.int32),
                             cache_hits=jnp.asarray(6, jnp.int32),
                             cache_miss_new=jnp.asarray(4, jnp.int32))])
    rec = run_record(name="demo", telemetry=log, tracer=tr,
                     history={"final_server_acc": 0.75,
                              "comm": {"rounds": 1,
                                       "cumulative_total": 1e6,
                                       "uplink_mean": 5e5,
                                       "downlink_mean": 5e5}})
    md = render(rec, fmt="markdown")
    txt = render(rec, fmt="text")
    for body in (md, txt):
        assert "demo" in body and "cache_hits" in body and "0.75" in body
        assert "staleness" in body.lower()
    assert "| cache_hits | 6 |" in md and "|" not in txt
    with pytest.raises(ValueError, match="unknown format"):
        render(rec, fmt="html")
    assert "empty record" in render({"name": "nothing"}, fmt="text")


def test_cli_render_and_validate(tmp_path, capsys):
    tr = SpanTracer("cli")
    with tr.span("work"):
        pass
    trace_path = str(tmp_path / "trace.json")
    write_chrome_trace(trace_path, tr)
    rec_path = str(tmp_path / "rec.json")
    write_run_record(rec_path, name="cli-demo", tracer=tr)

    assert obs_main(["validate", trace_path]) == 0
    assert "ok:" in capsys.readouterr().out

    out_path = str(tmp_path / "report.md")
    assert obs_main(["render", rec_path, "--out", out_path]) == 0
    capsys.readouterr()
    assert "cli-demo" in open(out_path).read()

    # invalid trace -> exit 1
    bad_path = str(tmp_path / "bad.json")
    json.dump({"traceEvents": [{"name": "a", "ph": "B", "ts": 0.0}]},
              open(bad_path, "w"))
    assert obs_main(["validate", bad_path]) == 1
    assert "INVALID" in capsys.readouterr().out
    json.dump([], open(bad_path, "w"))  # not even a trace object
    assert obs_main(["validate", bad_path]) == 1
    capsys.readouterr()
