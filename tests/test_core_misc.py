"""Unit tests: cache simulator, comm accounting, losses, optimizers,
checkpointing, data partitioning, HLO analyzer."""
import math
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import comm, losses
from repro.core.cache_sim import expected_steady_state_hit_rate, simulate_hit_rate
from repro.data.synthetic import dirichlet_partition, make_public_private, pad_client_shards


# --- cache simulator (paper Alg. 3 / Fig. 3) ------------------------------

def test_sim_matches_analytic_steady_state():
    for D in (10, 50, 100):
        sim = simulate_hit_rate(1000, 100, D, 1500, seed=1)
        steady = sim[700:].mean()
        analytic = expected_steady_state_hit_rate(1000, 100, D)
        assert abs(steady - analytic) < 0.03, (D, steady, analytic)


def test_sim_d0_all_miss():
    assert (simulate_hit_rate(100, 10, 0, 50) == 0).all()


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 50), st.integers(2, 200))
def test_sim_hit_rate_monotone_in_D(D, rounds):
    a = simulate_hit_rate(200, 40, D, rounds, seed=3).mean()
    b = simulate_hit_rate(200, 40, D + 20, rounds, seed=3).mean()
    assert b >= a - 1e-9


# --- comm accounting -------------------------------------------------------

def test_round_cost_scaling():
    c1 = comm.distillation_round_cost(n_clients=10, n_selected=100,
                                      n_requested=100, n_classes=10)
    c2 = comm.distillation_round_cost(n_clients=10, n_selected=100,
                                      n_requested=50, n_classes=10)
    assert c2.uplink == pytest.approx(c1.uplink / 2)
    c3 = comm.distillation_round_cost(n_clients=10, n_selected=100,
                                      n_requested=100, n_classes=10,
                                      uplink_bits=1.0)
    assert c3.uplink == pytest.approx(c1.uplink / 32)


def test_ledger_summary():
    led = comm.CommLedger()
    led.record(comm.RoundCost(100.0, 200.0))
    led.record(comm.RoundCost(300.0, 400.0))
    s = led.summary()
    assert s["uplink_mean"] == 200.0 and s["uplink_max"] == 300.0
    assert s["cumulative_total"] == 1000.0


def test_empty_ledger_summary_has_no_phantom_round(monkeypatch):
    """An empty ledger must report honest zeros derived from zero
    rounds — not pad itself with a fabricated zero-byte round.  The old
    code substituted ``np.zeros(1)`` for the empty round list, which
    yields the same numbers a genuine one-round zero-cost run would;
    the two cases are only distinguishable by the allocation itself, so
    the guard here is: summary() must never build a phantom row."""
    led = comm.CommLedger()

    def _phantom(*a, **k):
        raise AssertionError("summary() fabricated a phantom round")

    monkeypatch.setattr(comm.np, "zeros", _phantom)
    s = led.summary()
    assert s["rounds"] == 0.0
    for key, val in s.items():
        assert val == 0.0, (key, val)
        assert not math.isnan(val), key


# --- losses ---------------------------------------------------------------

def test_soft_ce_equals_kl_plus_entropy():
    k = jax.random.PRNGKey(0)
    logits = jax.random.normal(k, (16, 12))
    teacher = jax.nn.softmax(jax.random.normal(jax.random.fold_in(k, 1), (16, 12)))
    ce = float(losses.soft_cross_entropy(logits, teacher))
    kl = float(losses.kl_divergence(teacher, logits))
    ent = float(-(teacher * jnp.log(teacher)).sum(-1).mean())
    assert ce == pytest.approx(kl + ent, rel=1e-5)


def test_hard_ce_ignores_negative_labels():
    logits = jnp.zeros((4, 5))
    labels = jnp.asarray([0, 1, -1, -1])
    out = float(losses.cross_entropy(logits, labels))
    assert out == pytest.approx(math.log(5), rel=1e-5)


# --- optimizers -------------------------------------------------------------

def test_optimizers_descend_quadratic():
    from repro.optim import get

    target = jnp.asarray([1.0, -2.0, 3.0])

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2)

    for name, lr, steps in (("sgd", 0.1, 200), ("momentum", 0.05, 200),
                            ("adamw", 0.1, 300)):
        opt = get(name)
        params = {"w": jnp.zeros(3)}
        state = opt.init(params)
        for _ in range(steps):
            g = jax.grad(loss)(params)
            params, state = opt.update(g, state, params, lr)
        assert float(loss(params)) < 1e-2, name


def test_adamw_bf16_state_dtype():
    from repro.optim import get

    opt = get("adamw", state_dtype="bfloat16")
    params = {"w": jnp.zeros(4, jnp.bfloat16)}
    state = opt.init(params)
    assert state["m"]["w"].dtype == jnp.bfloat16


# --- checkpointing -----------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint import load_pytree, save_pytree

    tree = {
        "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "b": {"c": jnp.ones((4,), jnp.bfloat16), "d": jnp.asarray(3, jnp.int32)},
    }
    path = os.path.join(tmp_path, "ckpt.npz")
    save_pytree(path, tree)
    loaded = load_pytree(path, tree)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a, np.float32),
                                                   np.asarray(b, np.float32)),
        tree, loaded)
    assert loaded["b"]["c"].dtype == jnp.bfloat16


# --- data partitioning --------------------------------------------------------

def test_dirichlet_partition_covers_everything():
    y = np.random.default_rng(0).integers(0, 10, 1000).astype(np.int32)
    parts = dirichlet_partition(y, 10, alpha=0.1, seed=0)
    all_idx = np.concatenate(parts)
    assert sorted(all_idx) == list(range(1000))
    assert all(len(p) >= 2 for p in parts)


def test_dirichlet_alpha_controls_skew():
    y = np.random.default_rng(0).integers(0, 10, 5000).astype(np.int32)

    def skew(alpha):
        parts = dirichlet_partition(y, 10, alpha=alpha, seed=0)
        # mean per-client class concentration (fraction in top class)
        fracs = []
        for p in parts:
            counts = np.bincount(y[p], minlength=10)
            fracs.append(counts.max() / max(counts.sum(), 1))
        return np.mean(fracs)

    assert skew(0.05) > skew(10.0) + 0.2


def test_pad_client_shards_mask():
    x = np.arange(20, dtype=np.float32).reshape(10, 2)
    y = np.arange(10, dtype=np.int32)
    parts = [np.array([0, 1, 2]), np.array([3])]
    xs, ys, m = pad_client_shards(x, y, parts)
    assert xs.shape == (2, 3, 2) and m.sum() == 4
    assert (ys[1][m[1]] == [3]).all()


def test_public_private_distinct_distributions():
    d = make_public_private(500, 500, 5, 8, seed=0, public_shift=2.0)
    # public centers shifted: mean distance should be clearly nonzero
    assert d["x_public"].shape == (500, 8)
    assert not np.allclose(d["x_private"].mean(0), d["x_public"].mean(0), atol=0.2)


# --- HLO analyzer --------------------------------------------------------------

def test_hlo_analyzer_counts_dots_and_collectives():
    from repro.launch import hlo_analysis as ha

    text = """
HloModule test

%fused (p: f32[8,16]) -> f32[8,32] {
  %p = f32[8,16]{1,0} parameter(0)
  %w = f32[16,32]{1,0} constant(0)
  ROOT %dot.1 = f32[8,32]{1,0} dot(%p, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}

ENTRY %main (a: f32[8,16]) -> f32[8,32] {
  %a = f32[8,16]{1,0} parameter(0)
  %c = f32[8,32]{1,0} fusion(%a), kind=kLoop, calls=%fused
  %c2 = f32[8,32]{1,0} fusion(%a), kind=kLoop, calls=%fused
  %ar = f32[8,32]{1,0} all-reduce(%c), replica_groups={}
  ROOT %add = f32[8,32]{1,0} add(%ar, %c2)
}
"""
    s = ha.analyze(text)
    # dot: 2*8*32*16 = 8192 flops, fusion called twice
    assert s.dot_flops == pytest.approx(2 * 8192)
    # all-reduce: 2x 8*32*4 bytes
    assert s.collective_bytes == pytest.approx(2 * 8 * 32 * 4)
    assert s.collective_counts.get("all-reduce") == 1
    assert s.residual_while_loops == 0


def test_probabilistic_sim_smoother_than_hard_at_large_D():
    hard = simulate_hit_rate(2000, 200, 100, 600, seed=2)[200:]
    from repro.core.cache_sim import simulate_hit_rate_probabilistic

    prob = simulate_hit_rate_probabilistic(2000, 200, 100, 600, seed=2)[200:]
    assert prob.std() < hard.std()  # no mass-refresh waves
