"""Traffic models + async-engine semantics under real latency.

The zero-delay byte-identity anchor lives in
``tests/test_engine_conformance.py``; this module pins everything the
async engine does *beyond* that regime:

- traffic compilation determinism and absolute-round keying (chained
  legs see the identical traffic a single run would);
- the dispatch/arrival split itself: with a fixed one-window latency
  every report lands one round late, so uplink alternates between
  zero (dispatch-only rounds) and full windows;
- the ledger/staleness separation: staleness decay reweights the
  aggregation but must never change a single ledger byte;
- the telemetry handshake: staleness-histogram buckets equal the
  report delay;
- widening the aggregation window until it swallows the latency
  distribution restores byte-identity with the scan engine.
"""
import dataclasses

import numpy as np
import pytest

from repro.fl import (
    ArrivalProcess,
    AsyncFederatedDistillation,
    ChurnEvent,
    FLConfig,
    LatencyModel,
    ScannedFederatedDistillation,
    TrafficModel,
    run_method,
)
from repro.fl.strategies import STRATEGIES

CFG = FLConfig(
    n_clients=4, n_classes=4, dim=8, rounds=6, local_steps=1,
    distill_steps=1, public_size=48, public_per_round=10,
    private_size=64, alpha=0.5, eval_every=3, seed=0, hidden=12,
)


def _ledger(hist):
    return ([r.uplink for r in hist.ledger.rounds],
            [r.downlink for r in hist.ledger.rounds])


def _build(traffic, rounds=None, cfg=CFG, **strat_kw):
    eng = AsyncFederatedDistillation(
        cfg, STRATEGIES["scarlet"](beta=1.5, **strat_kw), cache_duration=3,
        traffic=traffic)
    return eng, eng.run(rounds)


# ---------------------------------------------------------------------------
# TrafficModel compilation
# ---------------------------------------------------------------------------

def test_compile_shapes_dtypes_and_determinism():
    tm = TrafficModel(arrivals=ArrivalProcess("poisson", rate=0.7),
                      latency=LatencyModel("uniform", lo=0, hi=3), seed=5)
    a = tm.compile(7, 9)
    assert a.available.shape == (7, 9) and a.available.dtype == bool
    assert a.delay.shape == (7, 9) and a.delay.dtype == np.int32
    b = tm.compile(7, 9)
    np.testing.assert_array_equal(a.available, b.available)
    np.testing.assert_array_equal(a.delay, b.delay)
    # some variation across rounds and clients (rate 0.7 -> p ~ 0.5)
    assert 0 < a.available.sum() < a.available.size


def test_compile_absolute_round_keying():
    """Round t's draws depend only on (seed, t): a chained leg's compile
    is a row slice of the full-run compile."""
    tm = TrafficModel(arrivals=ArrivalProcess("poisson", rate=1.0),
                      latency=LatencyModel("uniform", lo=0, hi=2), seed=2)
    full = tm.compile(8, 5, start=1)
    tail = tm.compile(4, 5, start=5)
    np.testing.assert_array_equal(full.available[4:], tail.available)
    np.testing.assert_array_equal(full.delay[4:], tail.delay)


def test_is_synchronous():
    assert TrafficModel().is_synchronous
    assert not TrafficModel(latency=LatencyModel("fixed", ticks=1)
                            ).is_synchronous
    # geometric latency is unbounded: never provably synchronous
    assert not TrafficModel(latency=LatencyModel("geometric", p=0.9)
                            ).is_synchronous
    # a window wider than the worst latency restores the sync regime
    assert TrafficModel(latency=LatencyModel("uniform", lo=0, hi=3),
                        window_ticks=4).is_synchronous


def test_churn_membership():
    tm = TrafficModel(churn=(ChurnEvent(0, join=3),
                             ChurnEvent(2, join=1, leave=2)))
    compiled = tm.compile(4, 3)
    # client 0 joins at round 3; client 2 leaves after round 2; client 1
    # (no event) is a member throughout
    np.testing.assert_array_equal(
        compiled.available,
        [[False, True, True], [False, True, True],
         [True, True, False], [True, True, False]])


def test_validation_errors():
    with pytest.raises(ValueError, match="window_ticks"):
        TrafficModel(window_ticks=0)
    with pytest.raises(ValueError, match="arrival kind"):
        TrafficModel(arrivals=ArrivalProcess("lunar")).compile(1, 2)
    with pytest.raises(ValueError, match="lo <= hi"):
        TrafficModel(latency=LatencyModel("uniform", lo=3, hi=1)).compile(1, 2)
    with pytest.raises(ValueError, match=">= 0"):
        TrafficModel(latency=LatencyModel("fixed", ticks=-1)).compile(1, 2)
    with pytest.raises(ValueError, match="latency kind"):
        TrafficModel(latency=LatencyModel("carrier-pigeon")).compile(1, 2)


def test_geometric_latency_support():
    rng = np.random.default_rng(0)
    ticks = LatencyModel("geometric", p=0.5).sample_ticks(2000, rng)
    assert ticks.min() == 0  # shifted to the >= 0 convention
    assert ticks.max() > 0


def test_run_method_rejects_traffic_on_sync_engines():
    with pytest.raises(ValueError, match="async"):
        run_method("scarlet", CFG, cache_duration=3, engine="scan",
                   traffic=TrafficModel())


# ---------------------------------------------------------------------------
# Async engine under real latency
# ---------------------------------------------------------------------------

def test_fixed_delay_alternates_dispatch_and_arrival():
    """One-window latency: round 1 dispatches everyone (uplink 0 — no
    report has landed), round 2 aggregates the late reports (uplink >
    0, and no dispatch — everyone was in flight), and the cycle
    repeats.  Server accuracy still moves: stale reports aggregate."""
    tm = TrafficModel(latency=LatencyModel("fixed", ticks=1))
    _, hist = _build(tm)
    up, _ = _ledger(hist)
    assert up[0] == 0.0 and up[2] == 0.0 and up[4] == 0.0
    assert up[1] > 0.0 and up[3] > 0.0 and up[5] > 0.0


def test_staleness_decay_never_changes_the_ledger():
    """Decay weights multiply soft-labels inside the aggregation — the
    byte ledger must be bitwise invariant under them (metrics may
    differ; the weights are the point)."""
    tm = TrafficModel(arrivals=ArrivalProcess("poisson", rate=1.5),
                      latency=LatencyModel("uniform", lo=0, hi=2), seed=3)
    _, unit = _build(tm, staleness_decay=1.0)
    _, decayed = _build(tm, staleness_decay=0.5)
    np.testing.assert_array_equal(_ledger(unit)[0], _ledger(decayed)[0])
    np.testing.assert_array_equal(_ledger(unit)[1], _ledger(decayed)[1])


def test_staleness_histogram_buckets_equal_delay():
    """Fixed two-window latency: every arrival spent exactly two rounds
    in flight, so ALL histogram mass lands in bucket 2 (the dispatch
    handshake marks a dispatched client synced through t_d - 1)."""
    cfg = dataclasses.replace(CFG, rounds=9, telemetry=True)
    tm = TrafficModel(latency=LatencyModel("fixed", ticks=2))
    _, hist = _build(tm, cfg=cfg)
    h = np.asarray(hist.telemetry.summary()["staleness_hist"])
    assert h[2] > 0
    assert h.sum() == h[2]


def test_wide_window_restores_scan_byte_identity():
    """window_ticks > max latency ticks => every delay floors to zero
    and the async ledger is byte-identical to the scan engine."""
    tm = TrafficModel(latency=LatencyModel("uniform", lo=0, hi=3),
                      window_ticks=4)
    assert tm.is_synchronous
    _, ha = _build(tm)
    scan = ScannedFederatedDistillation(
        CFG, STRATEGIES["scarlet"](beta=1.5), cache_duration=3)
    hs = scan.run()
    np.testing.assert_array_equal(_ledger(ha)[0], _ledger(hs)[0])
    np.testing.assert_array_equal(_ledger(ha)[1], _ledger(hs)[1])
    np.testing.assert_allclose(ha.server_acc, hs.server_acc, atol=1e-6)


def test_split_runs_match_unsplit_with_reports_in_flight():
    """run(3) + run(3) must equal run(6) bit-for-bit on the ledger:
    flight state persists across legs and traffic draws are keyed by
    absolute round."""
    tm = TrafficModel(arrivals=ArrivalProcess("poisson", rate=1.5),
                      latency=LatencyModel("uniform", lo=0, hi=2), seed=7)
    _, full = _build(tm)
    eng = AsyncFederatedDistillation(
        CFG, STRATEGIES["scarlet"](beta=1.5), cache_duration=3, traffic=tm)
    ha, hb = eng.run(3), eng.run(3)
    up = [r.uplink for r in ha.ledger.rounds] + \
         [r.uplink for r in hb.ledger.rounds]
    dn = [r.downlink for r in ha.ledger.rounds] + \
         [r.downlink for r in hb.ledger.rounds]
    np.testing.assert_array_equal(up, _ledger(full)[0])
    np.testing.assert_array_equal(dn, _ledger(full)[1])


def test_in_flight_clients_are_never_redispatched():
    """With fixed latency 2 and always-available arrivals, dispatch and
    flight state must tile the population: a client is either free or
    mid-report, never both drawn and busy."""
    tm = TrafficModel(latency=LatencyModel("fixed", ticks=2))
    eng, hist = _build(tm)
    up, _ = _ledger(hist)
    # cycle: dispatch t=1, silent t=2, arrive t=3, dispatch t=4, ...
    assert up[0] == 0.0 and up[1] == 0.0 and up[2] > 0.0
    assert up[3] == 0.0 and up[4] == 0.0 and up[5] > 0.0
    # after 6 rounds (two full cycles) nothing is left in flight
    assert not eng.in_flight.any()


def test_diurnal_arrival_probability_modulates():
    ap = ArrivalProcess("diurnal", rate=0.5, period=8, amplitude=0.9)
    probs = [ap.window_probability(t, 1) for t in range(1, 9)]
    assert max(probs) > min(probs)
    assert all(0.0 <= p < 1.0 for p in probs)
