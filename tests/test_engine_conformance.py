"""Cross-engine conformance matrix: host x scan x shard.

All three engines draw subsets/participation from the identical jax key
stream, so for every (strategy, participation, codec) cell of the matrix
the same rounds run with the same cohorts.  Each cell is one test item
that runs every engine exactly once and asserts both pairwise
contracts (one item per cell also keeps xdist from recomputing cells):

- **host vs scan** — ledger allclose at float32 exactness (the host loop
  computes costs in python float64, the device engines in float32),
  metrics/cache allclose;
- **scan vs shard** — ledger **byte-identical** (both engines derive
  every cost from exact small-integer counts in float32; the shard
  engine's psum reductions of exact integers are order-independent),
  metrics/cache allclose (aggregation reduction order differs).

The shard runs use ``make_test_mesh``-shaped meshes on the 8 forced
host devices (see ``conftest.py``), so the ``shard_map`` paths —
two-phase aggregation psum, shard-aware byte accounting, conscription
slicing — execute for real in every environment.

A second, cohort matrix re-asserts both contracts for heterogeneous
client-model cohorts (``repro.fl.cohorts``): different architectures
per client block, identical ledger/cache/metric guarantees, plus
per-cohort accuracy columns allclose across engines.
"""
import dataclasses

import numpy as np
import pytest

from repro.fl import (
    ActiveSetFederatedDistillation,
    AsyncFederatedDistillation,
    CohortSpec,
    FederatedDistillation,
    FLConfig,
    Outage,
    Scenario,
    ScannedFederatedDistillation,
    ShardedFederatedDistillation,
    bernoulli_participation,
    fixed_fraction,
    full_participation,
)
from repro.fl.strategies import STRATEGIES

CFG = FLConfig(
    n_clients=4, n_classes=4, dim=8, rounds=3, local_steps=2,
    distill_steps=2, public_size=48, public_per_round=10,
    private_size=64, alpha=0.5, eval_every=2, seed=0, hidden=12,
    mesh_spec="2x4",
)

STRATEGY_KW = {
    "scarlet": dict(beta=1.5),
    "dsfl": dict(T=0.1),
    "mean": dict(),
}
# scarlet runs with its synchronized cache so cache_delta coding and
# catch-up packages are exercised against real cache state
CACHE_D = {"scarlet": 3, "dsfl": 0, "mean": 0}

PARTICIPATIONS = {
    "full": Scenario(participation=full_participation()),
    "bernoulli": Scenario(participation=bernoulli_participation(0.5)),
    # outage windows + fixed-fraction sampling: returning stragglers
    # exercise the catch-up byte accounting (dense/psum'd vs per-package)
    "outage": Scenario(participation=fixed_fraction(0.5),
                       outages=(Outage(0, 2, 3), Outage(2, 1, 2))),
}

CODECS = ("identity", "quant8", "cache_delta+quant8")

MATRIX = [(s, p, c) for s in sorted(STRATEGY_KW)
          for p in sorted(PARTICIPATIONS) for c in CODECS]


# ---------------------------------------------------------------------------
# Parity assertion, shared with tests/test_scan_parity.py
# ---------------------------------------------------------------------------

def assert_parity(eng_a, hist_a, eng_b, hist_b, *, ledger="close",
                  cache_atol=1e-5):
    """Engine/History pair parity.  ``ledger="exact"`` demands bitwise
    byte-identity (device engine vs device engine); ``"close"`` allows
    float32-level rounding (host float64 vs device float32).

    ``cache_atol`` bounds the cached teacher values.  Cells with a
    *lossy* wire codec pass one quantization step here: a sub-ulp
    cross-engine difference in the pre-codec soft-labels can flip a
    quantization bucket, which the decode amplifies to a full step
    (~range/255 for quant8) — inherent to lossy codecs, not drift."""
    up_a = [r.uplink for r in hist_a.ledger.rounds]
    up_b = [r.uplink for r in hist_b.ledger.rounds]
    down_a = [r.downlink for r in hist_a.ledger.rounds]
    down_b = [r.downlink for r in hist_b.ledger.rounds]
    assert len(up_a) == len(up_b)
    if ledger == "exact":
        np.testing.assert_array_equal(up_a, up_b)
        np.testing.assert_array_equal(down_a, down_b)
    else:
        np.testing.assert_allclose(up_a, up_b, rtol=1e-7)
        np.testing.assert_allclose(down_a, down_b, rtol=1e-7)
    # --- History metrics ----------------------------------------------
    assert hist_a.rounds == hist_b.rounds
    np.testing.assert_allclose(hist_a.server_acc, hist_b.server_acc, atol=1e-4)
    np.testing.assert_allclose(hist_a.client_acc, hist_b.client_acc, atol=1e-4)
    np.testing.assert_allclose(hist_a.cumulative_mb, hist_b.cumulative_mb,
                               rtol=1e-7)
    np.testing.assert_allclose(hist_a.server_val_loss, hist_b.server_val_loss,
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(hist_a.client_val_loss, hist_b.client_val_loss,
                               rtol=1e-4, atol=1e-5)
    # per-cohort client accuracy (one column per model cohort; a single
    # column for homogeneous runs)
    np.testing.assert_allclose(hist_a.cohort_client_acc,
                               hist_b.cohort_client_acc, atol=1e-4)
    # --- cache state + sync bookkeeping -------------------------------
    np.testing.assert_array_equal(np.asarray(eng_a.cache_g.present),
                                  np.asarray(eng_b.cache_g.present))
    np.testing.assert_array_equal(np.asarray(eng_a.cache_g.ts),
                                  np.asarray(eng_b.cache_g.ts))
    np.testing.assert_allclose(np.asarray(eng_a.cache_g.values),
                               np.asarray(eng_b.cache_g.values),
                               rtol=0, atol=cache_atol)
    np.testing.assert_array_equal(eng_a.last_sync, eng_b.last_sync)


def _build(engine_cls, name, participation, codec, **kw):
    cfg = dataclasses.replace(CFG, uplink_codec=codec)
    eng = engine_cls(cfg, STRATEGIES[name](**STRATEGY_KW[name]),
                     cache_duration=CACHE_D[name],
                     scenario=PARTICIPATIONS[participation], **kw)
    return eng, eng.run()


@pytest.mark.parametrize("name,participation,codec", MATRIX,
                         ids=["-".join(p) for p in MATRIX])
def test_engine_conformance_cell(name, participation, codec):
    """One matrix cell: each engine runs once, then both pairwise parity
    contracts are asserted.  A single test item per cell keeps the
    three engine runs computed exactly once per pytest/xdist worker."""
    host = _build(FederatedDistillation, name, participation, codec,
                  rng_backend="jax")
    scan = _build(ScannedFederatedDistillation, name, participation, codec)
    shard = _build(ShardedFederatedDistillation, name, participation, codec)
    assert_parity(*host, *scan, ledger="close")
    assert_parity(*scan, *shard, ledger="exact")


# ---------------------------------------------------------------------------
# Heterogeneous client-model cohorts (repro.fl.cohorts): host x scan x
# shard over {scarlet, dsfl} x {2-cohort, 3-cohort} x {identity,
# cache_delta+quant8}.  Soft-label shapes are architecture-independent,
# so the exact engine contracts must hold unchanged: scan<->shard
# ledgers byte-identical, host<->scan allclose at float32 exactness,
# per-cohort metrics allclose everywhere.  K=8 so every cohort block
# splits evenly over the 2-way "data" axis of the 2x4 mesh.
# ---------------------------------------------------------------------------

COHORTS = {
    "2cohort": (CohortSpec(4, 16, 2), CohortSpec(4, 8, 1)),
    "3cohort": (CohortSpec(4, 16, 2), CohortSpec(2, 8, 1),
                CohortSpec(2, 24, 3)),
}
COHORT_CODECS = ("identity", "cache_delta+quant8")
COHORT_MATRIX = [(s, co, c) for s in ("dsfl", "scarlet")
                 for co in sorted(COHORTS) for c in COHORT_CODECS]


@pytest.mark.parametrize("name,cohort,codec", COHORT_MATRIX,
                         ids=["-".join(p) for p in COHORT_MATRIX])
def test_cohort_conformance_cell(name, cohort, codec):
    cfg = dataclasses.replace(CFG, n_clients=8, cohorts=COHORTS[cohort],
                              uplink_codec=codec)
    sc = PARTICIPATIONS["bernoulli"]

    def build(engine_cls, **kw):
        eng = engine_cls(cfg, STRATEGIES[name](**STRATEGY_KW[name]),
                         cache_duration=CACHE_D[name], scenario=sc, **kw)
        return eng, eng.run()

    host = build(FederatedDistillation, rng_backend="jax")
    scan = build(ScannedFederatedDistillation)
    shard = build(ShardedFederatedDistillation)
    assert len(host[1].cohort_client_acc[0]) == len(COHORTS[cohort])
    # lossy cells tolerate one quant8 step on the widest possible row
    # (range ~1 -> 1/255 ~ 3.9e-3); identity cells stay tight
    cache_atol = 1e-5 if codec == "identity" else 5e-3
    assert_parity(*host, *scan, ledger="close", cache_atol=cache_atol)
    assert_parity(*scan, *shard, ledger="exact", cache_atol=cache_atol)


def test_shard_engine_rejects_indivisible_cohorts():
    """Every cohort block must split evenly over the client axis — a
    5+3 split cannot shard 2-ways even though K=8 can."""
    cfg = dataclasses.replace(
        CFG, n_clients=8, cohorts=(CohortSpec(5, 16, 2), CohortSpec(3, 8, 1)))
    with pytest.raises(ValueError, match="not divisible over"):
        ShardedFederatedDistillation(
            cfg, STRATEGIES["scarlet"](**STRATEGY_KW["scarlet"]),
            cache_duration=3)


# ---------------------------------------------------------------------------
# Fused round fast path (FLConfig.fused_round): the per-op conformance
# matrix above stays untouched; these cells assert that turning the
# fused kernel on changes NOTHING observable — ledgers byte-identical
# to the per-op scan run (comm accounting is analytic, counts are
# unaffected) and metrics/cache allclose (on CPU the interpreter runs
# the identical f32 expression sequence, so they are in fact equal) —
# across scarlet x {bernoulli, outage} x every fusable codec, on both
# device engines.
# ---------------------------------------------------------------------------

FUSED_CODECS = ("identity", "quant8", "cache_delta", "cache_delta+quant8")
FUSED_MATRIX = [(p, c) for p in ("bernoulli", "outage") for c in FUSED_CODECS]


@pytest.mark.parametrize("participation,codec", FUSED_MATRIX,
                         ids=["-".join(p) for p in FUSED_MATRIX])
def test_fused_round_conformance_cell(participation, codec):
    perop = _build(ScannedFederatedDistillation, "scarlet", participation,
                   codec)
    fused_cfg = dataclasses.replace(CFG, uplink_codec=codec, fused_round=True)

    def build_fused(engine_cls):
        eng = engine_cls(fused_cfg, STRATEGIES["scarlet"](beta=1.5),
                         cache_duration=CACHE_D["scarlet"],
                         scenario=PARTICIPATIONS[participation])
        return eng, eng.run()

    fused_scan = build_fused(ScannedFederatedDistillation)
    fused_shard = build_fused(ShardedFederatedDistillation)
    # fused vs per-op on the same engine: byte-identical ledger, and the
    # one-quant-step cache band for lossy codecs (native-TPU headroom;
    # interpret mode is exact)
    cache_atol = 1e-5 if "quant" not in codec else 5e-3
    assert_parity(*perop, *fused_scan, ledger="exact", cache_atol=cache_atol)
    assert_parity(*fused_scan, *fused_shard, ledger="exact",
                  cache_atol=cache_atol)


def test_host_engine_ignores_fused_flag():
    """The host loop is the per-op reference: FLConfig.fused_round must
    not change its behavior (it has no fused path to take)."""
    cfg = dataclasses.replace(CFG, uplink_codec="quant8")
    on = FederatedDistillation(
        dataclasses.replace(cfg, fused_round=True),
        STRATEGIES["scarlet"](beta=1.5), cache_duration=3,
        scenario=PARTICIPATIONS["bernoulli"], rng_backend="jax")
    off = FederatedDistillation(
        cfg, STRATEGIES["scarlet"](beta=1.5), cache_duration=3,
        scenario=PARTICIPATIONS["bernoulli"], rng_backend="jax")
    assert_parity(on, on.run(), off, off.run(), ledger="exact")


# ---------------------------------------------------------------------------
# Active-set engine (repro.fl.active_engine): host-resident client
# store, O(m) gathered device compute.  Contract: ledger **byte-
# identical** to the scan engine (every cost input is an exact
# small-integer count evaluated by the same f32 expression) and
# float32-exact against the host loop; metrics/cache allclose (the
# gathered stack sums m rows where the dense engines sum K masked
# rows).  {scarlet, dsfl} x {bernoulli, outage} x {identity,
# cache_delta+quant8} per the engine's acceptance matrix.
# ---------------------------------------------------------------------------

ACTIVE_MATRIX = [(s, p, c) for s in ("dsfl", "scarlet")
                 for p in ("bernoulli", "outage")
                 for c in ("identity", "cache_delta+quant8")]


@pytest.mark.parametrize("name,participation,codec", ACTIVE_MATRIX,
                         ids=["-".join(p) for p in ACTIVE_MATRIX])
def test_active_engine_conformance_cell(name, participation, codec):
    host = _build(FederatedDistillation, name, participation, codec,
                  rng_backend="jax")
    scan = _build(ScannedFederatedDistillation, name, participation, codec)
    active = _build(ActiveSetFederatedDistillation, name, participation,
                    codec)
    cache_atol = 1e-5 if codec == "identity" else 5e-3
    assert_parity(*active, *scan, ledger="exact", cache_atol=cache_atol)
    assert_parity(*active, *host, ledger="close", cache_atol=cache_atol)


def test_active_engine_cohort_conformance():
    """Heterogeneous model cohorts: gather/scatter is per-cohort, so the
    mixed-architecture path must keep the byte-exact ledger contract."""
    cfg = dataclasses.replace(CFG, n_clients=8, cohorts=COHORTS["2cohort"])
    sc = PARTICIPATIONS["bernoulli"]

    def build(engine_cls):
        eng = engine_cls(cfg, STRATEGIES["scarlet"](beta=1.5),
                         cache_duration=3, scenario=sc)
        return eng, eng.run()

    scan = build(ScannedFederatedDistillation)
    active = build(ActiveSetFederatedDistillation)
    assert len(active[1].cohort_client_acc[0]) == 2
    assert_parity(*active, *scan, ledger="exact")


def test_active_engine_heterogeneous_schedules():
    """Per-client lr/step schedules are gathered rows, not K-stacks:
    the scheduled cells must still agree byte-exactly on the ledger."""
    from repro.fl import Heterogeneity

    het = Heterogeneity(local_steps=(1, 2, 3, 2),
                        lr_scale=(1.0, 0.5, 2.0, 1.0), lr_decay=0.9)
    sc = Scenario(participation=bernoulli_participation(0.7),
                  heterogeneity=het)
    scan = ScannedFederatedDistillation(
        CFG, STRATEGIES["scarlet"](beta=1.5), cache_duration=3, scenario=sc)
    active = ActiveSetFederatedDistillation(
        CFG, STRATEGIES["scarlet"](beta=1.5), cache_duration=3, scenario=sc)
    assert_parity(scan, scan.run(), active, active.run(), ledger="exact")


def test_active_engine_memmap_backing(tmp_path):
    """The memory-mapped store is an I/O detail: a memmap-backed run is
    byte-identical to the default RAM-backed run."""
    def build(**kw):
        eng = ActiveSetFederatedDistillation(
            CFG, STRATEGIES["scarlet"](beta=1.5), cache_duration=3,
            scenario=PARTICIPATIONS["bernoulli"], **kw)
        return eng, eng.run()

    ram = build()
    mm = build(store_backing="memmap", store_dir=str(tmp_path))
    assert_parity(*ram, *mm, ledger="exact")


def test_active_engine_telemetry_matches_scan():
    """Telemetry rows: exact counters byte-equal, gauges allclose."""
    from repro.obs.device import EXACT_FIELDS, GAUGE_FIELDS

    cfg = dataclasses.replace(CFG, telemetry=True)

    def build(engine_cls):
        eng = engine_cls(cfg, STRATEGIES["scarlet"](beta=1.5),
                         cache_duration=3,
                         scenario=PARTICIPATIONS["outage"])
        return eng.run()

    ts = build(ScannedFederatedDistillation).telemetry.stacks()
    ta = build(ActiveSetFederatedDistillation).telemetry.stacks()
    for f in EXACT_FIELDS:
        np.testing.assert_array_equal(ta[f], ts[f], err_msg=f)
    for f in GAUGE_FIELDS:
        np.testing.assert_allclose(ta[f], ts[f], atol=1e-5, err_msg=f)


def test_active_engine_rejects_bad_store_config():
    strat = STRATEGIES["scarlet"](beta=1.5)
    with pytest.raises(ValueError, match="directory"):
        ActiveSetFederatedDistillation(CFG, strat, cache_duration=3,
                                       store_backing="memmap")
    with pytest.raises(ValueError, match="backing"):
        ActiveSetFederatedDistillation(CFG, strat, cache_duration=3,
                                       store_backing="tape")


# ---------------------------------------------------------------------------
# Async engine (repro.fl.async_engine): buffered aggregation under a
# traffic model.  Conformance anchor: under the DEFAULT traffic model
# (always-on arrivals, zero latency, full windows, unit staleness) the
# async engine must be **byte-identical** to the scan engine on the
# ledger — dispatch and arrival coincide every round, so the split
# catch-up charge collapses to scan's single dispatch-time charge and
# the staleness hook is statically skipped — and allclose on metrics.
# {scarlet, dsfl, mean} x {full, bernoulli, outage} x {identity,
# quant8, cache_delta+quant8}, same cells as the host/scan/shard
# matrix.
# ---------------------------------------------------------------------------

ASYNC_MATRIX = [(s, p, c) for s in sorted(STRATEGY_KW)
                for p in sorted(PARTICIPATIONS)
                for c in ("identity", "quant8", "cache_delta+quant8")]


@pytest.mark.parametrize("name,participation,codec", ASYNC_MATRIX,
                         ids=["-".join(p) for p in ASYNC_MATRIX])
def test_async_engine_zero_delay_conformance_cell(name, participation, codec):
    scan = _build(ScannedFederatedDistillation, name, participation, codec)
    asyn = _build(AsyncFederatedDistillation, name, participation, codec)
    assert_parity(*asyn, *scan, ledger="exact")


def test_async_engine_telemetry_matches_scan():
    """Zero-delay async telemetry rows: exact counters byte-equal to
    scan (including the staleness histogram — arrive == participate),
    gauges allclose."""
    from repro.obs.device import EXACT_FIELDS, GAUGE_FIELDS

    cfg = dataclasses.replace(CFG, telemetry=True)

    def build(engine_cls):
        eng = engine_cls(cfg, STRATEGIES["scarlet"](beta=1.5),
                         cache_duration=3,
                         scenario=PARTICIPATIONS["outage"])
        return eng.run()

    ts = build(ScannedFederatedDistillation).telemetry.stacks()
    ta = build(AsyncFederatedDistillation).telemetry.stacks()
    for f in EXACT_FIELDS:
        np.testing.assert_array_equal(ta[f], ts[f], err_msg=f)
    for f in GAUGE_FIELDS:
        np.testing.assert_allclose(ta[f], ts[f], atol=1e-5, err_msg=f)


# ---------------------------------------------------------------------------
# Shard-engine specifics not covered by the matrix
# ---------------------------------------------------------------------------

def test_shard_engine_data_only_mesh():
    """A 4x1 mesh (one client per shard, no model axis) must agree with
    the 2x4 matrix mesh — the shard count is an implementation detail."""
    a, ha = _build(ShardedFederatedDistillation, "scarlet", "bernoulli",
                   "identity")
    cfg = dataclasses.replace(CFG, uplink_codec="identity", mesh_spec="4")
    b = ShardedFederatedDistillation(
        cfg, STRATEGIES["scarlet"](**STRATEGY_KW["scarlet"]),
        cache_duration=CACHE_D["scarlet"],
        scenario=PARTICIPATIONS["bernoulli"])
    hb = b.run()
    assert_parity(a, ha, b, hb, ledger="exact")


def test_shard_engine_heterogeneous_schedules():
    """Per-client local-step counts / lr scales ride the client shard
    (``lr_k``/``steps_k`` consts are partitioned): sharded and scanned
    runs must still agree byte-exactly on the ledger."""
    from repro.fl import Heterogeneity

    het = Heterogeneity(local_steps=(1, 2, 3, 2), lr_scale=(1.0, 0.5, 2.0, 1.0),
                        lr_decay=0.9)
    sc = Scenario(participation=bernoulli_participation(0.7),
                  heterogeneity=het)
    scan = ScannedFederatedDistillation(
        CFG, STRATEGIES["scarlet"](beta=1.5), cache_duration=3, scenario=sc)
    shard = ShardedFederatedDistillation(
        CFG, STRATEGIES["scarlet"](beta=1.5), cache_duration=3, scenario=sc)
    assert_parity(scan, scan.run(), shard, shard.run(), ledger="exact")


def test_shard_engine_rejects_bad_meshes():
    strat = STRATEGIES["scarlet"](beta=1.5)
    with pytest.raises(ValueError, match="divide evenly"):
        ShardedFederatedDistillation(
            dataclasses.replace(CFG, n_clients=6), strat, cache_duration=3,
            mesh="4x2")
    with pytest.raises(ValueError, match="unknown mesh_spec"):
        ShardedFederatedDistillation(CFG, strat, cache_duration=3,
                                     mesh="not-a-mesh")
    with pytest.raises(ValueError):  # scan-engine mode checks inherited
        ShardedFederatedDistillation(CFG, STRATEGIES["comet"](), mesh="2x4")


def test_run_method_shard_engine():
    from repro.fl import run_method

    cfg = dataclasses.replace(CFG, mesh_spec="4x2")
    h_scan = run_method("scarlet", cfg, cache_duration=3, beta=1.5,
                        engine="scan", rounds=2)
    h_shard = run_method("scarlet", cfg, cache_duration=3, beta=1.5,
                        engine="shard", rounds=2)
    np.testing.assert_array_equal(
        [r.uplink for r in h_scan.ledger.rounds],
        [r.uplink for r in h_shard.ledger.rounds])
    np.testing.assert_allclose(h_scan.server_acc, h_shard.server_acc,
                               atol=1e-4)
