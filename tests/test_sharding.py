"""Sharding-rule unit tests + a small-mesh dry-run smoke via subprocess
(needs its own process: the device count is locked at first jax init)."""
import json
import os
import subprocess
import sys

import pytest
from jax.sharding import PartitionSpec as P

from repro.launch import sharding as sh


class _FakeMesh:
    """Duck-typed mesh: axis_names + devices.shape (no jax device init)."""

    def __init__(self, sizes):
        self.axis_names = tuple(sizes)
        self.devices = type("A", (), {"shape": tuple(sizes.values())})()


MESH = _FakeMesh({"data": 16, "model": 16})
POD = _FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_tp_rules():
    # embedding (V, D): vocab -> model
    assert sh.spec_for_param(("vocab", "embed"), (163840, 7168), MESH, "tp") == P("model", None)
    # ffn (D, F): ffn -> model
    assert sh.spec_for_param(("embed", "ffn"), (4096, 14336), MESH, "tp") == P(None, "model")
    # heads divisible -> model
    assert sh.spec_for_param(("layers", "embed", "heads", None),
                             (61, 7168, 64, 112), MESH, "tp") == P(None, None, "model", None)
    # kv=8 NOT divisible by 16 -> replicated (GQA fallback)
    assert sh.spec_for_param(("layers", "embed", "kv", None),
                             (61, 7168, 8, 112), MESH, "tp") == P(None, None, None, None)
    # whisper heads=20 -> replicated
    assert sh.spec_for_param(("layers", "embed", "heads", None),
                             (32, 1280, 20, 64), MESH, "tp") == P(None, None, None, None)


def test_fsdp_adds_data_axis():
    # kimi experts (L, E, D, F): ffn->model, experts->data
    spec = sh.spec_for_param(("layers", "experts", "embed", "ffn"),
                             (61, 384, 7168, 2048), MESH, "fsdp")
    assert spec == P(None, "data", None, "model")
    # grok experts=8 not divisible -> embed gets data
    spec = sh.spec_for_param(("layers", "experts", "embed", "ffn"),
                             (64, 8, 6144, 32768), MESH, "fsdp")
    assert spec == P(None, None, "data", "model")
    # embedding: vocab->model, embed->data
    spec = sh.spec_for_param(("vocab", "embed"), (163840, 7168), MESH, "fsdp")
    assert spec == P("model", "data")


def test_no_axis_reuse():
    """One mesh axis must never shard two dims of the same param."""
    for axes, shape in [
        (("vocab", "ffn"), (4096, 4096)),
        (("experts", "vocab", "ffn"), (16, 256, 512)),
    ]:
        spec = sh.spec_for_param(axes, shape, MESH, "fsdp")
        used = [s for s in spec if s is not None]
        assert len(used) == len(set(used))


def test_batch_spec():
    assert sh.batch_spec(MESH) == P(("data",))
    assert sh.batch_spec(POD) == P(("pod", "data"))


def test_activation_specs():
    # KV cache: batch over (data), kv heads over model when divisible
    spec = sh.spec_for_activation(("layers", "batch", None, "kv", None),
                                  (46, 128, 32768, 16, 128), MESH)
    assert spec == P(None, ("data",), None, "model", None)
    # long-context: ctx over data
    spec = sh.spec_for_activation(("layers", None, "ctx", "kv", None),
                                  (46, 1, 524288, 16, 128), MESH)
    assert spec == P(None, None, "data", "model", None)
    # batch=1 cannot shard
    spec = sh.spec_for_activation(("batch", None), (1, 10), MESH)
    assert spec == P(None, None)


@pytest.mark.slow
def test_dryrun_smoke_subprocess(tmp_path):
    """End-to-end dry-run on a tiny arch/mesh in a fresh process."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses, json, sys
import jax
from repro.models import common as cm
from repro.configs.registry import ARCHS
from repro.configs.base import InputShape
from repro.launch.dryrun import lower_one
from repro.launch.mesh import make_test_mesh

mesh = make_test_mesh(2, 4)
cfg = dataclasses.replace(ARCHS["granite-3-2b"].reduced(), vocab_size=1024)
for shape in (InputShape("t", 64, 8, "train"), InputShape("d", 256, 8, "decode")):
    _, comp = lower_one(cfg, shape, mesh, "fsdp")
    mem = comp.memory_analysis()
    assert comp.cost_analysis() is not None
print("DRYRUN_SMOKE_OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath("src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=300, env=env)
    assert "DRYRUN_SMOKE_OK" in out.stdout, out.stderr[-2000:]


@pytest.mark.slow
def test_moe_a2a_dispatch_subprocess(tmp_path):
    """shard_map all-to-all MoE dispatch matches the reference capacity
    dispatch under 4-way expert parallelism, and its HLO contains
    all-to-all (not all-gather) collectives."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np, re
from repro.models import common as cm
from repro.models.moe_a2a import moe_ffn_a2a
from repro.launch.mesh import make_test_mesh

mesh = make_test_mesh(4, 2)
D, F, E, topk = 32, 64, 8, 2
k = jax.random.PRNGKey(0)
x = jax.random.normal(k, (8, 16, D))
router = jax.random.normal(jax.random.fold_in(k,1), (D, E)) * 0.3
w1 = jax.random.normal(jax.random.fold_in(k,2), (E, D, F)) * 0.1
w3 = jax.random.normal(jax.random.fold_in(k,3), (E, D, F)) * 0.1
w2 = jax.random.normal(jax.random.fold_in(k,4), (E, F, D)) * 0.1
ref, _ = cm.moe_ffn(x, router, w1, w3, w2, top_k=topk, capacity_factor=8.0)
with mesh:
    f = jax.jit(lambda *a: moe_ffn_a2a(*a, top_k=topk, mesh=mesh, capacity_factor=8.0))
    out, _ = f(x, router, w1, w3, w2)
    hlo = f.lower(x, router, w1, w3, w2).compile().as_text()
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-3, atol=2e-3)
assert len(re.findall(r"\ball-to-all(-start)?\(", hlo)) >= 2
assert len(re.findall(r"\ball-gather(-start)?\(", hlo)) == 0
print("MOE_A2A_OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath("src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=300, env=env)
    assert "MOE_A2A_OK" in out.stdout, out.stderr[-2000:]
