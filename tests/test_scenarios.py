"""Scenario subsystem: participation/outage composition, heterogeneous
schedules, the partial-uplink invariant, and cache catch-up identity
(paper §III-D) through the full engine."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.fl import (
    FederatedDistillation,
    FLConfig,
    Heterogeneity,
    Outage,
    Participation,
    Scenario,
    bernoulli_participation,
    fixed_fraction,
    run_method,
)
from repro.fl.strategies import STRATEGIES

CFG = FLConfig(
    n_clients=4, n_classes=4, dim=8, rounds=6, local_steps=2,
    distill_steps=2, public_size=60, public_per_round=12,
    private_size=80, alpha=0.5, eval_every=3, seed=0, hidden=16,
)
ROUNDS = CFG.rounds
D = 5


def _run(scenario=None, track=False):
    fd = FederatedDistillation(
        CFG, STRATEGIES["scarlet"](beta=1.5), cache_duration=D,
        scenario=scenario, track_local_caches=track)
    hist = fd.run()
    return fd, hist


_FULL_UPLINK = None


def _full_uplink():
    """Full-participation baseline ledger (computed once per session)."""
    global _FULL_UPLINK
    if _FULL_UPLINK is None:
        _, hist = _run()
        _FULL_UPLINK = hist.ledger.cumulative_uplink
    return _FULL_UPLINK


# --- mask semantics ---------------------------------------------------------

def test_fixed_fraction_mask_exact_count():
    rng = np.random.default_rng(0)
    for rate, expect in ((0.5, 2), (0.25, 1), (1.0, 4), (0.01, 1)):
        m = Scenario(participation=fixed_fraction(rate)).participation_mask(1, 4, rng)
        assert m.sum() == expect, rate


def test_outage_overrides_participation():
    sc = Scenario(outages=(Outage(0, 2, 4),))
    rng = np.random.default_rng(0)
    assert sc.participation_mask(1, 3, rng)[0]
    for t in (2, 3, 4):
        assert not sc.participation_mask(t, 3, rng)[0]
    assert sc.participation_mask(5, 3, rng)[0]


def test_empty_bernoulli_draw_conscripts_available_client():
    sc = Scenario(participation=bernoulli_participation(0.0))
    m = sc.participation_mask(1, 4, np.random.default_rng(0))
    assert m.sum() == 1
    # ...unless everyone is offline: then the round is truly empty
    sc = Scenario(participation=bernoulli_participation(0.0),
                  outages=tuple(Outage(k, 1, 1) for k in range(4)))
    m = sc.participation_mask(1, 4, np.random.default_rng(0))
    assert m.sum() == 0


def test_total_outage_round_costs_nothing_and_run_survives():
    sc = Scenario(outages=tuple(Outage(k, 3, 3) for k in range(CFG.n_clients)))
    _, hist = _run(sc)
    assert hist.ledger.rounds[2].uplink == 0.0
    assert hist.ledger.rounds[2].downlink == 0.0
    assert np.isfinite(hist.final_server_acc)


# --- heterogeneous schedules -----------------------------------------------

def test_heterogeneous_schedules_run_and_zero_steps_freeze_client():
    het = Heterogeneity(local_steps=(0, 1, 2, 4), lr_scale=(1.0, 0.5, 1.0, 2.0),
                        lr_decay=0.9)
    fd, hist = _run(Scenario(heterogeneity=het))
    assert np.isfinite(hist.final_server_acc)
    assert np.isfinite(hist.client_val_loss).all()


def test_heterogeneity_rejects_wrong_length():
    with pytest.raises(ValueError):
        Heterogeneity(local_steps=(1, 2)).resolve(4, 0.1, 5)


# --- strategy x scenario orthogonality --------------------------------------

@pytest.mark.parametrize("name", sorted(STRATEGIES))
def test_any_strategy_accepts_any_scenario(name):
    sc = Scenario(participation=fixed_fraction(0.5), outages=(Outage(0, 2, 3),))
    h = run_method(name, CFG, rounds=4, cache_duration=D, scenario=sc)
    assert np.isfinite(h.final_server_acc)


# --- property: partial uplink never exceeds full participation --------------

@settings(max_examples=10, deadline=None)
@given(
    kind=st.sampled_from(["fraction", "bernoulli"]),
    rate=st.floats(0.1, 1.0),
    part_seed=st.integers(0, 2**31 - 1),
    outage=st.tuples(st.integers(0, 3), st.integers(1, ROUNDS),
                     st.integers(0, ROUNDS)),
)
def test_partial_uplink_never_exceeds_full(kind, rate, part_seed, outage):
    """Any dropout/participation mask yields a ledger whose cumulative
    uplink bytes never exceed the full-participation ledger's: the
    public-subset stream is participation-independent, so each refresh
    is paid by at most as many (and never earlier) clients."""
    client, start, dur = outage
    sc = Scenario(participation=Participation(kind, rate),
                  outages=(Outage(client, start, start + dur),))
    cfg = FLConfig(**{**CFG.__dict__, "seed": CFG.seed})
    fd = FederatedDistillation(
        cfg, STRATEGIES["scarlet"](beta=1.5), cache_duration=D, scenario=sc)
    # vary participation draws without touching the P^t stream
    fd.rng_part = np.random.default_rng(part_seed)
    hist = fd.run()
    assert hist.ledger.cumulative_uplink <= _full_uplink() + 1e-9


# --- property: catch-up restores byte-identical caches ----------------------

@settings(max_examples=10, deadline=None)
@given(
    client=st.integers(0, 3),
    start=st.integers(2, ROUNDS - 1),
    dur=st.integers(0, 3),
)
def test_catch_up_cache_byte_identity(client, start, dur):
    """A dropped-then-returning client's mirrored cache is byte-identical
    to the server's global cache after the catch-up package (Alg. 2/3
    invariant: global cache state fully determines local caches)."""
    end = min(start + dur, ROUNDS - 1)  # client returns before the run ends
    sc = Scenario(outages=(Outage(client, start, end),))
    fd, _ = _run(sc, track=True)
    assert fd.last_sync[client] == ROUNDS
    for k in range(CFG.n_clients):
        ck, cg = fd.local_caches[k], fd.cache_g
        np.testing.assert_array_equal(np.asarray(ck.values), np.asarray(cg.values))
        np.testing.assert_array_equal(np.asarray(ck.ts), np.asarray(cg.ts))
        np.testing.assert_array_equal(np.asarray(ck.present), np.asarray(cg.present))


def test_catch_up_accounted_in_downlink():
    """Returning stragglers cost catch-up downlink bytes."""
    sc = Scenario(outages=(Outage(0, 2, 4),))
    _, h_out = _run(sc)
    _, h_full = _run()
    # round 5 (index 4) is when client 0 returns and gets the package
    assert h_out.ledger.rounds[4].downlink > h_full.ledger.rounds[4].downlink
