"""Scenario subsystem: participation/outage composition, heterogeneous
schedules, the partial-uplink invariant, and cache catch-up identity
(paper §III-D) through the full engine."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.fl import (
    FederatedDistillation,
    FLConfig,
    Heterogeneity,
    Outage,
    Participation,
    Scenario,
    bernoulli_participation,
    fixed_fraction,
    run_method,
)
from repro.fl.strategies import STRATEGIES

CFG = FLConfig(
    n_clients=4, n_classes=4, dim=8, rounds=6, local_steps=2,
    distill_steps=2, public_size=60, public_per_round=12,
    private_size=80, alpha=0.5, eval_every=3, seed=0, hidden=16,
)
ROUNDS = CFG.rounds
D = 5


def _run(scenario=None, track=False):
    fd = FederatedDistillation(
        CFG, STRATEGIES["scarlet"](beta=1.5), cache_duration=D,
        scenario=scenario, track_local_caches=track)
    hist = fd.run()
    return fd, hist


_FULL_UPLINK = None


def _full_uplink():
    """Full-participation baseline ledger (computed once per session)."""
    global _FULL_UPLINK
    if _FULL_UPLINK is None:
        _, hist = _run()
        _FULL_UPLINK = hist.ledger.cumulative_uplink
    return _FULL_UPLINK


# --- mask semantics ---------------------------------------------------------

def test_fixed_fraction_mask_exact_count():
    rng = np.random.default_rng(0)
    for rate, expect in ((0.5, 2), (0.25, 1), (1.0, 4), (0.01, 1)):
        m = Scenario(participation=fixed_fraction(rate)).participation_mask(1, 4, rng)
        assert m.sum() == expect, rate


def test_outage_overrides_participation():
    sc = Scenario(outages=(Outage(0, 2, 4),))
    rng = np.random.default_rng(0)
    assert sc.participation_mask(1, 3, rng)[0]
    for t in (2, 3, 4):
        assert not sc.participation_mask(t, 3, rng)[0]
    assert sc.participation_mask(5, 3, rng)[0]


def test_empty_bernoulli_draw_conscripts_available_client():
    sc = Scenario(participation=bernoulli_participation(0.0))
    m = sc.participation_mask(1, 4, np.random.default_rng(0))
    assert m.sum() == 1
    # ...unless everyone is offline: then the round is truly empty
    sc = Scenario(participation=bernoulli_participation(0.0),
                  outages=tuple(Outage(k, 1, 1) for k in range(4)))
    m = sc.participation_mask(1, 4, np.random.default_rng(0))
    assert m.sum() == 0


def test_total_outage_round_costs_nothing_and_run_survives():
    sc = Scenario(outages=tuple(Outage(k, 3, 3) for k in range(CFG.n_clients)))
    _, hist = _run(sc)
    assert hist.ledger.rounds[2].uplink == 0.0
    assert hist.ledger.rounds[2].downlink == 0.0
    assert np.isfinite(hist.final_server_acc)


# --- heterogeneous schedules -----------------------------------------------

def test_heterogeneous_schedules_run_and_zero_steps_freeze_client():
    het = Heterogeneity(local_steps=(0, 1, 2, 4), lr_scale=(1.0, 0.5, 1.0, 2.0),
                        lr_decay=0.9)
    fd, hist = _run(Scenario(heterogeneity=het))
    assert np.isfinite(hist.final_server_acc)
    assert np.isfinite(hist.client_val_loss).all()


def test_heterogeneity_rejects_wrong_length():
    with pytest.raises(ValueError):
        Heterogeneity(local_steps=(1, 2)).resolve(4, 0.1, 5)


# --- strategy x scenario orthogonality --------------------------------------

@pytest.mark.parametrize("name", sorted(STRATEGIES))
def test_any_strategy_accepts_any_scenario(name):
    sc = Scenario(participation=fixed_fraction(0.5), outages=(Outage(0, 2, 3),))
    h = run_method(name, CFG, rounds=4, cache_duration=D, scenario=sc)
    assert np.isfinite(h.final_server_acc)


# --- property: partial uplink never exceeds full participation --------------

@settings(max_examples=10, deadline=None)
@given(
    kind=st.sampled_from(["fraction", "bernoulli"]),
    rate=st.floats(0.1, 1.0),
    part_seed=st.integers(0, 2**31 - 1),
    outage=st.tuples(st.integers(0, 3), st.integers(1, ROUNDS),
                     st.integers(0, ROUNDS)),
)
def test_partial_uplink_never_exceeds_full(kind, rate, part_seed, outage):
    """Any dropout/participation mask yields a ledger whose cumulative
    uplink bytes never exceed the full-participation ledger's: the
    public-subset stream is participation-independent, so each refresh
    is paid by at most as many (and never earlier) clients."""
    client, start, dur = outage
    sc = Scenario(participation=Participation(kind, rate),
                  outages=(Outage(client, start, start + dur),))
    cfg = FLConfig(**{**CFG.__dict__, "seed": CFG.seed})
    fd = FederatedDistillation(
        cfg, STRATEGIES["scarlet"](beta=1.5), cache_duration=D, scenario=sc)
    # vary participation draws without touching the P^t stream
    fd.rng_part = np.random.default_rng(part_seed)
    hist = fd.run()
    assert hist.ledger.cumulative_uplink <= _full_uplink() + 1e-9


# --- property: catch-up restores byte-identical caches ----------------------

@settings(max_examples=10, deadline=None)
@given(
    client=st.integers(0, 3),
    start=st.integers(2, ROUNDS - 1),
    dur=st.integers(0, 3),
)
def test_catch_up_cache_byte_identity(client, start, dur):
    """A dropped-then-returning client's mirrored cache is byte-identical
    to the server's global cache after the catch-up package (Alg. 2/3
    invariant: global cache state fully determines local caches)."""
    end = min(start + dur, ROUNDS - 1)  # client returns before the run ends
    sc = Scenario(outages=(Outage(client, start, end),))
    fd, _ = _run(sc, track=True)
    assert fd.last_sync[client] == ROUNDS
    for k in range(CFG.n_clients):
        ck, cg = fd.local_caches[k], fd.cache_g
        np.testing.assert_array_equal(np.asarray(ck.values), np.asarray(cg.values))
        np.testing.assert_array_equal(np.asarray(ck.ts), np.asarray(cg.ts))
        np.testing.assert_array_equal(np.asarray(ck.present), np.asarray(cg.present))


def test_catch_up_accounted_in_downlink():
    """Returning stragglers cost catch-up downlink bytes."""
    sc = Scenario(outages=(Outage(0, 2, 4),))
    _, h_out = _run(sc)
    _, h_full = _run()
    # round 5 (index 4) is when client 0 returns and gets the package
    assert h_out.ledger.rounds[4].downlink > h_full.ledger.rounds[4].downlink


# ---------------------------------------------------------------------------
# Conscription agreement: host vs device participation masks
# ---------------------------------------------------------------------------
# min_participants conscription runs twice — an imperative host loop and
# a branch-free cumsum ranking inside the compiled engines.  They must
# pick the IDENTICAL clients in every corner: deficit larger than the
# available pool, everyone offline, and negative deficit (draw already
# exceeds the floor).  The two samplers draw from different RNGs, so
# the property pins the *policy* by injecting the same base draw into
# both paths.

from dataclasses import dataclass as _dataclass


@_dataclass(frozen=True)
class _FixedDraw(Participation):
    """Participation whose draw is a fixed boolean vector — identical on
    the host and device paths, isolating the conscription logic."""

    draw: tuple = ()

    def sample(self, n_clients, rng):
        return np.asarray(self.draw, bool).copy()

    def sample_device(self, key, n_clients):
        import jax.numpy as jnp

        return jnp.asarray(np.asarray(self.draw, bool))


def _assert_conscription_agrees(draw, offline, min_participants):
    import jax
    import jax.numpy as jnp

    K = len(draw)
    outages = tuple(Outage(i, 1, 1) for i, off in enumerate(offline) if off)
    sc = Scenario(participation=_FixedDraw(draw=tuple(draw)),
                  outages=outages, min_participants=min_participants)
    host = sc.participation_mask(1, K, np.random.default_rng(0))
    dev = np.asarray(sc.participation_mask_device(
        jax.random.PRNGKey(0), jnp.asarray(list(offline), dtype=bool)))
    np.testing.assert_array_equal(
        host, dev,
        err_msg=f"draw={draw} offline={offline} min={min_participants}")
    # both must also respect the invariants themselves
    assert not (dev & np.asarray(offline)).any()
    avail = (~np.asarray(offline)).sum()
    assert dev.sum() >= min(min_participants, avail)


@settings(max_examples=80, deadline=None)
@given(st.data())
def test_conscription_host_device_agree(data):
    K = data.draw(st.integers(1, 10), label="K")
    draw = data.draw(st.lists(st.booleans(), min_size=K, max_size=K),
                     label="draw")
    offline = data.draw(st.lists(st.booleans(), min_size=K, max_size=K),
                        label="offline")
    min_p = data.draw(st.integers(0, K + 3), label="min_participants")
    _assert_conscription_agrees(draw, offline, min_p)


def test_conscription_agreement_corner_sweep():
    """Deterministic twin of the property above (runs even where
    hypothesis is unavailable): the named corners plus a seeded fuzz
    sweep."""
    # deficit exceeds the available pool
    _assert_conscription_agrees([False] * 5, [False, True, True, True, True], 4)
    # everyone offline: zero participants, no conscription possible
    _assert_conscription_agrees([False] * 4, [True] * 4, 2)
    # negative deficit: draw already above the floor, nobody added
    _assert_conscription_agrees([True, True, True, False], [False] * 4, 1)
    # min_participants = 0 never conscripts
    _assert_conscription_agrees([False] * 3, [False] * 3, 0)
    rng = np.random.default_rng(1234)
    for _ in range(200):
        K = int(rng.integers(1, 11))
        draw = (rng.random(K) < 0.4).tolist()
        offline = (rng.random(K) < 0.4).tolist()
        min_p = int(rng.integers(0, K + 4))
        _assert_conscription_agrees(draw, offline, min_p)
